"""Remove stale records for given cells from dryrun.jsonl (so the driver
re-runs them with current code)."""
import json, sys

path = "results/dryrun.jsonl"
drop = set()
for spec in sys.argv[1:]:
    kind, arch, shape, mesh = spec.split("/")
    drop.add((kind, arch, shape, mesh))
rows = [json.loads(l) for l in open(path)]
kept = [r for r in rows
        if (r.get("kind"), r["arch"], r["shape"], r["mesh"]) not in drop]
with open(path, "w") as f:
    for r in kept:
        f.write(json.dumps(r) + "\n")
print(f"dropped {len(rows)-len(kept)} records, kept {len(kept)}")
