#!/usr/bin/env bash
# Fast CI gate: byte-compile every tree we ship, run the fast test tier
# (pytest.ini defaults to -m "not slow"), then run three examples
# end-to-end: quickstart at PIR_SMOKE scale (the public serving facade —
# TwoServerPIR over the protocol registry), db_updates at PIR_SMOKE_UPD
# scale (the database plane's stage/publish path on the 3-server
# protocol), and single_server at PIR_SMOKE_LWE scale (the hint
# lifecycle on the 1-server LWE protocol), so API breakage in any plane
# is caught here instead of by users. The k-server facade demo
# (examples/multi_server.py) and the slow tier (system / sharding /
# compile-heavy) run out-of-band:  pytest -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m compileall -q src benchmarks examples scripts tests
python -m pytest -q
# smoke gate: one compiled serve step per party (~1 min each on the dev
# container), full client -> two servers -> reconstruct round trip
python examples/quickstart.py
# db-plane smoke: preload -> query -> stage+publish -> re-query on the
# 3-server protocol (tiny shape, one bucket: 3 serve compiles total)
python examples/db_updates.py
# single-server smoke: the LWE hint lifecycle end-to-end — query with
# hint reuse, publish -> hint delta + client cache refresh (cheap: the
# LWE GEMM has no GGM chains, its serve step compiles in ~1 s)
python examples/single_server.py
# replica-plane smoke: 2-replica fleet behind the router — publish
# fan-out converges epochs, a mid-load kill fails over with zero lost
# answers, and a warm rejoin serves its first query without re-tuning
# (PIR_SMOKE_REPL scale: 3 cheap LWE compiles total)
python examples/replicas.py
# batch-plane smoke: cuckoo-bucketed m=4 retrieval at PIR_SMOKE_BATCH
# scale — uniform B-wide rounds, a mid-session stage+publish landing in
# every candidate bucket, checksummed reconstruction, and the one-compile-
# per-party invariant (B buckets share one serve step: 2 compiles total)
python examples/batch_query.py
# engine-plane smoke: tiny-budget autotune (interpret mode, <=2 candidates
# per kernel, nothing persisted) + the heuristic-fallback gate — asserts
# an empty plan cache resolves to exactly the pre-engine plan_for choices
python -m repro.engine --smoke
# chaos-plane smoke: seeded kill + share-corruption scenarios on the
# 2-replica LWE fleet — asserts detection (InjectedFault / IntegrityError,
# never a silently wrong record) AND recovery (every answer byte-correct
# on the survivor after failover; 4 cheap LWE compiles total)
python -m repro.chaos --smoke
