#!/usr/bin/env bash
# Fast CI gate: byte-compile every tree we ship, run the fast test tier
# (pytest.ini defaults to -m "not slow"), then run the quickstart example
# end-to-end at PIR_SMOKE scale — it exercises the public serving facade
# (TwoServerPIR over the protocol registry), so API breakage there is
# caught here instead of by users. The k-server facade demo
# (examples/multi_server.py) and the slow tier (system / sharding /
# compile-heavy) run out-of-band:  pytest -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m compileall -q src benchmarks examples scripts tests
python -m pytest -q
# smoke gate: one compiled serve step per party (~1 min each on the dev
# container), full client -> two servers -> reconstruct round trip
python examples/quickstart.py
