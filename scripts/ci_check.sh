#!/usr/bin/env bash
# Fast CI gate: byte-compile every tree we ship, then run the fast test
# tier (pytest.ini defaults to -m "not slow"). The slow tier (system /
# sharding / compile-heavy) runs out-of-band:  pytest -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m compileall -q src benchmarks examples scripts tests
python -m pytest -q
