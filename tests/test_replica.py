"""Replica plane: router balancing, failover, epochs, registry, metrics.

Fast tier: the router's control plane driven by fake replicas honoring
the ``ServeReplica`` surface (deterministic, no XLA compiles) — P2C
balancing, session affinity, zero-lost failover, graceful handoff,
bounded-staleness eligibility, delta-log catch-up, registry health. Slow
tier: two REAL ``ServeReplica`` deployments over the single-server LWE
protocol (cheap compiles — no GGM expansion) — kill one mid-load and
assert every future resolves byte-correct with a valid epoch tag, then
rejoin it warm and assert the plan cache hit via provenance.
"""
import threading
import time

import numpy as np
import pytest

from repro.replica import (ReplicaLost, ReplicaRegistry, Router,
                           ServeReplica, metrics)
from repro.runtime.serve_loop import AnswerFuture, ServeStats


# ---------------------------------------------------------------------------
# fake replicas: the ServeReplica surface, no data plane
# ---------------------------------------------------------------------------

class FakeDelta:
    def __init__(self, epoch, rows, vals):
        self.epoch, self.rows, self.vals = epoch, rows, vals


class FakeDB:
    """Epoch counter + delta recorder with the subscribe/stage/publish
    surface the router's propagation path uses."""

    def __init__(self):
        self.epoch = 0
        self.applied = []            # [(rows, vals), ...] across publishes
        self._staged = []
        self._subs = []

    def subscribe(self, fn):
        self._subs.append(fn)
        return lambda: self._subs.remove(fn)

    def stage(self, rows, vals):
        self._staged.append((np.asarray(rows), np.asarray(vals)))
        return len(self._staged)

    def publish(self):
        if not self._staged:
            return self.epoch
        self.epoch += 1
        batch, self._staged = self._staged, []
        self.applied.extend(batch)
        for fn in list(self._subs):
            fn(FakeDelta(self.epoch, batch[0][0], batch[0][1]))
        return self.epoch


class FakeReplica:
    """Manually-pumped replica: queries queue until ``pump()`` resolves
    them to ``("ans", item, replica_id)`` tagged with the DB epoch."""

    def __init__(self, rid):
        self.id = rid
        self.db = FakeDB()
        self.stats = ServeStats()
        self._q = []                 # (item, future)
        self._closed = False
        self.running = False
        self.lost = False
        self.started = 0
        self.warmed = None

    @property
    def epoch(self):
        return self.db.epoch

    @property
    def queue_depth(self):
        return len(self._q)

    def submit(self, index):
        fut = AnswerFuture()
        self.resubmit(index, fut)
        return fut

    def resubmit(self, item, future):
        if self._closed:
            raise RuntimeError("scheduler is stopped")
        self._q.append((item, future))
        return future

    def pump(self):
        q, self._q = self._q, []
        for item, fut in q:
            fut.epoch = self.db.epoch
            fut.set_result(("ans", item, self.id))
            self.stats.answered += 1
        return len(q)

    def start(self):
        self._closed = False
        self.lost = False
        self.running = True
        self.started += 1

    def close(self):
        self._closed = True
        self.running = False

    def drain_handoff(self):
        self._closed = True
        self.running = False
        q, self._q = self._q, []
        return q

    def kill(self, reason="injected fault"):
        exc = ReplicaLost(self.id, reason)
        self._closed = True
        self.running = False
        self.lost = True
        victims, self._q = self._q, []
        for _, fut in victims:
            fut.set_exception(exc)
        return exc

    def set_heartbeat(self, fn):
        self.heartbeat = fn

    def subscribe_epochs(self, fn):
        return self.db.subscribe(lambda d: fn(d.epoch))

    def export_plans(self):
        return {4: "fake-plan"}

    def warm_start(self, plans, persist=False):
        self.warmed = dict(plans)
        return len(plans)


def make_router(n=2, **kw):
    kw.setdefault("rng", np.random.default_rng(0))
    kw.setdefault("sleep", lambda s: None)
    router = Router(**kw)
    reps = [router.attach(FakeReplica(f"r{i}")) for i in range(n)]
    return router, reps


# ---------------------------------------------------------------------------
# routing: P2C + affinity
# ---------------------------------------------------------------------------

def test_round_trip_and_epoch_tag():
    router, (r0, r1) = make_router()
    futs = [router.submit(i) for i in range(8)]
    assert r0.queue_depth + r1.queue_depth == 8
    r0.pump(), r1.pump()
    for i, f in enumerate(futs):
        ans, item, rid = f.result(0)
        assert (ans, item) == ("ans", i) and rid in ("r0", "r1")
        assert f.epoch == 0                      # tagged, valid at epoch 0


def test_p2c_always_picks_the_shallower_of_two():
    """With exactly two eligible replicas P2C samples both — the pick is
    fully deterministic: the shallower queue, smallest id on ties. A
    single-entry head start on r0 pins the exact depth trajectory."""
    router, (r0, r1) = make_router()
    r0.resubmit("preload", AnswerFuture())       # depths (1, 0)
    futs = [router.submit(i) for i in range(6)]
    # gap -> r1 (1,1); tie -> r0 (2,1); gap -> r1 (2,2); tie -> r0 ...
    assert (r0.queue_depth, r1.queue_depth) == (4, 3)
    r0.pump(), r1.pump()
    assert all(f.done() for f in futs)


def test_p2c_tie_breaks_deterministically():
    """Equal depths: the tie goes to the lexically smallest id, for ANY
    router rng seed — routing decisions are replayable."""
    for seed in (0, 1, 12345):
        router, (r0, r1) = make_router(rng=np.random.default_rng(seed))
        assert r0.queue_depth == r1.queue_depth == 0
        router.submit(0)
        assert (r0.queue_depth, r1.queue_depth) == (1, 0)


def test_session_affinity_sticks_while_eligible():
    router, (r0, r1) = make_router()
    s = router.session("client-a")
    router.submit(0, session=s)
    first = s.replica
    assert first in ("r0", "r1")
    # deepen the pinned replica: affinity must still win over P2C
    pinned = router.replicas[first]
    for _ in range(5):
        pinned.resubmit("preload", AnswerFuture())
    router.submit(1, session=s)
    assert s.replica == first
    # pinned replica quarantined -> session re-pins transparently
    router.registry.report_failure(first)
    router.submit(2, session=s)
    other = ({"r0", "r1"} - {first}).pop()
    assert s.replica == other


# ---------------------------------------------------------------------------
# failover: zero lost queries
# ---------------------------------------------------------------------------

def test_kill_fails_over_every_queued_query():
    router, (r0, r1) = make_router()
    s = router.session("pinned")
    s.replica = "r0"                             # deterministic routing
    futs = [router.submit(i, session=s) for i in range(5)]
    assert r0.queue_depth == 5
    r0.kill()                # fails the inner futures -> router resubmits
    assert "r0" in router.registry.suspects()    # quarantined instantly
    assert r1.queue_depth == 5                   # re-keyed by index onto r1
    r1.pump()
    for i, f in enumerate(futs):
        assert f.result(0) == ("ans", i, "r1")   # zero lost, none dropped
    assert router.failovers == 5
    assert router.retry_stats.retried == 5


def test_failover_exhaustion_propagates_last_error():
    router, (r0,) = make_router(n=1, retries=2)
    fut = router.submit(7)
    r0.kill()
    # no healthy peer: retries burn out, the outer future resolves (not
    # hangs) with the failure
    assert fut.done()
    with pytest.raises(RuntimeError):
        fut.result(0)
    assert router.retry_stats.retried >= 1


def test_submit_with_no_replicas_resolves_with_error():
    router = Router(sleep=lambda s: None, retries=1)
    fut = router.submit(0)
    assert fut.done()
    with pytest.raises(RuntimeError, match="no eligible replica"):
        fut.result(0)


def test_backoff_is_capped():
    sleeps = []
    router, (r0,) = make_router(n=1, retries=6, base_delay=1.0,
                                max_delay=4.0, sleep=sleeps.append)
    r0.kill()
    router.submit(0)                             # routes to dead fleet
    assert sleeps == [1.0, 2.0, 4.0, 4.0, 4.0, 4.0]


def test_graceful_detach_hands_off_futures_unchanged():
    router, (r0, r1) = make_router()
    s = router.session("pinned")
    s.replica = "r0"
    futs = [router.submit(i, session=s) for i in range(4)]
    moved = router.detach("r0")
    assert moved == 4
    assert router.resubmitted == 4
    assert "r0" not in router.replicas
    assert "r0" not in router.registry.members()  # left, not suspect
    assert r1.queue_depth == 4                    # same futures moved over
    r1.pump()
    assert [f.result(0) for f in futs] == [("ans", i, "r1")
                                           for i in range(4)]
    assert router.failovers == 0                  # handoff, not failover


# ---------------------------------------------------------------------------
# epochs: fan-out, catch-up, bounded staleness, monotonic reads
# ---------------------------------------------------------------------------

def _delta(i):
    return [i], np.full((1, 8), i, np.uint32)


def test_publish_fans_out_and_tracks_epochs():
    router, (r0, r1) = make_router()
    router.update(*_delta(1))
    assert router.publish() == 1
    assert (r0.epoch, r1.epoch) == (1, 1)
    assert router.epochs == {"r0": 1, "r1": 1}
    assert router.publish() == 1                 # nothing staged: no churn
    assert router.epoch_lag("r0") == 0


def test_suspect_replica_skips_then_catches_up_in_order():
    router, (r0, r1) = make_router()
    router.update(*_delta(1))
    router.publish()
    router.registry.report_failure("r1")
    router.update(*_delta(2))
    router.update(*_delta(3))                    # two batches, one epoch
    assert router.publish() == 2
    assert (r0.epoch, r1.epoch) == (2, 1)        # r1 missed epoch 2
    assert router.epoch_lag("r1") == 1
    # recovery: next publish replays r1's missing suffix in order
    router.registry.join(r1)
    router.update(*_delta(4))
    assert router.publish() == 3
    assert (r0.epoch, r1.epoch) == (3, 3)
    assert [r for r, _ in r1.db.applied] == [[1], [2], [3], [4]]


def test_attach_replays_delta_log_for_late_joiner():
    router, (r0,) = make_router(n=1)
    for i in range(3):
        router.update(*_delta(i))
        router.publish()
    late = FakeReplica("late")
    router.attach(late)
    assert late.epoch == 3                       # converged before serving
    assert [r for r, _ in late.db.applied] == [[0], [1], [2]]
    assert late.running


def test_staleness_bound_excludes_laggards():
    router, (r0, r1) = make_router(staleness_bound=0)
    router.registry.report_failure("r1")
    router.update(*_delta(1))
    router.publish()
    router.registry.join(r1)                     # healthy again, but stale
    assert router._eligible(0) == ["r0"]         # lag 1 > bound 0
    fut = router.submit(5)
    assert r0.queue_depth == 1 and r1.queue_depth == 0
    r0.pump()
    assert fut.result(0)[2] == "r0"


def test_session_min_epoch_gives_monotonic_reads():
    router, (r0, r1) = make_router()
    router.registry.report_failure("r1")
    router.update(*_delta(1))
    router.publish()                             # r0 at 1, r1 at 0
    router.registry.join(r1)
    s = router.session("reader")
    fut = router.submit(3, session=s)
    assert s.replica == "r0"                     # only r0 is at epoch >= 0...
    r0.pump()
    assert fut.result(0)[2] == "r0" and fut.epoch == 1
    assert s.min_epoch == 1                      # floor ratcheted to the read
    # r1 (epoch 0) can never serve this session until it catches up
    for _ in range(8):
        router.submit(4, session=s)
    assert r1.queue_depth == 0
    router.update(*_delta(2))
    router.publish()                             # both converge to epoch 2
    s2 = router.session("reader", min_epoch=2)   # explicit pin, same object
    assert s2 is s and s.min_epoch == 2
    assert sorted(router._eligible(2)) == ["r0", "r1"]


def test_attach_warm_from_peer_records_plans():
    router, (r0,) = make_router(n=1)
    joiner = FakeReplica("j")
    router.attach(joiner, warm_from=r0)
    assert joiner.warmed == {4: "fake-plan"}
    router.attach(FakeReplica("k"), warm_from={2: "p"})
    assert router.replicas["k"].warmed == {2: "p"}


# ---------------------------------------------------------------------------
# registry health
# ---------------------------------------------------------------------------

def test_registry_silence_and_failure_are_independent_signals():
    t = [0.0]
    reg = ReplicaRegistry(timeout=10.0, clock=lambda: t[0])
    a, b = FakeReplica("a"), FakeReplica("b")
    reg.join(a), reg.join(b)
    assert reg.suspects() == []
    t[0] = 11.0
    reg.beat("b")
    assert reg.suspects() == ["a"]               # silence
    reg.report_failure("b")
    assert reg.suspects() == ["a", "b"]          # observed failure
    reg.join(b)                                  # rejoin clears quarantine
    assert reg.suspects() == ["a"]


def test_registry_leave_is_not_failure_and_drops_late_beats():
    reg = ReplicaRegistry(timeout=10.0, clock=lambda: 0.0)
    a = FakeReplica("a")
    reg.join(a)
    assert reg.leave("a") is True
    assert "a" not in reg and reg.suspects() == []
    a.heartbeat()            # drained scheduler's last loop iterations
    assert reg.members() == []                   # must not resurrect
    assert reg.leave("a") is False
    reg.report_failure("a")                      # unknown id: ignored
    assert reg.suspects() == []


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_snapshot_and_export(tmp_path):
    router, (r0, r1) = make_router()
    s = router.session("pinned")
    s.replica = "r0"
    futs = [router.submit(i, session=s) for i in range(3)]
    router.update(*_delta(1))
    router.publish()
    r0.kill()                                    # 3 failovers onto r1
    r1.pump()
    assert all(f.done() for f in futs)
    snap = metrics.snapshot(router)
    rows = {r["id"]: r for r in snap["replicas"]}
    assert rows["r0"]["state"] == "lost"
    assert rows["r1"]["state"] == "healthy"
    assert rows["r1"]["answered"] == 3
    assert snap["router"]["failovers"] == 3
    assert snap["router"]["published_epoch"] == 1
    assert snap["router"]["retry"]["attempts"] >= 6
    path = metrics.export_json(router, str(tmp_path / "m" / "fleet.json"))
    import json
    with open(path) as f:
        assert json.load(f)["router"]["failovers"] == 3


# ---------------------------------------------------------------------------
# data plane (slow): real 2-replica LWE fleet — kill, failover, rejoin hot
# ---------------------------------------------------------------------------

LOG_N = 10
N = 1 << LOG_N


@pytest.fixture()
def lwe_fleet(monkeypatch):
    """Two real single-server LWE replicas behind a router; in-memory
    plan cache only (no cross-test pollution via the JSON file)."""
    from repro import engine
    from repro.config import PIRConfig
    from repro.core import pir
    from repro.runtime.elastic import carve_submeshes

    monkeypatch.setenv("REPRO_PLAN_CACHE", "off")
    engine.plan_cache(reload=True)
    db = pir.make_database(np.random.default_rng(0), N, 32)
    cfg = PIRConfig(n_items=N, item_bytes=32, protocol="lwe-simple-1",
                    n_servers=1, batch_queries=4)
    meshes = carve_submeshes(2, model_axis=1)
    router = Router(rng=np.random.default_rng(0), base_delay=0.01,
                    max_delay=0.1)
    kw = dict(n_queries=4, buckets=(4,), max_wait_s=0.002,
              client_rng=np.random.default_rng(7))
    replicas = [
        router.attach(ServeReplica(f"r{i}", db, cfg, meshes[i], **kw))
        for i in range(2)
    ]
    yield router, replicas, db, cfg, meshes
    for r in list(router.replicas.values()):
        try:
            r.close()
        except Exception:
            pass
    engine.plan_cache(reload=True)


@pytest.mark.slow
def test_fleet_failover_zero_lost_then_rejoin_hot(lwe_fleet):
    router, (r0, r1), db, cfg, meshes = lwe_fleet

    # publish an update through the front tier: both replicas converge
    new_val = np.arange(8, dtype=np.uint32).reshape(1, 8)
    router.update([5], new_val)
    assert router.publish() == 1
    assert (r0.epoch, r1.epoch) == (1, 1)

    # pin a session to r0 and load it up, then kill r0 mid-flight: every
    # future must still resolve byte-correct with a valid epoch tag
    s = router.session("victim")
    s.replica = "r0"
    indices = [5, 0, 9, N - 1, 3, 77, 5, 12]
    futs = [router.submit(i, session=s) for i in indices]
    r0.kill("injected mid-load fault")
    rows = [np.asarray(f.result(timeout=180.0)) for f in futs]
    expect = np.asarray(db, dtype=np.uint32).copy()
    expect[5] = new_val
    expect_bytes = expect.view(np.uint8).reshape(N, 32)
    for i, row in zip(indices, rows):
        np.testing.assert_array_equal(row, expect_bytes[i])
    for f in futs:
        assert f.epoch == 1                       # valid tag, post-update
    assert "r0" in router.registry.suspects()
    assert router.failovers >= 1                  # at least the queued ones

    # rejoin: fresh replica, warmed from the healthy peer BEFORE its
    # facade compiles -> first query is served off a non-heuristic plan
    router.detach("r0")
    r0b = ServeReplica("r0", db, cfg, meshes[0],
                       warm_plans=r1.export_plans(), **dict(
                           n_queries=4, buckets=(4,), max_wait_s=0.002,
                           client_rng=np.random.default_rng(8)))
    router.attach(r0b)
    assert r0b.epoch == 1                         # delta log replayed
    report = r0b.plan_report()
    assert all(r["provenance"] in ("tuned", "warm") for r in report.values())
    s2 = router.session("rejoined")
    s2.replica = "r0"
    fut = router.submit(5, session=s2)
    np.testing.assert_array_equal(np.asarray(fut.result(timeout=180.0)),
                                  expect_bytes[5])
    assert fut.epoch == 1
