"""Distribution glue: spec fixing, FSDP rewrite, step builders (local mesh)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import MeshConfig, OptimizerConfig, RunConfig
from repro.configs import SMOKES
from repro.configs.shapes import SMOKE_DECODE, SMOKE_PREFILL, SMOKE_TRAIN
from repro.launch.mesh import make_local_mesh
from repro.runtime.steps import (_apply_fsdp, _filter_axes,
                                 _fix_divisibility, make_serve_step,
                                 make_train_step)

pytestmark = pytest.mark.slow    # compile-heavy: full-step jits on a 1-core CPU


def _fake_mesh(shape, axes):
    """Axis-size stand-in with mesh-like .shape/.axis_names (no devices)."""
    class M:
        pass
    m = M()
    m.axis_names = axes
    m.shape = dict(zip(axes, shape))
    return m


def test_fix_divisibility_moves_axis():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    spec = {"k": P(None, "data", None, "model", None)}
    struct = {"k": jax.ShapeDtypeStruct((30, 128, 64, 8, 128), np.float32)}
    fixed = _fix_divisibility(spec, struct, mesh)
    # kv=8 not divisible by model=16 -> relocated to head_dim (128)
    assert fixed["k"] == P(None, "data", None, None, "model")


def test_fix_divisibility_drops_when_stuck():
    mesh = _fake_mesh((16,), ("data",))
    spec = {"x": P("data",)}
    struct = {"x": jax.ShapeDtypeStruct((1,), np.float32)}
    fixed = _fix_divisibility(spec, struct, mesh)
    assert fixed["x"] == P(None,)


def test_filter_axes_removes_missing_mesh_axes():
    mesh = _fake_mesh((4, 2), ("data", "model"))
    spec = {"t": P(("pod", "data"), None)}
    assert _filter_axes(spec, mesh)["t"] == P(("data",), None)


def test_apply_fsdp_targets_feature_dims_not_scan_dim():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    specs = {"embed": P("model", None),
             "moe_layers": {"ffn": {"gate": P(None, None, None, "model")}}}
    structs = {"embed": jax.ShapeDtypeStruct((64000, 7168), np.float32),
               "moe_layers": {"ffn": {"gate": jax.ShapeDtypeStruct(
                   (64, 8, 6144, 32768), np.float32)}}}
    out = _apply_fsdp(specs, structs, mesh)
    # scan dim 0 untouched; E=8 skipped (8 % 16); d=6144 gets the axis
    assert out["moe_layers"]["ffn"]["gate"] == P(None, None, "data", "model")
    assert out["embed"] == P("model", None)     # non-stacked untouched


@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-v3-671b"])
def test_train_step_runs_local(arch):
    mesh = make_local_mesh()
    run = RunConfig(model=SMOKES[arch], shape=SMOKE_TRAIN,
                    mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
                    optimizer=OptimizerConfig(lr=1e-3, total_steps=10),
                    microbatches=2)
    with mesh:
        ts = make_train_step(run, mesh)
        params, opt_state, ef = ts.init_state(jax.random.PRNGKey(0))
        batch = {
            k: jnp.zeros(v.shape, v.dtype)
            for k, v in ts.input_structs.items()
        }
        if "tokens" in batch:
            batch["tokens"] = jnp.ones(batch["tokens"].shape, jnp.int32)
        params, opt_state, ef, m = ts.step(params, opt_state, ef, batch)
        assert np.isfinite(float(m["loss"]))


def test_train_step_microbatch_structs_shape():
    mesh = make_local_mesh()
    run = RunConfig(model=SMOKES["granite-3-2b"], shape=SMOKE_TRAIN,
                    mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
                    microbatches=2)
    with mesh:
        ts = make_train_step(run, mesh)
    t = ts.input_structs["tokens"]
    assert t.shape[0] == 2                      # [micro, B/micro, S]
    assert t.shape[1] == SMOKE_TRAIN.global_batch // 2


@pytest.mark.parametrize("arch", ["granite-3-2b", "zamba2-7b"])
def test_serve_steps_run_local(arch):
    mesh = make_local_mesh()
    run = RunConfig(model=SMOKES[arch], shape=SMOKE_PREFILL,
                    mesh=MeshConfig(shape=(1, 1), axes=("data", "model")))
    with mesh:
        ss = make_serve_step(run, mesh, decode_write=False)
        params = jax.jit(ss.model.init_params,
                         out_shardings=ss.param_shardings)(
            jax.random.PRNGKey(0))
        batch = {k: (jnp.ones(v.shape, v.dtype) if v.dtype == np.int32
                     else jnp.zeros(v.shape, v.dtype))
                 for k, v in ss.input_structs.items()}
        logits, cache = ss.prefill(params, batch)
        assert np.isfinite(np.asarray(logits, np.float32)
                           [:, :run.model.vocab]).all()
        toks = jnp.ones((SMOKE_PREFILL.global_batch, 1), jnp.int32)
        logits2, _ = ss.decode(params, cache, toks)
        assert np.isfinite(np.asarray(logits2, np.float32)
                           [:, :run.model.vocab]).all()


def test_compressed_train_step_learns():
    mesh = make_local_mesh()
    run = RunConfig(model=SMOKES["granite-3-2b"], shape=SMOKE_TRAIN,
                    mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
                    optimizer=OptimizerConfig(lr=1e-2, warmup_steps=0,
                                              total_steps=100,
                                              compress_grads=True))
    from repro.data.pipeline import TokenPipeline
    with mesh:
        ts = make_train_step(run, mesh)
        params, opt, ef = ts.init_state(jax.random.PRNGKey(0))
        assert ef is not None
        pipe = TokenPipeline(run.model, run.shape)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
        losses = []
        for _ in range(15):
            params, opt, ef, m = ts.step(params, opt, ef, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 1.0     # overfits the fixed batch
