"""Chaos plane + verified reconstruction (DESIGN.md §12).

Fast tier throughout (no XLA compiles): FaultPlan/ChaosInjector
mechanics, the row-checksum + verify_records primitives, eager
verified-reconstruction coverage of all four registered protocols
(synthetic shares / numpy LWE oracle), per-query deadlines
(AnswerFuture + the router's hedging reaper), chaos seams in the
scheduler, registry, router publish path and plan cache, seeded backoff
jitter, and the satellite property test driving random fault plans
against a fake-replica fleet — zero lost answers, no silent corruption.
"""
import time

import numpy as np
import pytest

from _prop import given, settings, st
from test_replica import FakeReplica, make_router

from repro.chaos import (ACTIONS, SEAMS, ChaosInjector, FaultEvent,
                         FaultPlan, InjectedFault)
from repro.config import PIRConfig
from repro.core import protocol as protocol_mod
from repro.db.spec import (DatabaseSpec, IntegrityError, row_checksum,
                           verify_records)
from repro.replica import ReplicaLost, ReplicaRegistry, Router
from repro.runtime.fault import RetryStats, retry_step
from repro.runtime.serve_loop import (AnswerFuture, QueryScheduler,
                                      QueryTimeout)


# ---------------------------------------------------------------------------
# FaultPlan / ChaosInjector mechanics
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(seam="nope", action="kill")
    with pytest.raises(ValueError):
        FaultEvent(seam="heartbeat", action="explode")
    with pytest.raises(ValueError):
        FaultEvent(seam="heartbeat", action="drop", at=-1)
    with pytest.raises(ValueError):
        FaultEvent(seam="heartbeat", action="drop", count=0)


def test_fault_plan_random_is_seed_deterministic():
    p1 = FaultPlan.random(42, targets=("a", "b"))
    p2 = FaultPlan.random(42, targets=("a", "b"))
    assert p1 == p2
    assert p1 != FaultPlan.random(43, targets=("a", "b"))
    for ev in p1.events:
        assert ev.seam in SEAMS and ev.action in ACTIONS
        if ev.action == "corrupt":    # the only share-bearing seam
            assert ev.seam == "replica.serve_step"


def test_injector_visit_window_and_target_matching():
    inj = ChaosInjector(FaultPlan(seed=0, events=(
        FaultEvent("heartbeat", "drop", target="a", at=2, count=2),)))
    assert [inj.should_drop("heartbeat", "a") for _ in range(6)] == \
        [False, False, True, True, False, False]
    assert not inj.should_drop("heartbeat", "b")      # wrong target
    # target None matches any target, with independent visit counters
    inj2 = ChaosInjector(FaultPlan(seed=0, events=(
        FaultEvent("heartbeat", "drop", at=0),)))
    assert inj2.should_drop("heartbeat", "x")
    assert inj2.should_drop("heartbeat", "y")
    assert inj2.fired_actions("heartbeat") == ["drop", "drop"]


def test_injector_kill_raises_and_stall_sleeps_injected():
    inj = ChaosInjector(FaultPlan(seed=0, events=(
        FaultEvent("router.resubmit", "kill", at=0),)))
    with pytest.raises(InjectedFault, match="router.resubmit"):
        inj.visit("router.resubmit")
    sleeps = []
    inj2 = ChaosInjector(FaultPlan(seed=0, events=(
        FaultEvent("db.publish", "stall", at=0, duration_s=1.5),)),
        sleep=sleeps.append)
    inj2.fire("db.publish")
    assert sleeps == [1.5]


def test_corrupt_shares_flips_one_element_deterministically():
    plan = FaultPlan(seed=9, events=(
        FaultEvent("replica.serve_step", "corrupt", at=1),))
    shares = (np.arange(12, dtype=np.uint32).reshape(3, 4),
              np.arange(12, dtype=np.uint32).reshape(3, 4) + 100)
    outs = []
    for _ in range(2):
        inj = ChaosInjector(plan)
        s1 = inj.corrupt_shares("replica.serve_step", None, shares)
        # visit 0 is before the event window: shares pass through intact
        assert all(np.array_equal(a, b) for a, b in zip(s1, shares))
        outs.append(inj.corrupt_shares("replica.serve_step", None, shares))
    # same plan => bit-identical corruption on replay
    assert all(np.array_equal(a, b) for a, b in zip(outs[0], outs[1]))
    diffs = sum(int((np.asarray(a) != np.asarray(b)).sum())
                for a, b in zip(outs[0], shares))
    assert diffs == 1                 # exactly one element of one share
    # and the flip is the repeated-byte top-bit mask
    changed = [k for k, (a, b) in enumerate(zip(outs[0], shares))
               if not np.array_equal(a, b)][0]
    delta = np.asarray(outs[0][changed]) ^ shares[changed]
    assert int(delta.max()) == 0x80808080


# ---------------------------------------------------------------------------
# row checksum + verify_records + DatabaseSpec stored widths
# ---------------------------------------------------------------------------

def test_row_checksum_sensitivity_and_determinism():
    rng = np.random.default_rng(0)
    w = rng.integers(0, 1 << 32, size=(64, 8), dtype=np.uint32)
    c1 = row_checksum(w)
    np.testing.assert_array_equal(c1, row_checksum(w))
    assert c1.dtype == np.uint32
    w2 = w.copy()
    w2[10, 3] ^= np.uint32(1)         # single-bit flip
    c2 = row_checksum(w2)
    assert c2[10] != c1[10]
    np.testing.assert_array_equal(np.delete(c2, 10), np.delete(c1, 10))
    # position-dependent fold: permuting a row's words changes its sum
    w3 = w.copy()
    w3[0] = w[0][::-1]
    assert row_checksum(w3)[0] != c1[0]


def test_verify_records_both_forms_and_bad_indices():
    rng = np.random.default_rng(2)
    w = rng.integers(0, 1 << 32, size=(5, 2), dtype=np.uint32)
    spec = DatabaseSpec(n_items=8, item_bytes=8, checksum=True)
    stored = spec.attach_checksums(w)
    np.testing.assert_array_equal(verify_records(stored, 8), w)
    b = spec.words_to_bytes_host(stored)
    np.testing.assert_array_equal(verify_records(b, 8),
                                  spec.words_to_bytes_host(w))
    bad = stored.copy()
    bad[0, 1] ^= np.uint32(2)
    bad[4, 0] ^= np.uint32(1 << 31)
    with pytest.raises(IntegrityError) as ei:
        verify_records(bad, 8)
    assert ei.value.bad_queries == (0, 4)
    with pytest.raises(ValueError):   # neither stored-width form
        verify_records(np.zeros((2, 7), np.uint8), 8)


def test_spec_stored_widths_and_idempotent_attach():
    spec = DatabaseSpec(n_items=8, item_bytes=8, checksum=True)
    assert (spec.stored_words, spec.stored_bytes) == (3, 12)
    assert spec.view_shape("words") == (8, 3)
    assert spec.view_shape("bytes") == (8, 12)
    w = np.arange(16, dtype=np.uint32).reshape(8, 2)
    st1 = spec.attach_checksums(w)
    np.testing.assert_array_equal(spec.attach_checksums(st1), st1)
    np.testing.assert_array_equal(spec.verify_stored_rows(st1), w)
    bad = st1.copy()
    bad[3, 0] ^= np.uint32(4)
    with pytest.raises(IntegrityError):
        spec.verify_stored_rows(bad)
    off = DatabaseSpec(n_items=8, item_bytes=8)   # checksum off: identity
    assert (off.stored_words, off.stored_bytes) == (2, 8)
    np.testing.assert_array_equal(off.attach_checksums(w), w)
    np.testing.assert_array_equal(off.verify_stored_rows(w), w)


def test_make_database_checksum_layout_and_cache_signature():
    from repro.core import pir
    from repro.engine.cache import spec_signature
    db = pir.make_database(np.random.default_rng(0), 8, 8, checksum=True)
    assert db.shape == (8, 3)
    np.testing.assert_array_equal(db[:, 2], row_checksum(db[:, :2]))
    # checksummed configs get their own plan-cache rows (shape change)
    assert spec_signature(PIRConfig(n_items=8, item_bytes=8,
                                    checksum=True)) == "8x8+c"
    assert spec_signature(PIRConfig(n_items=8, item_bytes=8)) == "8x8"


# ---------------------------------------------------------------------------
# verified reconstruction: every registered protocol, eager (no XLA)
# ---------------------------------------------------------------------------

def test_xor2_verified_reconstruction_detects_share_corruption():
    cfg = PIRConfig(n_items=16, item_bytes=8, checksum=True)
    spec = DatabaseSpec.from_config(cfg)
    rng = np.random.default_rng(0)
    logical = rng.integers(0, 1 << 32, size=(4, 2), dtype=np.uint32)
    stored = spec.attach_checksums(logical)
    s0 = rng.integers(0, 1 << 32, size=stored.shape, dtype=np.uint32)
    s1 = s0 ^ stored
    proto = protocol_mod.for_config(cfg)
    rec = np.asarray(proto.reconstruct_with([s0, s1], [None] * 4, cfg=cfg))
    np.testing.assert_array_equal(rec, logical)   # verified AND stripped
    bad = s1.copy()
    bad[2, 0] ^= np.uint32(0x80808080)
    with pytest.raises(IntegrityError) as ei:
        proto.reconstruct_with([s0, bad], [None] * 4, cfg=cfg)
    assert ei.value.bad_queries == (2,)


def test_additive_verified_reconstruction_detects_byte_flip():
    cfg = PIRConfig(n_items=16, item_bytes=8, protocol="additive-dpf-2",
                    checksum=True)
    spec = DatabaseSpec.from_config(cfg)
    rng = np.random.default_rng(1)
    logical = rng.integers(0, 1 << 32, size=(3, 2), dtype=np.uint32)
    stored_b = spec.words_to_bytes_host(spec.attach_checksums(logical))
    s0 = rng.integers(0, 256, size=stored_b.shape, dtype=np.uint8)
    s1 = ((stored_b.astype(np.int32) - s0) % 256).astype(np.uint8)
    proto = protocol_mod.for_config(cfg)
    rec = np.asarray(proto.reconstruct_with([s0, s1], [None] * 3, cfg=cfg))
    np.testing.assert_array_equal(rec, spec.words_to_bytes_host(logical))
    bad = s1.copy()
    # the 0x80 top-bit flip is +128 mod 256 — never a Z_256 no-op (a
    # bit-31 flip on the int32 accumulator WOULD be: 2^31 ≡ 0 mod 256)
    bad[1, 3] ^= np.uint8(0x80)
    with pytest.raises(IntegrityError) as ei:
        proto.reconstruct_with([s0, bad], [None] * 3, cfg=cfg)
    assert ei.value.bad_queries == (1,)


def test_xor_k_verified_reconstruction_three_shares():
    cfg = PIRConfig(n_items=16, item_bytes=8, protocol="xor-dpf-k",
                    n_servers=3, checksum=True)
    spec = DatabaseSpec.from_config(cfg)
    rng = np.random.default_rng(3)
    logical = rng.integers(0, 1 << 32, size=(2, 2), dtype=np.uint32)
    stored = spec.attach_checksums(logical)
    s0 = rng.integers(0, 1 << 32, size=stored.shape, dtype=np.uint32)
    s1 = rng.integers(0, 1 << 32, size=stored.shape, dtype=np.uint32)
    s2 = s0 ^ s1 ^ stored
    proto = protocol_mod.for_config(cfg)
    rec = np.asarray(proto.reconstruct_with([s0, s1, s2], [None] * 2,
                                            cfg=cfg))
    np.testing.assert_array_equal(rec, logical)
    bad = s0.copy()
    bad[0, 2] ^= np.uint32(0x80808080)   # the checksum word itself
    with pytest.raises(IntegrityError) as ei:
        proto.reconstruct_with([bad, s1, s2], [None] * 2, cfg=cfg)
    assert ei.value.bad_queries == (0,)


def test_lwe_checksum_closes_the_delta_aliasing_gap():
    """The LWE noise bound catches gross corruption, but a shift by a
    multiple of Delta aliases to a clean plaintext shift — noise-check
    blind. The row checksum closes exactly that gap."""
    from repro.core import lwe

    N = 256
    cfg = PIRConfig(n_items=N, item_bytes=8, protocol="lwe-simple-1",
                    n_servers=1, checksum=True)
    spec = DatabaseSpec.from_config(cfg)
    params = lwe.params_for(N)
    rng = np.random.default_rng(0)
    logical = rng.integers(0, 1 << 32, size=(N, 2), dtype=np.uint32)
    stored_b = spec.words_to_bytes_host(spec.attach_checksums(logical))
    hint = lwe.hint_np(params, stored_b).astype(np.uint32)
    proto = protocol_mod.for_config(cfg)
    indices = [3, 200]
    cts, states = [], []
    for i in indices:
        ct, state = lwe.encrypt(rng, i, N, params)
        cts.append(np.asarray(ct.ct).view(np.uint32).astype(np.uint64))
        states.append(state)
    mask = np.uint64(0xFFFFFFFF)
    ans = np.stack([(c @ stored_b.astype(np.uint64)) & mask
                    for c in cts]).astype(np.uint32).view(np.int32)

    rec = np.asarray(proto.reconstruct_with([ans], states, cfg=cfg,
                                            hint=hint))
    np.testing.assert_array_equal(
        rec, spec.words_to_bytes_host(logical)[indices])

    # gross corruption: the analytic noise bound alone catches it
    g = ans.copy()
    g.view(np.uint32)[0, 0] ^= np.uint32(0x80808080)
    with pytest.raises(IntegrityError, match="noise overflow"):
        proto.reconstruct_with([g], states, cfg=cfg, hint=hint)

    # Delta-multiple shift: residual noise unchanged, decoded byte off by
    # one — invisible to the noise check, caught by the checksum
    d = ans.copy()
    dv = d.view(np.uint32)
    dv[1, 2] = np.uint32((int(dv[1, 2]) + params.delta) & 0xFFFFFFFF)
    with pytest.raises(IntegrityError, match="checksum"):
        proto.reconstruct_with([d], states, cfg=cfg, hint=hint)

    # ... and without the checksum column the same shift IS silent
    # corruption (treat the stored layout as a checksum-less 12-byte db)
    cfg0 = PIRConfig(n_items=N, item_bytes=12, protocol="lwe-simple-1",
                     n_servers=1)
    proto0 = protocol_mod.for_config(cfg0)
    rec0 = np.asarray(proto0.reconstruct_with([d], states, cfg=cfg0,
                                              hint=hint))
    np.testing.assert_array_equal(rec0[0], stored_b[indices[0]])
    assert not np.array_equal(rec0[1], stored_b[indices[1]])


# ---------------------------------------------------------------------------
# per-query deadlines: AnswerFuture + QueryTimeout context
# ---------------------------------------------------------------------------

def test_answer_future_deadline_drives_result_timeout():
    fut = AnswerFuture(deadline=time.monotonic() + 0.05)
    fut.context.update(session="s7", bucket=4, replica="r1")
    with pytest.raises(QueryTimeout) as ei:
        fut.result()                 # no explicit timeout: deadline rules
    msg = str(ei.value)
    for frag in ("session=s7", "bucket=4", "replica=r1", "elapsed=",
                 "deadline_over_by="):
        assert frag in msg, f"{frag!r} missing from {msg!r}"
    fut2 = AnswerFuture()            # no deadline: explicit timeout only
    with pytest.raises(QueryTimeout):
        fut2.result(timeout=0.01)


def test_answer_future_deadline_is_no_obstacle_once_resolved():
    fut = AnswerFuture(deadline=time.monotonic() - 1.0)   # already past
    fut.set_result("late but landed")
    assert fut.result() == "late but landed"


# ---------------------------------------------------------------------------
# router deadlines: the reaper hedges at half budget, expires at deadline
# ---------------------------------------------------------------------------

def test_reap_hedges_at_half_budget_then_first_answer_wins():
    t = [0.0]
    router, (r0, r1) = make_router(clock=lambda: t[0])
    s = router.session("dl")
    s.replica = "r0"
    fut = router.submit(5, session=s, deadline_s=10.0)
    assert (r0.queue_depth, r1.queue_depth) == (1, 0)
    assert router.reap() == {"expired": 0, "hedged": 0}   # budget fresh
    t[0] = 5.0
    assert router.reap() == {"expired": 0, "hedged": 1}
    assert r1.queue_depth == 1       # resubmitted, excluding the holder
    assert router.hedges == 1
    assert router.reap()["hedged"] == 0                   # once per query
    r1.pump()
    assert fut.result(0) == ("ans", 5, "r1")
    r0.pump()                        # straggler's late duplicate answer
    assert fut.result(0) == ("ans", 5, "r1")              # first wins
    assert router.reap() == {"expired": 0, "hedged": 0}
    assert router._pending_q == {}   # resolved futures leave the table


def test_reap_expires_past_deadline_with_query_context():
    t = [0.0]
    router, (r0, r1) = make_router(clock=lambda: t[0])
    s = router.session("sess-42")
    s.replica = "r0"
    fut = router.submit(9, session=s, deadline_s=4.0)
    t[0] = 4.5
    out = router.reap()
    assert out["expired"] == 1 and router.deadline_expired == 1
    with pytest.raises(QueryTimeout) as ei:
        fut.result(0)
    msg = str(ei.value)
    assert "session=sess-42" in msg and "deadline_over_by" in msg
    assert router._pending_q == {}


def test_submit_without_deadline_stays_out_of_the_pending_table():
    router, (r0, r1) = make_router()
    router.submit(1)
    assert router._pending_q == {}
    assert router.reap() == {"expired": 0, "hedged": 0}


# ---------------------------------------------------------------------------
# router integrity failover: a corrupting replica is unfit to serve
# ---------------------------------------------------------------------------

class IntegrityFakeReplica(FakeReplica):
    """pump() fails every queued future with IntegrityError — the shape
    a corrupted answer surfaces in after verified reconstruction."""

    def pump(self):
        q, self._q = self._q, []
        for _item, fut in q:
            fut.set_exception(IntegrityError(
                "checksum mismatch on 1/1 reconstructed record(s)",
                bad_queries=(0,)))
        return len(q)


def test_integrity_error_quarantines_and_resubmits():
    router = Router(rng=np.random.default_rng(0), sleep=lambda s: None)
    bad = router.attach(IntegrityFakeReplica("bad"))
    good = router.attach(FakeReplica("good"))
    s = router.session("c")
    s.replica = "bad"
    futs = [router.submit(i, session=s) for i in range(3)]
    bad.pump()                       # integrity failures -> failover
    assert "bad" in router.registry.suspects()
    assert router.integrity_failures == 3
    assert good.queue_depth == 3
    good.pump()
    assert [f.result(0) for f in futs] == [("ans", i, "good")
                                           for i in range(3)]


# ---------------------------------------------------------------------------
# chaos seams: scheduler dispatch, registry heartbeat, publish, plan cache
# ---------------------------------------------------------------------------

def _mini_scheduler(chaos=None, target=None):
    return QueryScheduler(
        collate=list, stage=lambda p: p, dispatch=lambda s: s,
        finalize=lambda raw, n: raw[:n], buckets=(2,), max_wait_s=0.001,
        chaos=chaos, chaos_target=target)


def test_scheduler_dispatch_kill_resolves_every_future():
    inj = ChaosInjector(FaultPlan(seed=0, events=(
        FaultEvent("scheduler.dispatch", "kill", at=0),)))
    sched = _mini_scheduler(chaos=inj)
    sched.start()
    futs = [sched.submit(i) for i in range(6)]
    errors = 0
    for f in futs:                   # nothing hangs: every future resolves
        try:
            f.result(timeout=10.0)
        except InjectedFault:
            errors += 1
    assert errors >= 2               # at least the killed batch
    assert inj.fired_actions("scheduler.dispatch") == ["kill"]
    with pytest.raises(RuntimeError):
        sched.submit(99)             # dead session rejects new work


def test_chaos_heartbeat_drop_ages_replica_into_suspicion():
    t = [0.0]
    reg = ReplicaRegistry(timeout=10.0, clock=lambda: t[0])
    a, b = FakeReplica("a"), FakeReplica("b")
    reg.join(a)
    reg.join(b)
    reg.chaos = ChaosInjector(FaultPlan(seed=0, events=(
        FaultEvent("heartbeat", "drop", target="a", at=0, count=10),)))
    t[0] = 11.0
    reg.beat("a")                    # dropped: never reaches last_seen
    reg.beat("b")
    assert reg.suspects() == ["a"]


def test_chaos_publish_drop_lags_replica_then_converges():
    inj = ChaosInjector(FaultPlan(seed=0, events=(
        FaultEvent("db.publish", "drop", target="r1", at=0),)))
    router, (r0, r1) = make_router(chaos=inj)
    router.update([1], np.full((1, 8), 1, np.uint32))
    router.publish()
    assert (r0.epoch, r1.epoch) == (1, 0)     # r1 missed the fan-out
    assert router.epoch_lag("r1") == 1
    router.update([2], np.full((1, 8), 2, np.uint32))
    router.publish()                 # delta-log replay converges r1
    assert (r0.epoch, r1.epoch) == (2, 2)


def test_plan_cache_chaos_load_degrades_never_crashes(tmp_path):
    import json
    from repro.engine.cache import PlanCache
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        json.dump({"schema": 1, "plans": {}}, f)
    assert PlanCache(path).load_error is None   # healthy load
    for action in ("drop", "kill"):
        inj = ChaosInjector(FaultPlan(seed=0, events=(
            FaultEvent("plan_cache.load", action, at=0),)))
        pc = PlanCache(path, chaos=inj)
        assert pc.load_error is not None        # degraded, remembered why
        assert pc.plans == {}                   # ... to heuristic-only


# ---------------------------------------------------------------------------
# seeded backoff jitter (runtime.fault.retry_step)
# ---------------------------------------------------------------------------

def _always_fail():
    raise RuntimeError("transient")


def test_retry_backoff_jitter_is_seeded_capped_and_accounted():
    sleeps1, stats1 = [], RetryStats()
    with pytest.raises(RuntimeError):
        retry_step(_always_fail, retries=4, base_delay=1.0, max_delay=4.0,
                   sleep=sleeps1.append, jitter=0.5,
                   rng=np.random.default_rng(5), stats=stats1)
    sleeps2 = []
    with pytest.raises(RuntimeError):
        retry_step(_always_fail, retries=4, base_delay=1.0, max_delay=4.0,
                   sleep=sleeps2.append, jitter=0.5,
                   rng=np.random.default_rng(5))
    assert sleeps1 == sleeps2        # seeded rng: bit-identical replay
    base = [1.0, 2.0, 4.0, 4.0]
    assert sleeps1 != base           # jitter actually moved the delays
    for s, b in zip(sleeps1, base):
        assert 0.5 * b <= s <= min(1.5 * b, 4.0)   # spread AND re-capped
    assert stats1.slept_s == sum(sleeps1)          # actual, not nominal
    assert stats1.retried == 4 and stats1.attempts == 5


def test_retry_backoff_without_jitter_keeps_exact_schedule():
    sleeps = []
    with pytest.raises(RuntimeError):
        retry_step(_always_fail, retries=4, base_delay=1.0, max_delay=4.0,
                   sleep=sleeps.append)
    assert sleeps == [1.0, 2.0, 4.0, 4.0]


# ---------------------------------------------------------------------------
# satellite property test: random fault plans over a fake fleet
# ---------------------------------------------------------------------------

class ChaosFakeReplica(FakeReplica):
    """FakeReplica serving real checksummed rows through a ChaosInjector.

    ``pump()`` resolves each queued query the way the real serve stack
    would: a ``kill`` event fails everything with ReplicaLost, a
    ``corrupt`` event trips ``verify_records`` into IntegrityError, and
    clean rows resolve to the logical payload words. Publishes fan in
    through the FakeDB subscription, keeping the stored rows current.
    """

    def __init__(self, rid, spec, stored_words, injector):
        super().__init__(rid)
        self.spec = spec
        self.rows = np.array(stored_words)
        self.injector = injector
        self.db.subscribe(self._apply_delta)

    def _apply_delta(self, delta):
        vals = self.spec.attach_checksums(
            self.spec.coerce_rows_to_words(np.asarray(delta.vals)))
        self.rows[np.asarray(delta.rows)] = vals

    def pump(self):
        q, self._q = self._q, []
        n = 0
        for item, fut in q:
            if self.lost:
                fut.set_exception(ReplicaLost(self.id, "chaos kill"))
                continue
            try:
                (row,) = self.injector.corrupt_shares(
                    "replica.serve_step", self.id,
                    (self.rows[int(item)],))
            except InjectedFault:
                self.kill("chaos kill")       # clears + fails the queue
                fut.set_exception(ReplicaLost(self.id, "chaos kill"))
                continue
            try:
                payload = verify_records(row[None, :],
                                         self.spec.item_bytes)[0]
            except IntegrityError as e:
                fut.set_exception(e)          # never a silently wrong row
                continue
            fut.epoch = self.db.epoch
            fut.set_result(np.array(payload))
            n += 1
        return n


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=(1 << 32) - 1))
def test_random_fault_plans_never_lose_or_corrupt_answers(seed):
    """Under ANY seeded fault plan: every submitted future resolves
    (zero lost answers), every resolved RESULT is byte-correct (silent
    corruption is impossible — corruption surfaces as IntegrityError and
    is retried), the session's min_epoch ratchet stays within the
    front-tier epoch, and a fired corruption is always counted by the
    router (it can never slip through as data)."""
    spec = DatabaseSpec(n_items=32, item_bytes=8, checksum=True)
    data_rng = np.random.default_rng(123)
    logical = data_rng.integers(0, 1 << 32, size=(32, 2), dtype=np.uint32)
    stored = spec.attach_checksums(logical)

    plan = FaultPlan.random(
        seed, targets=("r0", "r1", "r2", None),
        seams=("replica.serve_step", "heartbeat", "db.publish"),
        actions=("corrupt", "kill", "drop"), n_events=5, max_at=6)
    injector = ChaosInjector(plan)
    t = [0.0]
    reg = ReplicaRegistry(timeout=30.0, clock=lambda: t[0])
    reg.chaos = injector
    router = Router(registry=reg, rng=np.random.default_rng(1),
                    sleep=lambda s: None, retries=6, chaos=injector)
    reps = [router.attach(ChaosFakeReplica(f"r{i}", spec, stored,
                                           injector))
            for i in range(3)]

    s = router.session("prop")
    indices = [1 + (i % (spec.n_items - 1)) for i in range(12)]
    futs = [router.submit(j, session=s) for j in indices]
    # exercise the publish fan-out (and its chaos drops) mid-load; only
    # row 0 changes, and no query targets row 0 — answers stay stable
    router.update([0], np.full((1, spec.item_words), 7, np.uint32))
    router.publish()

    for _ in range(24):
        if all(f.done() for f in futs):
            break
        for r in reps:
            if not r.lost:
                r.pump()
    assert all(f.done() for f in futs), "lost answers under chaos"

    for j, f in zip(indices, futs):
        if f.exception() is None:
            np.testing.assert_array_equal(np.asarray(f.result(0)),
                                          logical[j])
            assert f.epoch is not None
            assert f.epoch <= router.published_epoch
    assert 0 <= s.min_epoch <= router.published_epoch
    if "corrupt" in injector.fired_actions("replica.serve_step"):
        # every fired corruption became a counted IntegrityError — the
        # "never silent" half of the contract
        assert router.integrity_failures >= 1
