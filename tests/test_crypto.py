"""Crypto layer: AES-128 reference vectors + ChaCha PRG properties.

The AES reference documents parity with the paper's PRF choice (IM-PIR
uses AES-128 via AES-NI); the DPF construction is PRF-agnostic and the
repo's production PRG is the ChaCha ARX permutation (DESIGN.md §2).
"""
import numpy as np
from _prop import given, settings, st

import jax.numpy as jnp

from repro.crypto.aes_ref import aes_ggm_double, encrypt_block
from repro.crypto.chacha import chacha_block, ggm_double, prg_bits


def test_aes128_fips197_vector():
    """FIPS-197 Appendix C.1."""
    key = np.frombuffer(bytes.fromhex("000102030405060708090a0b0c0d0e0f"),
                        np.uint8)
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       np.uint8)
    ct = encrypt_block(pt, key)
    assert ct.tobytes().hex() == "69c4e0d86a7b0430d8cdb78070b4c55a"


def test_aes_ggm_double_deterministic_and_split():
    seed = np.arange(16, dtype=np.uint8)
    s_l, t_l, s_r, t_r = aes_ggm_double(seed)
    s_l2, t_l2, _, _ = aes_ggm_double(seed)
    np.testing.assert_array_equal(s_l, s_l2)
    assert t_l == t_l2
    assert not np.array_equal(s_l, s_r)     # children differ
    assert t_l in (0, 1) and t_r in (0, 1)


def test_chacha_block_shape_and_determinism():
    key = jnp.arange(4, dtype=jnp.uint32)
    out1 = np.asarray(chacha_block(key))
    out2 = np.asarray(chacha_block(key))
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (16,)
    # different counter -> different stream (domain separation)
    out3 = np.asarray(chacha_block(key, counter=1))
    assert not np.array_equal(out1, out3)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_ggm_double_children_distinct(a, b):
    seeds = jnp.asarray([[a, b, a ^ b, (a + b) & 0xFFFFFFFF]], jnp.uint32)
    s_l, t_l, s_r, t_r = ggm_double(seeds)
    assert not np.array_equal(np.asarray(s_l), np.asarray(s_r))
    assert set(np.asarray([t_l, t_r]).ravel()) <= {0, 1}


def test_prg_bits_lengths_and_domain_separation():
    seeds = jnp.asarray([[1, 2, 3, 4]], jnp.uint32)
    w20 = np.asarray(prg_bits(seeds, 20))
    w4 = np.asarray(prg_bits(seeds, 4))
    assert w20.shape == (1, 20)
    np.testing.assert_array_equal(w20[:, :4], w4)       # prefix-consistent
    blk0 = np.asarray(chacha_block(seeds, counter=0))
    assert not np.array_equal(w20[0, :16], blk0[0])      # ctr-separated


def test_chacha_bit_balance():
    """Output bits of the PRG are ~balanced (smoke-level PRF sanity)."""
    seeds = jnp.asarray(
        np.random.default_rng(0).integers(0, 1 << 32, size=(256, 4),
                                          dtype=np.uint32))
    blk = np.asarray(chacha_block(seeds))
    bits = np.unpackbits(blk.view(np.uint8))
    frac = bits.mean()
    assert 0.49 < frac < 0.51, frac
