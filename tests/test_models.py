"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned archs: instantiate the REDUCED same-family
config, run one forward/train step on CPU, assert output shapes and no
NaNs; then exercise the serving path (prefill + one decode step).
Consistency property: prefill's last-position logits must equal the
teacher-forced forward's last-position logits (same math, two code paths).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import SMOKES
from repro.models import build_model
from repro.models.layers import pad_vocab

B, S = 2, 32
RNG = jax.random.PRNGKey(0)


def _aux_inputs(cfg):
    aux = {}
    if cfg.family == "vlm":
        aux["prefix_embeds"] = jnp.zeros(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        aux["frame_embeds"] = jnp.zeros(
            (B, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    return aux


@pytest.fixture(scope="module")
def built():
    out = {}
    for name, cfg in SMOKES.items():
        model = build_model(cfg, remat="none")
        out[name] = (cfg, model, model.init_params(RNG))
    return out


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_forward_shapes_and_finite(built, arch):
    cfg, model, params = built[arch]
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    logits, _ = model.forward(params, tokens, **{
        "prefix_embeds" if cfg.family == "vlm" else "frame_embeds": v
        for v in _aux_inputs(cfg).values()})
    n_prefix = cfg.n_frontend_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + n_prefix, pad_vocab(cfg.vocab))
    lf = np.asarray(logits, np.float32)
    assert np.isfinite(lf[..., :cfg.vocab]).all(), arch
    # padded-vocab tail is masked to -inf
    if pad_vocab(cfg.vocab) > cfg.vocab:
        assert (lf[..., cfg.vocab:] < -1e29).all()


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_train_loss_finite(built, arch):
    cfg, model, params = built[arch]
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    loss, _ = model.loss(params, tokens, **_aux_inputs(cfg))
    val = float(loss)
    assert np.isfinite(val) and 0 < val < 20, (arch, val)


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_prefill_matches_forward(built, arch):
    cfg, model, params = built[arch]
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    aux = _aux_inputs(cfg)
    logits_fwd, _ = model.forward(params, tokens, **{
        "prefix_embeds" if cfg.family == "vlm" else "frame_embeds": v
        for v in aux.values()})
    logits_pre, cache = model.prefill(params, tokens, **aux)
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32)[:, :cfg.vocab],
        np.asarray(logits_fwd[:, -1], np.float32)[:, :cfg.vocab],
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_decode_step(built, arch):
    cfg, model, params = built[arch]
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    _, cache = model.prefill(params, tokens, **_aux_inputs(cfg))
    nxt = jax.random.randint(RNG, (B, 1), 0, cfg.vocab)
    logits, cache2 = model.decode(params, cache, nxt, write=False)
    assert logits.shape == (B, pad_vocab(cfg.vocab))
    assert np.isfinite(np.asarray(logits, np.float32)[:, :cfg.vocab]).all()
    n_prefix = cfg.n_frontend_tokens if cfg.family == "vlm" else 0
    assert int(cache2.length) == S + n_prefix + 1


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_param_specs_cover_params(built, arch):
    cfg, model, params = built[arch]
    specs = model.param_specs()
    # same tree structure; every leaf spec rank <= leaf rank
    from jax.sharding import PartitionSpec as P
    def chk(p, s):
        assert isinstance(s, P), (arch, p.shape, s)
        assert len(s) <= p.ndim, (arch, p.shape, s)
    jax.tree_util.tree_map(chk, params, specs,
                           is_leaf=lambda x: isinstance(x, P) and False)


def test_decode_continuation_consistency():
    """Teacher-forced forward on [t0..t_{S}] vs prefill+decode of t_S:
    the next-token logits must agree (dense arch, exact cache math)."""
    cfg = SMOKES["granite-3-2b"]
    model = build_model(cfg, remat="none")
    params = model.init_params(RNG)
    tokens = jax.random.randint(RNG, (B, S + 1), 0, cfg.vocab)
    logits_fwd, _ = model.forward(params, tokens)
    _, cache = model.prefill(params, tokens[:, :S])
    logits_dec, _ = model.decode(params, cache, tokens[:, S:], write=False)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32)[:, :cfg.vocab],
        np.asarray(logits_fwd[:, -1], np.float32)[:, :cfg.vocab],
        rtol=3e-2, atol=3e-2)
