"""Property-test compat shim: hypothesis when installed, seeded examples when not.

The five crypto/protocol test modules are written against the hypothesis
API (`@given` over integer/list strategies). The CI container has no
network, so hypothesis may be absent; importing it unconditionally made
the whole suite error at collection. This shim re-exports the real
library when it is importable and otherwise degrades each `@given`
strategy to a fixed, seeded example sweep:

* strategies become samplers drawing from a `numpy` Generator seeded per
  test function (by function name), so failures are reproducible;
* `@given(...)` expands to a loop over drawn example tuples — the paired
  `@settings(max_examples=...)` is honoured but capped at ``_MAX_FALLBACK``
  examples so the fallback stays a *fast, fixed* example set (full
  randomized coverage is hypothesis's job when it is installed);
* the first example of every integer strategy is pinned to the bounds
  (lo, then hi) before random interior draws, so the classic edge cases
  the property tests rely on (alpha = 0, alpha = N - 1) are always hit.

Only the API surface the test modules use is emulated: ``given``,
``settings``, ``st.integers``, ``st.lists``, ``st.data``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    _MAX_FALLBACK = 5    # examples per @given test in fallback mode

    class _Strategy:
        def draw(self, rng: np.random.Generator, first: int):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value=None, max_value=None):
            self.lo = 0 if min_value is None else int(min_value)
            self.hi = (1 << 64) - 1 if max_value is None else int(max_value)

        def draw(self, rng, first):
            if first == 0:
                return self.lo
            if first == 1:
                return self.hi
            # numpy bounds are exclusive-high and capped at uint64
            return int(rng.integers(self.lo, self.hi, endpoint=True,
                                    dtype=np.uint64)) \
                if self.hi > (1 << 62) else \
                int(rng.integers(self.lo, self.hi + 1))

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=None):
            self.elements = elements
            self.min_size = min_size
            self.max_size = max_size if max_size is not None else min_size + 8

        def draw(self, rng, first):
            size = int(rng.integers(self.min_size, self.max_size + 1))
            return [self.elements.draw(rng, 2) for _ in range(size)]

    class _DataObject:
        """Interactive draws (`data.draw(strategy)`) inside a test body."""

        def __init__(self, rng, first):
            self.rng = rng
            self.first = first

        def draw(self, strategy):
            v = strategy.draw(self.rng, self.first)
            self.first = 2   # only the outermost draw gets the edge pin
            return v

    class _Data(_Strategy):
        def draw(self, rng, first):
            return _DataObject(rng, first)

    class _St:
        @staticmethod
        def integers(min_value=None, max_value=None):
            return _Integers(min_value, max_value)

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            return _Lists(elements, min_size, max_size)

        @staticmethod
        def data():
            return _Data()

    st = _St()

    def settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                # read at call time from the wrapper: `@settings` may sit
                # above `@given` (sets the attr on the wrapper) or below
                # it (sets it on fn; copied into wrapper.__dict__ below)
                n = min(getattr(wrapper, "_prop_max_examples", 10),
                        _MAX_FALLBACK)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    drawn = [s.draw(rng, min(i, 2)) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # NOT functools.wraps: pytest must see the zero-fixture
            # (*args, **kwargs) signature, not the strategy parameters
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper
        return deco
