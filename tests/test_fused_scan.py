"""Fused GGM-expand + DB-scan megakernel (kernels/fused_scan.py).

Three concerns, in cost order:

* **Byte parity** against the materialized oracle (host GGM expansion +
  reference scan) — integer-exact, so every comparison is array_equal.
  The fast tier keeps the compile count minimal (each distinct static
  (tile_r, clog, depth) config is a fresh interpret-mode compile on this
  container); the full legalized grid, party-1 additive, and sharded
  start_block cases run in the slow tier.
* **VMEM footprint model** at the 16 MiB boundary — pure arithmetic on
  the engine descriptors, no compiles. The double-buffer factor must be
  the term that flips feasibility.
* **Backend resolution** (REPRO_FORCE_BACKEND) — the one probe governs
  interpret mode for every Pallas entry point, enforced both
  functionally and as a source convention.
"""
import os
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dpf, pir
import importlib

backend_mod = importlib.import_module("repro.engine.backend")
from repro.engine.kernels import ProblemShape, get_kernel
from repro.kernels import ops

RNG = np.random.default_rng(23)

LOG_N = 5
N = 1 << LOG_N
W = 2                    # item_bytes 8
L = 8

DB_WORDS = jnp.asarray(RNG.integers(0, 1 << 32, size=(N, W),
                                    dtype=np.uint32))
DB_BYTES = jnp.asarray(RNG.integers(-128, 128, size=(N, L)).astype(np.int8))
IDXS = [0, 13, 31]


def _xor_keys(party=0):
    return dpf.stack_keys([dpf.gen_keys(RNG, i, LOG_N)[party]
                           for i in IDXS])


def _add_keys(party=0):
    return dpf.stack_keys(
        [dpf.gen_keys(RNG, i, LOG_N, payload=np.array([1], np.uint32),
                      payload_mod=256)[party] for i in IDXS])


def _fused_xor(keys, db, tile_r, clog, depth, start_block=0,
               log_local=LOG_N):
    roots, t_roots = dpf.eval_roots_batch(keys, start_block, log_local,
                                          clog)
    lvl0 = keys.log_n - clog
    return ops.fused_scan_xor(db, roots, t_roots,
                              keys.cw_seed[:, lvl0:, :],
                              keys.cw_t[:, lvl0:, :],
                              tile_r=tile_r, depth=depth)


def _fused_add(keys, db, tile_r, clog, depth):
    roots, t_roots = dpf.eval_roots_batch(keys, 0, LOG_N, clog)
    lvl0 = keys.log_n - clog
    return ops.fused_scan_bytes(db, roots, t_roots,
                                keys.cw_seed[:, lvl0:, :],
                                keys.cw_t[:, lvl0:, :],
                                keys.cw_final[:, 0], party=int(keys.party),
                                tile_r=tile_r, depth=depth)


# ---------------------------------------------------------------------------
# Byte parity — fast tier (two xor compiles, one additive)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tile_r,clog,depth", [
    (8, 3, 2),       # multi-tile, double-buffered, mid-depth expand
    (32, 0, 1),      # degenerate: roots ARE the leaves (zero CW levels)
])
def test_fused_xor_parity(tile_r, clog, depth):
    keys = _xor_keys()
    bits = dpf.eval_bits_batch(keys, 0, LOG_N)
    want = jax.vmap(lambda b: pir.dpxor(DB_WORDS, b))(bits)
    got = _fused_xor(keys, DB_WORDS, tile_r, clog, depth)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_add_parity():
    keys = _add_keys()
    shares = dpf.eval_bytes_batch(keys, 0, LOG_N)
    want = pir.answer_additive_matmul(DB_BYTES, shares)
    got = _fused_add(keys, DB_BYTES, tile_r=8, clog=2, depth=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Byte parity — slow tier: full legalized grid, party 1, sharding
# ---------------------------------------------------------------------------

@pytest.mark.slow   # one interpret-mode compile per distinct config
def test_fused_xor_parity_full_grid():
    keys = _xor_keys(party=1)
    bits = dpf.eval_bits_batch(keys, 0, LOG_N)
    want = jax.vmap(lambda b: pir.dpxor(DB_WORDS, b))(bits)
    for tile_r in (8, 16, 32):
        for clog in range(tile_r.bit_length()):
            for depth in (1, 2, 4):
                d = max(1, min(depth, N // tile_r))
                got = _fused_xor(keys, DB_WORDS, tile_r, clog, d)
                np.testing.assert_array_equal(
                    np.asarray(got), np.asarray(want),
                    err_msg=f"tile={tile_r} clog={clog} depth={d}")


@pytest.mark.slow
def test_fused_add_party1_and_reconstruction():
    k0, k1 = _add_keys(0), _add_keys(1)
    got0 = _fused_add(k0, DB_BYTES, tile_r=16, clog=3, depth=2)
    got1 = _fused_add(k1, DB_BYTES, tile_r=16, clog=3, depth=2)
    sh0 = dpf.eval_bytes_batch(k0, 0, LOG_N)
    sh1 = dpf.eval_bytes_batch(k1, 0, LOG_N)
    np.testing.assert_array_equal(
        np.asarray(got0), np.asarray(pir.answer_additive_matmul(DB_BYTES,
                                                                sh0)))
    np.testing.assert_array_equal(
        np.asarray(got1), np.asarray(pir.answer_additive_matmul(DB_BYTES,
                                                                sh1)))
    # the shares reconstruct the selected rows mod 256
    rec = (np.asarray(got0) + np.asarray(got1)) % 256
    rows = np.asarray(DB_BYTES).astype(np.uint8)[IDXS]
    np.testing.assert_array_equal(rec.astype(np.uint8), rows)


@pytest.mark.slow
def test_fused_xor_sharded_start_block():
    """Shard-local evaluation: start_block offsets the GGM descent."""
    keys = _xor_keys()
    log_local = LOG_N - 2
    rows_local = 1 << log_local
    for blk in range(4):
        shard = DB_WORDS[blk * rows_local:(blk + 1) * rows_local]
        bits = dpf.eval_bits_batch(keys, blk, log_local)
        want = jax.vmap(lambda b: pir.dpxor(shard, b))(bits)
        got = _fused_xor(keys, shard, tile_r=4, clog=2, depth=2,
                         start_block=blk, log_local=log_local)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"shard {blk}")


# ---------------------------------------------------------------------------
# VMEM footprint model at the 16 MiB edge (pure arithmetic, no compiles)
# ---------------------------------------------------------------------------

def test_xor_footprint_formula():
    desc = get_kernel("xor-fused-pallas")
    shape = ProblemShape(bucket=4, rows=1 << 20, item_bytes=32)
    p = {"tile_r": 1024, "chunk_log": 8, "depth": 2}
    want = 4 * (2 * 8 * 1024 + 4 * 1024 * 27 + 4 * 8 * 1024 + 4 * 8)
    assert desc.footprint_fn(shape, p) == want


def test_vmem_boundary_double_buffer_factor():
    """At the 16 MiB edge the rotating-buffer term must be what flips
    feasibility: same tile, deeper buffering -> infeasible."""
    from repro.analysis.roofline import VMEM_BYTES
    desc = get_kernel("xor-fused-pallas")
    shape = ProblemShape(bucket=1, rows=1 << 20, item_bytes=512)
    shallow = {"tile_r": 8192, "chunk_log": 8, "depth": 2}
    deep = dict(shallow, depth=4)
    assert desc.footprint_fn(shape, shallow) <= VMEM_BYTES
    assert desc.footprint_fn(shape, deep) > VMEM_BYTES
    assert desc.feasible(shape, shallow)
    assert not desc.feasible(shape, deep)
    # the delta between the two is exactly the extra DB buffers
    extra = desc.footprint_fn(shape, deep) - desc.footprint_fn(shape,
                                                               shallow)
    assert extra == 4 * 2 * 128 * 8192   # (4-2) u32 buffers of [W, TR]


def test_add_footprint_counts_buffers():
    desc = get_kernel("gemm-fused-pallas")
    shape = ProblemShape(bucket=2, rows=1 << 16, item_bytes=64)
    f1 = desc.footprint_fn(shape, {"tile_r": 2048, "chunk_log": 8,
                                   "depth": 1})
    f3 = desc.footprint_fn(shape, {"tile_r": 2048, "chunk_log": 8,
                                   "depth": 3})
    assert f3 - f1 == 2 * 2048 * 64      # two extra int8 tiles [TR, L]


def test_legalize_couples_chunk_to_tile():
    """chunk_log can never exceed log2(tile_r): a DMA tile holds whole
    chunks; depth never exceeds the tile count."""
    desc = get_kernel("xor-fused-pallas")
    shape = ProblemShape(bucket=2, rows=256, item_bytes=16)
    p = desc.legalize_fn(shape, {"tile_r": 64, "chunk_log": 12,
                                 "depth": 8})
    assert p["tile_r"] == 64
    assert p["chunk_log"] == 6
    assert p["depth"] == 4               # 256/64 tiles
    for params in desc.candidates(shape):
        assert (1 << params["chunk_log"]) <= params["tile_r"]
        assert 1 <= params["depth"] <= max(1, shape.rows
                                           // params["tile_r"])


# ---------------------------------------------------------------------------
# REPRO_FORCE_BACKEND governs interpret mode for every Pallas entry point
# ---------------------------------------------------------------------------

def test_force_backend_resolves_interpret(monkeypatch):
    monkeypatch.setenv(backend_mod.FORCE_BACKEND_ENV, "tpu")
    assert backend_mod.resolve_interpret(None) is False
    monkeypatch.setenv(backend_mod.FORCE_BACKEND_ENV, "cpu")
    assert backend_mod.resolve_interpret(None) is True
    # explicit requests always win over the probe
    assert backend_mod.resolve_interpret(False) is False
    monkeypatch.setenv(backend_mod.FORCE_BACKEND_ENV, "tpu")
    assert backend_mod.resolve_interpret(True) is True


def test_all_pallas_wrappers_resolve_interpret():
    """Source convention: every pallas_call site in kernels/ either
    resolves via resolve_interpret at the wrapper seam or receives the
    already-resolved static bool inside a jitted body. A raw
    ``interpret=None``/hardcoded flag reaching pallas_call would silently
    decouple that kernel from REPRO_FORCE_BACKEND."""
    kdir = pathlib.Path(ops.__file__).parent
    modules = ["dpxor.py", "ggm_expand.py", "pir_matmul.py",
               "fused_scan.py"]
    for name in modules:
        src = (kdir / name).read_text()
        assert "pl.pallas_call" in src, name
        assert "resolve_interpret(interpret)" in src, (
            f"{name}: wrapper must resolve interpret through the one "
            f"backend probe (engine/backend.py)")
