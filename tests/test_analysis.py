"""HLO cost analyzer + roofline model tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import hlo_cost, roofline
from repro.compat import shard_map


def _compile(f, *shapes):
    structs = [jax.ShapeDtypeStruct(s, np.float32) for s in shapes]
    return jax.jit(f).lower(*structs).compile()


def test_flops_single_matmul():
    c = _compile(lambda a, b: a @ b, (128, 64), (64, 32))
    cost = hlo_cost.analyze(c.as_text())
    assert abs(cost.flops - 2 * 128 * 64 * 32) / cost.flops < 0.05


def test_flops_scan_multiplied():
    def f(x, w):
        def body(c, _):
            return c @ w, ()
        c, _ = jax.lax.scan(body, x, jnp.arange(13))
        return c
    c = _compile(f, (64, 64), (64, 64))
    cost = hlo_cost.analyze(c.as_text())
    expect = 13 * 2 * 64 ** 3
    assert abs(cost.flops - expect) / expect < 0.05
    assert cost.unknown_loops == 0


def test_flops_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, ()
            c2, _ = jax.lax.scan(inner, c, jnp.arange(4))
            return c2, ()
        c, _ = jax.lax.scan(outer, x, jnp.arange(3))
        return c
    c = _compile(f, (32, 32), (32, 32))
    cost = hlo_cost.analyze(c.as_text())
    expect = 12 * 2 * 32 ** 3
    assert abs(cost.flops - expect) / expect < 0.1


def test_dynamic_slice_not_full_operand():
    """Slicing one row of a big table must not count the whole table."""
    def f(table, i):
        return jax.lax.dynamic_slice_in_dim(table, 0, 1, 0)
    big = jax.ShapeDtypeStruct((4096, 1024), np.float32)
    idx = jax.ShapeDtypeStruct((), np.int32)
    c = jax.jit(f).lower(big, idx).compile()
    cost = hlo_cost.analyze(c.as_text())
    assert cost.bytes < 4096 * 1024 * 4 * 0.5   # far below full-table read


def test_roofline_terms_and_bottleneck():
    r = roofline.Roofline(name="x", n_chips=256,
                          hlo_flops=256 * 197e12,       # 1 s compute
                          hlo_bytes=256 * 819e9 * 2,    # 2 s memory
                          collective_bytes=256 * 50e9 * 0.5,
                          model_flops=0.5 * 256 * 197e12)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.step_time - 2.0) < 1e-9
    assert abs(r.mfu - 0.25) < 1e-9
    assert abs(r.useful_flop_ratio - 0.5) < 1e-9


def test_collective_parse_counts_psum():
    """An 8-way pmapped psum lowers to an all-reduce we can count."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()), ("d",))   # 1 device: still emits
    x = jax.ShapeDtypeStruct((8, 128), np.float32)

    def f(a):
        return jax.lax.psum(a, "d")

    fn = jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                               out_specs=P(None, None), check_vma=False))
    c = fn.lower(x).compile()
    cost = hlo_cost.analyze(c.as_text())
    # single-device all-reduce may fold away; just assert the parse ran
    assert cost.bytes >= 0


def test_model_flops_for():
    assert roofline.model_flops_for(10, 5, training=True) == 300
    assert roofline.model_flops_for(10, 5, training=False) == 100


def test_format_table():
    r = roofline.Roofline(name="cell", n_chips=2, hlo_flops=1e12,
                          hlo_bytes=1e12, collective_bytes=1e9,
                          model_flops=5e11)
    txt = roofline.format_table([r.to_dict()])
    assert "cell" in txt and "|" in txt
