"""Checkpoint manager: roundtrip, atomicity, retention, elastic restore."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((5,), jnp.bfloat16)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(100, tree, blocking=True)
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 100
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        tree, restored)
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_no_tmp_dirs_counted(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "step_00000009.tmp")   # simulated crash artifact
    mgr.save(3, _tree(), blocking=True)
    assert mgr.all_steps() == [3]


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree())


def test_elastic_restore_new_sharding(tmp_path):
    """Restore under different shardings (the elastic remesh path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(5, tree, blocking=True)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    shardings = {"params": {"w": NamedSharding(mesh, P()),
                            "b": NamedSharding(mesh, P())},
                 "opt": {"step": NamedSharding(mesh, P())}}
    restored, meta = mgr.restore(tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_manifest_metadata(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(8, _tree(), metadata={"config": {"name": "x"}}, blocking=True)
    with open(tmp_path / "step_00000008" / "manifest.json") as f:
        meta = json.load(f)
    assert meta["config"]["name"] == "x"
    assert meta["step"] == 8
