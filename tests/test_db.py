"""Database-plane tests: spec math, placement, views, epoched updates.

Fast tier: everything runs eagerly or through tiny elementwise jits (the
scatter/pack helpers compile in well under a second — never a serve-step
compile). The three-protocol parity test contracts per-party answers
*eagerly* against the ``ShardedDatabase`` views after ``stage``+``publish``
and checks reconstruction versus a numpy oracle with the same rows
rewritten; transfer accounting asserts the update path moves
O(rows · item_bytes), not O(db_bytes) — the acceptance bar for online
updates. The full compiled serving stack across a publish lives in the
slow tier (one ``TwoServerPIR`` session) and in ``examples/db_updates.py``
(3-server, wired into CI).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.config import PIRConfig
from repro.core import dpf, pir
from repro.core.protocol import for_config
from repro.db import DatabaseSpec, ShardedDatabase
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import MultiServerPIR, QueryScheduler

LOG_N = 6
N = 1 << LOG_N
DB = pir.make_database(np.random.default_rng(0), N, 32)
RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh()


def _fresh_db(mesh, cfg=None) -> ShardedDatabase:
    return ShardedDatabase(DB, cfg or PIRConfig(n_items=N), mesh)


def _rand_rows(rng, n_rows):
    rows = rng.choice(N, size=n_rows, replace=False)
    vals = rng.integers(0, 1 << 32, size=(n_rows, 8), dtype=np.uint32)
    return rows, vals


# ---------------------------------------------------------------------------
# DatabaseSpec: the one owner of shape/packing math
# ---------------------------------------------------------------------------

def test_spec_geometry_and_views():
    cfg = PIRConfig(n_items=N, item_bytes=32)
    spec = DatabaseSpec.from_config(cfg)
    assert (spec.item_words, spec.log_n, spec.db_bytes) == (8, LOG_N, N * 32)
    assert spec.view_shape("words") == (N, 8)
    assert spec.view_shape("bytes") == (N, 32)
    assert spec.view_struct("words").dtype == np.uint32
    assert spec.view_struct("bytes").dtype == np.int8
    with pytest.raises(KeyError, match="unknown db view"):
        spec.view_shape("float16")
    # shard math: divisibility and power-of-two rows enforced here
    assert spec.rows_per_shard(4) == N // 4
    with pytest.raises(ValueError, match="divisible"):
        spec.rows_per_shard(3)
    with pytest.raises(ValueError, match="power of two"):
        DatabaseSpec(n_items=N + 1)
    # host and device packing agree (and round-trip)
    host_bytes = spec.words_to_bytes_host(DB)
    np.testing.assert_array_equal(host_bytes, pir.db_as_bytes(DB))
    np.testing.assert_array_equal(
        np.asarray(spec.words_to_bytes_device(jnp.asarray(DB))).view(
            np.uint8), host_bytes)
    np.testing.assert_array_equal(spec.bytes_to_words_host(host_bytes), DB)


def test_spec_coerce_update_rows():
    spec = DatabaseSpec(n_items=N, item_bytes=32)
    words = RNG.integers(0, 1 << 32, size=(3, 8), dtype=np.uint32)
    np.testing.assert_array_equal(spec.coerce_rows_to_words(words), words)
    as_bytes = spec.words_to_bytes_host(words)
    np.testing.assert_array_equal(spec.coerce_rows_to_words(as_bytes), words)
    with pytest.raises(ValueError, match="2-D"):
        spec.coerce_rows_to_words(words[0])
    with pytest.raises(ValueError, match="row values"):
        spec.coerce_rows_to_words(np.zeros((3, 5), np.uint32))


# ---------------------------------------------------------------------------
# placement + shared residency
# ---------------------------------------------------------------------------

def test_chunked_placement_single_pass(mesh):
    db = _fresh_db(mesh)
    assert db.stats.n_full_placements == 1
    assert db.stats.preload_h2d_bytes == DB.nbytes
    np.testing.assert_array_equal(np.asarray(db.view("words")), DB)
    # byte view derives on device, once, lazily
    assert db.stats.n_view_packs == 0
    np.testing.assert_array_equal(
        np.asarray(db.view("bytes")).view(np.uint8), pir.db_as_bytes(DB))
    assert db.stats.n_view_packs == 1
    db.view("bytes")
    assert db.stats.n_view_packs == 1        # cached, not re-derived


def test_multiserver_shares_one_database(mesh):
    """k parties reference ONE ShardedDatabase: no k-fold host/device
    copies (the PR 4 acceptance bar). Construction compiles nothing."""
    cfg = PIRConfig(n_items=N, protocol="xor-dpf-k", n_servers=3)
    system = MultiServerPIR(DB, cfg, mesh, n_queries=2, buckets=(2,))
    assert len(system.servers) == 3
    assert all(s.db is system.db for s in system.servers)
    assert system.db.stats.n_full_placements == 1
    assert system.db.stats.preload_h2d_bytes == DB.nbytes
    assert system.epoch == 0
    # a pre-built (possibly shared) database passes straight through
    again = MultiServerPIR(system.db, cfg, mesh, n_queries=2, buckets=(2,))
    assert again.db is system.db
    assert system.db.stats.n_full_placements == 1
    # ... but a database whose spec disagrees with the config fails fast
    # at construction, not as a shape error inside the first serve step
    from repro.core.server import PIRServer
    wrong = PIRConfig(n_items=N * 2, protocol="xor-dpf-k", n_servers=3)
    with pytest.raises(ValueError, match="spec"):
        PIRServer(party=0, database=system.db, cfg=wrong, mesh=mesh,
                  n_queries=2, buckets=(2,))
    with pytest.raises(ValueError, match="required"):
        PIRServer(party=0, database=system.db)


# ---------------------------------------------------------------------------
# epoched updates: staging, dedup, incremental views, transfer accounting
# ---------------------------------------------------------------------------

def test_stage_validates_and_publish_applies_last_write_wins(mesh):
    db = _fresh_db(mesh)
    with pytest.raises(ValueError, match="out of range"):
        db.stage([N], np.zeros((1, 8), np.uint32))
    with pytest.raises(ValueError, match="mismatch"):
        db.stage([1, 2], np.zeros((1, 8), np.uint32))
    v1 = RNG.integers(0, 1 << 32, size=(1, 8), dtype=np.uint32)
    v2 = RNG.integers(0, 1 << 32, size=(1, 8), dtype=np.uint32)
    assert db.stage([9], v1) == 1
    assert db.stage([9], v2) == 2            # same row staged twice
    assert db.n_staged == 2
    assert db.publish() == 1
    assert db.n_staged == 0
    expect = DB.copy()
    expect[9] = v2                           # the later write wins
    np.testing.assert_array_equal(np.asarray(db.view("words")), expect)
    assert db.published[-1].n_staged == 2
    np.testing.assert_array_equal(db.published[-1].rows, [9])
    # publishing nothing is a no-op at the same epoch — including when
    # only zero-row stage calls arrived (no epoch churn on empty deltas)
    assert db.publish() == 1
    db.stage(np.zeros((0,), np.int64), np.zeros((0, 8), np.uint32))
    assert db.publish() == 1


def test_publish_notifies_subscribers_with_replayable_delta(mesh):
    """Multi-subscriber fan-out: every publish delivers a PublishedDelta
    whose deduped (rows, vals) replayed into a second database reproduces
    the epoch byte-for-byte (the replica plane's propagation seam)."""
    src, dst = _fresh_db(mesh), _fresh_db(mesh)
    seen = []
    unsubscribe = src.subscribe(seen.append)
    src.subscribe(lambda d: dst.stage(d.rows, d.vals) and dst.publish())
    v1 = RNG.integers(0, 1 << 32, size=(1, 8), dtype=np.uint32)
    v2 = RNG.integers(0, 1 << 32, size=(1, 8), dtype=np.uint32)
    src.stage([9], v1)
    src.stage([9], v2)                       # dedup: last write wins
    src.stage([3], v1)
    assert src.publish() == 1
    assert src.publish() == 1                # no-op publish: no callback
    assert [d.epoch for d in seen] == [1]
    np.testing.assert_array_equal(np.sort(seen[0].rows), [3, 9])
    assert seen[0].vals.shape == (2, 8)      # deduped, unpadded
    assert seen[0].n_staged == 3
    # the replaying subscriber converged to identical epoch AND contents
    assert dst.epoch == 1
    np.testing.assert_array_equal(np.asarray(dst.view("words")),
                                  np.asarray(src.view("words")))
    # unsubscribe stops delivery; the other subscriber keeps receiving
    unsubscribe()
    src.stage([0], v1)
    src.publish()
    assert [d.epoch for d in seen] == [1]
    assert dst.epoch == 2
    np.testing.assert_array_equal(np.asarray(dst.view("words")),
                                  np.asarray(src.view("words")))


def test_byte_view_incremental_after_random_writes(mesh):
    """Random row writes keep the byte view consistent WITHOUT a second
    full pack — the delta scatter maintains it in place."""
    db = _fresh_db(mesh)
    db.view("bytes")
    assert db.stats.n_view_packs == 1
    expect = DB.copy()
    rng = np.random.default_rng(23)
    for _ in range(3):
        rows, vals = _rand_rows(rng, 5)
        db.stage(rows, vals)
        db.publish()
        expect[rows] = vals
        np.testing.assert_array_equal(np.asarray(db.view("words")), expect)
        np.testing.assert_array_equal(
            np.asarray(db.view("bytes")).view(np.uint8),
            pir.db_as_bytes(expect))
    assert db.stats.n_view_packs == 1        # never re-packed from scratch
    assert db.stats.n_full_placements == 1   # never re-placed
    assert db.stats.n_publishes == 3


def test_delta_transfer_is_o_rows_not_o_db(mesh):
    """The acceptance bar: updating R rows moves O(R · item_bytes) over
    the host→device boundary, not O(db_bytes), and triggers no full
    re-pack / re-placement."""
    cfg = PIRConfig(n_items=1 << 12, item_bytes=32)
    big = pir.make_database(np.random.default_rng(1), cfg.n_items, 32)
    db = ShardedDatabase(big, cfg, make_local_mesh())
    db.view("bytes")                          # both views resident
    preload = db.stats.preload_h2d_bytes
    rows = np.asarray([5, 99, 2048, 4095])
    vals = RNG.integers(0, 1 << 32, size=(4, 8), dtype=np.uint32)
    db.stage(rows, vals)
    db.publish()
    # delta = 4 int32 indices + 4 rows of 32 B values (padded pow2: 4)
    assert db.stats.update_h2d_bytes == 4 * 4 + 4 * 32
    assert db.stats.update_h2d_bytes < cfg.db_bytes // 64
    assert db.stats.preload_h2d_bytes == preload   # no re-placement
    assert db.stats.n_full_placements == 1
    assert db.stats.n_view_packs == 1              # no re-pack
    expect = big.copy()
    expect[rows] = vals
    np.testing.assert_array_equal(np.asarray(db.view("words")), expect)


# ---------------------------------------------------------------------------
# epochs: double buffering + answer tagging across a publish
# ---------------------------------------------------------------------------

def test_epoch_double_buffer_pins_previous_epoch(mesh):
    db = _fresh_db(mesh)
    v0 = db.view("words")
    rows, vals = _rand_rows(np.random.default_rng(3), 2)
    db.stage(rows, vals)
    assert db.publish() == 1
    # the captured array is immutable: in-flight work on epoch 0 is exact
    np.testing.assert_array_equal(np.asarray(v0), DB)
    np.testing.assert_array_equal(np.asarray(db.view("words", epoch=0)), DB)
    expect = DB.copy()
    expect[rows] = vals
    np.testing.assert_array_equal(np.asarray(db.view("words")), expect)
    assert db.epoch == 1
    db.stage(rows[:1], vals[:1])
    db.publish()
    with pytest.raises(KeyError, match="not resident"):
        db.view("words", epoch=0)            # two publishes back: released


def test_scheduler_tags_answers_with_dispatch_epoch(mesh):
    """A publish landing while a batch is 'on device' neither corrupts
    nor retags it: the answer reconstructs against the pre-update DB and
    carries the pre-update epoch; later batches compute and tag against
    the new epoch (the scheduler's re-tag across a swap)."""
    db = _fresh_db(mesh)
    new_val = RNG.integers(0, 1 << 32, size=(1, 8), dtype=np.uint32)
    state = {"publish_mid_flight": True}

    def dispatch(staged):
        # the batch-local contract: the epoch rides with the dispatch
        # result, so concurrent dispatchers can never cross-tag
        epoch, views = db.snapshot(("words",))
        if state["publish_mid_flight"]:
            # the swap lands after dispatch captured its snapshot
            db.stage([0], new_val)
            db.publish()
            state["publish_mid_flight"] = False
        return views["words"], staged, epoch

    sched = QueryScheduler(
        collate=list, stage=lambda p: p, dispatch=dispatch,
        finalize=lambda raw, n: [np.asarray(raw[0])[i] for i in raw[1][:n]],
        buckets=(2,), epoch_of=lambda raw: raw[2])

    first = [sched.submit(0), sched.submit(3)]
    sched.pump()
    assert [f.epoch for f in first] == [0, 0]
    np.testing.assert_array_equal(first[0].result(0), DB[0])   # pre-update
    np.testing.assert_array_equal(first[1].result(0), DB[3])
    assert db.epoch == 1

    second = [sched.submit(0), sched.submit(3)]
    sched.pump()
    assert [f.epoch for f in second] == [1, 1]
    np.testing.assert_array_equal(second[0].result(0), new_val[0])
    np.testing.assert_array_equal(second[1].result(0), DB[3])


# ---------------------------------------------------------------------------
# update-then-query parity vs the numpy oracle, all three protocols
# ---------------------------------------------------------------------------

def _party_bits_np(party_key: dpf.DPFKey, log_n: int) -> np.ndarray:
    """One party's full selection vector, component-by-component (eager).

    Handles both plain 2-server keys (no component axis) and the k-server
    component pytrees (leaves ``[C, ...]``) without any compiled dispatch.
    """
    if party_key.root_seed.ndim == 1:          # plain key
        _, t = dpf.eval_range(party_key, 0, log_n)
        return np.asarray(t, np.uint32)
    acc = np.zeros(1 << log_n, np.uint32)
    for c in range(party_key.root_seed.shape[0]):
        comp = jax.tree_util.tree_map(lambda x, c=c: x[c], party_key)
        _, t = dpf.eval_range(comp, 0, log_n)
        acc ^= np.asarray(t, np.uint32)
    return acc


def _xor_answer_np(db_words: np.ndarray, bits: np.ndarray) -> np.ndarray:
    out = np.zeros(db_words.shape[1], np.uint32)
    for j in np.nonzero(bits)[0]:
        out ^= db_words[j]
    return out


@pytest.mark.parametrize("proto_name,n_servers", [
    ("xor-dpf-2", 2), ("additive-dpf-2", 2), ("xor-dpf-k", 3)])
def test_update_then_query_parity(mesh, proto_name, n_servers):
    """stage+publish, then per-party answers contracted eagerly against
    the protocol's declared ShardedDatabase view; reconstruction matches
    the numpy oracle for updated AND untouched rows."""
    cfg = PIRConfig(n_items=N, protocol=proto_name, n_servers=n_servers)
    proto = for_config(cfg)
    db = ShardedDatabase(DB, cfg, mesh)
    rows, vals = _rand_rows(np.random.default_rng(31), 3)
    db.stage(rows, vals)
    db.publish()
    oracle = DB.copy()
    oracle[rows] = vals

    indices = [int(rows[0]), int((rows[0] + 1) % N)]   # updated + untouched
    assert indices[1] not in rows
    view_np = np.asarray(db.view(proto.db_view))
    per_query_keys = [proto.query_gen(RNG, idx, cfg) for idx in indices]

    def one_answer(key):
        if proto.share_kind == "additive":
            shares = np.asarray(dpf.eval_bytes_batch(
                dpf.stack_keys([key]), 0, LOG_N))[0]
            return shares.astype(np.int64) @ view_np.astype(np.int64)
        return _xor_answer_np(view_np, _party_bits_np(key, LOG_N))

    answers = [
        jnp.asarray(np.stack([one_answer(keys[p]) for keys in
                              per_query_keys]).astype(
            np.int32 if proto.share_kind == "additive" else np.uint32))
        for p in range(proto.n_parties(cfg))
    ]
    rec = np.asarray(proto.reconstruct(answers))
    want = (pir.db_as_bytes(oracle)[indices]
            if proto.share_kind == "additive" else oracle[indices])
    np.testing.assert_array_equal(rec, want)


# ---------------------------------------------------------------------------
# hint lifecycle (single-server preprocessing, DESIGN.md §10)
# ---------------------------------------------------------------------------

def _lwe_db(mesh):
    cfg = PIRConfig(n_items=N, protocol="lwe-simple-1", n_servers=1)
    proto = for_config(cfg)
    db = ShardedDatabase(DB, cfg, mesh)
    db.register_hint(proto.name, proto.hint_builder(cfg),
                     proto.hint_delta(cfg))
    return db, proto, cfg


def test_hint_lazy_build_cached_per_epoch(mesh):
    from repro.core import lwe
    db, proto, cfg = _lwe_db(mesh)
    with pytest.raises(KeyError, match="unknown hint"):
        db.hint("never-registered")
    assert db.stats.n_hint_builds == 0       # lazy: nothing built yet
    h = np.asarray(db.hint(proto.name))
    assert db.stats.n_hint_builds == 1
    db.hint(proto.name)
    assert db.stats.n_hint_builds == 1       # cached, not re-derived
    # built hint matches the numpy oracle on the words view
    params = lwe.params_for(N)
    np.testing.assert_array_equal(
        h.view(np.uint32),
        lwe.hint_np(params, pir.db_as_bytes(DB)).astype(np.uint32))


def test_hint_delta_update_matches_full_recompute(mesh):
    """publish() maintains a materialized hint via the registered O(rows)
    delta — byte-for-byte equal to a full rebuild on the new words, and
    exact across dedup (same row staged twice: last write wins once)."""
    db, proto, cfg = _lwe_db(mesh)
    h0 = np.asarray(db.hint(proto.name))
    rng = np.random.default_rng(41)
    rows, vals = _rand_rows(rng, 4)
    db.stage(rows, vals)
    # restage row[0]: the delta must see ONE transition old -> final value
    v_final = rng.integers(0, 1 << 32, size=(1, 8), dtype=np.uint32)
    db.stage(rows[:1], v_final)
    db.publish()
    assert db.stats.n_hint_deltas == 1
    assert db.stats.n_hint_builds == 1       # never a full rebuild
    expect = DB.copy()
    expect[rows] = vals
    expect[rows[0]] = v_final
    want = np.asarray(proto.hint_builder(cfg)(jnp.asarray(expect)))
    got = np.asarray(db.hint(proto.name))
    np.testing.assert_array_equal(got, want)
    assert not np.array_equal(got, h0)       # the hint genuinely moved
    # and the delta-updated hint keeps delta-updating on later epochs
    rows2, vals2 = _rand_rows(np.random.default_rng(43), 2)
    db.stage(rows2, vals2)
    db.publish()
    expect[rows2] = vals2
    np.testing.assert_array_equal(
        np.asarray(db.hint(proto.name)),
        np.asarray(proto.hint_builder(cfg)(jnp.asarray(expect))))
    assert db.stats.n_hint_deltas == 2
    assert db.stats.n_hint_builds == 1


def test_hint_without_delta_dropped_and_rebuilt(mesh):
    """A hint registered with no delta fn is dropped at publish() and
    lazily rebuilt against the new epoch's words on next access."""
    db = _fresh_db(mesh, PIRConfig(n_items=N))
    # wrapping u32 column sums (jax has no x64 here; mod 2^32 is exact)
    db.register_hint("colsum", lambda words: jnp.sum(words, axis=0,
                                                     dtype=jnp.uint32))
    s0 = np.asarray(db.hint("colsum"))
    np.testing.assert_array_equal(s0, DB.sum(axis=0, dtype=np.uint32))
    assert db.stats.n_hint_builds == 1
    rows, vals = _rand_rows(np.random.default_rng(47), 3)
    db.stage(rows, vals)
    db.publish()
    assert db.stats.n_hint_deltas == 0       # no delta fn registered
    expect = DB.copy()
    expect[rows] = vals
    np.testing.assert_array_equal(np.asarray(db.hint("colsum")),
                                  expect.sum(axis=0, dtype=np.uint32))
    assert db.stats.n_hint_builds == 2       # full lazy rebuild


def test_stale_hint_cache_refreshes_on_epoch_bump(mesh):
    """The client-contract half of invalidation: a session caching the
    hint by epoch misses after publish() and fetches the fresh one; the
    retired epoch's hint stays servable for in-flight batches."""
    db, proto, cfg = _lwe_db(mesh)
    cache = {}                               # a client's epoch-keyed cache

    def client_hint(epoch):
        if epoch not in cache:
            cache[epoch] = np.asarray(db.hint(proto.name, epoch=epoch))
        return cache[epoch]

    h0 = client_hint(db.epoch)
    assert client_hint(db.epoch) is h0       # same epoch: cache hit
    rows, vals = _rand_rows(np.random.default_rng(53), 2)
    db.stage(rows, vals)
    db.publish()
    h1 = client_hint(db.epoch)               # stale cache missed: refetch
    assert not np.array_equal(h0, h1)
    # in-flight answers tagged with the retired epoch still reconstruct:
    # the old hint is pinned with the old views (double buffer)
    np.testing.assert_array_equal(
        np.asarray(db.hint(proto.name, epoch=0)), h0)
    # two publishes back the epoch is released, like views
    db.stage(rows[:1], vals[:1])
    db.publish()
    with pytest.raises(KeyError, match="not resident"):
        db.hint(proto.name, epoch=0)


# ---------------------------------------------------------------------------
# config satellite: share_kind fallback is narrow
# ---------------------------------------------------------------------------

def test_share_kind_fallback_only_for_missing_registrations(monkeypatch):
    # unregistered names still resolve by naming convention (KeyError path)
    assert PIRConfig(n_items=N, protocol="additive-frontier-9").share_kind \
        == "additive"
    assert PIRConfig(n_items=N, protocol="xor-frontier-9").share_kind == "xor"
    # ... but a real protocol-plane bug must surface, not degrade silently
    import repro.core.protocol as protocol_mod

    def boom(name):
        raise RuntimeError("protocol plane corrupted")
    monkeypatch.setattr(protocol_mod, "get", boom)
    with pytest.raises(RuntimeError, match="corrupted"):
        PIRConfig(n_items=N).share_kind


# ---------------------------------------------------------------------------
# slow tier: the full compiled serving stack across a publish
# ---------------------------------------------------------------------------

@pytest.mark.slow   # jit-compiles serve steps (~40 s each on this container)
def test_two_server_session_serves_updates(mesh):
    from repro.runtime.serve_loop import TwoServerPIR
    n = 1 << 8
    host = pir.make_database(np.random.default_rng(2), n, 32)
    cfg = PIRConfig(n_items=n, batch_queries=2)
    sys2 = TwoServerPIR(host, cfg, mesh, path="fused", n_queries=2,
                        buckets=(2,))
    idx = [7, 200]
    np.testing.assert_array_equal(sys2.query(idx), host[idx])
    new_row = RNG.integers(0, 1 << 32, size=(1, 8), dtype=np.uint32)
    sys2.update([7], new_row)
    assert sys2.publish() == 1
    expect = host.copy()
    expect[7] = new_row
    futs = [sys2.submit(i) for i in idx]
    sys2.scheduler.pump()
    np.testing.assert_array_equal(np.stack([f.result(120.0) for f in futs]),
                                  expect[idx])
    assert all(f.epoch == 1 for f in futs)
    # the update path re-used the compiled bucket: no recompiles
    assert all(s.n_compiles == 1 for s in sys2.servers)
