"""Engine-plane tests: backend probe, legal tiling, search-space parity,
plan cache robustness, and the heuristic-fallback equivalence gate.

Fast tier: everything here runs eager or through small interpret-mode
kernel jits (log N <= 6 DBs, tiny tune budgets) — no serve-step compiles.

The two load-bearing guarantees (ISSUE 5 acceptance):
  * every candidate plan in the search space produces byte-identical
    answers (the tuner can never trade correctness for speed);
  * an empty/corrupted/stale plan cache resolves to exactly the pre-engine
    ``plan_for`` choices (asserted against an inline replica of the old
    rules), so default behavior is unchanged bit-for-bit.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine
from repro.config import PIRConfig
from repro.core import pir
from repro.core import protocol as protocol_mod
from repro.core.protocol import ExecutionPlan, plan_for, resolve_plan
from repro.engine.backend import FORCE_BACKEND_ENV, legal_tile
from repro.engine.cache import PlanCache, spec_signature
from repro.engine.kernels import ProblemShape
from repro.engine.tuner import TuneBudget, plan_label
from repro.kernels import ops, ref

RNG = np.random.default_rng(23)
LOG_N = 6
N = 1 << LOG_N


# ---------------------------------------------------------------------------
# backend probe + legal tiles
# ---------------------------------------------------------------------------

def test_backend_probe_and_force_override(monkeypatch):
    monkeypatch.delenv(FORCE_BACKEND_ENV, raising=False)
    assert engine.probe_backend() == jax.default_backend()
    # kernels/ops.py interpret default and plan selection read ONE probe
    assert ops.default_interpret() == (engine.probe_backend() != "tpu")
    monkeypatch.setenv(FORCE_BACKEND_ENV, "tpu")
    assert engine.probe_backend() == "tpu"
    assert ops.default_interpret() is False
    # plan selection is pinned too: CI can force the TPU plan rules on CPU
    plan = plan_for(PIRConfig(n_items=N), 4)
    assert plan.scan == "pallas"
    monkeypatch.setenv(FORCE_BACKEND_ENV, "cpu")
    assert plan_for(PIRConfig(n_items=N), 4).scan == "jnp"


def test_backend_submodule_not_shadowed_by_reexport():
    # regression (PR 9 note): a package global named ``backend`` used to
    # shadow the submodule attribute on ``repro.engine`` (module globals
    # ARE package attrs), so ``import repro.engine.backend as m`` bound
    # the re-exported *function* instead of the module. The probe is now
    # re-exported as ``probe_backend`` and the submodule must win.
    import importlib
    import types

    import repro.engine.backend as m
    assert isinstance(m, types.ModuleType)
    assert m is importlib.import_module("repro.engine.backend")
    assert getattr(engine, "backend") is m
    # the renamed re-export is the same callable as the module's probe
    assert engine.probe_backend is m.backend
    assert engine.probe_backend() == m.backend()
    assert "backend" not in engine.__all__
    assert "probe_backend" in engine.__all__


def test_legal_tile_rules():
    # divides evenly: the request is kept
    assert legal_tile(4096, 2048, pow2=True) == 2048
    assert legal_tile(64, 2048, pow2=True) == 64
    # non-power-of-two dims: largest pow2 divisor <= request
    assert legal_tile(96, 2048, pow2=True) == 32
    assert legal_tile(96, 16, pow2=True) == 16
    # non-pow2 mode: largest divisor <= request
    assert legal_tile(1536, 1024) == 768
    assert legal_tile(192, 128) == 96
    assert legal_tile(7, 4) == 1          # prime rows: only 1 divides
    with pytest.raises(ValueError):
        legal_tile(0, 8)
    with pytest.raises(ValueError):
        legal_tile(8, 0)


def test_ops_non_pow2_shard_shapes_regression():
    """min(tile, R) used to emit illegal tiles on non-pow2 row counts —
    the engine's legal-tile computation must pick a working tiling."""
    db = jnp.asarray(RNG.integers(0, 1 << 32, size=(96, 8),
                                  dtype=np.uint32))
    bits = jnp.asarray(RNG.integers(0, 2, size=(2, 96), dtype=np.uint32))
    got = ops.dpxor(db, bits)             # default request 2048 -> tile 32
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.dpxor_ref(db, bits)))

    s = jnp.asarray(RNG.integers(-128, 128, size=(2, 192), dtype=np.int8))
    d = jnp.asarray(RNG.integers(-128, 128, size=(192, 32), dtype=np.int8))
    got = ops.pir_gemm(s, d, tile_r=128)  # 128 does not divide 192 -> 96
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.pir_matmul_ref(s, d)))


# ---------------------------------------------------------------------------
# heuristic fallback == the pre-engine plan_for, bit for bit
# ---------------------------------------------------------------------------

def _pre_engine_plan_for(cfg, n_queries, backend, chunk_log=12):
    """Inline replica of the pre-PR ``core.protocol.plan_for`` body."""
    scan = "pallas" if backend == "tpu" else "jnp"
    proto = protocol_mod.get(cfg.protocol)
    if proto.share_kind == "additive":
        # tiles were then hardcoded in kernels/ops.py: gemm tile_r=1024
        return ExecutionPlan(expand="materialize", scan=scan,
                             chunk_log=chunk_log, tile_r=1024)
    small_db = cfg.n_items <= (1 << chunk_log)
    expand = "materialize" if small_db or n_queries <= 1 else "fused"
    return ExecutionPlan(expand=expand, scan=scan, chunk_log=chunk_log)


@pytest.mark.parametrize("protocol", ["xor-dpf-2", "additive-dpf-2",
                                      "xor-dpf-k"])
def test_heuristic_reproduces_pre_engine_plan_for(protocol):
    for n_items in (1 << 10, 1 << 14, 1 << 20):
        cfg = PIRConfig(n_items=n_items, protocol=protocol, n_servers=3)
        for n_q in (1, 4, 32):
            for be in ("cpu", "tpu"):
                want = _pre_engine_plan_for(cfg, n_q, be)
                assert plan_for(cfg, n_q, backend=be) == want
                # a cache miss must resolve identically (the fallback)
                got = engine.resolve(cfg, n_q, backend_name=be)
                if engine.plan_cache().get(be, cfg.protocol,
                                           spec_signature(cfg), n_q) is None:
                    assert got == want
                    assert got.provenance == "heuristic"


def test_resolve_plan_paths_and_provenance():
    cfg = PIRConfig(n_items=N)
    forced = resolve_plan("fused", cfg, 4, chunk_log=9)
    assert forced.provenance == "forced" and forced.chunk_log == 9
    # additive forced paths pin the GEMM reduction tile to the pre-engine
    # kernel default (ops.py used 1024, the scan used 2048)
    add = resolve_plan("matmul", PIRConfig(n_items=N,
                                           protocol="additive-dpf-2"), 4)
    assert add.tile_r == 1024
    assert plan_for(cfg, 4, backend="cpu").provenance == "heuristic"


# ---------------------------------------------------------------------------
# search space: feasibility pruning + answer parity across ALL candidates
# ---------------------------------------------------------------------------

def test_candidate_space_prunes_infeasible_tiles():
    shape_ok = ProblemShape(bucket=32, rows=1 << 20, item_bytes=32)
    desc = engine.get_kernel("xor-materialize-pallas")
    tiles_ok = {p["tile_r"] for p in desc.candidates(shape_ok)}
    assert 4096 in tiles_ok               # 32q x 8w x 4096 x 4B = 4 MB: fits
    shape_big = ProblemShape(bucket=256, rows=1 << 20, item_bytes=32)
    tiles_big = {p["tile_r"] for p in desc.candidates(shape_big)}
    assert 4096 not in tiles_big          # 256q: 32 MB intermediate: pruned
    assert 512 in tiles_big               # but the space never goes empty
    # pruning happens before measurement: candidates() is pure arithmetic
    assert all(desc.feasible(shape_big, {"tile_r": t}) for t in tiles_big)


def test_fused_chunk_space_clips_to_shard():
    cands = engine.get_kernel("xor-fused").candidates(
        ProblemShape(bucket=4, rows=N, item_bytes=32))
    logs = {p["chunk_log"] for p in cands}
    assert logs == {LOG_N}                # chunks > shard are degenerate


def test_candidate_plans_cover_registered_kernels():
    """Every registered serve kernel of a share algebra contributes at
    least one candidate, and tile fields arrive legalized (fast-tier
    structural complement of the slow parity sweep below)."""
    cfg = PIRConfig(n_items=N)
    names = {(p.expand, p.scan) for p in engine.candidate_plans(cfg, 2)}
    assert names == {("materialize", "jnp"), ("materialize", "pallas"),
                     ("fused", "jnp"), ("fused-pallas", "pallas")}
    for p in engine.candidate_plans(cfg, 2):
        if p.scan == "pallas":
            assert N % p.tile_r == 0 and p.tile_r & (p.tile_r - 1) == 0
        if p.expand == "fused-pallas":
            # megakernel coupling: one DMA tile holds whole chunks, and
            # the rotation never exceeds the tile count
            assert (1 << p.chunk_log) <= p.tile_r
            assert 1 <= p.depth <= max(1, N // p.tile_r)
    cfga = PIRConfig(n_items=N, protocol="additive-dpf-2")
    names_a = {(p.expand, p.scan) for p in engine.candidate_plans(cfga, 2)}
    assert names_a == {("materialize", "jnp"), ("materialize", "pallas"),
                       ("fused-pallas", "pallas")}
    for p in engine.candidate_plans(cfga, 2):
        if p.scan == "pallas" and p.expand == "materialize":
            assert N % p.tile_r == 0 and 2 % p.tile_q == 0 \
                and 32 % p.tile_l == 0


def test_lwe_gemm_candidates_cover_and_legalize():
    """The LWE GEMM rides the engine like the additive GEMM: jnp + pallas
    descriptors contribute candidates with legalized tiles."""
    cfg = PIRConfig(n_items=N, protocol="lwe-simple-1", n_servers=1)
    plans = engine.candidate_plans(cfg, 2)
    names = {(p.expand, p.scan) for p in plans}
    assert names == {("materialize", "jnp"), ("materialize", "pallas")}
    for p in plans:
        if p.scan == "pallas":
            assert N % p.tile_r == 0 and 2 % p.tile_q == 0 \
                and 32 % p.tile_l == 0


def test_lwe_gemm_feasibility_prunes_before_int8_gemm():
    """int32 operands: the LWE GEMM's VMEM footprint is 4x the int8
    streams, so the same tile crosses the budget earlier. At the boundary
    the int8 descriptor accepts a tile the LWE descriptor prunes."""
    lwe_desc = engine.get_kernel("lwe-gemm-pallas")
    int8_desc = engine.get_kernel("gemm-pallas")
    shape = ProblemShape(bucket=16, rows=1 << 20, item_bytes=256)
    # boundary tile: A = tr*(tq+tl) = 4.46 MB of streamed blocks ->
    # int8 ~2A = 8.9 MB fits the 16 MiB budget, int32 ~8A = 35.7 MB not
    tile = {"tile_q": 16, "tile_r": 16384, "tile_l": 256}
    assert int8_desc.feasible(shape, tile)
    assert not lwe_desc.feasible(shape, tile)
    # the shipped ladder itself never goes empty for either kernel
    assert lwe_desc.candidates(shape)
    assert {tuple(sorted(c.items())) for c in lwe_desc.candidates(shape)} \
        <= {tuple(sorted(c.items())) for c in int8_desc.candidates(shape)}


def test_lwe_plan_resolution_through_engine(tmp_path, monkeypatch):
    """ISSUE 6 acceptance: the LWE GEMM plan resolves through the engine —
    heuristic on a cache miss, tuned provenance in plan_report on a hit."""
    from repro.core.server import BucketedServeFns
    from repro.engine.kernels import descriptor_for_plan
    from repro.launch.mesh import make_local_mesh
    cfg = PIRConfig(n_items=N, protocol="lwe-simple-1", n_servers=1)
    path = str(tmp_path / "plans.json")
    tuned = ExecutionPlan(expand="materialize", scan="jnp", tile_r=512,
                          tile_q=8, tile_l=128, provenance="tuned")
    c = PlanCache(path)
    c.put(engine.probe_backend(), cfg.protocol, spec_signature(cfg), 2, tuned)
    c.save()
    monkeypatch.setenv("REPRO_PLAN_CACHE", path)
    engine.plan_cache(reload=True)
    try:
        # cache miss (bucket 4): lwe shares the additive GEMM heuristic
        # (materialize + GEMM reduction tile) and maps onto the lwe kernels
        miss = engine.resolve(cfg, 4, backend_name="cpu")
        assert miss.provenance == "heuristic"
        assert (miss.expand, miss.scan) == ("materialize", "jnp")
        assert descriptor_for_plan(miss, "lwe").name == "lwe-gemm-jnp"
        assert descriptor_for_plan(
            ExecutionPlan(scan="pallas"), "lwe").name == "lwe-gemm-pallas"
        # cache hit (bucket 2) -> tuned provenance through plan_report
        b = BucketedServeFns(cfg, make_local_mesh(), buckets=(2,),
                             path=None)
        rep = b.plan_report()[2]
        assert rep["provenance"] == "tuned"
        assert b.plan_for_bucket(2).tile_r == 512
        assert rep["predicted_step_bytes"] > 0
        assert b.n_compiles == 0           # resolution never lowers
    finally:
        monkeypatch.delenv("REPRO_PLAN_CACHE")
        engine.plan_cache(reload=True)


def test_ggm_descriptor_registered_with_space():
    desc = engine.get_kernel("ggm-expand")
    assert not desc.serve                 # tuned standalone, not in plans
    cands = desc.candidates(ProblemShape(bucket=1, rows=1 << 16,
                                         item_bytes=4))
    assert {p["tile"] for p in cands} <= {512, 2048, 8192, 65536}
    assert cands                          # something survives pruning


@pytest.mark.slow          # ~30 s of XLA compile per candidate plan here
@pytest.mark.parametrize("protocol,n_servers", [
    ("xor-dpf-2", 2), ("additive-dpf-2", 2), ("xor-dpf-k", 3),
    ("lwe-simple-1", 1),
])
def test_all_candidate_plans_answer_identically(protocol, n_servers):
    """Byte parity across the whole search space, per registered protocol:
    whatever the tuner picks, the answer shares cannot change.

    Slow tier: each candidate plan is a fresh jit of ``answer_local``
    (~30 s compile on this container). The fast tier keeps per-kernel
    oracle parity (tests/test_kernels.py, tests/test_protocols.py) and
    ``test_candidate_plans_cover_registered_kernels`` below; the CI gate
    additionally measures two tunes end-to-end
    (``python -m repro.engine --smoke``)."""
    cfg = PIRConfig(n_items=N, protocol=protocol, n_servers=n_servers)
    proto = protocol_mod.get(cfg.protocol)
    db_words = pir.make_database(np.random.default_rng(5), N, 32)
    from repro.db import DatabaseSpec
    db = jnp.asarray(DatabaseSpec.from_config(cfg)
                     .pack_host(db_words, proto.db_view))
    keys = pir.batch_queries(np.random.default_rng(6), [3, N - 2], cfg)[0]

    plans = engine.candidate_plans(cfg, 2)
    assert len(plans) >= 2                # always >1 way to run a step
    ref_ans = None
    for plan in plans:
        fn = jax.jit(lambda d, k, p=plan: proto.answer_local(d, k, 0,
                                                             LOG_N, p))
        ans = np.asarray(jax.block_until_ready(fn(db, keys)))
        if ref_ans is None:
            ref_ans = ans
        else:
            np.testing.assert_array_equal(
                ans, ref_ans, err_msg=f"plan {plan_label(plan)} diverged")


# ---------------------------------------------------------------------------
# plan cache: round-trip, corruption, stale schema
# ---------------------------------------------------------------------------

def test_plan_cache_roundtrip(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path)
    plan = ExecutionPlan(expand="fused", scan="jnp", chunk_log=10,
                         tile_r=512, provenance="tuned")
    cfg = PIRConfig(n_items=N)
    cache.put("cpu", cfg.protocol, spec_signature(cfg), 4, plan,
              meta={"tuned_s": 0.001})
    assert cache.save() is not None
    re = PlanCache(path)
    hit = re.get("cpu", cfg.protocol, spec_signature(cfg), 4)
    assert hit == plan and hit.provenance == "tuned"
    assert re.get("cpu", cfg.protocol, spec_signature(cfg), 8) is None
    assert re.get("tpu", cfg.protocol, spec_signature(cfg), 4) is None


def test_engine_resolve_uses_cache_hit(tmp_path, monkeypatch):
    path = str(tmp_path / "plans.json")
    cfg = PIRConfig(n_items=N)
    tuned = ExecutionPlan(expand="fused", scan="jnp", chunk_log=5,
                          provenance="tuned")
    c = PlanCache(path)
    c.put("cpu", cfg.protocol, spec_signature(cfg), 4, tuned)
    c.save()
    monkeypatch.setenv("REPRO_PLAN_CACHE", path)
    monkeypatch.setenv(FORCE_BACKEND_ENV, "cpu")
    engine.plan_cache(reload=True)
    try:
        got = engine.resolve(cfg, 4, collective="butterfly")
        assert got.provenance == "tuned"
        # tuned tiling survives; only the (untuned) collective is caller's
        assert got.chunk_log == 5 and got.collective == "butterfly"
        # other buckets still miss -> heuristic
        assert engine.resolve(cfg, 8).provenance == "heuristic"
        # the serving stack resolves through the same seam
        assert resolve_plan(None, cfg, 4).provenance == "tuned"
        assert resolve_plan("auto", cfg, 8).provenance == "heuristic"
    finally:
        monkeypatch.delenv("REPRO_PLAN_CACHE")
        monkeypatch.delenv(FORCE_BACKEND_ENV)
        engine.plan_cache(reload=True)


@pytest.mark.parametrize("payload", [
    "{not json at all",                                        # corrupted
    json.dumps({"schema": 999, "plans": {}}),                  # stale schema
    json.dumps({"schema": 1, "plans": {"k": {"plan": {
        "expand": "materialize", "scan": "jnp", "warp": 9}}}}),  # bad field
    json.dumps({"schema": 1, "plans": []}),                    # malformed
])
def test_plan_cache_degrades_to_heuristic(tmp_path, monkeypatch, payload):
    path = str(tmp_path / "plans.json")
    with open(path, "w") as f:
        f.write(payload)
    cache = PlanCache(path)                # must not raise
    assert len(cache) == 0
    assert cache.load_error is not None
    monkeypatch.setenv("REPRO_PLAN_CACHE", path)
    engine.plan_cache(reload=True)
    try:
        cfg = PIRConfig(n_items=N)
        got = engine.resolve(cfg, 4, backend_name="cpu")
        assert got == plan_for(cfg, 4, backend="cpu")
        assert got.provenance == "heuristic"
    finally:
        monkeypatch.delenv("REPRO_PLAN_CACHE")
        engine.plan_cache(reload=True)


def test_plan_cache_disabled_via_env(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", "off")
    assert engine.cache_path() is None
    cache = engine.plan_cache(reload=True)
    assert cache.path is None and cache.save() is None
    monkeypatch.delenv("REPRO_PLAN_CACHE")
    engine.plan_cache(reload=True)


# ---------------------------------------------------------------------------
# measured tuner (tiny budget) + build-time plan resolution
# ---------------------------------------------------------------------------

@pytest.mark.slow          # two answer_local compiles (~30 s each here)
def test_tuner_tiny_budget_picks_no_worse_than_heuristic(tmp_path):
    cfg = PIRConfig(n_items=1 << 8, item_bytes=32)
    cache = PlanCache(str(tmp_path / "plans.json"))
    budget = TuneBudget(max_candidates=1, warmup=1, iters=1,
                        max_seconds=60.0)
    res = engine.tune(cfg, 2, budget=budget, cache=cache)
    assert res.plan.provenance == "tuned"
    assert res.tuned_s <= res.heuristic_s + 1e-9
    assert plan_label(res.heuristic) in res.timings
    # the winner was persisted under the engine's cache key
    cache.save()
    hit = PlanCache(cache.path).get(engine.probe_backend(), cfg.protocol,
                                    spec_signature(cfg), 2)
    assert hit == res.plan


def test_bucketed_serve_fns_resolve_plans_at_build_time():
    """Plan resolution is per bucket and needs no compile: plan_for_bucket
    and plan_report work before any serve step is built."""
    from repro.core.server import BucketedServeFns
    from repro.launch.mesh import make_local_mesh
    cfg = PIRConfig(n_items=N)
    b = BucketedServeFns(cfg, make_local_mesh(), buckets=(2, 4),
                         path=None)
    assert b.n_compiles == 0
    p2, p4 = b.plan_for_bucket(2), b.plan_for_bucket(4)
    assert p2 == resolve_plan(None, cfg, 2)
    assert p4 == resolve_plan(None, cfg, 4)
    assert b.plan_for_bucket(2) is p2      # cached: one resolution/bucket
    rep = b.plan_report()
    assert set(rep) == {2, 4}
    for row in rep.values():
        assert row["provenance"] in ("heuristic", "tuned")
        assert row["predicted_step_bytes"] > 0
    assert b.n_compiles == 0               # nothing was lowered for this


def test_plan_report_handles_additive_fused_path():
    """Regression: an additive protocol under the legacy ``path="fused"``
    (dryrun's default) yields a fused/jnp plan that the GEMM ignores —
    plan_report/descriptor mapping must follow answer_local dispatch
    (scan only) instead of raising KeyError."""
    from repro.core.server import BucketedServeFns
    from repro.engine.kernels import descriptor_for_plan
    from repro.launch.mesh import make_local_mesh
    cfg = PIRConfig(n_items=N, protocol="additive-dpf-2")
    plan = resolve_plan("fused", cfg, 2)
    assert descriptor_for_plan(plan, "additive").name == "gemm-jnp"
    b = BucketedServeFns(cfg, make_local_mesh(), buckets=(2,), path="fused")
    rep = b.plan_report()[2]
    assert rep["provenance"] == "forced"
    assert rep["predicted_step_bytes"] > 0


def test_predicted_bytes_models_are_sane():
    cfg = PIRConfig(n_items=1 << 14)
    fused = ExecutionPlan(expand="fused", scan="jnp")
    mat_pl = ExecutionPlan(expand="materialize", scan="pallas")
    rep_f = engine.plan_report(cfg, fused, 8)
    rep_m = engine.plan_report(cfg, mat_pl, 8)
    # the Pallas scan reads the DB once per batch; the fused path streams
    # it once per query -> strictly more modeled traffic at Q=8
    assert rep_f["predicted_step_bytes"] > rep_m["predicted_step_bytes"]
    assert rep_m["provenance"] == "heuristic"
