"""Serving frontend: dynamic batching, pipelining, straggler shedding.

Fast tier: the scheduler's control plane driven by fake collate/stage/
dispatch/finalize callables (no XLA compiles, deterministic). Slow tier:
the real two-party protocol through the scheduler — ragged batch sizes,
bucket-cache reuse, and the streaming session API — sharing one pair of
compiled serve steps across the module (compiles cost ~40 s each on this
container).
"""
import threading
import time

import numpy as np
import pytest

from repro.config import PIRConfig
from repro.core import dpf, pir
from repro.launch.mesh import make_local_mesh
from repro.runtime.fault import StragglerMonitor
from repro.runtime.serve_loop import (DEFAULT_MAX_WAIT_S, AnswerFuture,
                                      QueryScheduler, TwoServerPIR)

# ---------------------------------------------------------------------------
# control plane (fast: fake data plane)
# ---------------------------------------------------------------------------


def make_fake_scheduler(log=None, buckets=(2, 4), n_clusters=1, **kw):
    """Scheduler whose 'device' doubles each item; logs stage/dispatch/
    finalize events so tests can assert pipeline interleaving."""
    log = log if log is not None else []

    def collate(items):
        return list(items)

    def stage(payload):
        log.append(("stage", tuple(payload)))
        # padding rule: replicate the last item up to the bucket
        b = next(bb for bb in sorted(buckets) if bb >= len(payload))
        return payload + [payload[-1]] * (b - len(payload))

    def dispatch(staged):
        log.append(("dispatch", tuple(staged)))
        return [x * 2 for x in staged]

    def finalize(raw, n):
        log.append(("finalize", tuple(raw[:n])))
        return raw[:n]

    return QueryScheduler(collate=collate, stage=stage, dispatch=dispatch,
                          finalize=finalize, buckets=buckets,
                          n_clusters=n_clusters, **kw), log


def test_coalesce_pad_and_answer_order():
    sched, _ = make_fake_scheduler(buckets=(2, 4))
    futs = [sched.submit(i) for i in range(5)]       # 4 cut eagerly, 1 left
    n = sched.pump()                                 # flush cuts the tail
    assert n == 5
    assert [f.result(0) for f in futs] == [0, 2, 4, 6, 8]
    assert sched.stats.batches == 2
    assert sched.stats.bucket_counts == {4: 1, 2: 1}
    assert sched.stats.padded == 1                   # 1 query in a 2-bucket
    assert 0 < sched.stats.pad_fraction < 1


def test_double_buffer_stages_next_before_completing_current():
    sched, log = make_fake_scheduler(buckets=(2,))
    for i in range(6):
        sched.submit(i)                              # three 2-query batches
    sched.pump()
    kinds = [k for k, _ in log]
    # batch 2 must be staged AND dispatched before batch 1 finalizes
    assert kinds.index("finalize") > kinds.index("dispatch", 1)
    assert kinds == ["stage", "dispatch", "stage", "dispatch", "finalize",
                     "stage", "dispatch", "finalize", "finalize"]


def test_ragged_bucket_selection():
    sched, _ = make_fake_scheduler(buckets=(2, 4, 8))
    futs = [sched.submit(i) for i in range(3)]
    sched.pump()
    assert [f.result(0) for f in futs] == [0, 2, 4]
    assert sched.stats.bucket_counts == {4: 1}       # 3 -> smallest cover
    assert sched.stats.padded == 1


def test_straggler_reassignment_sheds_queued_batches():
    mon = StragglerMonitor(factor=2.0, alpha=1.0)
    mon.record("cluster0", 50.0)                     # cluster0 is flagged
    mon.record("cluster1", 1.0)
    mon.record("cluster2", 1.1)
    sched, _ = make_fake_scheduler(buckets=(2,), n_clusters=3, monitor=mon)
    for i in range(12):                              # 6 batches round-robin
        sched.submit(i)
    sched.flush()
    assert len(sched.queues["cluster0"]) == 2
    moved = sched.rebalance()
    assert moved == 2
    assert sched.stats.reassignments == 2
    assert sched.queues["cluster0"] == []
    relocated = [b for lane in ("cluster1", "cluster2")
                 for b in sched.queues[lane]]
    assert len(relocated) == 6                       # nothing lost
    for lane in ("cluster1", "cluster2"):
        for b in sched.queues[lane]:
            assert b.cluster == lane                 # ownership rewritten
    # queued work still completes after shedding
    assert sched.pump() == 12


def test_failure_propagates_to_futures():
    def boom(raw, n):
        raise RuntimeError("device lost")
    sched = QueryScheduler(collate=list, stage=lambda p: p,
                           dispatch=lambda s: s, finalize=boom,
                           buckets=(2,))
    futs = [sched.submit(i) for i in range(2)]
    with pytest.raises(RuntimeError):
        sched.pump()
    with pytest.raises(RuntimeError, match="device lost"):
        futs[0].result(0)
    assert futs[1].done()


def test_background_session_thread():
    sched, _ = make_fake_scheduler(buckets=(2, 4), max_wait_s=0.001)
    sched.start()
    try:
        futs = [sched.submit(i) for i in range(7)]
        assert [f.result(10.0) for f in futs] == [2 * i for i in range(7)]
        # under-full tail was cut by the max_wait timer, not lost
        assert sched.stats.answered == 7
    finally:
        sched.stop()
    assert not sched.running
    # stop() drains: a post-stop pump has nothing left
    assert sched.pump() == 0


def test_submit_after_stop_raises():
    """submit() on a stopped session must raise, not enqueue into a dead
    loop (the future would otherwise never resolve)."""
    sched, _ = make_fake_scheduler(buckets=(2,), max_wait_s=0.001)
    sched.start()
    fut = sched.submit(1)
    sched.stop()
    assert fut.result(10.0) == 2              # stop() drains in-flight work
    with pytest.raises(RuntimeError, match="stop"):
        sched.submit(2)
    assert sched.pump() == 0                  # pump stays a harmless no-op
    # start() reopens the session: submit works again, then closes again
    sched.start()
    fut2 = sched.submit(3)
    sched.stop()
    assert fut2.result(10.0) == 6
    with pytest.raises(RuntimeError, match="stop"):
        sched.submit(4)
    # a never-started scheduler keeps the synchronous submit+pump mode
    sync_sched, _ = make_fake_scheduler(buckets=(2,))
    sync_sched.stop()                         # no-op: nothing ran yet
    futs = [sync_sched.submit(i) for i in range(2)]
    sync_sched.pump()
    assert [f.result(0) for f in futs] == [0, 2]


def test_submit_after_thread_death_raises():
    """A dead (errored) session thread must also reject new submits."""
    def boom(raw, n):
        raise RuntimeError("device lost")
    sched = QueryScheduler(collate=list, stage=lambda p: p,
                           dispatch=lambda s: s, finalize=boom,
                           buckets=(1,), max_wait_s=0.001)
    sched.start()
    with pytest.raises(RuntimeError, match="device lost"):
        sched.submit(1).result(timeout=30.0)
    deadline = time.monotonic() + 30.0
    while sched.running and time.monotonic() < deadline:
        time.sleep(0.01)                      # thread exits after _fail
    with pytest.raises(RuntimeError, match="stop"):
        sched.submit(2)


def test_session_thread_death_resolves_every_future():
    """A data-plane failure must fail ALL outstanding futures, not hang
    the clients whose batches were queued behind the poisoned one."""
    def boom(raw, n):
        raise RuntimeError("poisoned batch")
    sched = QueryScheduler(collate=list, stage=lambda p: p,
                           dispatch=lambda s: s, finalize=boom,
                           buckets=(2,), max_wait_s=0.001)
    futs = [sched.submit(i) for i in range(6)]     # 3 batches outstanding
    sched.start()
    for f in futs:
        with pytest.raises(RuntimeError, match="poisoned batch"):
            f.result(timeout=30.0)
    sched.stop()


def test_shed_never_assigns_onto_idle_stragglers():
    """A flagged lane with an empty queue is still slow: it must not be a
    reassignment receiver."""
    mon = StragglerMonitor(factor=2.0, alpha=1.0)
    for lane, lat in (("c0", 100.0), ("c1", 100.0), ("c2", 1.0),
                      ("c3", 1.0), ("c4", 1.0)):
        mon.record(lane, lat)
    assert sorted(mon.stragglers()) == ["c0", "c1"]
    queues = {"c0": [], "c1": ["a", "b"], "c2": [], "c3": [], "c4": []}
    out, moved = mon.shed_stragglers(queues)
    assert moved == 2
    assert out["c0"] == [] and out["c1"] == []     # c0 received nothing
    assert sorted(sum((out[c] for c in ("c2", "c3", "c4")), [])) == ["a", "b"]


def test_two_server_facade_rejects_k_party_protocols_before_building():
    """The alias validates up front — no k DB replicas built just to
    throw away on the ValueError."""
    from repro.config import PIRConfig
    from repro.launch.mesh import make_local_mesh
    cfg = PIRConfig(n_items=1 << 6, protocol="xor-dpf-k", n_servers=3)
    db = pir.make_database(np.random.default_rng(0), 1 << 6, 32)
    with pytest.raises(ValueError, match="2-party"):
        TwoServerPIR(db, cfg, make_local_mesh(), n_queries=2, buckets=(2,))


def test_answer_future_timeout():
    fut = AnswerFuture()
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)
    fut.set_result(41)
    assert fut.done() and fut.result() == 41


def test_answer_future_first_wins_and_callbacks():
    """First resolution wins; later set_result/set_exception are ignored
    (what makes the router's kill-vs-complete race benign). Callbacks
    fire exactly once, immediately when already done."""
    fut = AnswerFuture()
    seen = []
    fut.add_done_callback(lambda f: seen.append(f.result(0)))
    assert fut.set_result(1) is True
    assert fut.set_result(2) is False            # ignored
    assert fut.set_exception(RuntimeError("late")) is False
    assert fut.result(0) == 1 and fut.exception() is None
    assert seen == [1]
    fut.add_done_callback(lambda f: seen.append(f.result(0)))
    assert seen == [1, 1]                        # immediate on a done future
    # exception-first symmetric case
    bad = AnswerFuture()
    bad.set_exception(RuntimeError("dead"))
    assert bad.set_result(3) is False
    assert isinstance(bad.exception(), RuntimeError)


def test_queue_depth_counts_pending_queued_and_inflight():
    sched, _ = make_fake_scheduler(buckets=(2, 4))
    assert sched.queue_depth == 0
    for i in range(5):                           # 4 cut into a lane, 1 pending
        sched.submit(i)
    assert sched.queue_depth == 5                # pad slots excluded
    sched.pump()
    assert sched.queue_depth == 0


def test_drain_handoff_moves_undispatched_futures():
    """Graceful leave: queued + pending pairs come back FIFO with their
    ORIGINAL futures; resubmitting them under future= on another
    scheduler resolves the same handles the clients already hold."""
    src, _ = make_fake_scheduler(buckets=(2, 4))
    futs = [src.submit(i) for i in range(5)]     # batch of 4 + 1 pending
    pairs = src.drain_handoff()
    assert [item for item, _ in pairs] == [0, 1, 2, 3, 4]   # FIFO
    assert [f for _, f in pairs] == futs                    # same handles
    with pytest.raises(RuntimeError, match="stop"):
        src.submit(9)                            # intake closed
    assert src.pump() == 0                       # nothing left behind
    dst, _ = make_fake_scheduler(buckets=(2, 4))
    for item, fut in pairs:
        assert dst.submit(item, future=fut) is fut
    dst.pump()
    assert [f.result(0) for f in futs] == [0, 2, 4, 6, 8]


def test_kill_fails_all_outstanding_first_wins():
    sched, _ = make_fake_scheduler(buckets=(2, 4))
    futs = [sched.submit(i) for i in range(5)]
    done_early = futs[0]
    done_early.set_result("beat the kill")       # completes before the kill
    sched.kill(RuntimeError("replica lost"))
    for f in futs[1:]:
        assert f.done()
        with pytest.raises(RuntimeError, match="replica lost"):
            f.result(0)
    assert done_early.result(0) == "beat the kill"   # first-wins preserved
    with pytest.raises(RuntimeError, match="stop"):
        sched.submit(9)


def test_kill_aborts_running_session_and_resolves_everything():
    sched, _ = make_fake_scheduler(buckets=(2,), max_wait_s=60.0)
    sched.start()
    try:
        futs = [sched.submit(i) for i in range(3)]   # 1 batch + 1 pending
        sched.kill(RuntimeError("injected fault"))
        for f in futs:
            with pytest.raises(RuntimeError, match="injected fault"):
                f.result(timeout=30.0)
        deadline = time.monotonic() + 30.0
        while sched.running and time.monotonic() < deadline:
            time.sleep(0.01)
        assert not sched.running                 # loop aborted, not hung
    finally:
        sched.stop()


def test_scheduler_heartbeat_fires_per_pump_and_loop():
    beats = []
    sched, _ = make_fake_scheduler(buckets=(2,), heartbeat=lambda:
                                   beats.append(1))
    sched.submit(0), sched.submit(1)
    sched.pump()
    assert len(beats) >= 1                       # pump beats
    n = len(beats)
    sched.start()
    try:
        fut = sched.submit(2)
        sched.submit(3)
        fut.result(timeout=30.0)
    finally:
        sched.stop()
    assert len(beats) > n                        # session loop beats too


def test_pad_keys_replicates_last_key():
    k0, _ = dpf.gen_keys(np.random.default_rng(0), 3, 5)
    batch = dpf.stack_keys([k0, k0])
    padded = dpf.pad_keys(batch, 4)
    assert dpf.n_queries_of(padded) == 4
    np.testing.assert_array_equal(np.asarray(padded.root_seed[3]),
                                  np.asarray(batch.root_seed[-1]))
    assert padded.cw_seed.shape == (4,) + batch.cw_seed.shape[1:]
    with pytest.raises(ValueError):
        dpf.pad_keys(batch, 1)


# ---------------------------------------------------------------------------
# data plane (slow: real two-party protocol, shared compiled steps)
# ---------------------------------------------------------------------------

LOG_N = 8
N = 1 << LOG_N


@pytest.fixture(scope="module")
def system():
    db = pir.make_database(np.random.default_rng(0), N, 32)
    cfg = PIRConfig(n_items=N, item_bytes=32, batch_queries=4)
    sys2 = TwoServerPIR(db, cfg, make_local_mesh(), path="fused",
                        n_queries=4, buckets=(4,))
    return sys2, db


@pytest.mark.slow
def test_ragged_traffic_padded_answers_correct(system):
    """Batch sizes off the bucket grid: padded slots never corrupt answers."""
    sys2, db = system
    for idx in ([3], [9, 200, N - 1], [0, 1, 2, 3]):   # 1, 3, 4 -> bucket 4
        np.testing.assert_array_equal(sys2.query(idx), db[idx])
    assert sys2.scheduler.stats.padded >= 3 + 1        # 1->4 and 3->4 pads


@pytest.mark.slow
def test_bucket_cache_no_recompile_on_repeat_sizes(system):
    """Every ragged size maps onto the one compiled bucket: no recompiles."""
    sys2, db = system
    sys2.query([5])                                    # warm the bucket cache
    before = [s.n_compiles for s in sys2.servers]
    for idx in ([7], [8, 9], [1, 2, 3], [4, 5, 6, 7], [250]):
        np.testing.assert_array_equal(sys2.query(idx), db[idx])
    assert [s.n_compiles for s in sys2.servers] == before
    assert all(c == 1 for c in before)                 # one bucket, one lower


@pytest.mark.slow
def test_streaming_session_reconciles_async(system):
    """submit(index) futures resolve correctly from the session thread."""
    sys2, db = system
    indices = [5, 77, 250, 0, 131, 17]
    with sys2:
        futs = [sys2.submit(i) for i in indices]
        rows = [f.result(timeout=120.0) for f in futs]
    for i, r in zip(indices, rows):
        np.testing.assert_array_equal(r, db[i])
    assert not sys2.scheduler.running
