"""Data pipeline determinism/sharding + bit-packing roundtrips."""
import numpy as np
import pytest
from _prop import given, settings, st

import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.configs import SMOKES
from repro.crypto.packing import (bytes_to_words, pack_bits_to_words,
                                  unpack_words_to_bits, words_to_bytes)
from repro.data.pipeline import QueryPipeline, TokenPipeline

SHAPE = ShapeConfig(name="t", seq_len=16, global_batch=8, kind="train")


def test_tokens_deterministic_and_step_dependent():
    p = TokenPipeline(SMOKES["granite-3-2b"], SHAPE, seed=1)
    a1 = p.tokens(0)
    a2 = TokenPipeline(SMOKES["granite-3-2b"], SHAPE, seed=1).tokens(0)
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(a1, p.tokens(1))
    assert a1.shape == (8, 16)
    assert a1.min() >= 0 and a1.max() < SMOKES["granite-3-2b"].vocab


def test_host_shards_are_disjoint_streams():
    ps = [TokenPipeline(SMOKES["granite-3-2b"], SHAPE, seed=1,
                        process_index=i, num_processes=4) for i in range(4)]
    batches = [p.tokens(0) for p in ps]
    assert batches[0].shape == (2, 16)
    assert not np.array_equal(batches[0], batches[1])


def test_modality_stub_batches():
    vlm = SMOKES["llava-next-34b"]
    p = TokenPipeline(vlm, SHAPE, seed=0)
    b = p.batch(0)
    assert b["prefix_embeds"].shape == (8, vlm.n_frontend_tokens,
                                        vlm.d_model)
    assert b["tokens"].shape == (8, 16 - vlm.n_frontend_tokens)
    audio = SMOKES["whisper-small"]
    b = TokenPipeline(audio, SHAPE, seed=0).batch(0)
    assert b["frame_embeds"].shape == (8, audio.encoder_len, audio.d_model)


def test_query_pipeline():
    qp = QueryPipeline(n_items=1 << 10, batch=32, seed=3)
    i1, i2 = qp.indices(0), qp.indices(0)
    np.testing.assert_array_equal(i1, i2)
    assert i1.shape == (32,)
    assert (i1 < (1 << 10)).all()


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=8))
def test_words_bytes_roundtrip(k):
    rng = np.random.default_rng(k)
    w = jnp.asarray(rng.integers(0, 1 << 32, size=(3, k), dtype=np.uint32))
    back = bytes_to_words(words_to_bytes(w))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=4))
def test_bits_words_roundtrip(k):
    rng = np.random.default_rng(k)
    bits = jnp.asarray(rng.integers(0, 2, size=(2, 32 * k),
                                    dtype=np.uint32))
    back = unpack_words_to_bits(pack_bits_to_words(bits))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(bits))


def test_packing_rejects_bad_sizes():
    with pytest.raises(ValueError):
        bytes_to_words(jnp.zeros((3,), jnp.uint8))
    with pytest.raises(ValueError):
        pack_bits_to_words(jnp.zeros((31,), jnp.uint32))
