"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All three kernels are integer-exact, so every comparison is array_equal.
Interpret mode executes the kernel bodies on CPU; the grid>1 GGM case runs
at reduced rounds only because XLA:CPU compile time of the interpreted
emulation grows superlinearly in rounds × grid (kernels/ops.py note) —
the indexing logic under test is round-count independent.
"""
import numpy as np
import pytest
from _prop import given, settings, st

import jax.numpy as jnp

from repro.core import dpf
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# dpXOR
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,r,w,tile", [
    (1, 64, 8, 64),
    (4, 256, 8, 64),       # grid = 4
    (8, 512, 16, 128),     # grid = 4, wider records
    (2, 1024, 4, 1024),    # single tile
])
def test_dpxor_sweep(q, r, w, tile):
    db = jnp.asarray(RNG.integers(0, 1 << 32, size=(r, w), dtype=np.uint32))
    bits = jnp.asarray(RNG.integers(0, 2, size=(q, r), dtype=np.uint32))
    got = ops.dpxor(db, bits, tile_r=tile)
    want = ref.dpxor_ref(db, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dpxor_transposed_layout():
    db = jnp.asarray(RNG.integers(0, 1 << 32, size=(256, 8), dtype=np.uint32))
    bits = jnp.asarray(RNG.integers(0, 2, size=(3, 256), dtype=np.uint32))
    got = ops.dpxor_transposed(db.T, bits, tile_r=128)
    want = ref.dpxor_ref(db, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 255))
def test_dpxor_onehot_selects_row(row):
    """A one-hot selection vector must return exactly that DB row."""
    db = jnp.asarray(RNG.integers(0, 1 << 32, size=(256, 8),
                                  dtype=np.uint32))
    bits = np.zeros((1, 256), np.uint32)
    bits[0, row] = 1
    got = np.asarray(ops.dpxor(db, jnp.asarray(bits), tile_r=64))
    np.testing.assert_array_equal(got[0], np.asarray(db)[row])


# ---------------------------------------------------------------------------
# GGM expansion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 4, 64])
def test_ggm_expand_matches_ref(n):
    seeds = jnp.asarray(RNG.integers(0, 1 << 32, size=(n, 4),
                                     dtype=np.uint32))
    t = jnp.asarray(RNG.integers(0, 2, size=(n,), dtype=np.uint32))
    cw_s = jnp.asarray(RNG.integers(0, 1 << 32, size=(4,), dtype=np.uint32))
    cw_t = jnp.asarray(RNG.integers(0, 2, size=(2,), dtype=np.uint32))
    got_c, got_t = ops.ggm_expand(seeds, t, cw_s, cw_t)
    want_c, want_t = ref.ggm_expand_ref(seeds, t, cw_s, cw_t)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(want_t))


def test_ggm_expand_grid_indexing_low_rounds():
    """grid=4 tiles at rounds=2: validates BlockSpec index maps."""
    n, tile = 256, 64
    seeds = jnp.asarray(RNG.integers(0, 1 << 32, size=(n, 4),
                                     dtype=np.uint32))
    t = jnp.asarray(RNG.integers(0, 2, size=(n,), dtype=np.uint32))
    cw_s = jnp.asarray(RNG.integers(0, 1 << 32, size=(4,), dtype=np.uint32))
    cw_t = jnp.asarray(RNG.integers(0, 2, size=(2,), dtype=np.uint32))
    got_c, got_t = ops.ggm_expand(seeds, t, cw_s, cw_t, rounds=2, tile=tile)
    want_c, want_t = ref.ggm_expand_ref(seeds, t, cw_s, cw_t, rounds=2)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(want_t))


@pytest.mark.slow   # ~1-2 min on the 1-core container
def test_ggm_leaf_path_matches_dpf():
    """Full-domain kernel-driven expansion == core.dpf.eval_all."""
    log_n = 6
    k0, k1 = dpf.gen_keys(np.random.default_rng(5), 21, log_n)
    for k in (k0, k1):
        s_ref, t_ref = dpf.eval_all(k)
        s_got, t_got = ops.ggm_eval_leaves(
            k.root_seed, np.uint32(k.party), k.cw_seed, k.cw_t, log_n)
        np.testing.assert_array_equal(np.asarray(s_got), np.asarray(s_ref))
        np.testing.assert_array_equal(np.asarray(t_got), np.asarray(t_ref))


# ---------------------------------------------------------------------------
# PIR matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,r,l,tiles", [
    (8, 1024, 128, (8, 512, 128)),    # grid over reduction
    (2, 256, 32, (2, 256, 32)),       # single tile
    (16, 512, 256, (8, 256, 128)),    # grid over all three dims
])
def test_pir_matmul_sweep(q, r, l, tiles):
    s = jnp.asarray(RNG.integers(-128, 128, size=(q, r), dtype=np.int8))
    d = jnp.asarray(RNG.integers(-128, 128, size=(r, l), dtype=np.int8))
    got = ops.pir_gemm(s, d, tile_q=tiles[0], tile_r=tiles[1],
                       tile_l=tiles[2])
    want = ref.pir_matmul_ref(s, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pir_matmul_mod256_semantics():
    """int32 accumulation preserves the Z_256 residue (2^8 | 2^32)."""
    q, r, l = 2, 512, 16
    s0 = RNG.integers(0, 256, size=(q, r)).astype(np.uint8)
    s1 = (np.zeros_like(s0) - s0)           # additive complements mod 256
    onehot = np.zeros((q, r), np.uint8)
    onehot[0, 3] = 1
    onehot[1, 100] = 1
    s1 = (onehot - s0).astype(np.uint8)
    d = RNG.integers(0, 256, size=(r, l)).astype(np.uint8)
    r0 = np.asarray(ops.pir_gemm(jnp.asarray(s0.view(np.int8)),
                                 jnp.asarray(d.view(np.int8))))
    r1 = np.asarray(ops.pir_gemm(jnp.asarray(s1.view(np.int8)),
                                 jnp.asarray(d.view(np.int8))))
    rec = (r0.astype(np.int64) + r1.astype(np.int64)) % 256
    np.testing.assert_array_equal(rec[0], d[3])
    np.testing.assert_array_equal(rec[1], d[100])
