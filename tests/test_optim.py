"""Optimizer + compression tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.config import OptimizerConfig
from repro.optim import compression
from repro.optim.optimizer import (adafactor_init, adafactor_update,
                                   adamw_init, adamw_update,
                                   clip_by_global_norm, lr_schedule,
                                   opt_init, opt_update, spec_for_state)


def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.5]),
            "b": jnp.asarray([[1.0, -1.0], [0.5, 2.0]])}


def _grad(params):
    # grad of 0.5*||p||^2 is p: minimizing drives params to 0
    return params


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_minimize_quadratic(name):
    cfg = OptimizerConfig(name=name, lr=0.1, warmup_steps=0,
                          total_steps=10000, weight_decay=0.0)
    params = _quadratic_params()
    state = opt_init(cfg, params)
    for _ in range(60):
        params, state, m = opt_update(cfg, _grad(params), state, params)
    norm = sum(float(jnp.sum(p * p)) for p in jax.tree_util.tree_leaves(params))
    assert norm < 0.5, (name, norm)
    assert np.isfinite(m["grad_norm"])


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # end of warmup
    assert lrs[-1] < lrs[1]                    # decayed
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-12       # floor at 10%


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(1000.0)) < 1e-3
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert abs(total - 1.0) < 1e-5


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((16,))}
    state = adafactor_init(params)
    assert state.vr["big"].shape == (64,)
    assert state.vc["big"].shape == (32,)
    assert state.v["big"] == ()
    assert state.v["vec"].shape == (16,)


def test_spec_for_state_shapes():
    from jax.sharding import PartitionSpec as P
    params = {"w": jnp.zeros((8, 4))}
    specs = {"w": P(None, "model")}
    shapes = jax.eval_shape(lambda: params)
    s = spec_for_state(OptimizerConfig(name="adafactor"), specs, shapes)
    assert s.vr["w"] == P(None)
    assert s.vc["w"] == P("model")
    s2 = spec_for_state(OptimizerConfig(name="adamw"), specs, shapes)
    assert s2.m["w"] == P(None, "model")


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000) * 5)
    q, s = compression.quantize(g)
    err = np.abs(np.asarray(compression.dequantize(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_sum():
    """EF carries the residual: quantized stream sums to the true sum."""
    rng = np.random.default_rng(1)
    grads_seq = [
        {"w": jnp.asarray(rng.standard_normal(64) * 0.01)}
        for _ in range(50)
    ]
    ef = compression.ef_init(grads_seq[0])
    total_sent = np.zeros(64)
    for g in grads_seq:
        q, s, ef = compression.compress_with_feedback(g, ef)
        total_sent += np.asarray(compression.dequantize(q["w"], s["w"]))
    true_total = sum(np.asarray(g["w"]) for g in grads_seq)
    residual = np.asarray(ef["w"])
    np.testing.assert_allclose(total_sent + residual, true_total,
                               rtol=1e-4, atol=1e-5)


def test_compressed_psum_single_axis():
    """shard_map form over a 1-device axis degenerates to identity mean."""
    import jax
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("pod",))
    g = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    ef = compression.ef_init(g)

    def f(g, ef):
        return compression.compressed_psum(g, ef, "pod")

    out, _ = jax.jit(shard_map(
        f, mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False))(g, ef)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=0.05)
