"""DPF correctness: unit + hypothesis property tests.

Invariants under test (the cryptographic contract of core/dpf.py):
  P1  Eval(k0, j) XOR Eval(k1, j) == 1{j == alpha}      (point function)
  P2  eval_range tiles eval_all exactly (shard-parallel form)
  P3  additive word shares sum to beta * 1{j == alpha} mod 2^32
  P4  byte shares sum to 1{j == alpha} mod 256 (MXU matmul form)
  P5  each key alone is (statistically) uninformative: leaf bits of a
      single party are ~balanced — a smoke-level distinguisher check
"""
import numpy as np
import pytest
from _prop import given, settings, st

import jax.numpy as jnp

from repro.core import dpf

RNG = np.random.default_rng(7)


def _keys(alpha, log_n, **kw):
    return dpf.gen_keys(np.random.default_rng(42), alpha, log_n, **kw)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=9), st.data())
def test_onehot_property(log_n, data):
    alpha = data.draw(st.integers(0, (1 << log_n) - 1))
    k0, k1 = _keys(alpha, log_n)
    _, t0 = dpf.eval_all(k0)
    _, t1 = dpf.eval_all(k1)
    onehot = np.asarray(t0 ^ t1)
    assert onehot.sum() == 1
    assert onehot[alpha] == 1


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=2, max_value=8), st.data())
def test_eval_range_tiles_eval_all(log_n, data):
    alpha = data.draw(st.integers(0, (1 << log_n) - 1))
    log_range = data.draw(st.integers(0, log_n))
    k0, _ = _keys(alpha, log_n)
    seeds_all, t_all = dpf.eval_all(k0)
    n_blocks = 1 << (log_n - log_range)
    width = 1 << log_range
    for blk in range(n_blocks):
        seeds, t = dpf.eval_range(k0, blk, log_range)
        np.testing.assert_array_equal(
            np.asarray(t), np.asarray(t_all[blk * width:(blk + 1) * width]))
        np.testing.assert_array_equal(
            np.asarray(seeds),
            np.asarray(seeds_all[blk * width:(blk + 1) * width]))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=4), st.data())
def test_additive_word_shares(log_n, n_words, data):
    alpha = data.draw(st.integers(0, (1 << log_n) - 1))
    beta = data.draw(st.lists(st.integers(0, (1 << 32) - 1),
                              min_size=n_words, max_size=n_words))
    payload = np.asarray(beta, np.uint32)
    k0, k1 = _keys(alpha, log_n, payload=payload)
    out = []
    for k in (k0, k1):
        seeds, t = dpf.eval_all(k)
        out.append(np.asarray(dpf.leaf_words(k, seeds, t, n_words),
                              np.uint32))
    total = (out[0].astype(np.uint64) + out[1].astype(np.uint64)) \
        % (1 << 32)
    expect = np.zeros(((1 << log_n), n_words), np.uint64)
    expect[alpha] = payload
    np.testing.assert_array_equal(total, expect)


@pytest.mark.slow   # ~1-2 min on the 1-core container
def test_byte_shares_sum_mod_256():
    log_n = 7
    alpha = 93
    k0, k1 = _keys(alpha, log_n, payload=np.array([1], np.uint32))
    shares = []
    for k in (k0, k1):
        s = dpf.eval_bytes_batch(dpf.stack_keys([k]), 0, log_n)
        shares.append(np.asarray(s, np.int64)[0])
    total = (shares[0] + shares[1]) % 256
    expect = np.zeros(1 << log_n, np.int64)
    expect[alpha] = 1
    np.testing.assert_array_equal(total, expect)


@pytest.mark.slow   # ~1-2 min on the 1-core container
def test_single_key_leaf_bits_balanced():
    """One party's selection bits look ~uniform (no trivial leakage)."""
    log_n = 12
    k0, _ = _keys(1234, log_n)
    _, t = dpf.eval_all(k0)
    frac = float(np.asarray(t).mean())
    assert 0.40 < frac < 0.60, frac


def test_keys_differ_per_alpha():
    k_a, _ = _keys(3, 6)
    k_b, _ = _keys(4, 6)
    assert not np.array_equal(np.asarray(k_a.cw_seed),
                              np.asarray(k_b.cw_seed))


def test_batched_eval_matches_single():
    log_n = 6
    alphas = [0, 5, 63]
    keys = [dpf.gen_keys(np.random.default_rng(i), a, log_n)[0]
            for i, a in enumerate(alphas)]
    batch = dpf.stack_keys(keys)
    bits = np.asarray(dpf.eval_bits_batch(batch, 0, log_n))
    for i, k in enumerate(keys):
        _, t = dpf.eval_all(k)
        np.testing.assert_array_equal(bits[i], np.asarray(t))


def test_invalid_alpha_raises():
    with pytest.raises(ValueError):
        _keys(1 << 5, 5)
