"""End-to-end PIR protocol tests: client + two servers, all server paths.

Covers the paper's Algorithm 1 on the reference (single-shard) forms and
the sharded server (shard_map over a local mesh) in baseline / fused /
matmul paths, plus the cluster topology and the aggregation collectives.
"""
import numpy as np
import pytest
from _prop import given, settings, st

import jax
import jax.numpy as jnp

from repro.config import PIRConfig
from repro.core import dpf, pir
from repro.core.server import PIRServer, build_serve_fn
from repro.launch.mesh import make_local_mesh

RNG = np.random.default_rng(3)
LOG_N = 10
N = 1 << LOG_N
DB = pir.make_database(np.random.default_rng(0), N, 32)


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, N - 1))
def test_xor_roundtrip_reference(idx):
    cfg = PIRConfig(n_items=N)
    q = pir.query_gen(RNG, idx, cfg)
    r0 = pir.answer_xor(jnp.asarray(DB), q.keys[0])
    r1 = pir.answer_xor(jnp.asarray(DB), q.keys[1])
    rec = np.asarray(pir.reconstruct_xor(r0, r1))
    np.testing.assert_array_equal(rec, DB[idx])


@pytest.mark.slow   # jit-compiles serve/eval steps (~1 min each here)
def test_additive_roundtrip_reference():
    cfg = PIRConfig(n_items=N, mode="additive")
    dbb = pir.db_as_bytes(DB).astype(np.int8)
    for idx in (0, 17, N - 1):
        q = pir.query_gen(RNG, idx, cfg)
        rs = []
        for k in q.keys:
            shares = dpf.eval_bytes_batch(dpf.stack_keys([k]), 0, LOG_N)
            rs.append(pir.answer_additive_matmul(jnp.asarray(dbb), shares))
        rec = np.asarray(pir.reconstruct_additive(rs[0], rs[1]))[0]
        np.testing.assert_array_equal(rec, pir.db_as_bytes(DB)[idx])


@pytest.mark.slow   # jit-compiles serve/eval steps (~1 min each here)
@pytest.mark.parametrize("path", ["baseline", "fused", "matmul"])
def test_sharded_server_paths(mesh, path):
    mode = "additive" if path == "matmul" else "xor"
    cfg = PIRConfig(n_items=N, mode=mode, batch_queries=4)
    servers = [PIRServer(party=b, db_words=DB, cfg=cfg, mesh=mesh,
                         n_queries=4, path=path) for b in (0, 1)]
    indices = [3, 99, 512, N - 1]
    k0, k1 = pir.batch_queries(RNG, indices, cfg)
    r0 = servers[0].answer(k0)
    r1 = servers[1].answer(k1)
    if path == "matmul":
        rec = np.asarray(pir.reconstruct_additive(r0, r1))
        expect = pir.db_as_bytes(DB)[indices]
    else:
        rec = np.asarray(pir.reconstruct_xor(r0, r1))
        expect = DB[indices]
    np.testing.assert_array_equal(rec, expect)


@pytest.mark.slow   # jit-compiles serve/eval steps (~1 min each here)
def test_collective_variants_agree(mesh):
    cfg = PIRConfig(n_items=N, batch_queries=2)
    idxs = [7, 700]
    k0, _ = pir.batch_queries(RNG, idxs, cfg)
    outs = []
    for coll in ("gather", "butterfly"):
        fns = build_serve_fn(cfg, mesh, n_queries=2, path="baseline",
                             collective=coll)
        db = jax.device_put(jnp.asarray(DB), fns.db_sharding)
        outs.append(np.asarray(fns.serve(db, k0)))
    np.testing.assert_array_equal(outs[0], outs[1])


@pytest.mark.slow   # jit-compiles serve/eval steps (~1 min each here)
def test_fused_equals_baseline(mesh):
    cfg = PIRConfig(n_items=N, batch_queries=2)
    k0, _ = pir.batch_queries(RNG, [11, 222], cfg)
    res = {}
    for path in ("baseline", "fused"):
        fns = build_serve_fn(cfg, mesh, n_queries=2, path=path)
        db = jax.device_put(jnp.asarray(DB), fns.db_sharding)
        res[path] = np.asarray(fns.serve(db, k0))
    np.testing.assert_array_equal(res["baseline"], res["fused"])


@pytest.mark.slow   # jit-compiles serve/eval steps (~1 min each here)
def test_two_server_deployment(mesh):
    from repro.runtime.serve_loop import TwoServerPIR
    cfg = PIRConfig(n_items=N, batch_queries=4)
    sys2 = TwoServerPIR(DB, cfg, mesh, path="fused", n_queries=4)
    idx = [1, 2, 3, 1000]
    out = sys2.query(idx)
    np.testing.assert_array_equal(out, DB[idx])


@pytest.mark.slow   # jit-compiles serve/eval steps (~1 min each here)
def test_phase_split_matches_paper_structure():
    """Table 1 instrumentation path: eval-then-scan == fused answers."""
    cfg = PIRConfig(n_items=N, batch_queries=2)
    k0, k1 = pir.batch_queries(RNG, [5, 50], cfg)
    bits0 = pir.phase_eval_bits(k0, LOG_N)
    r0 = pir.phase_dpxor(jnp.asarray(DB), bits0)
    bits1 = pir.phase_eval_bits(k1, LOG_N)
    r1 = pir.phase_dpxor(jnp.asarray(DB), bits1)
    rec = np.asarray(pir.reconstruct_xor(r0, r1))
    np.testing.assert_array_equal(rec, DB[[5, 50]])
