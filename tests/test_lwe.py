"""LWE protocol tests: parameter invariants, oracle parity, noise budget,
query indistinguishability, and the SingleServerPIR hint lifecycle.

Fast tier throughout: the LWE serve step is a slice + int32 GEMM (no GGM
chains), so even the full compiled ``SingleServerPIR`` session at
``N = 2^10`` builds in well under a second on this container. Only the
``pir-smoke-lwe``-scale (``N = 2^14``) session lives in the slow tier.

Property structure (the ISSUE's three satellites):
  (a) end-to-end correctness vs a pure-numpy LWE oracle across random
      ``(N, item_bytes, index)`` shapes — the server GEMM, the device
      hint builder, and the modulus-switching reconstruction each
      checked against their numpy reference;
  (b) the noise-budget invariant: the *sampled* post-reconstruction
      error magnitude stays under ``q/(2p)`` for every shipped
      parameter row (the empirical form of ``LWEParams.validate``);
  (c) query indistinguishability smoke: at test scale, ciphertext byte
      histograms / means / variances for two different indices are
      statistically indistinguishable (a sanity check, not a proof).
"""
import dataclasses

import numpy as np
import pytest
from _prop import given, settings, st

import jax
import jax.numpy as jnp

from repro.config import PIRConfig
from repro.core import dpf, lwe, pir
from repro.core.protocol import ExecutionPlan, for_config, get
from repro.db import DatabaseSpec

RNG = np.random.default_rng(1317)


def _db_pair(rng, n, item_bytes):
    """(words [N, W] u32, bytes [N, L] u8) for one random DB."""
    words = pir.make_database(rng, n, item_bytes)
    return words, DatabaseSpec(n, item_bytes).words_to_bytes_host(words)


# ---------------------------------------------------------------------------
# parameter table: every correctness condition is checkable, not a comment
# ---------------------------------------------------------------------------

def test_param_table_invariants():
    for max_items, params in lwe.PARAM_TABLE:
        # each row decodes its whole coverage range (validate returns self)
        assert params.validate(max_items) is params
        # q = Delta * p exactly: the modulus switch absorbs negative wrap
        assert params.delta * params.p == params.q == lwe.LWE_Q
        assert params.noise_budget == params.delta // 2
        # the analytic tail bound is what validate enforces
        assert params.noise_bound(max_items) < params.noise_budget


def test_params_for_selects_covering_row_and_raises_past_table():
    assert lwe.params_for(1 << 10) is lwe.PARAM_TABLE[0][1]
    assert lwe.params_for(1 << 16) is lwe.PARAM_TABLE[0][1]
    assert lwe.params_for((1 << 16) + 1) is lwe.PARAM_TABLE[1][1]
    assert lwe.params_for(1 << 25) is lwe.PARAM_TABLE[2][1]
    with pytest.raises(ValueError, match="extend PARAM_TABLE"):
        lwe.params_for(1 << 26)


def test_validate_rejects_bad_parameters():
    # noise bound crossing q/(2p): sigma far too large for the DB size
    with pytest.raises(ValueError, match="cannot .* guarantee|noise bound"):
        lwe.LWEParams(n=128, sigma=1e6).validate(1 << 16)
    # p must divide q for an exact Delta
    with pytest.raises(ValueError, match="must divide"):
        lwe.LWEParams(n=128, sigma=1.0, p=3).validate(1 << 10)
    with pytest.raises(ValueError, match="degenerate"):
        lwe.LWEParams(n=0, sigma=1.0).validate(1 << 10)
    with pytest.raises(ValueError, match="degenerate"):
        lwe.LWEParams(n=128, sigma=0.0).validate(1 << 10)


def test_matrix_a_is_deterministic_and_never_reshipped():
    p = lwe.params_for(1 << 8)
    a1 = lwe.matrix_a(p, 1 << 8)
    a2 = lwe.matrix_a(p, 1 << 8)
    assert a1 is a2                      # PRG-regenerated once, cached
    assert a1.shape == (1 << 8, p.n)
    assert a1.max() < lwe.LWE_Q
    # a different seed is a different matrix (the seed IS the matrix)
    other = dataclasses.replace(p, a_seed=p.a_seed + 1)
    assert not np.array_equal(lwe.matrix_a(other, 1 << 8), a1)


# ---------------------------------------------------------------------------
# (a) end-to-end correctness vs the numpy LWE oracle, random shapes
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(6, 11), st.integers(1, 4), st.data())
def test_lwe_e2e_matches_numpy_oracle(log_n, words_per_item, data):
    """encrypt -> answer_local -> hint -> reconstruct, all against numpy.

    Randomizes (N, item_bytes, index); the server answer, the device hint
    builder, and the reconstruction are each contracted against their
    numpy reference before the final record equality.
    """
    n_items, item_bytes = 1 << log_n, 4 * words_per_item
    index = data.draw(st.integers(0, n_items - 1))
    cfg = PIRConfig(n_items=n_items, item_bytes=item_bytes,
                    protocol="lwe-simple-1", n_servers=1)
    proto = for_config(cfg)
    assert proto.n_parties(cfg) == 1 and proto.needs_hint
    rng = np.random.default_rng(log_n * 1000 + index)
    db_words, db_bytes = _db_pair(rng, n_items, item_bytes)
    params = lwe.params_for(n_items)

    keys, state = proto.query_gen_full(rng, index, cfg)
    assert state.index == index

    # server answer: eager answer_local on the bytes32 view vs ct^T.D mod q
    spec = DatabaseSpec.from_config(cfg)
    view32 = jnp.asarray(spec.pack_host(db_words, proto.db_view))
    batched = dpf.stack_keys([keys[0]])
    ans = np.asarray(proto.answer_local(view32, batched, 0, log_n,
                                        ExecutionPlan()))
    ct_u64 = np.asarray(keys[0].ct).view(np.uint32).astype(np.uint64)
    ans_oracle = (ct_u64 @ db_bytes.astype(np.uint64)) \
        & np.uint64(0xFFFFFFFF)
    np.testing.assert_array_equal(ans.view(np.uint32)[0],
                                  ans_oracle.astype(np.uint32))

    # hint: device builder (words view in) vs the numpy oracle
    hint_dev = np.asarray(proto.hint_builder(cfg)(jnp.asarray(db_words)))
    np.testing.assert_array_equal(
        hint_dev.view(np.uint32),
        lwe.hint_np(params, db_bytes).astype(np.uint32))

    # reconstruction: exact record recovery after the modulus switch
    rec = np.asarray(proto.reconstruct_with([ans], [state], cfg=cfg,
                                            hint=hint_dev))
    np.testing.assert_array_equal(rec[0], db_bytes[index])


def test_reconstruct_requires_state_and_hint():
    cfg = PIRConfig(n_items=1 << 8, protocol="lwe-simple-1", n_servers=1)
    proto = for_config(cfg)
    with pytest.raises(NotImplementedError, match="reconstruct_with"):
        proto.reconstruct([np.zeros((1, 32), np.int32)])
    with pytest.raises(ValueError, match="needs cfg"):
        proto.reconstruct_with([np.zeros((1, 32), np.int32)], [None],
                               cfg=cfg, hint=None)


def test_noise_overflow_is_detected_not_silent():
    """A hint/answer pair whose residual crosses the budget raises —
    corrupted reconstructions never pass as records."""
    cfg = PIRConfig(n_items=1 << 8, protocol="lwe-simple-1", n_servers=1)
    proto = for_config(cfg)
    rng = np.random.default_rng(5)
    db_words, _ = _db_pair(rng, cfg.n_items, cfg.item_bytes)
    keys, state = proto.query_gen_full(rng, 17, cfg)
    spec = DatabaseSpec.from_config(cfg)
    view32 = jnp.asarray(spec.pack_host(db_words, proto.db_view))
    ans = np.asarray(proto.answer_local(view32, dpf.stack_keys([keys[0]]),
                                        0, cfg.log_n, ExecutionPlan()))
    hint = np.asarray(proto.hint_builder(cfg)(jnp.asarray(db_words)))
    # corrupt the hint by a non-multiple of Delta: s^T.H shifts by a
    # near-uniform Z_q element per column, so the recovered residual
    # leaves the tail band (a Delta-multiple corruption would alias
    # cleanly into the plaintext — exactly why the check uses the tail
    # bound, not the vacuous Delta/2 window)
    bad = hint.copy()
    bad[0] ^= np.int32(1)
    with pytest.raises(RuntimeError, match="noise overflow"):
        proto.reconstruct_with([ans], [state], cfg=cfg, hint=bad)


# ---------------------------------------------------------------------------
# (b) noise-budget invariant: sampled error under q/(2p) per shipped row
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("max_items,params", lwe.PARAM_TABLE,
                         ids=lambda v: str(v) if isinstance(v, int) else "")
def test_sampled_noise_under_budget(max_items, params):
    """Empirical companion to ``LWEParams.validate``: run the scheme in
    pure numpy at a capped N for each shipped parameter row and assert
    every recovered error magnitude sits inside the budget — with the
    analytic tail bound also covering the *full* coverage range."""
    n_items = min(max_items, 1 << 14)        # container-sized sample
    params.validate(max_items)               # analytic bound, full range
    rng = np.random.default_rng(params.n)
    _, db_bytes = _db_pair(rng, n_items, 32)
    hint = lwe.hint_np(params, db_bytes)
    errs = []
    for index in (0, n_items // 2, n_items - 1):
        ct, state = lwe.encrypt(rng, index, n_items, params)
        ct_u64 = np.asarray(ct.ct).view(np.uint32).astype(np.uint64)
        ans = ((ct_u64 @ db_bytes.astype(np.uint64))
               & np.uint64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
        rec, err = lwe.decode(ans[None, :], state.s[None, :], hint, params)
        np.testing.assert_array_equal(rec[0], db_bytes[index])
        errs.append(np.abs(err).max())
    assert max(errs) < params.noise_budget
    # the sampled error is genuinely nonzero noise, not a degenerate zero
    # channel (sigma > 0 with N >= 2^14 samples makes all-zero absurd)
    assert max(errs) > 0


# ---------------------------------------------------------------------------
# (c) query-indistinguishability smoke at test scale
# ---------------------------------------------------------------------------

def test_query_indistinguishability_smoke():
    """Ciphertext populations for two fixed, distant indices are
    statistically indistinguishable at byte granularity.

    A smoke test, not a cryptographic proof: with Delta = 2^24 riding on
    uniformly-masked Z_{2^32} coordinates, any index leak would have to
    surface as a byte-histogram / moment shift; we bound the total
    variation distance and the first two moments between the populations.
    """
    n_items = 1 << 10
    params = lwe.params_for(n_items)
    n_cts = 48

    def population(index, seed):
        rng = np.random.default_rng(seed)
        cts = [lwe.encrypt(rng, index, n_items, params)[0].ct
               for _ in range(n_cts)]
        return np.asarray(jnp.stack(cts)).view(np.uint8).ravel()

    pop_i = population(3, seed=101)
    pop_j = population(n_items - 1, seed=202)
    assert pop_i.size == pop_j.size == n_cts * n_items * 4

    hist_i = np.bincount(pop_i, minlength=256) / pop_i.size
    hist_j = np.bincount(pop_j, minlength=256) / pop_j.size
    tv = 0.5 * np.abs(hist_i - hist_j).sum()
    assert tv < 0.05, f"byte-histogram TV distance {tv:.4f}"
    # uniform-byte moments: mean 127.5, std ~73.9; populations agree
    assert abs(pop_i.mean() - pop_j.mean()) < 1.0
    assert abs(pop_i.std() / pop_j.std() - 1.0) < 0.02
    assert abs(pop_i.mean() - 127.5) < 0.5
    # ... and the hot coordinate itself is not an outlier: the Delta-
    # shifted slot's bytes stay inside the population's uniform band
    hot = np.asarray(
        jnp.stack([lwe.encrypt(np.random.default_rng(s), 3, n_items,
                               params)[0].ct[3] for s in range(256)])
    ).view(np.uint8).ravel()
    assert abs(hot.mean() - 127.5) < 6.0     # 256*4 samples: ~4 sigma band


# ---------------------------------------------------------------------------
# batching: LWECiphertext through the generic key plumbing
# ---------------------------------------------------------------------------

def test_ciphertext_batching_pad_and_specs():
    cfg = PIRConfig(n_items=1 << 8, protocol="lwe-simple-1", n_servers=1)
    proto = for_config(cfg)
    per_query = [proto.query_gen(RNG, i, cfg)[0] for i in (1, 2, 3)]
    batch = dpf.stack_keys(per_query)
    assert proto.n_queries(batch) == 3
    padded = proto.pad(batch, 4)
    assert proto.n_queries(padded) == 4
    # pad slot replicates the last real ciphertext; real slots untouched
    np.testing.assert_array_equal(np.asarray(padded.ct[3]),
                                  np.asarray(batch.ct[2]))
    np.testing.assert_array_equal(np.asarray(padded.ct[:3]),
                                  np.asarray(batch.ct))
    with pytest.raises(ValueError, match="cannot pad"):
        proto.pad(batch, 2)
    # key_specs: treedef AND leaf shapes match real batched keys (the
    # per-bucket jit contract every protocol must honour)
    spec = proto.key_specs(cfg, 3)
    assert (jax.tree_util.tree_structure(batch)
            == jax.tree_util.tree_structure(spec))
    assert ([x.shape for x in jax.tree_util.tree_leaves(batch)]
            == [x.shape for x in jax.tree_util.tree_leaves(spec)])


# ---------------------------------------------------------------------------
# SingleServerPIR session: hint reuse + invalidation on publish
# ---------------------------------------------------------------------------

def _session(n_items, batch_queries=2):
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.serve_loop import SingleServerPIR
    cfg = PIRConfig(n_items=n_items, item_bytes=32, protocol="lwe-simple-1",
                    n_servers=1, batch_queries=batch_queries)
    rng = np.random.default_rng(9)
    db_words, db_bytes = _db_pair(rng, n_items, 32)
    system = SingleServerPIR(db_words, cfg, make_local_mesh(),
                             client_rng=np.random.default_rng(10))
    return system, db_bytes, rng


def test_single_server_session_hint_reuse_and_invalidation():
    """The ISSUE's session acceptance bar, compiled end to end: one hint
    fetch covers many queries in an epoch; ``publish()`` invalidates the
    client cache exactly when the data changes (served via the delta)."""
    system, db_bytes, rng = _session(1 << 10)
    np.testing.assert_array_equal(system.query([3, 777]), db_bytes[[3, 777]])
    np.testing.assert_array_equal(system.query([511])[0], db_bytes[511])
    assert system.hint_fetches == 1          # >= 2 queries, ONE hint fetch
    assert system.db.stats.n_hint_builds == 1

    new_row = rng.integers(0, 256, size=(1, 32), dtype=np.uint8)
    system.update(np.array([3]), new_row)
    assert system.publish() == 1
    rec = system.query([3])
    np.testing.assert_array_equal(rec[0], new_row[0])      # fresh record
    assert system.hint_fetches == 2          # stale cache -> one refetch
    # the server side delta-updated (O(rows) GEMM), never a full rebuild
    assert system.db.stats.n_hint_deltas == 1
    assert system.db.stats.n_hint_builds == 1


def test_single_server_session_epoch_tags_and_session_mode():
    system, db_bytes, _ = _session(1 << 10)
    with system:
        fut = system.submit(42)
        rec = np.asarray(fut.result(timeout=120.0))
    np.testing.assert_array_equal(rec, db_bytes[42])
    assert fut.epoch == 0


def test_single_server_rejects_multi_party_and_vice_versa():
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.serve_loop import MultiServerPIR, SingleServerPIR
    mesh = make_local_mesh()
    db = pir.make_database(np.random.default_rng(0), 1 << 8, 32)
    lwe_cfg = PIRConfig(n_items=1 << 8, protocol="lwe-simple-1", n_servers=1)
    with pytest.raises(ValueError, match="SingleServerPIR"):
        MultiServerPIR(db, lwe_cfg, mesh)    # no hint plumbing here
    with pytest.raises(ValueError, match="1-party"):
        SingleServerPIR(db, PIRConfig(n_items=1 << 8), mesh)


@pytest.mark.slow   # pir-smoke-lwe scale: 2^14 rows through the full stack
def test_single_server_session_smoke_scale():
    from repro.configs.pir import PIR_SMOKE_LWE
    assert PIR_SMOKE_LWE.protocol == "lwe-simple-1"
    system, db_bytes, _ = _session(PIR_SMOKE_LWE.n_items,
                                   PIR_SMOKE_LWE.batch_queries)
    idx = [0, 5, 12345, (1 << 14) - 1]
    np.testing.assert_array_equal(system.query(idx), db_bytes[idx])
    assert system.hint_fetches == 1
