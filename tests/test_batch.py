"""Batch-PIR cuckoo layer + bucketed database tests (DESIGN.md §14).

Fast tier: the cuckoo math is pure host numpy; the BucketedDatabase
checks touch device arrays only through placement/scatter (no serve-step
compiles). Property tests run through the ``tests/_prop.py`` shim —
hypothesis when available, the seeded fallback otherwise.
"""
import numpy as np
import pytest
from _prop import given, settings, st

from repro.config import PIRConfig
from repro.core import pir
from repro.core.batch import (ALPHA_MAX, CuckooFailure, CuckooLayout,
                              CuckooParams, bucket_hashes, cuckoo_assign,
                              plan_round, reassemble)
from repro.db import BucketedDatabase, DatabaseSpec
from repro.launch.mesh import make_local_mesh

N = 1 << 8
DB = pir.make_database(np.random.default_rng(5), N, 32)
PARAMS = CuckooParams(m=4)
LAYOUT = CuckooLayout.build(N, PARAMS)


# ---------------------------------------------------------------------------
# parameters: the LWEParams.validate-style analytic gate
# ---------------------------------------------------------------------------

def test_params_validate_enforces_load_margin():
    assert CuckooParams(m=4).validate().n_buckets == 8
    assert CuckooParams(m=4).load_factor == 0.5 <= ALPHA_MAX
    # past the margin: insertion failure is no longer O(1/B) — construction
    # must fail, not queries probabilistically
    with pytest.raises(ValueError, match="load factor"):
        CuckooParams(m=10, c=1.0).validate()
    with pytest.raises(ValueError, match="m must be >= 1"):
        CuckooParams(m=0).validate()
    with pytest.raises(ValueError, match="hash functions"):
        CuckooParams(m=4, n_hashes=1).validate()
    with pytest.raises(ValueError, match="c must be > 0"):
        CuckooParams(m=4, c=-1.0).validate()
    # config plumbing
    cfg = PIRConfig(n_items=N, batch_m=4)
    p = CuckooParams.from_config(cfg)
    assert (p.m, p.c, p.n_hashes) == (4, 2.0, 3)


def test_failure_bound_shrinks_with_buckets():
    bounds = [CuckooParams(m=m).failure_bound() for m in (2, 8, 32, 128)]
    assert bounds == sorted(bounds, reverse=True)      # monotone in B
    assert all(0 < b <= 1 for b in bounds)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 64))
def test_bucket_hashes_deterministic_in_range(m):
    p = CuckooParams(m=m)
    idx = np.arange(2 * N)
    h1, h2 = bucket_hashes(idx, p), bucket_hashes(idx, p)
    np.testing.assert_array_equal(h1, h2)              # pure function
    assert h1.shape == (2 * N, p.n_hashes)
    assert h1.min() >= 0 and h1.max() < p.n_buckets
    # a different seed is a different hash family
    assert not np.array_equal(
        h1, bucket_hashes(idx, CuckooParams(m=m, seed=1)))


# ---------------------------------------------------------------------------
# layout: server-side simple-hashing placement
# ---------------------------------------------------------------------------

def test_layout_places_every_record_in_every_candidate_bucket():
    assert LAYOUT.capacity & (LAYOUT.capacity - 1) == 0    # pow2 (GGM)
    assert LAYOUT.capacity >= LAYOUT.loads.max()
    for i in range(N):
        occ = LAYOUT.occurrences(i)
        assert {b for b, _ in occ} == set(LAYOUT.hashes[i].tolist())
        for b, s in occ:
            assert LAYOUT.bucket_rows[b][s] == i
            assert LAYOUT.slot(i, b) == s
    with pytest.raises(KeyError, match="not a candidate"):
        bad = next(b for b in range(LAYOUT.n_buckets)
                   if b not in LAYOUT.hashes[0])
        LAYOUT.slot(0, bad)
    # total placements = number of distinct (record, bucket) pairs
    assert LAYOUT.loads.sum() == sum(len(set(LAYOUT.hashes[i]))
                                     for i in range(N))


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 32 - 1))
def test_cuckoo_assign_property(seed):
    """Any unique batch of ≤ m indices either assigns injectively into
    candidate buckets or raises the bounded CuckooFailure — never a wrong
    assignment, never silence."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(N, size=PARAMS.m, replace=False)
    try:
        table = cuckoo_assign(idx, LAYOUT, rng)
    except CuckooFailure as e:
        assert e.index in idx                          # names the culprit
        return
    assert sorted(table.values()) == sorted(int(i) for i in idx)
    assert len(table) == len(idx)                      # capacity 1
    for b, i in table.items():
        assert b in LAYOUT.hashes[i]


def test_cuckoo_assign_rejects_bad_batches():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="unique"):
        cuckoo_assign([1, 1], LAYOUT, rng)
    with pytest.raises(ValueError, match="exceeds m"):
        cuckoo_assign(list(range(PARAMS.m + 1)), LAYOUT, rng)
    # a single index ALWAYS places (the split-retry termination argument)
    for i in range(0, N, 17):
        assert list(cuckoo_assign([i], LAYOUT, rng).values()) == [i]


def test_plan_round_structure_and_reassembly():
    import dataclasses
    from repro.core.protocol import for_config
    cfg = PIRConfig(n_items=N, batch_m=4)
    proto = for_config(cfg)
    inner = dataclasses.replace(cfg, n_items=LAYOUT.capacity)
    rng = np.random.default_rng(1)
    plan = plan_round(rng, [3, 3, 200, 77], LAYOUT, inner, proto)
    assert plan.n_buckets == PARAMS.n_buckets
    assert sum(plan.real) == 3                         # 3 unique
    assert len(plan.party_keys(0)) == plan.n_buckets
    # dummy slots stay inside the bucket domain
    assert all(0 <= s < LAYOUT.capacity for s in plan.slots)
    # reassembly fans the duplicate out of its single assigned bucket
    recs = np.arange(plan.n_buckets)[:, None] * np.ones((1, 8), np.int64)
    out = reassemble(plan, recs)
    assert out.shape == (4, 8)
    assert out[0, 0] == out[1, 0] == plan.bucket_of[3]
    assert out[2, 0] == plan.bucket_of[200]


# ---------------------------------------------------------------------------
# BucketedDatabase: placement, fan-out updates, outer epoch
# ---------------------------------------------------------------------------

def _host_view(bdb, b):
    return np.asarray(bdb.snapshot(("words",))[1]["words"][b])


def test_bucketed_database_materializes_layout():
    cfg = PIRConfig(n_items=N, batch_m=4, checksum=True)
    bdb = BucketedDatabase(DB, cfg, make_local_mesh())
    assert bdb.n_buckets == PARAMS.n_buckets
    assert bdb.capacity == bdb.layout.capacity
    assert bdb.inner_spec == DatabaseSpec(n_items=bdb.capacity,
                                          item_bytes=32, checksum=True)
    assert bdb.inner_cfg.n_items == bdb.capacity
    assert bdb.expansion == pytest.approx(
        bdb.n_buckets * bdb.capacity / N)
    stored = bdb.inner_spec.attach_checksums(DB)
    for b in range(bdb.n_buckets):
        view = _host_view(bdb, b)
        rows = bdb.layout.bucket_rows[b]
        np.testing.assert_array_equal(view[:len(rows)], stored[rows])
        # pad rows: zero payload with a VALID checksum (dummy queries may
        # hit them; verification must not fire)
        pad = view[len(rows):]
        assert (pad[:, :-1] == 0).all()
        bdb.inner_spec.verify_stored_rows(pad)
    # stats aggregate across buckets: one full placement per bucket
    assert bdb.stats.n_full_placements == bdb.n_buckets


def test_bucketed_stage_publish_fans_out_to_candidate_buckets():
    cfg = PIRConfig(n_items=N, batch_m=4, checksum=True)
    bdb = BucketedDatabase(DB, cfg, make_local_mesh())
    assert bdb.epoch == 0
    assert bdb.publish() == 0                          # no-op stays no-op
    target = 123
    new_val = np.random.default_rng(2).integers(
        0, 1 << 32, size=(1, 8), dtype=np.uint32)
    assert bdb.stage([target], new_val) == 1
    assert bdb.n_staged == len(bdb.layout.occurrences(target))
    assert bdb.publish() == 1 and bdb.epoch == 1
    stored_row = bdb.inner_spec.attach_checksums(new_val)[0]
    for b, slot in bdb.layout.occurrences(target):
        np.testing.assert_array_equal(_host_view(bdb, b)[slot], stored_row)
    # untouched buckets kept their epoch-0 contents (spot check)
    other = next(i for i in range(N)
                 if not set(dict(bdb.layout.occurrences(i)))
                 & set(dict(bdb.layout.occurrences(target))))
    b0, s0 = bdb.layout.occurrences(other)[0]
    np.testing.assert_array_equal(
        _host_view(bdb, b0)[s0],
        bdb.inner_spec.attach_checksums(DB[other][None])[0])
    # update traffic is O(rows · n_hashes), not O(db)
    assert bdb.stats.update_h2d_bytes < cfg.db_bytes // 4
    with pytest.raises(ValueError, match="out of range"):
        bdb.stage([N], new_val)


def test_bucketed_database_validates_inputs():
    cfg = PIRConfig(n_items=N, batch_m=4)
    with pytest.raises(ValueError, match="batch size m"):
        BucketedDatabase(DB, PIRConfig(n_items=N), make_local_mesh())
    with pytest.raises(ValueError, match="db_words"):
        BucketedDatabase(DB[: N // 2], cfg, make_local_mesh())
    with pytest.raises(ValueError, match="does not match cfg"):
        BucketedDatabase(DB, cfg, make_local_mesh(),
                         layout=CuckooLayout.build(N, CuckooParams(m=8)))
