"""System-level behaviour tests: the paper's end-to-end story in one place.

1. Private retrieval is *correct* at system level (client never sends the
   index; two servers answer independently; reconstruction yields the
   record) — across DB sizes, batch sizes and server paths.
2. The serve loop batches queries and tracks throughput stats.
3. The LM serving integration: PIR-backed private token-embedding lookup
   returns bit-exact embedding rows (the Lam et al. [61] use case the
   paper benchmarks against).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.config import PIRConfig
from repro.core import dpf, pir
from repro.core.server import PIRServer
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import PIRServeLoop, TwoServerPIR

pytestmark = pytest.mark.slow    # compile-heavy: full-step jits on a 1-core CPU



@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh()


@pytest.mark.parametrize("log_n,item_bytes", [(8, 32), (12, 32), (10, 64)])
def test_end_to_end_retrieval(mesh, log_n, item_bytes):
    n = 1 << log_n
    db = pir.make_database(np.random.default_rng(0), n, item_bytes)
    cfg = PIRConfig(n_items=n, item_bytes=item_bytes, batch_queries=2)
    sys2 = TwoServerPIR(db, cfg, mesh, path="fused", n_queries=2)
    idx = [0, n - 1]
    np.testing.assert_array_equal(sys2.query(idx), db[idx])


def test_serve_loop_stats(mesh):
    n = 1 << 10
    db = pir.make_database(np.random.default_rng(1), n, 32)
    cfg = PIRConfig(n_items=n, batch_queries=4)
    server = PIRServer(party=0, db_words=db, cfg=cfg, mesh=mesh,
                       n_queries=4, path="baseline")
    loop = PIRServeLoop(server, n_clusters=2)
    rng = np.random.default_rng(2)
    for step in range(3):
        k0, _ = pir.batch_queries(rng, [step, step + 1, step + 2, step + 3],
                                  cfg)
        loop.submit(k0)
    answers = loop.drain()
    assert len(answers) == 3
    assert loop.stats.answered == 12
    assert loop.stats.qps > 0


def test_private_embedding_lookup(mesh):
    """PIR over an LM embedding table: retrieved rows are bit-exact.

    The table's bf16 rows are viewed as uint32 words (pairs of bf16), the
    XOR-PIR protocol retrieves the row for a *hidden* token id, and the
    client reassembles the bf16 vector — exact retrieval of arbitrary
    payloads, which quantization-based schemes cannot guarantee.
    """
    vocab_pow2, d = 1 << 10, 64
    rng = np.random.default_rng(3)
    table_bf16 = jnp.asarray(rng.standard_normal((vocab_pow2, d)),
                             jnp.bfloat16)
    # view bf16 pairs as uint32 words: [V, d/2]
    table_u16 = np.asarray(table_bf16).view(np.uint16).astype(np.uint32)
    table_words = ((table_u16[:, 1::2] << 16) | table_u16[:, 0::2])

    cfg = PIRConfig(n_items=vocab_pow2, item_bytes=d * 2, batch_queries=2)
    sys2 = TwoServerPIR(table_words, cfg, mesh, path="fused", n_queries=2)
    token_ids = [17, 513]
    rows = sys2.query(token_ids)                     # [2, d/2] uint32
    # unpack back to the bf16 bit pattern
    out = np.empty((2, d), np.uint16)
    out[:, 0::2] = (rows & 0xFFFF).astype(np.uint16)
    out[:, 1::2] = (rows >> 16).astype(np.uint16)
    want = np.asarray(table_bf16)[np.asarray(token_ids)].view(np.uint16)
    np.testing.assert_array_equal(out, want)


def test_query_privacy_shape_invariance(mesh):
    """Server-visible work is index-independent: the key tensors a server
    receives have identical shapes/dtypes for every query index (the
    all-for-one principle's observable side)."""
    n = 1 << 8
    cfg = PIRConfig(n_items=n)
    rng = np.random.default_rng(4)
    shapes = set()
    for idx in (0, 1, n // 2, n - 1):
        q = pir.query_gen(rng, idx, cfg)
        k = q.keys[0]
        shapes.add((k.root_seed.shape, k.cw_seed.shape, k.cw_t.shape))
    assert len(shapes) == 1
