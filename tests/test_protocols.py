"""Protocol-plane tests: registry, config shim, and oracle parity.

Fast tier: everything here evaluates DPF components *eagerly* (python
loops over ``dpf.eval_range``) or through the small interpret-mode Pallas
kernels — no serve-step compiles (those cost ~40-70 s each on this
container and live in the slow tier / examples).

Oracle pairs:
  * ``kernels/pir_matmul.py`` (Pallas GEMM) vs ``kernels/ref.py`` oracle;
  * ``XorDpfK`` (k = 3) vs a pure-numpy reference: per-party selection
    vectors XOR to the one-hot e_alpha, and numpy-folded answers XOR to
    the DB row — while every single party's vector stays dense
    pseudorandom (the 1-privacy sanity check);
  * the ``pad_keys`` round-trip: pad -> answer -> slice == unpadded.
"""
import warnings

import numpy as np
import pytest
from _prop import given, settings, st

import jax
import jax.numpy as jnp

from repro.config import PIRConfig
from repro.core import dpf, pir
from repro.core import protocol as protocol_mod
from repro.core.protocol import (ExecutionPlan, PATH_PLANS, available,
                                 for_config, get, plan_for, resolve_plan)
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)
LOG_N = 6
N = 1 << LOG_N
DB = pir.make_database(np.random.default_rng(0), N, 32)


# ---------------------------------------------------------------------------
# registry + config shim
# ---------------------------------------------------------------------------

def test_registry_names():
    assert {"xor-dpf-2", "additive-dpf-2", "xor-dpf-k",
            "lwe-simple-1"} <= set(available())
    assert get("xor-dpf-2").n_parties(PIRConfig(n_items=N)) == 2
    with pytest.raises(KeyError, match="unknown protocol"):
        get("nope-9000")
    # record structs drive e.g. MultiServerPIR.query([])'s empty result
    cfg = PIRConfig(n_items=N, item_bytes=32)
    assert get("xor-dpf-2").record_struct(cfg) == ((8,), np.uint32)
    assert get("xor-dpf-k").record_struct(cfg) == ((8,), np.uint32)
    assert get("additive-dpf-2").record_struct(cfg) == ((32,), np.uint8)
    assert get("lwe-simple-1").record_struct(cfg) == ((32,), np.uint8)
    # the single-server protocol: 1 party, hint-carrying, lwe share kind
    lwe_proto = get("lwe-simple-1")
    assert lwe_proto.n_parties(PIRConfig(n_items=N, n_servers=1)) == 1
    assert lwe_proto.needs_hint and lwe_proto.share_kind == "lwe"
    assert PIRConfig(n_items=N, protocol="lwe-simple-1").share_kind == "lwe"


def test_config_protocol_defaults_and_mode_shim():
    import dataclasses
    cfg = PIRConfig(n_items=N)
    assert cfg.protocol == "xor-dpf-2" and cfg.share_kind == "xor"
    assert cfg.mode == ""              # constructor sugar, never stored
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = PIRConfig(n_items=N, mode="additive")
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert legacy.protocol == "additive-dpf-2"
    assert legacy.share_kind == "additive"
    assert for_config(legacy).name == "additive-dpf-2"
    with pytest.raises(ValueError, match="unknown PIR mode"):
        PIRConfig(n_items=N, mode="quantum")
    # both replace() directions keep working: protocol switches cleanly,
    # and the pre-protocol-plane mode= idiom still wins over the carried
    # protocol (with the deprecation warning)
    assert dataclasses.replace(cfg, protocol="additive-dpf-2").protocol \
        == "additive-dpf-2"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert dataclasses.replace(cfg, mode="additive").protocol \
            == "additive-dpf-2"
        # consistent share algebra: the richer protocol name survives
        assert PIRConfig(n_items=N, mode="xor",
                         protocol="xor-dpf-k").protocol == "xor-dpf-k"


def test_k_server_party_counts_and_specs():
    cfg = PIRConfig(n_items=N, protocol="xor-dpf-k", n_servers=3)
    proto = for_config(cfg)
    assert proto.n_parties(cfg) == 3
    q = pir.query_gen(RNG, 5, cfg)
    assert len(q.keys) == 3
    batch = pir.batch_queries(RNG, [1, 2], cfg)
    for party in range(3):
        spec = proto.key_specs(cfg, 2, party=party)
        # treedef AND shapes must match real keys (per-bucket jit contract)
        assert (jax.tree_util.tree_structure(batch[party])
                == jax.tree_util.tree_structure(spec))
        assert ([x.shape for x in jax.tree_util.tree_leaves(batch[party])]
                == [x.shape for x in jax.tree_util.tree_leaves(spec)])
    with pytest.raises(ValueError, match="n_servers"):
        proto.n_parties(PIRConfig(n_items=N, protocol="xor-dpf-k",
                                  n_servers=1))


def test_plan_selection_rules():
    # legacy path strings keep their meaning
    assert PATH_PLANS["baseline"].expand == "materialize"
    assert PATH_PLANS["fused"].expand == "fused"
    plan = resolve_plan("fused", PIRConfig(n_items=N), 4, chunk_log=9,
                        collective="butterfly")
    assert (plan.expand, plan.chunk_log, plan.collective) == \
        ("fused", 9, "butterfly")
    with pytest.raises(ValueError, match="unknown path"):
        resolve_plan("warp-drive", PIRConfig(n_items=N), 4)
    # the GEMM path needs additive shares: XOR protocols must refuse, not
    # silently fall back to the XOR scan (would mislabel benchmarks)
    from repro.core.server import build_serve_fn
    from repro.launch.mesh import make_local_mesh
    with pytest.raises(ValueError, match="additive"):
        build_serve_fn(PIRConfig(n_items=N), make_local_mesh(),
                       n_queries=2, path="matmul")
    # selector: additive -> GEMM contraction; XOR small db / single query
    # -> materialize; XOR big db -> fused; Pallas bodies only on TPU
    small = plan_for(PIRConfig(n_items=1 << 10), 4, backend="cpu")
    big = plan_for(PIRConfig(n_items=1 << 20), 8, backend="cpu")
    single = plan_for(PIRConfig(n_items=1 << 20), 1, backend="cpu")
    assert small.expand == "materialize" and big.expand == "fused"
    assert single.expand == "materialize"
    assert plan_for(PIRConfig(n_items=1 << 20), 8, backend="tpu").scan \
        == "pallas"
    assert big.scan == "jnp"     # CPU: interpret-mode Pallas would be slow


# ---------------------------------------------------------------------------
# registry conformance: ONE body every registered protocol must pass
# ---------------------------------------------------------------------------

def _conformance_cfg(name: str) -> PIRConfig:
    n_servers = {"xor-dpf-k": 3, "lwe-simple-1": 1}.get(name, 2)
    return PIRConfig(n_items=N, protocol=name, n_servers=n_servers)


def _oracle_records(proto, db_words, indices):
    """What reconstruction must return: u32 words (XOR algebras) or
    Z_256 bytes (GEMM algebras)."""
    if proto.share_kind == "xor":
        return db_words[indices]
    return pir.db_as_bytes(db_words)[indices]


def _answer_one(proto, view_np, key, log_n=LOG_N):
    """One party's answer for ONE query, eagerly, per share algebra.

    Deliberately the single-key evaluation idiom (``dpf.eval_range`` /
    Q=1 ``eval_bytes_batch``) the other fast-tier tests use: those
    primitive shapes are already op-cached in-process, while the batched
    vmap forms would each pay a fresh multi-second lowering here.
    """
    if proto.share_kind == "xor":
        bits = (_party_bits_np(key, log_n) if key.root_seed.ndim > 1
                else _bits_np(key, log_n))
        return _answer_np(view_np, bits)                       # [W] u32
    if proto.share_kind == "additive":
        shares = np.asarray(dpf.eval_bytes_batch(
            dpf.stack_keys([key]), 0, log_n))[0]
        return (shares.astype(np.int64)
                @ view_np.astype(np.int64)).astype(np.int32)   # [L] i32
    # lwe: ct^T.D mod q in numpy (device answer parity lives in test_lwe)
    ct = np.asarray(key.ct).view(np.uint32).astype(np.uint64)
    ans = (ct @ view_np.astype(np.uint64)) & np.uint64(0xFFFFFFFF)
    return ans.astype(np.uint32).view(np.int32)                # [L] i32


def _eager_answers(proto, cfg, view_np, batches):
    """All parties' [Q, ...] answers, slot by slot off the batched keys."""
    out = []
    for p in range(proto.n_parties(cfg)):
        n = proto.n_queries(batches[p])
        rows = [_answer_one(proto, view_np,
                            jax.tree_util.tree_map(lambda x, i=i: x[i],
                                                   batches[p]))
                for i in range(n)]
        out.append(np.stack(rows))
    return out


@pytest.mark.parametrize("name", sorted(available()))
def test_protocol_conformance(name):
    """The registry contract, one shared body per protocol: query_gen_full
    -> batch -> eager answers -> reconstruct_with matches the oracle; the
    pad round-trip leaves real slots untouched; and answers flowing
    through a QueryScheduler are epoch-tagged correctly across a publish.
    Any protocol added to the registry is swept automatically."""
    from repro.db import ShardedDatabase
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.serve_loop import QueryScheduler

    from repro.db import DatabaseSpec

    cfg = _conformance_cfg(name)
    proto = for_config(cfg)
    k = proto.n_parties(cfg)
    indices = [5, N - 1]
    view_np = DatabaseSpec.from_config(cfg).pack_host(DB, proto.db_view)

    full = [proto.query_gen_full(RNG, i, cfg) for i in indices]
    states = [f[1] for f in full]
    batches = [dpf.stack_keys([f[0][p] for f in full]) for p in range(k)]
    for b in batches:
        assert proto.n_queries(b) == 2

    hint = (np.asarray(proto.hint_builder(cfg)(jnp.asarray(DB)))
            if proto.needs_hint else None)
    answers = _eager_answers(proto, cfg, view_np, batches)
    rec = np.asarray(proto.reconstruct_with(answers, states, cfg=cfg,
                                            hint=hint))
    np.testing.assert_array_equal(rec, _oracle_records(proto, DB, indices))

    # pad round-trip: pad -> answer -> slice == unpadded on real slots
    padded = [proto.pad(b, 4) for b in batches]
    for p in padded:
        assert proto.n_queries(p) == 4
    answers_p = _eager_answers(proto, cfg, view_np, padded)
    rec_p = np.asarray(proto.reconstruct_with(
        [a[:2] for a in answers_p], states, cfg=cfg, hint=hint))
    np.testing.assert_array_equal(rec_p, rec)

    # epoch tagging: the same eager answer path behind a QueryScheduler,
    # across a publish — answers carry the epoch they computed against
    db = ShardedDatabase(DB, cfg, make_local_mesh())
    if proto.needs_hint:
        db.register_hint(proto.name, proto.hint_builder(cfg),
                         proto.hint_delta(cfg))

    def dispatch(items):
        epoch, views = db.snapshot((proto.db_view,))
        v_np, sts = np.asarray(views[proto.db_view]), [it[1] for it in items]
        ans = [np.stack([_answer_one(proto, v_np, it[0][p]) for it in items])
               for p in range(k)]
        return ans, sts, epoch

    def finalize(raw, n):
        ans, sts, epoch = raw
        h = (np.asarray(db.hint(proto.name, epoch=epoch))
             if proto.needs_hint else None)
        return list(np.asarray(proto.reconstruct_with(
            [a[:n] for a in ans], sts[:n], cfg=cfg, hint=h)))

    sched = QueryScheduler(
        collate=list, stage=lambda p: p, dispatch=dispatch,
        finalize=finalize, buckets=(2,), epoch_of=lambda raw: raw[2])

    fut0 = sched.submit(proto.query_gen_full(RNG, 9, cfg))
    sched.submit(proto.query_gen_full(RNG, 9, cfg))
    sched.pump()
    assert fut0.epoch == 0
    np.testing.assert_array_equal(fut0.result(0),
                                  _oracle_records(proto, DB, [9])[0])

    new_val = np.random.default_rng(8).integers(
        0, 1 << 32, size=(1, 8), dtype=np.uint32)
    db.stage([9], new_val)
    assert db.publish() == 1
    updated = DB.copy()
    updated[9] = new_val
    fut1 = sched.submit(proto.query_gen_full(RNG, 9, cfg))
    sched.submit(proto.query_gen_full(RNG, 9, cfg))
    sched.pump()
    assert fut1.epoch == 1
    np.testing.assert_array_equal(fut1.result(0),
                                  _oracle_records(proto, updated, [9])[0])


# ---------------------------------------------------------------------------
# numpy reference helpers (eager per-component eval: no compiles)
# ---------------------------------------------------------------------------

def _bits_np(key: dpf.DPFKey, log_n: int) -> np.ndarray:
    """Selection bits of one plain (component-free) DPF key."""
    _, t = dpf.eval_range(key, 0, log_n)
    return np.asarray(t, np.uint32)


def _party_bits_np(party_key: dpf.DPFKey, log_n: int) -> np.ndarray:
    """One k-server party's full selection vector (leaves ``[C, ...]``),
    component-by-component in numpy."""
    n_comp = party_key.root_seed.shape[0]
    acc = np.zeros(1 << log_n, np.uint32)
    for c in range(n_comp):
        comp = jax.tree_util.tree_map(lambda x, c=c: x[c], party_key)
        acc ^= _bits_np(comp, log_n)
    return acc


def _answer_np(db: np.ndarray, bits: np.ndarray) -> np.ndarray:
    """numpy select-XOR oracle: ⊕_{j: bits[j]=1} db[j]."""
    out = np.zeros(db.shape[1], np.uint32)
    for j in np.nonzero(bits)[0]:
        out ^= db[j]
    return out


# ---------------------------------------------------------------------------
# XorDpfK(k=3) vs the numpy reference
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, N - 1))
def test_xor_dpf_k3_matches_numpy_reference(alpha):
    cfg = PIRConfig(n_items=N, protocol="xor-dpf-k", n_servers=3)
    proto = for_config(cfg)
    keys = proto.query_gen(RNG, alpha, cfg)
    bits = [_party_bits_np(k, LOG_N) for k in keys]
    # k-of-k reconstruction: selection vectors XOR to e_alpha ...
    onehot = np.zeros(N, np.uint32)
    onehot[alpha] = 1
    np.testing.assert_array_equal(bits[0] ^ bits[1] ^ bits[2], onehot)
    # ... and numpy-folded answers XOR to the DB row
    answers = [_answer_np(DB, b) for b in bits]
    np.testing.assert_array_equal(answers[0] ^ answers[1] ^ answers[2],
                                  DB[alpha])
    # 1-privacy sanity: every single party's vector is dense pseudorandom
    # (a sparse vector would leak alpha's neighbourhood)
    for b in bits:
        assert 0.2 < b.mean() < 0.8


def test_xor_dpf_k2_degenerates_to_two_server():
    """k=2: the ring masks cancel pairwise; answers equal plain 2-DPF."""
    cfg = PIRConfig(n_items=N, protocol="xor-dpf-k", n_servers=2)
    proto = for_config(cfg)
    keys = proto.query_gen(np.random.default_rng(3), 42, cfg)
    bits = [_party_bits_np(k, LOG_N) for k in keys]
    onehot = np.zeros(N, np.uint32)
    onehot[42] = 1
    np.testing.assert_array_equal(bits[0] ^ bits[1], onehot)


# ---------------------------------------------------------------------------
# pir_matmul (Pallas) vs the jnp oracle
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, (1 << 31) - 1))
def test_pir_matmul_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    q, r, l = 4, 128, 32                 # grid over the reduction dim
    s = jnp.asarray(rng.integers(-128, 128, size=(q, r), dtype=np.int8))
    d = jnp.asarray(rng.integers(-128, 128, size=(r, l), dtype=np.int8))
    got = ops.pir_gemm(s, d, tile_q=4, tile_r=64, tile_l=32)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.pir_matmul_ref(s, d)))


# ---------------------------------------------------------------------------
# pad_keys round-trip: pad -> answer -> slice == unpadded
# ---------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(st.integers(0, N - 3))
def test_pad_keys_roundtrip_xor2(base):
    """Padded batches answer identically on the real slots (both parties)."""
    cfg = PIRConfig(n_items=N)
    idx = [base, base + 1, base + 2]                 # Q=3 -> bucket 4
    batch = pir.batch_queries(RNG, idx, cfg)
    def slot_answer(keys, i):
        one = jax.tree_util.tree_map(lambda x: x[i], keys)
        return _answer_np(DB, _bits_np(one, LOG_N))

    for party in range(2):
        padded = dpf.pad_keys(batch[party], 4)
        assert dpf.n_queries_of(padded) == 4
        unpadded_ans = [slot_answer(batch[party], i) for i in range(3)]
        padded_ans = [slot_answer(padded, i) for i in range(4)]
        # slice off the pad slot: real answers unchanged
        for i in range(3):
            np.testing.assert_array_equal(padded_ans[i], unpadded_ans[i])
        # the pad slot replicates the last real key's answer
        np.testing.assert_array_equal(padded_ans[3], unpadded_ans[2])


def test_pad_keys_roundtrip_k3_component_axis():
    """pad_keys pads the *query* axis of k-server component pytrees."""
    cfg = PIRConfig(n_items=N, protocol="xor-dpf-k", n_servers=3)
    proto = for_config(cfg)
    batch = pir.batch_queries(RNG, [4, 9], cfg)
    for party, key in enumerate(batch):
        padded = proto.pad(key, 4)
        assert proto.n_queries(padded) == 4
        # component axis untouched; pad slots replicate the last real key
        assert padded.root_seed.shape == (4,) + key.root_seed.shape[1:]
        np.testing.assert_array_equal(np.asarray(padded.root_seed[3]),
                                      np.asarray(key.root_seed[-1]))
        bits_last = _party_bits_np(
            jax.tree_util.tree_map(lambda x: x[1], key), LOG_N)
        bits_pad = _party_bits_np(
            jax.tree_util.tree_map(lambda x: x[3], padded), LOG_N)
        np.testing.assert_array_equal(bits_pad, bits_last)


# ---------------------------------------------------------------------------
# batch composite (cuckoo-bucketed, DESIGN.md §14) conformance
# ---------------------------------------------------------------------------

#: the inner protocols the batch composite serves (every registered
#: k-party protocol; hint protocols are rejected by BatchPIR)
BATCH_PROTOCOLS = ["xor-dpf-2", "additive-dpf-2", "xor-dpf-k"]


def _batch_cfg(name: str) -> PIRConfig:
    n_servers = {"xor-dpf-k": 3}.get(name, 2)
    # checksum ON: PR 8 verified reconstruction must ride through the
    # per-bucket reconstructions (incl. dummy buckets' pad rows)
    return PIRConfig(n_items=N, protocol=name, n_servers=n_servers,
                     batch_m=4, checksum=True)


def _eager_round(proto, bdb, plan):
    """One RoundPlan's per-party per-bucket answers + reassembled records,
    eagerly (single-key eval; no serve-step compiles) — the oracle-side
    mirror of BatchPIR's dispatch/finalize closures."""
    log_n = (bdb.capacity - 1).bit_length()
    epoch, views = bdb.snapshot((proto.db_view,))
    k = proto.n_parties(bdb.inner_cfg)
    shares = [np.stack([_answer_one(proto,
                                    np.asarray(views[proto.db_view][b]),
                                    plan.keys[b][p], log_n)
                        for b in range(bdb.n_buckets)])
              for p in range(k)]
    recs = np.asarray(proto.reconstruct_with(
        shares, [None] * bdb.n_buckets, cfg=bdb.inner_cfg))
    from repro.core.batch import reassemble
    return reassemble(plan, recs), epoch


@pytest.mark.parametrize("name", BATCH_PROTOCOLS)
def test_batch_composite_conformance(name):
    """The batch composite against the numpy oracle, per inner protocol:
    a cuckoo-planned round reconstructs the requested records (duplicates
    included, checksum verification riding through), and staged rows land
    in every candidate bucket's view across a publish (epoch tagging)."""
    from repro.core.batch import plan_round
    from repro.db import BucketedDatabase
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.serve_loop import QueryScheduler

    cfg = _batch_cfg(name)
    proto = for_config(cfg)
    bdb = BucketedDatabase(DB, cfg, make_local_mesh())
    rng = np.random.default_rng(3)

    indices = [5, N - 1, 17, 5]            # duplicate rides one bucket
    plan = plan_round(rng, indices, bdb.layout, bdb.inner_cfg, proto)
    rec, epoch = _eager_round(proto, bdb, plan)
    assert epoch == 0
    np.testing.assert_array_equal(rec, _oracle_records(proto, DB, indices))

    # epoch tagging through a QueryScheduler wired like BatchPIR's: the
    # answer computed after a publish carries the new OUTER epoch and the
    # staged row is served from every candidate bucket it was fanned to
    def dispatch(plans):
        outs = [_eager_round(proto, bdb, p) for p in plans]
        return [o[0] for o in outs], outs[0][1]

    sched = QueryScheduler(
        collate=list, stage=lambda p: p, dispatch=dispatch,
        finalize=lambda raw, n: raw[0][:n], buckets=(1,),
        epoch_of=lambda raw: raw[1])

    target = 9
    fut0 = sched.submit(plan_round(rng, [target], bdb.layout,
                                   bdb.inner_cfg, proto))
    sched.pump()
    assert fut0.epoch == 0
    np.testing.assert_array_equal(fut0.result(0)[0],
                                  _oracle_records(proto, DB, [target])[0])

    new_val = np.random.default_rng(8).integers(
        0, 1 << 32, size=(1, 8), dtype=np.uint32)
    bdb.stage([target], new_val)
    assert bdb.publish() == 1
    updated = DB.copy()
    updated[target] = new_val
    fut1 = sched.submit(plan_round(rng, [target], bdb.layout,
                                   bdb.inner_cfg, proto))
    sched.pump()
    assert fut1.epoch == 1
    np.testing.assert_array_equal(fut1.result(0)[0],
                                  _oracle_records(proto, updated,
                                                  [target])[0])


@pytest.mark.parametrize("name", BATCH_PROTOCOLS)
def test_batch_round_uniform_padding_no_occupancy_leak(name):
    """ACCEPTANCE: every round issues exactly B per-bucket queries with an
    identical server-observable key structure, REGARDLESS of which m
    indices were requested — bucket occupancy never leaks the batch."""
    from repro.core.batch import CuckooLayout, CuckooParams, plan_round
    import dataclasses

    cfg = _batch_cfg(name)
    proto = for_config(cfg)
    params = CuckooParams.from_config(cfg).validate()
    layout = CuckooLayout.build(cfg.n_items, params)
    inner_cfg = dataclasses.replace(cfg, n_items=layout.capacity)
    B = params.n_buckets
    rng = np.random.default_rng(11)

    # adversarial spreads: clustered, spread, partial, duplicated —
    # every round plan must be structurally identical
    batches = [[0, 1, 2, 3], [7, 19, 42, 63], [5], [9, 9, 9, 9],
               [N - 4, N - 3, N - 2, N - 1]]
    ref_struct = None
    for idx in batches:
        plan = plan_round(rng, idx, layout, inner_cfg, proto)
        assert plan.n_buckets == B                       # exactly B queries
        assert len(plan.keys) == B and len(plan.real) == B
        assert sum(plan.real) == len(set(idx))           # rest are dummies
        # the server-observable shape: per-party key pytree structure and
        # leaf shapes are index-independent (dummies share real keygen)
        struct = [
            [(jax.tree_util.tree_structure(plan.keys[b][p]),
              tuple(np.shape(leaf)
                    for leaf in jax.tree_util.tree_leaves(plan.keys[b][p])))
             for b in range(B)]
            for p in range(proto.n_parties(cfg))]
        if ref_struct is None:
            ref_struct = struct
        assert struct == ref_struct


def test_batch_dummy_query_indistinguishability_smoke():
    """Dummy-bucket keys run the real keygen on a uniform slot: their key
    material's marginal statistics match real keys' (loose first-moment
    smoke over DPF root seeds — cryptographic indistinguishability is the
    PRG's job; this guards against e.g. zeroed dummy seeds)."""
    from repro.core.batch import CuckooLayout, CuckooParams, plan_round
    import dataclasses

    cfg = _batch_cfg("xor-dpf-2")
    proto = for_config(cfg)
    params = CuckooParams.from_config(cfg).validate()
    layout = CuckooLayout.build(cfg.n_items, params)
    inner_cfg = dataclasses.replace(cfg, n_items=layout.capacity)
    rng = np.random.default_rng(29)

    real_w, dummy_w = [], []
    for _ in range(64):
        idx = rng.choice(N, size=4, replace=False)
        plan = plan_round(rng, idx, layout, inner_cfg, proto)
        for b in range(plan.n_buckets):
            for p in range(2):
                seed = np.asarray(plan.keys[b][p].root_seed,
                                  np.uint64).ravel()
                (real_w if plan.real[b] else dummy_w).extend(seed.tolist())
    assert len(real_w) >= 256 and len(dummy_w) >= 256
    # both populations are uniform u32 words: means within 10% of range
    mid, tol = 2.0 ** 31, 0.1 * 2.0 ** 32
    assert abs(np.mean(real_w) - mid) < tol
    assert abs(np.mean(dummy_w) - mid) < tol
    assert abs(np.mean(real_w) - np.mean(dummy_w)) < tol
