"""Protocol-plane tests: registry, config shim, and oracle parity.

Fast tier: everything here evaluates DPF components *eagerly* (python
loops over ``dpf.eval_range``) or through the small interpret-mode Pallas
kernels — no serve-step compiles (those cost ~40-70 s each on this
container and live in the slow tier / examples).

Oracle pairs:
  * ``kernels/pir_matmul.py`` (Pallas GEMM) vs ``kernels/ref.py`` oracle;
  * ``XorDpfK`` (k = 3) vs a pure-numpy reference: per-party selection
    vectors XOR to the one-hot e_alpha, and numpy-folded answers XOR to
    the DB row — while every single party's vector stays dense
    pseudorandom (the 1-privacy sanity check);
  * the ``pad_keys`` round-trip: pad -> answer -> slice == unpadded.
"""
import warnings

import numpy as np
import pytest
from _prop import given, settings, st

import jax
import jax.numpy as jnp

from repro.config import PIRConfig
from repro.core import dpf, pir
from repro.core import protocol as protocol_mod
from repro.core.protocol import (ExecutionPlan, PATH_PLANS, available,
                                 for_config, get, plan_for, resolve_plan)
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)
LOG_N = 6
N = 1 << LOG_N
DB = pir.make_database(np.random.default_rng(0), N, 32)


# ---------------------------------------------------------------------------
# registry + config shim
# ---------------------------------------------------------------------------

def test_registry_names():
    assert {"xor-dpf-2", "additive-dpf-2", "xor-dpf-k"} <= set(available())
    assert get("xor-dpf-2").n_parties(PIRConfig(n_items=N)) == 2
    with pytest.raises(KeyError, match="unknown protocol"):
        get("nope-9000")
    # record structs drive e.g. MultiServerPIR.query([])'s empty result
    cfg = PIRConfig(n_items=N, item_bytes=32)
    assert get("xor-dpf-2").record_struct(cfg) == ((8,), np.uint32)
    assert get("xor-dpf-k").record_struct(cfg) == ((8,), np.uint32)
    assert get("additive-dpf-2").record_struct(cfg) == ((32,), np.uint8)


def test_config_protocol_defaults_and_mode_shim():
    import dataclasses
    cfg = PIRConfig(n_items=N)
    assert cfg.protocol == "xor-dpf-2" and cfg.share_kind == "xor"
    assert cfg.mode == ""              # constructor sugar, never stored
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = PIRConfig(n_items=N, mode="additive")
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert legacy.protocol == "additive-dpf-2"
    assert legacy.share_kind == "additive"
    assert for_config(legacy).name == "additive-dpf-2"
    with pytest.raises(ValueError, match="unknown PIR mode"):
        PIRConfig(n_items=N, mode="quantum")
    # both replace() directions keep working: protocol switches cleanly,
    # and the pre-protocol-plane mode= idiom still wins over the carried
    # protocol (with the deprecation warning)
    assert dataclasses.replace(cfg, protocol="additive-dpf-2").protocol \
        == "additive-dpf-2"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert dataclasses.replace(cfg, mode="additive").protocol \
            == "additive-dpf-2"
        # consistent share algebra: the richer protocol name survives
        assert PIRConfig(n_items=N, mode="xor",
                         protocol="xor-dpf-k").protocol == "xor-dpf-k"


def test_k_server_party_counts_and_specs():
    cfg = PIRConfig(n_items=N, protocol="xor-dpf-k", n_servers=3)
    proto = for_config(cfg)
    assert proto.n_parties(cfg) == 3
    q = pir.query_gen(RNG, 5, cfg)
    assert len(q.keys) == 3
    batch = pir.batch_queries(RNG, [1, 2], cfg)
    for party in range(3):
        spec = proto.key_specs(cfg, 2, party=party)
        # treedef AND shapes must match real keys (per-bucket jit contract)
        assert (jax.tree_util.tree_structure(batch[party])
                == jax.tree_util.tree_structure(spec))
        assert ([x.shape for x in jax.tree_util.tree_leaves(batch[party])]
                == [x.shape for x in jax.tree_util.tree_leaves(spec)])
    with pytest.raises(ValueError, match="n_servers"):
        proto.n_parties(PIRConfig(n_items=N, protocol="xor-dpf-k",
                                  n_servers=1))


def test_plan_selection_rules():
    # legacy path strings keep their meaning
    assert PATH_PLANS["baseline"].expand == "materialize"
    assert PATH_PLANS["fused"].expand == "fused"
    plan = resolve_plan("fused", PIRConfig(n_items=N), 4, chunk_log=9,
                        collective="butterfly")
    assert (plan.expand, plan.chunk_log, plan.collective) == \
        ("fused", 9, "butterfly")
    with pytest.raises(ValueError, match="unknown path"):
        resolve_plan("warp-drive", PIRConfig(n_items=N), 4)
    # the GEMM path needs additive shares: XOR protocols must refuse, not
    # silently fall back to the XOR scan (would mislabel benchmarks)
    from repro.core.server import build_serve_fn
    from repro.launch.mesh import make_local_mesh
    with pytest.raises(ValueError, match="additive"):
        build_serve_fn(PIRConfig(n_items=N), make_local_mesh(),
                       n_queries=2, path="matmul")
    # selector: additive -> GEMM contraction; XOR small db / single query
    # -> materialize; XOR big db -> fused; Pallas bodies only on TPU
    small = plan_for(PIRConfig(n_items=1 << 10), 4, backend="cpu")
    big = plan_for(PIRConfig(n_items=1 << 20), 8, backend="cpu")
    single = plan_for(PIRConfig(n_items=1 << 20), 1, backend="cpu")
    assert small.expand == "materialize" and big.expand == "fused"
    assert single.expand == "materialize"
    assert plan_for(PIRConfig(n_items=1 << 20), 8, backend="tpu").scan \
        == "pallas"
    assert big.scan == "jnp"     # CPU: interpret-mode Pallas would be slow


# ---------------------------------------------------------------------------
# numpy reference helpers (eager per-component eval: no compiles)
# ---------------------------------------------------------------------------

def _bits_np(key: dpf.DPFKey, log_n: int) -> np.ndarray:
    """Selection bits of one plain (component-free) DPF key."""
    _, t = dpf.eval_range(key, 0, log_n)
    return np.asarray(t, np.uint32)


def _party_bits_np(party_key: dpf.DPFKey, log_n: int) -> np.ndarray:
    """One k-server party's full selection vector (leaves ``[C, ...]``),
    component-by-component in numpy."""
    n_comp = party_key.root_seed.shape[0]
    acc = np.zeros(1 << log_n, np.uint32)
    for c in range(n_comp):
        comp = jax.tree_util.tree_map(lambda x, c=c: x[c], party_key)
        acc ^= _bits_np(comp, log_n)
    return acc


def _answer_np(db: np.ndarray, bits: np.ndarray) -> np.ndarray:
    """numpy select-XOR oracle: ⊕_{j: bits[j]=1} db[j]."""
    out = np.zeros(db.shape[1], np.uint32)
    for j in np.nonzero(bits)[0]:
        out ^= db[j]
    return out


# ---------------------------------------------------------------------------
# XorDpfK(k=3) vs the numpy reference
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, N - 1))
def test_xor_dpf_k3_matches_numpy_reference(alpha):
    cfg = PIRConfig(n_items=N, protocol="xor-dpf-k", n_servers=3)
    proto = for_config(cfg)
    keys = proto.query_gen(RNG, alpha, cfg)
    bits = [_party_bits_np(k, LOG_N) for k in keys]
    # k-of-k reconstruction: selection vectors XOR to e_alpha ...
    onehot = np.zeros(N, np.uint32)
    onehot[alpha] = 1
    np.testing.assert_array_equal(bits[0] ^ bits[1] ^ bits[2], onehot)
    # ... and numpy-folded answers XOR to the DB row
    answers = [_answer_np(DB, b) for b in bits]
    np.testing.assert_array_equal(answers[0] ^ answers[1] ^ answers[2],
                                  DB[alpha])
    # 1-privacy sanity: every single party's vector is dense pseudorandom
    # (a sparse vector would leak alpha's neighbourhood)
    for b in bits:
        assert 0.2 < b.mean() < 0.8


def test_xor_dpf_k2_degenerates_to_two_server():
    """k=2: the ring masks cancel pairwise; answers equal plain 2-DPF."""
    cfg = PIRConfig(n_items=N, protocol="xor-dpf-k", n_servers=2)
    proto = for_config(cfg)
    keys = proto.query_gen(np.random.default_rng(3), 42, cfg)
    bits = [_party_bits_np(k, LOG_N) for k in keys]
    onehot = np.zeros(N, np.uint32)
    onehot[42] = 1
    np.testing.assert_array_equal(bits[0] ^ bits[1], onehot)


# ---------------------------------------------------------------------------
# pir_matmul (Pallas) vs the jnp oracle
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, (1 << 31) - 1))
def test_pir_matmul_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    q, r, l = 4, 128, 32                 # grid over the reduction dim
    s = jnp.asarray(rng.integers(-128, 128, size=(q, r), dtype=np.int8))
    d = jnp.asarray(rng.integers(-128, 128, size=(r, l), dtype=np.int8))
    got = ops.pir_gemm(s, d, tile_q=4, tile_r=64, tile_l=32)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.pir_matmul_ref(s, d)))


# ---------------------------------------------------------------------------
# pad_keys round-trip: pad -> answer -> slice == unpadded
# ---------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(st.integers(0, N - 3))
def test_pad_keys_roundtrip_xor2(base):
    """Padded batches answer identically on the real slots (both parties)."""
    cfg = PIRConfig(n_items=N)
    idx = [base, base + 1, base + 2]                 # Q=3 -> bucket 4
    batch = pir.batch_queries(RNG, idx, cfg)
    def slot_answer(keys, i):
        one = jax.tree_util.tree_map(lambda x: x[i], keys)
        return _answer_np(DB, _bits_np(one, LOG_N))

    for party in range(2):
        padded = dpf.pad_keys(batch[party], 4)
        assert dpf.n_queries_of(padded) == 4
        unpadded_ans = [slot_answer(batch[party], i) for i in range(3)]
        padded_ans = [slot_answer(padded, i) for i in range(4)]
        # slice off the pad slot: real answers unchanged
        for i in range(3):
            np.testing.assert_array_equal(padded_ans[i], unpadded_ans[i])
        # the pad slot replicates the last real key's answer
        np.testing.assert_array_equal(padded_ans[3], unpadded_ans[2])


def test_pad_keys_roundtrip_k3_component_axis():
    """pad_keys pads the *query* axis of k-server component pytrees."""
    cfg = PIRConfig(n_items=N, protocol="xor-dpf-k", n_servers=3)
    proto = for_config(cfg)
    batch = pir.batch_queries(RNG, [4, 9], cfg)
    for party, key in enumerate(batch):
        padded = proto.pad(key, 4)
        assert proto.n_queries(padded) == 4
        # component axis untouched; pad slots replicate the last real key
        assert padded.root_seed.shape == (4,) + key.root_seed.shape[1:]
        np.testing.assert_array_equal(np.asarray(padded.root_seed[3]),
                                      np.asarray(key.root_seed[-1]))
        bits_last = _party_bits_np(
            jax.tree_util.tree_map(lambda x: x[1], key), LOG_N)
        bits_pad = _party_bits_np(
            jax.tree_util.tree_map(lambda x: x[3], padded), LOG_N)
        np.testing.assert_array_equal(bits_pad, bits_last)
