"""Runtime: fault policies, straggler logic, elastic planning, train loop."""
import numpy as np
import pytest

import jax

from repro.config import MeshConfig, OptimizerConfig, RunConfig
from repro.configs import SMOKES
from repro.configs.shapes import SMOKE_TRAIN
from repro.launch.mesh import make_local_mesh
from repro.runtime.elastic import plan_mesh, rebuild_mesh
from repro.runtime.fault import (HeartbeatRegistry, PoisonPolicy,
                                 StragglerMonitor, retry_step)


# ---------------------------------------------------------------------------
# fault policies (injectable clocks — deterministic)
# ---------------------------------------------------------------------------

def test_heartbeat_suspects():
    t = [0.0]
    reg = HeartbeatRegistry(timeout=10.0, clock=lambda: t[0])
    reg.beat("a")
    reg.beat("b")
    t[0] = 5.0
    reg.beat("b")
    t[0] = 12.0
    assert reg.suspects() == ["a"]
    assert reg.healthy() == ["b"]


def test_retry_step_backoff():
    calls = {"n": 0}
    sleeps = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_step(flaky, retries=3, sleep=sleeps.append) == "ok"
    assert sleeps == [0.5, 1.0]


def test_retry_step_exhausts():
    def always():
        raise RuntimeError("down")
    with pytest.raises(RuntimeError):
        retry_step(always, retries=2, sleep=lambda s: None)


def test_poison_policy_transitions():
    p = PoisonPolicy(max_consecutive=3)
    assert p.observe(1.0) == "ok"
    assert p.observe(float("nan")) == "skip"
    assert p.observe(float("inf")) == "skip"
    assert p.observe(float("nan")) == "rewind"
    assert p.consecutive == 0
    assert p.total_skipped == 3


def test_straggler_detection_and_reassign():
    mon = StragglerMonitor(factor=2.0, alpha=1.0)
    for c, lat in (("c0", 1.0), ("c1", 1.1), ("c2", 5.0)):
        mon.record(c, lat)
    assert mon.stragglers() == ["c2"]
    queues = {"c0": [1], "c1": [2], "c2": [3, 4]}
    out = mon.reassign(queues)
    assert out["c2"] == []
    assert sorted(sum(out.values(), [])) == [1, 2, 3, 4]


# ---------------------------------------------------------------------------
# elastic planning
# ---------------------------------------------------------------------------

def test_plan_mesh_shrinks_data_axis():
    cfg = plan_mesh(256, model_axis=16)
    assert cfg.shape == (16, 16)
    cfg = plan_mesh(192, model_axis=16)   # lost 4 nodes of 16 devices
    assert cfg.shape == (8, 16)           # data halves, model pinned
    cfg = plan_mesh(512, model_axis=16, prefer_pods=2)
    assert cfg.shape == (2, 16, 16)


def test_rebuild_mesh_local():
    mesh = rebuild_mesh(model_axis=1)
    assert "model" in mesh.axis_names


# ---------------------------------------------------------------------------
# train loop end-to-end (smoke scale): ckpt + resume + rewind path
# ---------------------------------------------------------------------------

def _loop(tmp_path, steps=6):
    from repro.runtime.train_loop import TrainLoop, TrainLoopConfig
    run = RunConfig(
        model=SMOKES["granite-3-2b"], shape=SMOKE_TRAIN,
        mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                  total_steps=steps))
    return TrainLoop(run, make_local_mesh(),
                     TrainLoopConfig(total_steps=steps, ckpt_every=2,
                                     ckpt_dir=str(tmp_path), log_every=0),
                     log=lambda s: None)


def test_train_loop_with_checkpointing(tmp_path):
    loop = _loop(tmp_path)
    with loop.mesh:
        res = loop.run_loop()
    assert res.final_step == 6
    assert len(res.losses) == 6
    assert loop.ckpt.latest_step() == 6


def test_train_loop_resume(tmp_path):
    loop = _loop(tmp_path, steps=4)
    with loop.mesh:
        loop.run_loop()
    loop2 = _loop(tmp_path, steps=4)
    with loop2.mesh:
        res = loop2.run_loop(resume=True)
    assert res.final_step == 4       # resumed at 4, nothing left to do
    assert res.losses == []
