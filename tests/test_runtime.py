"""Runtime: fault policies, straggler logic, elastic planning, train loop."""
import numpy as np
import pytest

import jax

from repro.config import MeshConfig, OptimizerConfig, RunConfig
from repro.configs import SMOKES
from repro.configs.shapes import SMOKE_TRAIN
from repro.launch.mesh import make_local_mesh, split_devices
from repro.runtime.elastic import carve_submeshes, plan_mesh, rebuild_mesh
from repro.runtime.fault import (HeartbeatRegistry, PoisonPolicy,
                                 RetryStats, StragglerMonitor, retry_step)


# ---------------------------------------------------------------------------
# fault policies (injectable clocks — deterministic)
# ---------------------------------------------------------------------------

def test_heartbeat_suspects():
    t = [0.0]
    reg = HeartbeatRegistry(timeout=10.0, clock=lambda: t[0])
    reg.beat("a")
    reg.beat("b")
    t[0] = 5.0
    reg.beat("b")
    t[0] = 12.0
    assert reg.suspects() == ["a"]
    assert reg.healthy() == ["b"]


def test_retry_step_backoff():
    calls = {"n": 0}
    sleeps = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_step(flaky, retries=3, sleep=sleeps.append) == "ok"
    assert sleeps == [0.5, 1.0]


def test_retry_step_exhausts():
    def always():
        raise RuntimeError("down")
    with pytest.raises(RuntimeError):
        retry_step(always, retries=2, sleep=lambda s: None)


def test_heartbeat_remove_retires_departed_participant():
    """Departure is not failure: a removed participant must stop showing
    up as a suspect forever (the replica registry's leave path)."""
    t = [0.0]
    reg = HeartbeatRegistry(timeout=10.0, clock=lambda: t[0])
    reg.beat("a")
    reg.beat("b")
    assert reg.remove("a") is True
    assert reg.remove("a") is False              # already gone
    assert reg.forget("nope") is False           # alias, unknown id
    t[0] = 100.0                                 # way past timeout
    assert reg.suspects() == ["b"]               # "a" never resurfaces
    assert reg.healthy() == []


def test_retry_step_backoff_is_capped():
    calls = {"n": 0}
    sleeps = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 7:
            raise RuntimeError("transient")
        return "ok"

    assert retry_step(flaky, retries=6, base_delay=0.5, max_delay=2.0,
                      sleep=sleeps.append) == "ok"
    assert sleeps == [0.5, 1.0, 2.0, 2.0, 2.0, 2.0]   # capped, not 16.0


def test_retry_step_surfaces_attempt_stats():
    stats = RetryStats()
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_step(flaky, retries=3, sleep=lambda s: None,
                      stats=stats) == "ok"
    assert stats.attempts == 3
    assert stats.retried == 2
    assert stats.slept_s == pytest.approx(0.5 + 1.0)
    # stats accumulate across calls (the router reuses one instance)
    retry_step(lambda: "ok", stats=stats, sleep=lambda s: None)
    assert stats.attempts == 4 and stats.retried == 2


def test_poison_policy_transitions():
    p = PoisonPolicy(max_consecutive=3)
    assert p.observe(1.0) == "ok"
    assert p.observe(float("nan")) == "skip"
    assert p.observe(float("inf")) == "skip"
    assert p.observe(float("nan")) == "rewind"
    assert p.consecutive == 0
    assert p.total_skipped == 3


def test_straggler_detection_and_reassign():
    mon = StragglerMonitor(factor=2.0, alpha=1.0)
    for c, lat in (("c0", 1.0), ("c1", 1.1), ("c2", 5.0)):
        mon.record(c, lat)
    assert mon.stragglers() == ["c2"]
    queues = {"c0": [1], "c1": [2], "c2": [3, 4]}
    out = mon.reassign(queues)
    assert out["c2"] == []
    assert sorted(sum(out.values(), [])) == [1, 2, 3, 4]


# ---------------------------------------------------------------------------
# elastic planning
# ---------------------------------------------------------------------------

def test_plan_mesh_shrinks_data_axis():
    cfg = plan_mesh(256, model_axis=16)
    assert cfg.shape == (16, 16)
    cfg = plan_mesh(192, model_axis=16)   # lost 4 nodes of 16 devices
    assert cfg.shape == (8, 16)           # data halves, model pinned
    cfg = plan_mesh(512, model_axis=16, prefer_pods=2)
    assert cfg.shape == (2, 16, 16)


def test_rebuild_mesh_local():
    mesh = rebuild_mesh(model_axis=1)
    assert "model" in mesh.axis_names


def test_plan_mesh_non_pow2_device_counts():
    """Stragglers rarely leave neat shapes: data rounds DOWN to the
    largest power of two that fits; leftovers idle until the next
    resize."""
    assert plan_mesh(96, model_axis=16).shape == (4, 16)     # 96//16=6 -> 4
    assert plan_mesh(17, model_axis=16).shape == (1, 16)
    assert plan_mesh(3, model_axis=1).shape == (2, 1)
    assert plan_mesh(1, model_axis=1).shape == (1, 1)


def test_plan_mesh_prefer_pods_divides_before_rounding():
    cfg = plan_mesh(96, model_axis=16, prefer_pods=2)        # 48 per pod
    assert cfg.shape == (2, 2, 16) and cfg.axes == ("pod", "data", "model")
    cfg = plan_mesh(64, model_axis=16, prefer_pods=4)        # 16 per pod
    assert cfg.shape == (4, 1, 16)


def test_plan_mesh_rejects_too_few_devices():
    with pytest.raises(ValueError, match="< model axis"):
        plan_mesh(8, model_axis=16)
    with pytest.raises(ValueError, match="< model axis"):
        rebuild_mesh([object()] * 2, model_axis=4)


def test_split_devices_partitions_or_shares():
    devs = [f"d{i}" for i in range(8)]
    groups = split_devices(2, devs)
    assert groups == [devs[:4], devs[4:]]                    # disjoint halves
    groups = split_devices(3, devs)                          # 8//3=2 each
    assert [len(g) for g in groups] == [2, 2, 2]             # 2 idle
    assert len({d for g in groups for d in g}) == 6
    # degenerate single-host case: too few devices -> every group gets
    # the FULL list (replicas share silicon, keep separate schedulers)
    groups = split_devices(4, devs[:2], min_per_group=1)
    assert groups == [devs[:2]] * 4
    groups = split_devices(2, devs, min_per_group=8)
    assert groups == [devs] * 2
    with pytest.raises(ValueError, match=">= 1"):
        split_devices(0, devs)


def test_carve_submeshes_one_mesh_per_replica():
    meshes = carve_submeshes(2, model_axis=1)
    assert len(meshes) == 2
    for m in meshes:
        assert "model" in m.axis_names and "data" in m.axis_names


# ---------------------------------------------------------------------------
# train loop end-to-end (smoke scale): ckpt + resume + rewind path
# ---------------------------------------------------------------------------

def _loop(tmp_path, steps=6):
    from repro.runtime.train_loop import TrainLoop, TrainLoopConfig
    run = RunConfig(
        model=SMOKES["granite-3-2b"], shape=SMOKE_TRAIN,
        mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                  total_steps=steps))
    return TrainLoop(run, make_local_mesh(),
                     TrainLoopConfig(total_steps=steps, ckpt_every=2,
                                     ckpt_dir=str(tmp_path), log_every=0),
                     log=lambda s: None)


def test_train_loop_with_checkpointing(tmp_path):
    loop = _loop(tmp_path)
    with loop.mesh:
        res = loop.run_loop()
    assert res.final_step == 6
    assert len(res.losses) == 6
    assert loop.ckpt.latest_step() == 6


def test_train_loop_resume(tmp_path):
    loop = _loop(tmp_path, steps=4)
    with loop.mesh:
        loop.run_loop()
    loop2 = _loop(tmp_path, steps=4)
    with loop2.mesh:
        res = loop2.run_loop(resume=True)
    assert res.final_step == 4       # resumed at 4, nothing left to do
    assert res.losses == []
