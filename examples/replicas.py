"""Replica plane demo: router, mid-load failover, bounded-staleness epochs.

IM-PIR scales PIR throughput with many independent clusters, each
scanning its own full database replica (Take-away 5). This demo runs
that topology one tier up: two :class:`ServeReplica` deployments (own
sub-mesh, own compiled LWE serve step, own ``ShardedDatabase``) behind a
:class:`Router` doing power-of-two-choices balancing — then

  1. publishes an update through the front tier and shows both replicas
     converge to the same epoch;
  2. kills one replica while its queue is loaded and shows every
     already-submitted query still resolves byte-correct (failover
     resubmits by index onto the healthy peer — zero lost answers);
  3. rejoins a fresh replica warmed from the healthy peer's plans and
     shows it comes up at the front-tier epoch with a non-heuristic plan
     (the delta-log catch-up + plan-cache warm start).

Run:  PYTHONPATH=src python examples/replicas.py
"""
import numpy as np

from repro.configs.pir import PIR_SMOKE_REPL
from repro.core import pir
from repro.replica import Router, ServeReplica, metrics
from repro.runtime.elastic import carve_submeshes


def main():
    cfg = PIR_SMOKE_REPL         # 2^12 records x 32 B, lwe-simple-1
    rng = np.random.default_rng(0)
    db_host = pir.make_database(rng, cfg.n_items, cfg.item_bytes)
    oracle = pir.db_as_bytes(db_host).copy()

    meshes = carve_submeshes(2, model_axis=1)
    router = Router(rng=np.random.default_rng(1), base_delay=0.01,
                    max_delay=0.5)
    kw = dict(n_queries=4, buckets=(4,), max_wait_s=0.002)
    r0 = router.attach(ServeReplica("r0", db_host, cfg, meshes[0], **kw))
    r1 = router.attach(ServeReplica("r1", db_host, cfg, meshes[1], **kw))
    print(f"fleet: 2 replicas x ({cfg.n_items} records x {cfg.item_bytes} B,"
          f" protocol={cfg.protocol}), P2C routing")

    # --- 1. epoch propagation: one publish, both replicas converge ------
    target = 7
    new_record = rng.integers(0, 1 << 32, size=(1, cfg.item_bytes // 4),
                              dtype=np.uint32)
    router.update([target], new_record)
    epoch = router.publish()
    oracle[target] = new_record.view(np.uint8).ravel()
    assert (r0.epoch, r1.epoch) == (epoch, epoch), "fleet must converge"
    print(f"published epoch {epoch}: fan-out converged "
          f"(r0={r0.epoch}, r1={r1.epoch}, lag=0)")

    # --- 2. kill one replica mid-load: zero lost answers ----------------
    session = router.session("demo-client")
    session.replica = "r0"       # pin the load onto the victim
    indices = [target, 3, 999, cfg.n_items - 1, 42, target, 17, 2048]
    futures = [router.submit(i, session=session) for i in indices]
    r0.kill("demo: power loss")
    answers = [np.asarray(f.result(timeout=180.0)) for f in futures]
    for idx, ans in zip(indices, answers):
        assert np.array_equal(ans, oracle[idx]), f"D[{idx}] mismatch"
        assert futures[indices.index(idx)].epoch == epoch
    assert "r0" in router.registry.suspects(), "dead replica quarantined"
    print(f"killed r0 with {len(indices)} queries submitted: all "
          f"{len(answers)} answers correct at epoch {epoch} "
          f"({router.failovers} failovers, zero lost)")

    # --- 3. rejoin warm: catch up the epoch, skip re-tuning --------------
    router.detach("r0")
    r0b = ServeReplica("r0", db_host, cfg, meshes[0],
                       warm_plans=r1.export_plans(), **kw)
    router.attach(r0b)
    assert r0b.epoch == epoch, "delta-log replay must catch the joiner up"
    provenances = {r["provenance"] for r in r0b.plan_report().values()}
    assert "heuristic" not in provenances, \
        f"warm-started replica must not fall back to the heuristic " \
        f"(got {provenances})"
    session2 = router.session("demo-client-2")
    session2.replica = "r0"
    check = router.submit(target, session=session2).result(timeout=180.0)
    assert np.array_equal(np.asarray(check), oracle[target])
    print(f"r0 rejoined hot: epoch {r0b.epoch}, plan provenance "
          f"{sorted(provenances)} (no re-tuning), first query correct")

    snap = metrics.snapshot(router)
    print(f"fleet metrics: answered={snap['router']['answered']} "
          f"failovers={snap['router']['failovers']} "
          f"max_epoch_lag={snap['router']['max_epoch_lag']}")
    for r in list(router.replicas.values()):
        r.close()
    print("replica-plane failover + epoch propagation verified.")


if __name__ == "__main__":
    main()
