"""Quickstart: private information retrieval in ~40 lines.

Spins up the two non-colluding servers, retrieves a record without either
server learning which, and verifies the reconstruction — the paper's
Figure 2 flow on the production code path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.pir import PIR_SMOKE
from repro.core import pir
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import TwoServerPIR

def main():
    # A database of 2^14 records, each a 32-byte hash — the paper's
    # certificate-transparency / breached-credentials shape (§5.2).
    cfg = PIR_SMOKE
    rng = np.random.default_rng(0)
    db = pir.make_database(rng, cfg.n_items, cfg.item_bytes)
    print(f"DB: {cfg.n_items} records x {cfg.item_bytes} B "
          f"({cfg.db_bytes / (1 << 20):.0f} MiB)")

    # Two servers, each holding a full replica; the 'fused' path runs DPF
    # evaluation and the select-XOR scan in one pass (IM-PIR's offload,
    # with the GGM tree on-device — see DESIGN.md §2).
    mesh = make_local_mesh()
    system = TwoServerPIR(db, cfg, mesh, path="fused", n_queries=4)

    secret_indices = [7, 4242, 9000, cfg.n_items - 1]
    print(f"querying indices {secret_indices} (servers never see these)")
    records = system.query(secret_indices)

    for idx, rec in zip(secret_indices, records):
        ok = np.array_equal(rec, db[idx])
        print(f"  D[{idx:6d}] -> {bytes(rec.view(np.uint8))[:8].hex()}... "
              f"{'OK' if ok else 'MISMATCH'}")
        assert ok
    print("private retrieval verified.")


if __name__ == "__main__":
    main()
