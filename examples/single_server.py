"""Single-server PIR (SimplePIR-style LWE): hint reuse + epoch refresh.

The two/k-server facades need non-colluding parties; this demo drops that
assumption (DESIGN.md §10). One server holds the database and answers
LWE-encrypted one-hot queries with an int32 GEMM — privacy rests on LWE
hardness, not on parties never comparing notes. The client downloads the
per-epoch hint ``H = A^T.DB`` once, reconstructs every query against it
locally, and re-fetches only when ``publish()`` bumps the epoch (the
server maintains H incrementally via the registered delta).

Parameters come from the validated table in ``core/lwe.py`` and are
demonstration-grade: the noise/modulus accounting is tested, the lattice
hardness is not a security review.

Run:  PYTHONPATH=src python examples/single_server.py
"""
import numpy as np

from repro.configs.pir import PIR_SMOKE_LWE
from repro.core import pir
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import SingleServerPIR


def main():
    cfg = PIR_SMOKE_LWE          # 2^14 records x 32 B, lwe-simple-1, k=1
    rng = np.random.default_rng(0)
    db_host = pir.make_database(rng, cfg.n_items, cfg.item_bytes)

    system = SingleServerPIR(db_host, cfg, make_local_mesh(),
                             n_queries=4, buckets=(4,))
    print(f"DB: {cfg.n_items} records x {cfg.item_bytes} B; "
          f"protocol={cfg.protocol} ({system.n_parties} server — "
          f"no collusion assumption, privacy from LWE)")

    # --- query twice: the hint is fetched once, reused across batches ---
    secret_indices = [7, 4242, 9000, cfg.n_items - 1]
    records = system.query(secret_indices)
    oracle = pir.db_as_bytes(db_host)
    for idx, rec in zip(secret_indices, records):
        assert np.array_equal(rec, oracle[idx]), f"D[{idx}] mismatch"
        print(f"  D[{idx:6d}] -> {bytes(rec)[:8].hex()}... OK")
    system.query([123, 456, 789, 1011])
    assert system.hint_fetches == 1, "second batch must reuse the hint"
    assert system.db.stats.n_hint_builds == 1
    print(f"hint: built once server-side, fetched once client-side "
          f"({system.hint_fetches} fetch across 2 batches)")

    # --- publish an update: hint delta server-side, re-fetch client-side
    target = secret_indices[0]
    new_record = rng.integers(0, 1 << 32, size=(1, cfg.item_bytes // 4),
                              dtype=np.uint32)
    system.update([target], new_record)
    epoch = system.publish()
    db_host[target] = new_record[0]
    after = system.query([target])[0]
    assert np.array_equal(after, pir.db_as_bytes(db_host)[target]), \
        "updated row must serve from the new epoch"
    assert system.db.stats.n_hint_deltas == 1, \
        "publish must delta-update the hint, not rebuild it"
    assert system.db.stats.n_hint_builds == 1
    assert system.hint_fetches == 2, "epoch bump must invalidate the cache"
    print(f"published epoch {epoch}: hint delta-updated (O(rows changed)), "
          f"stale client cache refreshed ({system.hint_fetches} fetches)")
    print("single-server private retrieval verified.")


if __name__ == "__main__":
    main()
