"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps.

Exercises the full production path at container scale: config system ->
data pipeline -> pjit train step (grad accumulation) -> fault-tolerant
loop -> async checkpointing -> resume.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
(≈100M params; pass --tiny for a fast CI-scale run.)
"""
import argparse
import os

import jax

from repro.config import (MeshConfig, ModelConfig, OptimizerConfig,
                          RunConfig, ShapeConfig)
from repro.launch.mesh import make_local_mesh
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig


def model_100m() -> ModelConfig:
    # ~104M params: 12L x 768, GQA 12/4, SwiGLU 2048, 32k vocab
    return ModelConfig(name="lm-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                       vocab=32000, attn_chunk=256)


def model_tiny() -> ModelConfig:
    return ModelConfig(name="lm-tiny", family="dense", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                       vocab=2048, attn_chunk=64)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    model = model_tiny() if args.tiny else model_100m()
    shape = ShapeConfig(
        name="example",
        seq_len=args.seq or (128 if args.tiny else 512),
        global_batch=args.batch or (8 if args.tiny else 16),
        kind="train")
    mesh = make_local_mesh()
    run = RunConfig(
        model=model, shape=shape,
        mesh=MeshConfig(shape=tuple(mesh.devices.shape),
                        axes=tuple(mesh.axis_names)),
        optimizer=OptimizerConfig(name="adamw", lr=3e-4,
                                  warmup_steps=max(args.steps // 20, 1),
                                  total_steps=args.steps),
        microbatches=2)
    n = model.n_params()
    print(f"model {model.name}: {n/1e6:.1f}M params, "
          f"batch {shape.global_batch}x{shape.seq_len}")

    loop = TrainLoop(run, mesh, TrainLoopConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 20, 1)))
    with mesh:
        res = loop.run_loop(resume=args.resume)
    print(f"done: step {res.final_step}, "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"(skipped {res.skipped_steps}, rewinds {res.rewinds})")
    print(f"checkpoints: {sorted(os.listdir(args.ckpt_dir))}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
