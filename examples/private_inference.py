"""Private-embedding LM serving — the Lam et al. [61] use case end-to-end.

A client runs a small LM but must not reveal its token stream to the
embedding-table host (on-device ML inference with server-side tables).
Per generated token:

  1. the client DPF-encodes the token id into two keys,
  2. two non-colluding servers answer with XOR shares of the embedding
     row (bf16 bit-exact — the table is served as uint32 words),
  3. the client reconstructs the row, runs the transformer locally, and
     greedily picks the next token.

Batched requests: several concurrent streams share each PIR step (the
paper's query batching, §3.4).

Run:  PYTHONPATH=src python examples/private_inference.py [--tokens 8]
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, PIRConfig
from repro.core import pir
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models.layers import pad_vocab
from repro.runtime.serve_loop import TwoServerPIR


def table_as_words(table_bf16: np.ndarray) -> np.ndarray:
    """[V, d] bf16 -> [V, d/2] uint32 (PIR payload view)."""
    u16 = table_bf16.view(np.uint16).astype(np.uint32)
    return (u16[:, 1::2] << 16) | u16[:, 0::2]


def words_as_rows(words: np.ndarray, d: int):
    out = np.empty(words.shape[:-1] + (d,), np.uint16)
    out[..., 0::2] = (words & 0xFFFF).astype(np.uint16)
    out[..., 1::2] = (words >> 16).astype(np.uint16)
    return out.view(jnp.bfloat16.dtype)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--streams", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = ModelConfig(name="pi-lm", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=1 << 10,
                      attn_chunk=16)
    model = build_model(cfg, remat="none")
    params = model.init_params(jax.random.PRNGKey(0))

    # The embedding table is the PIR database (vocab padded to 2^k rows).
    table = np.asarray(params["embed"], jnp.bfloat16)
    v_pow2 = 1 << (pad_vocab(cfg.vocab) - 1).bit_length()
    table_padded = np.zeros((v_pow2, cfg.d_model), jnp.bfloat16)
    table_padded[: table.shape[0]] = table
    words = table_as_words(table_padded)

    pir_cfg = PIRConfig(n_items=v_pow2, item_bytes=cfg.d_model * 2,
                        batch_queries=args.streams)
    mesh = make_local_mesh()
    servers = TwoServerPIR(words, pir_cfg, mesh, path="fused",
                           n_queries=args.streams)

    B = args.streams
    prompt = np.asarray([[3 + i, 17, 41] for i in range(B)], np.int32)

    # --- client-side embedding via PIR, trunk runs locally ---------------
    def embed_private(token_ids) -> jax.Array:
        rows = servers.query(list(int(t) for t in token_ids))
        return jnp.asarray(words_as_rows(rows, cfg.d_model))

    def forward_from_embeds(embeds):
        # teacher-forced trunk pass given client-reconstructed embeddings
        x = embeds
        positions = jnp.arange(x.shape[1])[None, :]
        x, _, _ = model._scan_stack(params["dense_layers"], x, positions,
                                    moe_layer=False, want_cache=False)
        from repro.models import layers as L
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return L.unembed(x, params["unembed"], cfg.vocab)

    stream = prompt
    pir_queries = 0
    for step in range(args.tokens):
        embeds = jnp.stack([
            embed_private(stream[:, t]) for t in range(stream.shape[1])
        ], axis=1)          # [B, T, d] — every lookup was private
        pir_queries += stream.shape[1] * 1
        logits = forward_from_embeds(embeds)
        nxt = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1),
                         np.int32)
        stream = np.concatenate([stream, nxt[:, None]], axis=1)
        print(f"step {step}: +{nxt.tolist()}")

    # verify privacy-path embeddings match plain lookups bit-exactly
    plain = np.asarray(params["embed"])[stream[:, -1]]
    priv = np.asarray(embed_private(stream[:, -1]))
    assert np.array_equal(plain.view(np.uint16), priv.view(np.uint16))
    print(f"generated streams:\n{stream}")
    print(f"PIR-backed lookups were bit-exact; "
          f"{pir_queries} private queries issued.")


if __name__ == "__main__":
    main()
