"""Batch PIR: m records per round through cuckoo buckets (DESIGN.md §14).

The amortization demo: a ``BatchPIR`` session retrieves m=4 records per
round by cuckoo-hashing the requested indices into B = 2m buckets (each a
capacity-rows slice of the database, replicated under 3 hash functions)
and issuing exactly ONE real-or-dummy inner query per bucket — the
servers see a fixed B-wide round regardless of which indices were asked,
and the scanned rows per round (B·capacity ≈ 4N) serve m records instead
of one. All B buckets share a single compiled serve step per party
(one shape -> one executable), so the m-fold batching costs zero extra
compiles. Mid-session, a stage+publish write lands in every candidate
bucket and the next round's answer futures carry the new epoch.

Run:  PYTHONPATH=src python examples/batch_query.py
"""
import numpy as np

from repro.configs.pir import PIR_SMOKE_BATCH
from repro.core import pir
from repro.launch.mesh import make_local_mesh
from repro.runtime.batch import BatchPIR


def main():
    cfg = PIR_SMOKE_BATCH        # 2^10 records x 32 B, m=4, checksums on
    rng = np.random.default_rng(0)
    db_host = pir.make_database(rng, cfg.n_items, cfg.item_bytes)

    system = BatchPIR(db_host, cfg, make_local_mesh(), path="fused")
    bdb = system.db
    print(f"DB: {cfg.n_items} records x {cfg.item_bytes} B -> "
          f"m={cfg.batch_m} batch: B={bdb.n_buckets} buckets x "
          f"{bdb.capacity} rows (expansion {bdb.expansion:.1f}x, "
          f"cuckoo failure bound "
          f"{system.layout.params.failure_bound():.3f})")

    # --- one m-record round --------------------------------------------
    batch = [123, 7, 877, 123]           # duplicates share a bucket query
    records = system.query_batch(batch)
    for i, rec in zip(batch, records):
        assert np.array_equal(rec, db_host[i]), f"record {i} mismatch"
    rounds, width = system.dispatch_log[-1]
    assert width == bdb.n_buckets, "every round must be exactly B wide"
    print(f"epoch {bdb.epoch}: {len(batch)} records in {rounds} round(s) "
          f"of {width} per-bucket queries "
          f"(scanned {width * bdb.capacity} rows vs "
          f"{len(set(batch)) * cfg.n_items} single-query)")

    # --- stage + publish mid-session, then re-query --------------------
    target = batch[0]
    new_record = rng.integers(0, 1 << 32, size=(1, cfg.item_bytes // 4),
                              dtype=np.uint32)
    system.update([target], new_record)
    epoch = system.publish()
    fut = system.submit_batch([target, 7])
    system.scheduler.pump()
    after = np.asarray(fut.result(timeout=360.0))
    assert np.array_equal(after[0], new_record[0]), "updated row must serve"
    assert np.array_equal(after[1], db_host[7]), "untouched row unchanged"
    assert fut.epoch == epoch, "answers must carry the published epoch"
    print(f"published epoch {epoch}: D[{target}] rewrote in all "
          f"{len(system.layout.occurrences(target))} candidate buckets; "
          f"post-publish round tagged epoch={fut.epoch}")

    # the whole session — every bucket, every round, pre/post publish —
    # ran on ONE compiled serve step per party
    assert all(s.n_compiles == 1 for s in system.serve), \
        "B buckets must share one compiled step per party"
    print(f"batch session served: {system.n_parties} parties x "
          f"1 compile each, uniform {bdb.n_buckets}-wide rounds, "
          f"checksums verified on every reconstruction.")


if __name__ == "__main__":
    main()
