"""Three-server PIR session via the protocol plane (beyond-paper).

The paper's deployment is two non-colluding servers; the protocol registry
(``core/protocol.py``) generalizes the share scheme, and this demo runs the
``xor-dpf-k`` protocol with k = 3: one real DPF pair blinded by a ring of
pairwise-shared GGM mask seeds (DESIGN.md §7.2). Each of the three servers
scans the full database with a *dense pseudorandom* selection vector — no
single server (nor its answer share) learns anything about the queried
index — and the client XORs all three answer shares to reconstruct.

Everything below the facade is the same production machinery as the
two-server quickstart: one ``PIRServer`` (bucketed compiled serve steps)
per party, one ``QueryScheduler`` coalescing the query stream, shares
reconciled through ``PIRProtocol.reconstruct``.

Run:  PYTHONPATH=src python examples/multi_server.py
"""
import numpy as np

from repro.configs.pir import PIR_SMOKE_K3
from repro.core import dpf, pir
from repro.core.protocol import for_config
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import MultiServerPIR


def main():
    cfg = PIR_SMOKE_K3           # 2^12 records x 32 B, xor-dpf-k, k=3
    proto = for_config(cfg)
    rng = np.random.default_rng(0)
    db = pir.make_database(rng, cfg.n_items, cfg.item_bytes)
    print(f"DB: {cfg.n_items} records x {cfg.item_bytes} B; "
          f"protocol={cfg.protocol} ({proto.n_parties(cfg)} parties)")

    # one bucket keeps this demo to one XLA compile per party (~40 s each
    # on a 1-core CPU container); ragged traffic pads up to it
    system = MultiServerPIR(db, cfg, make_local_mesh(), path="fused",
                            n_queries=4, buckets=(4,))
    assert len(system.servers) == 3

    secret_indices = [7, 1234, 4000, cfg.n_items - 1]
    print(f"querying indices {secret_indices} "
          f"(none of the 3 servers sees these)")
    records = system.query(secret_indices)

    for idx, rec in zip(secret_indices, records):
        ok = np.array_equal(rec, db[idx])
        print(f"  D[{idx:5d}] -> {bytes(rec.view(np.uint8))[:8].hex()}... "
              f"{'OK' if ok else 'MISMATCH'}")
        assert ok

    # show why a single server learns nothing: its share is pseudorandom
    q = pir.query_gen(np.random.default_rng(1), 7, cfg)
    share0 = np.asarray(system.servers[0].answer(
        dpf.stack_keys([q.keys[0]])))[0]
    print(f"server 0's answer share for D[7]: "
          f"{bytes(share0.view(np.uint8))[:8].hex()}... "
          f"(pseudorandom; equals D[7] only after XOR with the other two)")
    assert not np.array_equal(share0, db[7])
    print("3-server private retrieval verified.")


if __name__ == "__main__":
    main()
