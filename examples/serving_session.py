"""Streaming PIR session: concurrent clients, one pipelined scheduler.

The quickstart retrieves one synchronous batch; this example runs the
serving frontend the way production traffic hits it (DESIGN.md §6.2):
several client threads submit queries at their own pace, the scheduler
coalesces them into padded bucket batches, double-buffers dispatch, and
resolves each client's ``AnswerFuture`` as the two parties' shares are
reconciled.

Run:  PYTHONPATH=src python examples/serving_session.py
"""
import threading

import numpy as np

from repro.config import PIRConfig
from repro.core import pir
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import TwoServerPIR

N_CLIENTS = 3
QUERIES_PER_CLIENT = 4


def client(name: str, system: TwoServerPIR, db, rng, errors: list):
    indices = rng.integers(0, system.cfg.n_items,
                           size=QUERIES_PER_CLIENT).tolist()
    futures = [(i, system.submit(i)) for i in indices]   # returns immediately
    for idx, fut in futures:
        row = fut.result(timeout=300.0)
        ok = np.array_equal(row, db[idx])
        print(f"  [{name}] D[{idx:5d}] -> "
              f"{bytes(np.asarray(row).view(np.uint8))[:8].hex()}... "
            f"{'OK' if ok else 'MISMATCH'}")
        if not ok:
            errors.append((name, idx))


def main():
    cfg = PIRConfig(n_items=1 << 12, item_bytes=32)
    db = pir.make_database(np.random.default_rng(0), cfg.n_items,
                           cfg.item_bytes)
    # one bucket keeps this demo to a single XLA compile per party (~40 s
    # on a 1-core CPU container); ragged traffic pads up to it
    system = TwoServerPIR(db, cfg, make_local_mesh(), path="fused",
                          n_queries=4, buckets=(4,))
    print(f"DB: {cfg.n_items} records x {cfg.item_bytes} B; "
          f"buckets={system.servers[0].buckets}")

    errors: list = []
    with system:                                  # background session thread
        threads = [
            threading.Thread(target=client,
                             args=(f"client{c}", system, db,
                                   np.random.default_rng(100 + c), errors))
            for c in range(N_CLIENTS)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    stats = system.scheduler.stats
    print(f"answered={stats.answered} batches={stats.batches} "
          f"padded={stats.padded} (pad fraction {stats.pad_fraction:.0%})")
    assert not errors, errors
    print("all private retrievals verified.")


if __name__ == "__main__":
    main()
