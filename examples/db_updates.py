"""Online database updates under 3-server PIR: stage → publish → re-query.

The paper freezes the database after preloading (§3.3 excludes transfer
cost from query latency). The database plane (DESIGN.md §8) lifts that:
``MultiServerPIR.update`` stages *public* row writes into a delta log and
``publish`` swaps them in as a new epoch — an O(rows) scatter against the
resident views, never a re-preload, never a serving stall. Updates are
public metadata: privacy protects the *query index*, not the data, so all
three non-colluding parties apply the identical delta and their XOR answer
shares stay consistent. Every answer future is tagged with the epoch it
was computed at.

Run:  PYTHONPATH=src python examples/db_updates.py
"""
import numpy as np

from repro.configs.pir import PIR_SMOKE_UPD
from repro.core import pir
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import MultiServerPIR


def main():
    cfg = PIR_SMOKE_UPD          # 2^10 records x 32 B, xor-dpf-k, k=3
    rng = np.random.default_rng(0)
    db_host = pir.make_database(rng, cfg.n_items, cfg.item_bytes)

    # one bucket keeps this demo to one XLA compile per party (~40-90 s
    # each on a 1-core CPU container); all 3 parties share ONE placed
    # ShardedDatabase — the DB is public, only key material is per-party
    system = MultiServerPIR(db_host, cfg, make_local_mesh(), path="fused",
                            n_queries=2, buckets=(2,))
    print(f"DB: {cfg.n_items} records x {cfg.item_bytes} B; "
          f"protocol={cfg.protocol} ({system.n_parties} parties, "
          f"one shared placement: "
          f"{system.db.stats.preload_h2d_bytes} B host->device)")

    target, bystander = 123, 877
    before = system.query([target, bystander])
    assert np.array_equal(before[0], db_host[target])
    assert np.array_equal(before[1], db_host[bystander])
    print(f"epoch {system.epoch}: D[{target}] = "
          f"{bytes(before[0].view(np.uint8))[:8].hex()}...")

    # --- stage + publish one public row write --------------------------
    new_record = rng.integers(0, 1 << 32, size=(1, cfg.item_bytes // 4),
                              dtype=np.uint32)
    system.update([target], new_record)
    epoch = system.publish()
    delta_bytes = system.db.stats.update_h2d_bytes
    print(f"published epoch {epoch}: rewrote D[{target}] "
          f"({delta_bytes} B over the wire, vs {cfg.db_bytes} B full "
          f"re-preload)")
    assert delta_bytes < cfg.db_bytes // 100     # O(rows), not O(db)
    assert system.db.stats.n_full_placements == 1

    # --- re-query through the SAME compiled steps ----------------------
    futs = [system.submit(target), system.submit(bystander)]
    system.scheduler.pump()
    after = [np.asarray(f.result(timeout=360.0)) for f in futs]
    assert np.array_equal(after[0], new_record[0]), "updated row must serve"
    assert np.array_equal(after[1], db_host[bystander]), \
        "untouched row must be unchanged"
    assert all(f.epoch == epoch for f in futs)
    assert all(s.n_compiles == 1 for s in system.servers), \
        "the update path must not recompile serve steps"
    print(f"epoch {epoch}: D[{target}] = "
          f"{bytes(after[0].view(np.uint8))[:8].hex()}... (new record, "
          f"answer futures tagged epoch={futs[0].epoch})")
    print("online update served: updated + untouched rows verified on "
          "3-server PIR.")


if __name__ == "__main__":
    main()
