"""The database plane (DESIGN.md §8): layout, placement, online updates.

``DatabaseSpec`` owns shape/packing math; ``ShardedDatabase`` owns mesh
placement, the per-protocol device views, and epoched ``stage``/``publish``
online updates. Everything above (``core/server.py``,
``runtime/serve_loop.py``) consumes these instead of raw ``db_words``
arrays.
"""
from repro.db.spec import (VIEWS, DatabaseSpec, IntegrityError, row_checksum,
                           verify_records)
from repro.db.sharded import PublishedDelta, ShardedDatabase, TransferStats
from repro.db.bucketed import BucketedDatabase

__all__ = ["VIEWS", "BucketedDatabase", "DatabaseSpec", "IntegrityError",
           "PublishedDelta", "ShardedDatabase", "TransferStats",
           "row_checksum", "verify_records"]
