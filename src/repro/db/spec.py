"""`DatabaseSpec` — the one owner of PIR database shape/packing math.

Before the database plane, this arithmetic was smeared across four layers:
``core/pir.py db_as_bytes`` re-packed the whole DB on the host per call,
``core/server.py`` and ``launch/dryrun.py`` each rebuilt the
``(n_items, item_bytes // 4)`` struct by hand, and the additive protocol
converted words to bytes inside every compiled serve step. The spec
centralizes it: record geometry, the two protocol *views* (u32 words for
the XOR schemes, int8 bytes for the additive GEMM), per-shard row math,
and host/device packing conversions (``crypto/packing.py`` primitives).

A view name is protocol metadata (``PIRProtocol.db_view``): the serve
plumbing asks the spec for that view's shape/dtype/struct instead of
branching on the share scheme.

Verified reconstruction (DESIGN.md §12) adds an optional per-row checksum
column: with ``checksum=True`` every stored record carries one extra u32
word (``row_checksum`` of its payload words) packed after the payload, so
all three views widen by 4 bytes per record while ``item_bytes`` remains
the *logical* payload width the client sees. ``verify_records`` checks and
strips that column at reconstruction time, raising :class:`IntegrityError`
on mismatch — a corrupted share can no longer decode to silent garbage.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import numpy as np

from repro.config import PIRConfig
from repro.crypto.packing import (np_bytes_to_words, np_words_to_bytes,
                                  words_to_bytes_i8, words_to_bytes_i32)

#: registered database views: name -> (dtype, bytes-per-record-column)
VIEWS = {
    "words": np.dtype(np.uint32),   # [N, stored_words] — XOR schemes
    "bytes": np.dtype(np.int8),     # [N, stored_bytes] — additive GEMM
    "bytes32": np.dtype(np.int32),  # [N, stored_bytes] — LWE GEMM
    # bytes32 holds the same byte values 0..255 widened to int32: the LWE
    # contraction is mod-2^32 arithmetic, and the int8 view's reinterpreted
    # negatives (byte >= 128 -> byte - 256) would shift it by 256·k ≠ 0 mod q.
}


class IntegrityError(RuntimeError):
    """A reconstructed record failed verification.

    Raised instead of returning a silently wrong record when the stored
    per-row checksum disagrees with the reconstructed payload (a corrupted
    answer share, a byzantine party, bit rot) or, for the LWE protocol,
    when the recovered noise exceeds the validated budget. ``bad_queries``
    carries the batch-local indices of the offending queries so a router
    can resubmit exactly those.
    """

    def __init__(self, msg: str, bad_queries=()):
        super().__init__(msg)
        self.bad_queries = tuple(int(i) for i in bad_queries)


def row_checksum(words: np.ndarray) -> np.ndarray:
    """Per-row u32 mixing checksum over payload words: [..., W] -> [...].

    A murmur3-finalizer-style avalanche per word, folded left-to-right with
    a position-dependent multiply-add so permuting words changes the sum.
    Pure vectorized numpy over the leading axes (O(rows · W) host work —
    the same order as the packing conversions that already run per
    publish). This is an *integrity* check against corruption, not a MAC:
    a malicious server that knows the scheme can forge it (DESIGN.md §12
    spells out the trust-model delta).
    """
    w = np.asarray(words, dtype=np.uint64)
    if w.ndim < 1 or w.shape[-1] == 0:
        raise ValueError(f"need at least one payload word, got shape {w.shape}")
    h = np.full(w.shape[:-1], 0x9E3779B9, dtype=np.uint64)
    for k in range(w.shape[-1]):
        x = (w[..., k] * np.uint64(0x85EBCA6B)) & np.uint64(0xFFFFFFFF)
        x ^= x >> np.uint64(13)
        x = (x * np.uint64(0xC2B2AE35)) & np.uint64(0xFFFFFFFF)
        x ^= x >> np.uint64(16)
        h = ((h ^ x) * np.uint64(0x9E3779B1) + np.uint64(k)) \
            & np.uint64(0xFFFFFFFF)
    return h.astype(np.uint32)


def verify_records(rec: np.ndarray, item_bytes: int) -> np.ndarray:
    """Check + strip the checksum column of reconstructed records.

    Accepts either record form a protocol reconstructs into, both at
    *stored* width (payload + checksum):

    * words form  ``[Q, item_bytes//4 + 1]`` u32 — the XOR schemes;
    * bytes form  ``[Q, item_bytes + 4]`` integer bytes 0..255 (little-
      endian checksum word in the trailing 4 bytes) — additive / LWE.

    Returns the payload (same form, checksum column stripped) or raises
    :class:`IntegrityError` naming the offending batch indices.
    """
    arr = np.asarray(rec)
    if arr.ndim != 2:
        raise ValueError(f"records must be 2-D, got shape {arr.shape}")
    n_words = item_bytes // 4
    if arr.shape[1] == n_words + 1 and arr.dtype == np.uint32:
        payload_words, stored = arr[:, :n_words], arr[:, n_words]
        payload = payload_words
    elif arr.shape[1] == item_bytes + 4:
        b = (arr.astype(np.int64) & 0xFF).astype(np.uint8)
        payload_words = np_bytes_to_words(b[:, :item_bytes])
        stored = np_bytes_to_words(b[:, item_bytes:])[:, 0]
        payload = arr[:, :item_bytes]
    else:
        raise ValueError(
            f"records must be [Q, {n_words + 1}] u32 words or "
            f"[Q, {item_bytes + 4}] bytes (stored width incl. checksum), "
            f"got {arr.shape} {arr.dtype}")
    bad = np.nonzero(row_checksum(payload_words) != stored)[0]
    if bad.size:
        raise IntegrityError(
            f"checksum mismatch on {bad.size}/{arr.shape[0]} reconstructed "
            f"record(s) (batch indices {bad[:8].tolist()}"
            f"{'...' if bad.size > 8 else ''}): corrupted answer share",
            bad_queries=bad)
    return payload


@dataclass(frozen=True)
class DatabaseSpec:
    """Shape/packing math for one PIR database (N records × L bytes).

    ``item_bytes`` is the *logical* payload width; with ``checksum=True``
    each stored record additionally carries one u32 ``row_checksum`` word
    after the payload (``stored_bytes = item_bytes + 4``), and all views /
    shapes are in stored width — verification strips the column again at
    reconstruction.
    """

    n_items: int
    item_bytes: int = 32
    checksum: bool = False

    def __post_init__(self):
        if self.n_items <= 0 or self.n_items & (self.n_items - 1):
            raise ValueError(
                f"n_items must be a power of two (GGM tree domain), "
                f"got {self.n_items}")
        if self.item_bytes % 4:
            raise ValueError(
                f"item_bytes must be a multiple of 4 (u32 words), "
                f"got {self.item_bytes}")

    @classmethod
    def from_config(cls, cfg: PIRConfig) -> "DatabaseSpec":
        return cls(n_items=cfg.n_items, item_bytes=cfg.item_bytes,
                   checksum=getattr(cfg, "checksum", False))

    # -- geometry -------------------------------------------------------

    @property
    def item_words(self) -> int:
        return self.item_bytes // 4

    @property
    def stored_bytes(self) -> int:
        """Bytes per stored record (payload + optional checksum word)."""
        return self.item_bytes + (4 if self.checksum else 0)

    @property
    def stored_words(self) -> int:
        return self.item_words + (1 if self.checksum else 0)

    @property
    def log_n(self) -> int:
        return (self.n_items - 1).bit_length()

    @property
    def db_bytes(self) -> int:
        return self.n_items * self.item_bytes

    def rows_per_shard(self, n_shards: int) -> int:
        """Rows held by one DB shard; validates the paper's linear layout
        (shard d holds rows [d·B_d, (d+1)·B_d), B_d a power of two)."""
        n_shards = max(n_shards, 1)
        if self.n_items % n_shards:
            raise ValueError(
                f"{self.n_items} rows not divisible by {n_shards} shards")
        rows = self.n_items // n_shards
        if rows & (rows - 1):
            raise ValueError(
                f"per-shard row count must be a power of two, got {rows}")
        return rows

    # -- views ----------------------------------------------------------

    def view_dtype(self, view: str) -> np.dtype:
        if view not in VIEWS:
            raise KeyError(f"unknown db view {view!r}; known: {sorted(VIEWS)}")
        return VIEWS[view]

    def view_shape(self, view: str) -> Tuple[int, int]:
        self.view_dtype(view)
        cols = self.stored_words if view == "words" else self.stored_bytes
        return (self.n_items, cols)

    def view_struct(self, view: str) -> jax.ShapeDtypeStruct:
        """ShapeDtypeStruct of one view (dry-run lowering, `.lower` entries)."""
        return jax.ShapeDtypeStruct(self.view_shape(view),
                                    self.view_dtype(view))

    # -- packing --------------------------------------------------------

    def validate_words(self, db_words: np.ndarray) -> np.ndarray:
        arr = np.asarray(db_words)
        if arr.shape != self.view_shape("words") or arr.dtype != np.uint32:
            raise ValueError(
                f"db_words must be {self.view_shape('words')} uint32, got "
                f"{arr.shape} {arr.dtype}")
        return arr

    def attach_checksums(self, words: np.ndarray) -> np.ndarray:
        """Widen payload word rows to stored width: [R, W] -> [R, W+1].

        No-op when ``checksum`` is off or the rows already carry the
        column (idempotent — safe on replayed deltas). O(R) host work.
        """
        arr = np.asarray(words, dtype=np.uint32)
        if not self.checksum or (arr.ndim == 2
                                 and arr.shape[1] == self.stored_words):
            return arr
        if arr.ndim != 2 or arr.shape[1] != self.item_words:
            raise ValueError(
                f"payload rows must be [R, {self.item_words}] u32, got "
                f"{arr.shape}")
        col = row_checksum(arr)[:, None].astype(np.uint32)
        return np.concatenate([arr, col], axis=1)

    def verify_stored_rows(self, rows: np.ndarray) -> np.ndarray:
        """Check stored-width word rows against their checksum column and
        return the logical payload ([R, W+1] -> [R, W]); identity when
        checksums are off. Raises :class:`IntegrityError` on mismatch."""
        arr = np.asarray(rows, dtype=np.uint32)
        if not self.checksum:
            return arr
        return verify_records(arr, self.item_bytes)

    def words_to_bytes_host(self, words: np.ndarray) -> np.ndarray:
        """[..., W] u32 -> [..., 4W] u8 on the host (little-endian)."""
        return np_words_to_bytes(np.asarray(words))

    def bytes_to_words_host(self, b: np.ndarray) -> np.ndarray:
        """[..., 4W] u8 -> [..., W] u32 on the host (little-endian)."""
        return np_bytes_to_words(np.asarray(b, np.uint8))

    def words_to_bytes_device(self, words: jax.Array) -> jax.Array:
        """[..., W] u32 -> [..., 4W] i8 as a traced jax op (the device-side
        view derivation — never a host round trip)."""
        return words_to_bytes_i8(words)

    def words_to_view_device(self, view: str, words: jax.Array) -> jax.Array:
        """Device-side derivation of any registered view from word rows."""
        if view == "words":
            return words
        if view == "bytes":
            return words_to_bytes_i8(words)
        if view == "bytes32":
            return words_to_bytes_i32(words)
        raise KeyError(f"unknown db view {view!r}; known: {sorted(VIEWS)}")

    def pack_host(self, words: np.ndarray, view: str) -> np.ndarray:
        """Host-side packing of word rows into any registered view
        (tuner measurement inputs, test oracles)."""
        if view == "words":
            return np.asarray(words, np.uint32)
        if view == "bytes":
            return self.words_to_bytes_host(words).view(np.int8)
        if view == "bytes32":
            return self.words_to_bytes_host(words).astype(np.int32)
        raise KeyError(f"unknown db view {view!r}; known: {sorted(VIEWS)}")

    def coerce_rows_to_words(self, values: np.ndarray) -> np.ndarray:
        """Normalize update payloads to [R, W] u32 rows.

        Accepts either the word form ``[R, item_words] u32`` or the byte
        form ``[R, item_bytes] u8`` (converted host-side, O(R) work).
        """
        arr = np.asarray(values)
        if arr.ndim != 2:
            raise ValueError(f"row values must be 2-D, got shape {arr.shape}")
        if arr.shape[1] == self.item_bytes and arr.dtype == np.uint8:
            return self.bytes_to_words_host(arr)
        if arr.shape[1] == self.item_words:
            return arr.astype(np.uint32, copy=False)
        raise ValueError(
            f"row values must be [R, {self.item_words}] u32 words or "
            f"[R, {self.item_bytes}] u8 bytes, got {arr.shape} {arr.dtype}")
