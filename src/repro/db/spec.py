"""`DatabaseSpec` — the one owner of PIR database shape/packing math.

Before the database plane, this arithmetic was smeared across four layers:
``core/pir.py db_as_bytes`` re-packed the whole DB on the host per call,
``core/server.py`` and ``launch/dryrun.py`` each rebuilt the
``(n_items, item_bytes // 4)`` struct by hand, and the additive protocol
converted words to bytes inside every compiled serve step. The spec
centralizes it: record geometry, the two protocol *views* (u32 words for
the XOR schemes, int8 bytes for the additive GEMM), per-shard row math,
and host/device packing conversions (``crypto/packing.py`` primitives).

A view name is protocol metadata (``PIRProtocol.db_view``): the serve
plumbing asks the spec for that view's shape/dtype/struct instead of
branching on the share scheme.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import numpy as np

from repro.config import PIRConfig
from repro.crypto.packing import (np_bytes_to_words, np_words_to_bytes,
                                  words_to_bytes_i8, words_to_bytes_i32)

#: registered database views: name -> (dtype, bytes-per-record-column)
VIEWS = {
    "words": np.dtype(np.uint32),   # [N, item_bytes // 4] — XOR schemes
    "bytes": np.dtype(np.int8),     # [N, item_bytes]      — additive GEMM
    "bytes32": np.dtype(np.int32),  # [N, item_bytes]      — LWE GEMM
    # bytes32 holds the same byte values 0..255 widened to int32: the LWE
    # contraction is mod-2^32 arithmetic, and the int8 view's reinterpreted
    # negatives (byte >= 128 -> byte - 256) would shift it by 256·k ≠ 0 mod q.
}


@dataclass(frozen=True)
class DatabaseSpec:
    """Shape/packing math for one PIR database (N records × L bytes)."""

    n_items: int
    item_bytes: int = 32

    def __post_init__(self):
        if self.n_items <= 0 or self.n_items & (self.n_items - 1):
            raise ValueError(
                f"n_items must be a power of two (GGM tree domain), "
                f"got {self.n_items}")
        if self.item_bytes % 4:
            raise ValueError(
                f"item_bytes must be a multiple of 4 (u32 words), "
                f"got {self.item_bytes}")

    @classmethod
    def from_config(cls, cfg: PIRConfig) -> "DatabaseSpec":
        return cls(n_items=cfg.n_items, item_bytes=cfg.item_bytes)

    # -- geometry -------------------------------------------------------

    @property
    def item_words(self) -> int:
        return self.item_bytes // 4

    @property
    def log_n(self) -> int:
        return (self.n_items - 1).bit_length()

    @property
    def db_bytes(self) -> int:
        return self.n_items * self.item_bytes

    def rows_per_shard(self, n_shards: int) -> int:
        """Rows held by one DB shard; validates the paper's linear layout
        (shard d holds rows [d·B_d, (d+1)·B_d), B_d a power of two)."""
        n_shards = max(n_shards, 1)
        if self.n_items % n_shards:
            raise ValueError(
                f"{self.n_items} rows not divisible by {n_shards} shards")
        rows = self.n_items // n_shards
        if rows & (rows - 1):
            raise ValueError(
                f"per-shard row count must be a power of two, got {rows}")
        return rows

    # -- views ----------------------------------------------------------

    def view_dtype(self, view: str) -> np.dtype:
        if view not in VIEWS:
            raise KeyError(f"unknown db view {view!r}; known: {sorted(VIEWS)}")
        return VIEWS[view]

    def view_shape(self, view: str) -> Tuple[int, int]:
        self.view_dtype(view)
        cols = self.item_words if view == "words" else self.item_bytes
        return (self.n_items, cols)

    def view_struct(self, view: str) -> jax.ShapeDtypeStruct:
        """ShapeDtypeStruct of one view (dry-run lowering, `.lower` entries)."""
        return jax.ShapeDtypeStruct(self.view_shape(view),
                                    self.view_dtype(view))

    # -- packing --------------------------------------------------------

    def validate_words(self, db_words: np.ndarray) -> np.ndarray:
        arr = np.asarray(db_words)
        if arr.shape != self.view_shape("words") or arr.dtype != np.uint32:
            raise ValueError(
                f"db_words must be {self.view_shape('words')} uint32, got "
                f"{arr.shape} {arr.dtype}")
        return arr

    def words_to_bytes_host(self, words: np.ndarray) -> np.ndarray:
        """[..., W] u32 -> [..., 4W] u8 on the host (little-endian)."""
        return np_words_to_bytes(np.asarray(words))

    def bytes_to_words_host(self, b: np.ndarray) -> np.ndarray:
        """[..., 4W] u8 -> [..., W] u32 on the host (little-endian)."""
        return np_bytes_to_words(np.asarray(b, np.uint8))

    def words_to_bytes_device(self, words: jax.Array) -> jax.Array:
        """[..., W] u32 -> [..., 4W] i8 as a traced jax op (the device-side
        view derivation — never a host round trip)."""
        return words_to_bytes_i8(words)

    def words_to_view_device(self, view: str, words: jax.Array) -> jax.Array:
        """Device-side derivation of any registered view from word rows."""
        if view == "words":
            return words
        if view == "bytes":
            return words_to_bytes_i8(words)
        if view == "bytes32":
            return words_to_bytes_i32(words)
        raise KeyError(f"unknown db view {view!r}; known: {sorted(VIEWS)}")

    def pack_host(self, words: np.ndarray, view: str) -> np.ndarray:
        """Host-side packing of word rows into any registered view
        (tuner measurement inputs, test oracles)."""
        if view == "words":
            return np.asarray(words, np.uint32)
        if view == "bytes":
            return self.words_to_bytes_host(words).view(np.int8)
        if view == "bytes32":
            return self.words_to_bytes_host(words).astype(np.int32)
        raise KeyError(f"unknown db view {view!r}; known: {sorted(VIEWS)}")

    def coerce_rows_to_words(self, values: np.ndarray) -> np.ndarray:
        """Normalize update payloads to [R, W] u32 rows.

        Accepts either the word form ``[R, item_words] u32`` or the byte
        form ``[R, item_bytes] u8`` (converted host-side, O(R) work).
        """
        arr = np.asarray(values)
        if arr.ndim != 2:
            raise ValueError(f"row values must be 2-D, got shape {arr.shape}")
        if arr.shape[1] == self.item_bytes and arr.dtype == np.uint8:
            return self.bytes_to_words_host(arr)
        if arr.shape[1] == self.item_words:
            return arr.astype(np.uint32, copy=False)
        raise ValueError(
            f"row values must be [R, {self.item_words}] u32 words or "
            f"[R, {self.item_bytes}] u8 bytes, got {arr.shape} {arr.dtype}")
