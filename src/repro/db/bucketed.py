"""`BucketedDatabase` — the batch-PIR bucketed layout over ShardedDatabase.

The server half of the batch composite (DESIGN.md §14): one logical
N-record database materialized as B per-bucket sub-databases, each a
full :class:`~repro.db.sharded.ShardedDatabase` of ``capacity`` rows
(the cuckoo layout's power-of-two bucket height). Record i is
*replicated* into every distinct candidate bucket ``h_j(i)`` — simple
hashing server-side, so whichever bucket the client's cuckoo assignment
picks for i, that bucket can answer for it.

What stays inherited rather than re-implemented:

Placement / views
    Each bucket IS a ShardedDatabase (constructed from a ``DatabaseSpec``
    of ``capacity`` rows), so mesh placement, derived byte views, and the
    per-view pack accounting all apply per bucket unchanged — and so does
    the serving stack: `BucketedServeFns.answer(view, keys)` takes the
    view as an argument, so B same-shape buckets share ONE compiled serve
    step per party.

Epoch / publish semantics
    ``stage(rows, values)`` takes GLOBAL row ids and fans each write out
    to the (bucket, slot) occurrences the layout places that record at;
    ``publish()`` publishes every touched bucket and bumps ONE outer
    epoch, so a dispatch that snapshots under the outer lock always sees
    all buckets at a mutually consistent version (per-bucket double
    buffering keeps in-flight answers valid exactly as before).

Checksums ride through: buckets receive logical payload rows and attach
the per-row checksum column themselves (pad rows are zero payloads with
valid checksums), so per-bucket reconstruction verifies unchanged.

Memory cost is the textbook batch-PIR expansion: B·capacity stored rows
~= 2·n_hashes·N (replication × power-of-two rounding) — the space half
of the m-fold scan amortization the runtime layer cashes in.
"""
from __future__ import annotations

import threading
from dataclasses import replace as dc_replace
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.config import PIRConfig
from repro.core.batch import CuckooLayout, CuckooParams
from repro.db.sharded import ShardedDatabase, TransferStats
from repro.db.spec import DatabaseSpec


class BucketedDatabase:
    """B cuckoo buckets of one PIR database, versioned by one outer epoch.

    ``db_words``: the logical host store, ``[N, item_words]`` u32 payload
    rows (stored width with the checksum column already attached is also
    accepted — the column is recomputed per bucket either way, since pad
    rows need their own valid checksums).
    """

    def __init__(self, db_words: np.ndarray, cfg: PIRConfig,
                 mesh: jax.sharding.Mesh,
                 layout: Optional[CuckooLayout] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.params = CuckooParams.from_config(cfg).validate()
        self.spec = DatabaseSpec.from_config(cfg)       # outer, logical
        if layout is None:
            layout = CuckooLayout.build(cfg.n_items, self.params)
        if layout.n_items != cfg.n_items or layout.params != self.params:
            raise ValueError(
                f"layout built for (n_items={layout.n_items}, "
                f"{layout.params}) does not match cfg "
                f"(n_items={cfg.n_items}, {self.params})")
        self.layout = layout
        #: per-bucket spec/config: same record format, ``capacity`` rows.
        #: inner_cfg is what the inner protocol keygens/plans against —
        #: the engine's ``spec_signature`` sees the bucket shape, so plan
        #: resolution and cache keys are per bucket shape automatically.
        self.inner_spec = DatabaseSpec(n_items=layout.capacity,
                                       item_bytes=cfg.item_bytes,
                                       checksum=cfg.checksum)
        self.inner_cfg = dc_replace(cfg, n_items=layout.capacity)

        host = np.asarray(db_words)
        if host.ndim != 2 or host.shape[0] != cfg.n_items:
            raise ValueError(
                f"db_words must be [{cfg.n_items}, words], got {host.shape}")
        if host.shape[1] == self.spec.stored_words and self.spec.checksum:
            host = host[:, :self.spec.item_words]       # re-derived per bucket
        if host.shape[1] != self.spec.item_words:
            raise ValueError(
                f"db_words rows must be {self.spec.item_words} payload "
                f"words (or {self.spec.stored_words} stored), got "
                f"{host.shape[1]}")

        self._lock = threading.RLock()
        self._epoch = 0
        pad = np.zeros((1, self.spec.item_words), np.uint32)
        self.buckets: Tuple[ShardedDatabase, ...] = tuple(
            ShardedDatabase(
                np.concatenate(
                    [host[rows],
                     np.broadcast_to(pad, (layout.capacity - len(rows),
                                           self.spec.item_words))]),
                self.inner_spec, mesh)
            for rows in layout.bucket_rows)

    # ------------------------------------------------------------------
    # geometry
    # ------------------------------------------------------------------

    @property
    def n_buckets(self) -> int:
        return self.layout.n_buckets

    @property
    def capacity(self) -> int:
        return self.layout.capacity

    @property
    def expansion(self) -> float:
        """Stored rows / logical rows — the replication space cost."""
        return self.n_buckets * self.capacity / self.spec.n_items

    @property
    def epoch(self) -> int:
        """The OUTER epoch: bumped once per publish that changed any
        bucket, so answers from different buckets of one dispatch carry
        one comparable tag."""
        with self._lock:
            return self._epoch

    @property
    def n_staged(self) -> int:
        with self._lock:
            return sum(b.n_staged for b in self.buckets)

    @property
    def stats(self) -> TransferStats:
        """Aggregate transfer accounting across all buckets."""
        agg = TransferStats()
        for b in self.buckets:
            for k in vars(agg):
                setattr(agg, k, getattr(agg, k) + getattr(b.stats, k))
        return agg

    # ------------------------------------------------------------------
    # reads
    # ------------------------------------------------------------------

    def snapshot(self, names: Tuple[str, ...] = ("words",)
                 ) -> Tuple[int, Dict[str, Tuple[jax.Array, ...]]]:
        """Atomically capture (outer epoch, per-bucket views per name).

        The outer lock serializes against :meth:`publish`, so the B views
        of one snapshot are always a mutually consistent version — the
        bucketed extension of ``ShardedDatabase.snapshot``'s guarantee.
        """
        with self._lock:
            return self._epoch, {
                n: tuple(b.view(n) for b in self.buckets) for n in names}

    # ------------------------------------------------------------------
    # epoched online updates (global rows in, bucket deltas out)
    # ------------------------------------------------------------------

    def stage(self, rows, values) -> int:
        """Stage GLOBAL row writes; each lands in all its bucket views.

        ``rows``: [R] global indices; ``values``: [R, item_words] u32 or
        [R, item_bytes] u8 logical payloads. One logical write fans out
        to ≤ n_hashes (bucket, slot) writes — the replication invariant
        that keeps every candidate bucket able to answer for the record.
        Returns the total staged logical entry count.
        """
        idx = np.atleast_1d(np.asarray(rows, np.int64))
        vals = self.spec.coerce_rows_to_words(values)
        if idx.ndim != 1 or len(idx) != len(vals):
            raise ValueError(
                f"rows/values length mismatch: {idx.shape} vs {vals.shape}")
        if len(idx) and (idx.min() < 0 or idx.max() >= self.spec.n_items):
            raise ValueError(
                f"row indices out of range [0, {self.spec.n_items})")
        with self._lock:
            for r, v in zip(idx, vals):
                for b, slot in self.layout.occurrences(int(r)):
                    self.buckets[b].stage([slot], v[None, :])
            self._n_staged_logical = getattr(
                self, "_n_staged_logical", 0) + len(idx)
            return self._n_staged_logical

    def publish(self) -> int:
        """Publish every touched bucket; bump the outer epoch once.

        Per-bucket publishes keep their own double-buffered epochs (in-
        flight per-bucket answers stay valid); the outer epoch advances
        iff any bucket advanced, so no-op publishes stay no-ops.
        """
        with self._lock:
            changed = False
            for b in self.buckets:
                if b.n_staged:
                    b.publish()
                    changed = True
            if changed:
                self._epoch += 1
                self._n_staged_logical = 0
            return self._epoch
