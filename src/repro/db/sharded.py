"""`ShardedDatabase` — mesh placement + epoched online updates.

One object owns what used to be smeared across the serving stack:

Placement (DESIGN.md §8.2)
    The canonical u32 word store is placed **chunked per shard**
    (``jax.make_array_from_callback``): each device's row slice is cut as
    a numpy *view* of the host array and transferred directly, so a
    GB-scale DB is never materialized twice on the host (the old path —
    ``jnp.asarray(db_words)`` then ``device_put`` per party — copied the
    whole DB once per party before it ever reached a device). Layout is
    the paper's linear sharding: rows split over the ``model`` axis,
    replicated across cluster (``data``/``pod``) axes.

Views (DESIGN.md §8.1)
    Protocols declare the view they contract against
    (``PIRProtocol.db_view``): ``words`` (u32, XOR schemes), ``bytes``
    (int8, the additive GEMM) or ``bytes32`` (int32 bytes, the LWE GEMM).
    Derived views are packed **on device** from the resident word view
    (one elementwise pack, lazily on first use) and thereafter maintained
    *incrementally* by the update path — never re-packed from scratch,
    never round-tripped through the host.

Hints (DESIGN.md §10)
    Single-server protocols register per-epoch *hints* (server-side
    preprocessing, e.g. the LWE ``H = A^T.DB``): materialized lazily per
    epoch via ``hint(name)``, delta-updated exactly on ``publish()`` when
    the protocol registered a delta fn (dropped and lazily rebuilt
    otherwise). Retired-epoch hints stay fetchable for one epoch of
    hysteresis, matching the view double buffer.

Epoched updates (DESIGN.md §8.3)
    ``stage(rows, values)`` accumulates a public delta log on the host;
    ``publish()`` applies the whole delta to every resident view as one
    O(rows) scatter and bumps the epoch. Updates are *public metadata*
    (the DB contents are public in the PIR model — privacy protects the
    query index, never the data), so staging/publishing identical deltas
    at every party keeps all k parties' replicas — and therefore their
    answer shares — consistent. Publication is double-buffered: jax
    arrays are immutable, so serve steps already dispatched against the
    old epoch finish unperturbed, and the previous epoch's views are
    additionally pinned (one epoch of hysteresis) so epoch-tagged answers
    can be checked against the exact snapshot they were computed at.

All host→device traffic is accounted in :class:`TransferStats`, which is
what lets tests assert the update path moves O(rows · item_bytes), not
O(db_bytes).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import PIRConfig
from repro.db.spec import DatabaseSpec
from repro.launch.mesh import pir_shard_axis


@dataclass
class TransferStats:
    """Host→device byte accounting (per replica; clusters replicate)."""
    preload_h2d_bytes: int = 0     # full-view placements (epoch 0 only)
    update_h2d_bytes: int = 0      # delta transfers (idx + row values)
    n_full_placements: int = 0     # chunked host→device placements
    n_view_packs: int = 0          # on-device full word→byte derivations
    n_publishes: int = 0
    n_hint_builds: int = 0         # full hint recomputes (lazy, per epoch)
    n_hint_deltas: int = 0         # O(rows) incremental hint updates


@dataclass(frozen=True)
class _HintSpec:
    """One registered hint: full rebuild + optional exact delta update.

    build  words view [N, W] -> hint array (device)
    delta  (hint, rows, old_words, new_words) -> updated hint, or None —
           rows are the deduplicated UNPADDED published indices, old/new
           the [R, W] word rows before/after the scatter. Must be exact
           (byte-for-byte equal to a rebuild); hints without a delta are
           dropped on publish and lazily rebuilt.
    """
    build: object
    delta: object = None


@dataclass
class PublishedDelta:
    """Public metadata of one published epoch (the online-update log).

    ``rows``/``vals`` are the deduplicated (last-write-wins), unpadded
    delta: replaying ``stage(rows, vals); publish()`` against any replica
    of the previous epoch reproduces this epoch byte-for-byte — which is
    exactly what the replica plane's fan-out/catch-up does.
    """
    epoch: int                     # epoch the delta produced
    rows: np.ndarray               # deduplicated row indices written
    n_staged: int                  # staged entries folded into it
    vals: Optional[np.ndarray] = None   # deduplicated [R, item_words] u32


@dataclass
class _Epoch:
    """One immutable DB version: epoch id + its device-resident views
    and lazily materialized per-epoch hints (single-server protocols)."""
    epoch: int
    views: Dict[str, jax.Array] = field(default_factory=dict)
    hints: Dict[str, jax.Array] = field(default_factory=dict)


class ShardedDatabase:
    """The versioned, mesh-placed PIR database shared by all k parties.

    Thread-safe: the serving scheduler reads views from its session thread
    while clients ``stage``/``publish`` from theirs. ``view()`` is the
    only read entry point — callers must re-fetch it per dispatch (never
    cache across batches) so a published epoch is picked up immediately;
    batches already dispatched hold references to the old arrays and
    finish against the old epoch.
    """

    def __init__(self, db_words: np.ndarray,
                 cfg: Union[PIRConfig, DatabaseSpec],
                 mesh: jax.sharding.Mesh):
        self.spec = (cfg if isinstance(cfg, DatabaseSpec)
                     else DatabaseSpec.from_config(cfg))
        self.mesh = mesh
        shard = pir_shard_axis(mesh)
        self.n_shards = mesh.shape[shard] if shard else 1
        self.spec.rows_per_shard(self.n_shards)   # validate the layout
        self._row_spec = P(shard, None)
        self.stats = TransferStats()
        self._lock = threading.RLock()
        self._staged_rows: List[np.ndarray] = []
        self._staged_vals: List[np.ndarray] = []
        self.published: List[PublishedDelta] = []
        self._scatter_cache: dict = {}
        self._pack_cache: dict = {}
        self._hint_specs: Dict[str, _HintSpec] = {}
        self._subscribers: List = []   # publish fan-out callbacks
        #: optional ChaosInjector consulted at the "db.publish" seam
        #: (fault injection is repro/chaos's job; None in production)
        self.chaos = None
        host = np.asarray(db_words)
        if self.spec.checksum:
            # accept logical-width payload rows; the checksum column is
            # this plane's responsibility (attached once, host-side O(N),
            # then maintained through publish() O(rows) deltas)
            host = self.spec.attach_checksums(host)
        host = self.spec.validate_words(host)
        self._current = _Epoch(epoch=0,
                               views={"words": self._place(host)})
        self._retired: Optional[_Epoch] = None

    # ------------------------------------------------------------------
    # placement + views
    # ------------------------------------------------------------------

    def sharding(self, view: str = "words") -> NamedSharding:
        """NamedSharding of one view: rows over the DB-shard axis,
        replicated across cluster axes (both views share the row spec)."""
        self.spec.view_dtype(view)
        return NamedSharding(self.mesh, self._row_spec)

    def _place(self, host_words: np.ndarray) -> jax.Array:
        """Chunked per-shard placement of the canonical word store."""
        arr = jax.make_array_from_callback(
            self.spec.view_shape("words"), self.sharding("words"),
            lambda idx: host_words[idx])   # numpy view per device chunk
        self.stats.n_full_placements += 1
        self.stats.preload_h2d_bytes += host_words.nbytes
        return arr

    @property
    def epoch(self) -> int:
        with self._lock:
            return self._current.epoch

    @property
    def n_staged(self) -> int:
        with self._lock:
            return sum(len(r) for r in self._staged_rows)

    def view(self, name: str = "words", *,
             epoch: Optional[int] = None) -> jax.Array:
        """The device-resident array of one view at the current epoch.

        ``epoch`` may name the current epoch or the immediately previous
        one (the double-buffered snapshot kept for in-flight answers);
        anything older has been released.
        """
        with self._lock:
            holder = self._holder(epoch)
            if name not in holder.views:
                holder.views[name] = self._derive(name, holder.views["words"])
            return holder.views[name]

    def _holder(self, epoch: Optional[int]) -> _Epoch:
        """The resident _Epoch an epoch id names (lock held by caller)."""
        if epoch is None or epoch == self._current.epoch:
            return self._current
        if self._retired is None or epoch != self._retired.epoch:
            raise KeyError(
                f"epoch {epoch} is not resident (current="
                f"{self._current.epoch}, retired="
                f"{None if self._retired is None else self._retired.epoch})")
        return self._retired

    def snapshot(self, names: Tuple[str, ...] = ("words",)
                 ) -> Tuple[int, Dict[str, jax.Array]]:
        """Atomically capture (epoch, views) for one dispatch.

        A dispatcher that answers against the returned arrays and tags
        with the returned epoch can never mislabel an answer, even when a
        ``publish`` lands concurrently — the arrays are immutable and the
        pair was read under one lock.
        """
        with self._lock:
            return self._current.epoch, {n: self.view(n) for n in names}

    def _derive(self, name: str, words: jax.Array) -> jax.Array:
        self.spec.view_dtype(name)           # KeyError on unknown views
        if name == "words":
            return words
        # on-device pack; counted so tests can assert it happens at most
        # once per epoch lineage (updates maintain it incrementally)
        self.stats.n_view_packs += 1
        if name not in self._pack_cache:
            spec = self.spec
            self._pack_cache[name] = jax.jit(
                lambda w, name=name: spec.words_to_view_device(name, w),
                out_shardings=self.sharding(name))
        return self._pack_cache[name](words)

    # ------------------------------------------------------------------
    # hints (single-server preprocessing, DESIGN.md §10)
    # ------------------------------------------------------------------

    def register_hint(self, name: str, build, delta=None) -> None:
        """Register a per-epoch hint: ``build(words_view) -> hint`` plus an
        optional exact ``delta(hint, rows, old_words, new_words)`` update.

        Hints are epoch-scoped like views: materialized lazily on first
        :meth:`hint` call, delta-updated (or dropped for lazy rebuild when
        no delta is registered) on :meth:`publish`. Re-registering a name
        replaces the spec but keeps already-materialized epoch hints.
        """
        with self._lock:
            self._hint_specs[name] = _HintSpec(build=build, delta=delta)

    def hint(self, name: str, *, epoch: Optional[int] = None) -> jax.Array:
        """The device-resident hint for one epoch (current or retired).

        Clients cache the returned array keyed by the epoch their answers
        were tagged with; a publish bumps the epoch, so stale caches miss
        and re-fetch — that is the hint-invalidation contract.
        """
        with self._lock:
            if name not in self._hint_specs:
                raise KeyError(f"unknown hint {name!r}; registered: "
                               f"{sorted(self._hint_specs)}")
            holder = self._holder(epoch)
            if name not in holder.hints:
                holder.hints[name] = \
                    self._hint_specs[name].build(holder.views["words"])
                self.stats.n_hint_builds += 1
            return holder.hints[name]

    # ------------------------------------------------------------------
    # epoched online updates
    # ------------------------------------------------------------------

    def stage(self, rows, values) -> int:
        """Append row writes to the pending (public) delta log.

        ``rows``: [R] indices; ``values``: [R, item_words] u32 or
        [R, item_bytes] u8. Nothing touches the device until
        :meth:`publish`. Returns the total staged entry count.
        """
        idx = np.atleast_1d(np.asarray(rows, np.int64))
        vals = self.spec.coerce_rows_to_words(values)
        if idx.ndim != 1 or len(idx) != len(vals):
            raise ValueError(
                f"rows/values length mismatch: {idx.shape} vs {vals.shape}")
        if len(idx) and (idx.min() < 0 or idx.max() >= self.spec.n_items):
            raise ValueError(
                f"row indices out of range [0, {self.spec.n_items})")
        with self._lock:
            self._staged_rows.append(idx)
            self._staged_vals.append(np.array(vals, np.uint32, copy=True))
            return sum(len(r) for r in self._staged_rows)

    def subscribe(self, fn) -> "callable":
        """Register ``fn(delta: PublishedDelta)`` to fire after every
        :meth:`publish` that produced a new epoch; returns an unsubscribe
        callable.

        This is the multi-subscriber fan-out seam the replica plane hangs
        off: the front-tier router subscribes to each replica's database
        to track its epoch (bounded-staleness routing), and a downstream
        replica can replay ``delta.rows``/``delta.vals`` into its own
        database to reproduce the epoch exactly. Callbacks run on the
        publishing thread, OUTSIDE the database lock (a subscriber may
        itself stage/publish into another database); they fire in epoch
        order because publishes are serialized by the lock.
        """
        self._subscribers.append(fn)
        def _unsubscribe(fn=fn):
            if fn in self._subscribers:
                self._subscribers.remove(fn)
        return _unsubscribe

    def publish(self) -> int:
        """Apply the staged delta to every resident view; bump the epoch.

        One O(rows) scatter per view: only the deduplicated row indices
        and word values cross the host→device boundary — never a full
        re-pack or re-placement. The previous epoch's views stay pinned
        (double buffer) until the *next* publish. No-op (same epoch) when
        nothing is staged. Returns the now-current epoch. Subscribers
        (:meth:`subscribe`) are notified of the new epoch's delta after
        the swap, outside the lock.
        """
        with self._lock:
            rows = (np.concatenate(self._staged_rows) if self._staged_rows
                    else np.zeros((0,), np.int64))
            if not len(rows):
                # nothing staged (or only zero-row stage calls): no new
                # epoch — epoch churn with identical data would spuriously
                # invalidate epoch-keyed clients
                self._staged_rows.clear()
                self._staged_vals.clear()
                return self._current.epoch
            vals = np.concatenate(self._staged_vals)
            n_staged = len(rows)
            self._staged_rows.clear()
            self._staged_vals.clear()
            # last-write-wins dedup: scatter order is unspecified for
            # duplicate indices, so resolve collisions on the host
            _, first_of_rev = np.unique(rows[::-1], return_index=True)
            keep = np.sort(len(rows) - 1 - first_of_rev)
            rows, vals = rows[keep], vals[keep]
            rows_u, vals_u = rows, vals           # pre-padding references
            # device paths (scatter + hint deltas) run at *stored* width;
            # PublishedDelta.vals stays logical so replicas replaying the
            # delta through stage() re-attach their own checksum column
            vals_st_u = self.spec.attach_checksums(vals_u)
            vals = vals_st_u
            # hint deltas need the deduplicated UNPADDED delta (a padded
            # duplicate would subtract its old row twice) and the old word
            # rows gathered from the pre-publish view, before the scatter
            delta_hints = {n: h for n, h in self._current.hints.items()
                           if self._hint_specs[n].delta is not None}
            if delta_hints:
                old_words = self._current.views["words"][
                    jnp.asarray(rows_u.astype(np.int32))]
            # pad the delta to a power of two (replicating one entry:
            # identical index+value pairs scatter deterministically) so
            # ragged update sizes reuse a small set of compiled scatters
            r_pad = max(1, 1 << (len(rows) - 1).bit_length())
            if r_pad > len(rows):
                pad = r_pad - len(rows)
                rows = np.concatenate([rows, np.repeat(rows[-1:], pad)])
                vals = np.concatenate([vals, np.repeat(vals[-1:], pad,
                                                       axis=0)])
            idx_dev = jnp.asarray(rows.astype(np.int32))
            vals_dev = jnp.asarray(vals)
            self.stats.update_h2d_bytes += rows.astype(np.int32).nbytes \
                + vals.nbytes
            new_views = {
                name: self._scatter(name, len(rows))(arr, idx_dev, vals_dev)
                for name, arr in self._current.views.items()
            }
            # materialized hints: exact O(rows) delta where registered;
            # delta-less hints are dropped and lazily rebuilt on next use
            new_hints = {}
            for name, harr in delta_hints.items():
                new_hints[name] = self._hint_specs[name].delta(
                    harr, rows_u, old_words, jnp.asarray(vals_st_u))
                self.stats.n_hint_deltas += 1
            self._retired = self._current
            self._current = _Epoch(epoch=self._retired.epoch + 1,
                                   views=new_views, hints=new_hints)
            self.stats.n_publishes += 1
            delta = PublishedDelta(epoch=self._current.epoch, rows=rows_u,
                                   n_staged=n_staged, vals=vals_u)
            self.published.append(delta)
            epoch = self._current.epoch
            subscribers = tuple(self._subscribers)
        # chaos seam "db.publish": a drop swallows this epoch's fan-out
        # (subscribers converge via the delta-log catch-up on the next
        # publish); delay/stall events sleep before notification
        chaos = self.chaos
        if chaos is not None and chaos.should_drop("db.publish"):
            return epoch
        for fn in subscribers:       # outside the lock (see subscribe())
            fn(delta)
        return epoch

    def _scatter(self, view: str, r: int):
        """Cached compiled delta application for (view, padded row count).

        The update payload always crosses the host boundary in word form;
        the byte view's int8 rows are derived on device inside the
        scatter, so maintaining both views costs one H2D transfer."""
        key = (view, r)
        if key not in self._scatter_cache:
            sharding = self.sharding(view)
            spec = self.spec
            fn = lambda arr, idx, vals, view=view: arr.at[idx].set(
                spec.words_to_view_device(view, vals))
            self._scatter_cache[key] = jax.jit(fn, out_shardings=sharding)
        return self._scatter_cache[key]
