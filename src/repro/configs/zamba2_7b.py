"""zamba2-7b [hybrid]: 81L d_model=3584 32H d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 trunk + weight-shared attention block every 6 layers.
[arXiv:2411.15242; unverified]"""
from repro.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, headdim=64, chunk=256,
                  shared_attn_every=6),
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, attn_chunk=16,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=16, chunk=16,
                  shared_attn_every=2),
)
