"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) vocab=131072,
MoE 8 experts top-2, expert width 32768. [hf:xai-org/grok-1; unverified]"""
from repro.config import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768),
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=512, attn_chunk=16,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=64),
)
