"""whisper-small [audio]: 12L d_model=768 12H (MHA) d_ff=3072 vocab=51865
— enc-dec, conv frontend STUB (input_specs provides precomputed frame
embeddings [B, 1500, 768]). [arXiv:2212.04356; unverified]"""
from repro.config import ModelConfig

FULL = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, pos_kind="learned",
    n_encoder_layers=12, encoder_len=1500, attn_chunk=1024,
)

SMOKE = ModelConfig(
    name="whisper-small-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, pos_kind="learned",
    n_encoder_layers=2, encoder_len=30, attn_chunk=16,
)
