"""PIR database configurations — the paper's own evaluation grid (§5.2).

Records are 32-byte hashes (SHA-256-sized, the paper's CT / credential-
checking format). DB sizes mirror the paper's 0.5–8 GB sweep; n_items is
db_bytes / 32 and always a power of two (the GGM tree domain).
"""
from repro.config import PIRConfig

# paper evaluation points (Figure 9): 0.5, 1, 2, 4, 8 GB
PIR_512M = PIRConfig(n_items=1 << 24, item_bytes=32)
PIR_1G = PIRConfig(n_items=1 << 25, item_bytes=32)
PIR_2G = PIRConfig(n_items=1 << 26, item_bytes=32)
PIR_4G = PIRConfig(n_items=1 << 27, item_bytes=32)
PIR_8G = PIRConfig(n_items=1 << 28, item_bytes=32)

# additive-share mode (the MXU batched-matmul path, beyond-paper)
PIR_1G_ADD = PIRConfig(n_items=1 << 25, item_bytes=32, mode="additive")

# CPU-container scale for tests/benches
PIR_SMOKE = PIRConfig(n_items=1 << 14, item_bytes=32, batch_queries=4)
PIR_SMOKE_ADD = PIRConfig(n_items=1 << 14, item_bytes=32, mode="additive",
                          batch_queries=4)

PIR_CONFIGS = {
    "pir-512m": PIR_512M,
    "pir-1g": PIR_1G,
    "pir-2g": PIR_2G,
    "pir-4g": PIR_4G,
    "pir-8g": PIR_8G,
    "pir-1g-add": PIR_1G_ADD,
    "pir-smoke": PIR_SMOKE,
    "pir-smoke-add": PIR_SMOKE_ADD,
}
