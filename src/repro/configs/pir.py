"""PIR database configurations — the paper's own evaluation grid (§5.2).

Records are 32-byte hashes (SHA-256-sized, the paper's CT / credential-
checking format). DB sizes mirror the paper's 0.5–8 GB sweep; n_items is
db_bytes / 32 and always a power of two (the GGM tree domain).

Share schemes are named by protocol-registry entries (``core/protocol.py``):
``xor-dpf-2`` (default), ``additive-dpf-2``, ``xor-dpf-k``. The old
``mode="xor"|"additive"`` kwarg still works via the deprecation shim in
``PIRConfig`` but new configs should name a protocol.
"""
from repro.config import PIRConfig

# paper evaluation points (Figure 9): 0.5, 1, 2, 4, 8 GB
PIR_512M = PIRConfig(n_items=1 << 24, item_bytes=32)
PIR_1G = PIRConfig(n_items=1 << 25, item_bytes=32)
PIR_2G = PIRConfig(n_items=1 << 26, item_bytes=32)
PIR_4G = PIRConfig(n_items=1 << 27, item_bytes=32)
PIR_8G = PIRConfig(n_items=1 << 28, item_bytes=32)

# additive-share protocol (the MXU batched-matmul path, beyond-paper)
PIR_1G_ADD = PIRConfig(n_items=1 << 25, item_bytes=32,
                       protocol="additive-dpf-2")

# k-server XOR at 1 GB (beyond-paper scenario diversity; k = n_servers)
PIR_1G_K3 = PIRConfig(n_items=1 << 25, item_bytes=32,
                      protocol="xor-dpf-k", n_servers=3)

# single-server LWE at 1 GB (beyond-paper; no non-collusion assumption).
# Parameter selection is validated at query time (core/lwe.py params_for);
# note the client-side A matrix at this N is PRG-regenerated at ~GB scale —
# the 1 GB point is for plan/roofline math, not for this container.
PIR_1G_LWE = PIRConfig(n_items=1 << 25, item_bytes=32,
                       protocol="lwe-simple-1", n_servers=1)

# CPU-container scale for tests/benches/examples
PIR_SMOKE = PIRConfig(n_items=1 << 14, item_bytes=32, batch_queries=4)
PIR_SMOKE_ADD = PIRConfig(n_items=1 << 14, item_bytes=32,
                          protocol="additive-dpf-2", batch_queries=4)
# 2^12 records: three parties' serve steps compile in CI-tolerable time
PIR_SMOKE_K3 = PIRConfig(n_items=1 << 12, item_bytes=32,
                         protocol="xor-dpf-k", n_servers=3, batch_queries=4)
# online-update smoke (examples/db_updates.py): 3-server epoched updates
# at 2^10 records / bucket 2 — the smallest shape where the k-party serve
# steps still compile inside the CI gate's budget
PIR_SMOKE_UPD = PIRConfig(n_items=1 << 10, item_bytes=32,
                          protocol="xor-dpf-k", n_servers=3,
                          batch_queries=2)
# single-server LWE smoke (examples/single_server.py, tests): the LWE
# serve step is slice + int32 GEMM — no GGM chains — so it compiles far
# faster than the DPF steps and fits the CI gate at full smoke scale
PIR_SMOKE_LWE = PIRConfig(n_items=1 << 14, item_bytes=32,
                          protocol="lwe-simple-1", n_servers=1,
                          batch_queries=4)
# replica-plane smoke (examples/replicas.py, benchmarks/bench_replicas.py):
# every replica pays its own serve-step compile at construction, so the
# fleet demos run the cheap LWE step at 2^12 records to keep N compiles
# inside the CI gate's budget
PIR_SMOKE_REPL = PIRConfig(n_items=1 << 12, item_bytes=32,
                           protocol="lwe-simple-1", n_servers=1,
                           batch_queries=4)
# verified-reconstruction smoke (python -m repro.chaos --smoke,
# benchmarks/bench_chaos.py): replica scale + the per-row checksum column,
# so chaos-corrupted shares surface as IntegrityError instead of garbage
PIR_SMOKE_CHK = PIRConfig(n_items=1 << 12, item_bytes=32,
                          protocol="lwe-simple-1", n_servers=1,
                          batch_queries=4, checksum=True)
# batch-PIR smoke (examples/batch_query.py, tests): m=4 indices per round
# cuckoo-hashed into B=8 buckets of ~2^8 rows; checksum on so verified
# reconstruction rides through reassembly. One bucketed serve step is
# shared across all B same-shape bucket views — a single compile/party.
PIR_SMOKE_BATCH = PIRConfig(n_items=1 << 10, item_bytes=32,
                            batch_m=4, batch_queries=1, checksum=True)
# paper-scale batch point (plan/roofline math): 1 GB DB, 256-record batches
PIR_1G_BATCH = PIRConfig(n_items=1 << 25, item_bytes=32, batch_m=256)

PIR_CONFIGS = {
    "pir-512m": PIR_512M,
    "pir-1g": PIR_1G,
    "pir-2g": PIR_2G,
    "pir-4g": PIR_4G,
    "pir-8g": PIR_8G,
    "pir-1g-add": PIR_1G_ADD,
    "pir-1g-k3": PIR_1G_K3,
    "pir-1g-lwe": PIR_1G_LWE,
    "pir-smoke": PIR_SMOKE,
    "pir-smoke-add": PIR_SMOKE_ADD,
    "pir-smoke-k3": PIR_SMOKE_K3,
    "pir-smoke-upd": PIR_SMOKE_UPD,
    "pir-smoke-lwe": PIR_SMOKE_LWE,
    "pir-smoke-repl": PIR_SMOKE_REPL,
    "pir-smoke-chk": PIR_SMOKE_CHK,
    "pir-smoke-batch": PIR_SMOKE_BATCH,
    "pir-1g-batch": PIR_1G_BATCH,
}
