"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling frontend is a STUB: input_specs provides
precomputed patch embeddings (2880 tokens = 576 base + 4x576 anyres tiles)
prepended to the text stream. [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]"""
from repro.config import ModelConfig

FULL = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, n_frontend_tokens=2880,
)

SMOKE = ModelConfig(
    name="llava-next-34b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=512, n_frontend_tokens=8, attn_chunk=16,
)
