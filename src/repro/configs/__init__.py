"""Architecture registry: ``--arch <id>`` resolution for every launcher.

Each module under this package defines FULL (the assigned published config)
and SMOKE (a reduced same-family config runnable on one CPU device).
"""
from __future__ import annotations

from typing import Dict

from repro.config import ModelConfig
from repro.configs import (
    deepseek_v3_671b,
    granite_3_2b,
    grok_1_314b,
    llava_next_34b,
    qwen3_4b,
    stablelm_3b,
    starcoder2_3b,
    whisper_small,
    xlstm_350m,
    zamba2_7b,
)
from repro.configs.pir import PIR_CONFIGS
from repro.configs.shapes import SHAPES, get_shape

_MODULES = {
    "granite-3-2b": granite_3_2b,
    "qwen3-4b": qwen3_4b,
    "starcoder2-3b": starcoder2_3b,
    "stablelm-3b": stablelm_3b,
    "whisper-small": whisper_small,
    "xlstm-350m": xlstm_350m,
    "llava-next-34b": llava_next_34b,
    "grok-1-314b": grok_1_314b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "zamba2-7b": zamba2_7b,
}

ARCHS: Dict[str, ModelConfig] = {k: m.FULL for k, m in _MODULES.items()}
SMOKES: Dict[str, ModelConfig] = {k: m.SMOKE for k, m in _MODULES.items()}

# pure full-attention archs skip long_500k (sub-quadratic required; see
# DESIGN.md §4 shape-grid skips). SSM/hybrid run it.
LONG_CONTEXT_ARCHS = ("xlstm-350m", "zamba2-7b")


def get_arch(name: str, *, smoke: bool = False) -> ModelConfig:
    table = SMOKES if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]


def cell_is_skipped(arch: str, shape_name: str) -> bool:
    """True when an (arch × shape) cell is excluded by the assignment rules."""
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return True
    return False


__all__ = ["ARCHS", "SMOKES", "PIR_CONFIGS", "SHAPES", "LONG_CONTEXT_ARCHS",
           "get_arch", "get_shape", "cell_is_skipped"]
