"""deepseek-v3-671b [moe]: 61L d_model=7168 128H vocab=129280 — MLA
(q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128), 1 shared + 256
routed experts top-8 (expert width 2048; first 3 layers dense d_ff 18432),
MTP depth-1 head. [arXiv:2412.19437; hf]"""
from repro.config import AttentionKind, MLAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280,
    attention=AttentionKind.MLA,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  first_dense=3, dense_d_ff=18432),
    mtp=True,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512, attn_chunk=16,
    attention=AttentionKind.MLA,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                  first_dense=1, dense_d_ff=96),
    mtp=True,
)
