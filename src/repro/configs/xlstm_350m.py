"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM per the xLSTM paper's LM configs;
up/down projections live inside the blocks, hence d_ff=0).
[arXiv:2405.04517; unverified]"""
from repro.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    ssm=SSMConfig(d_state=0, d_conv=4, expand=2, headdim=0, chunk=256,
                  block_pattern=("mlstm",) * 7 + ("slstm",)),
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512,
    ssm=SSMConfig(d_state=0, d_conv=4, expand=2, headdim=0, chunk=16,
                  block_pattern=("mlstm", "slstm")),
)
