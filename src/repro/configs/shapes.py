"""Assigned input-shape presets (identical for every LM-family arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``; ``prefill_*`` lowers the prefill
pass of ``serve_step``.
"""
from __future__ import annotations

from typing import Dict

from repro.config import ShapeConfig

TRAIN_4K = ShapeConfig(name="train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig(name="prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig(name="decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig(name="long_500k", seq_len=524288, global_batch=1, kind="decode")

SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}

# Smoke-scale shapes (reduced configs, single CPU device).
SMOKE_TRAIN = ShapeConfig(name="smoke_train", seq_len=32, global_batch=2, kind="train")
SMOKE_PREFILL = ShapeConfig(name="smoke_prefill", seq_len=32, global_batch=2, kind="prefill")
SMOKE_DECODE = ShapeConfig(name="smoke_decode", seq_len=32, global_batch=2, kind="decode")


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
