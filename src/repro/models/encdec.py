"""Whisper-style encoder–decoder transformer (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings ``[B, encoder_len, d_model]``; the
encoder adds sinusoidal positions and runs non-causal self-attention.
The decoder uses learned positions (table sized for the 32k decode cell —
Whisper's native 448 ceiling is an operating-envelope choice, not a model
constraint), causal self-attention, and per-layer cross-attention whose K/V
are computed once from the encoder output and cached.

Faithfulness notes (DESIGN.md §4): GELU two-matrix MLPs and pre-LayerNorm
as in Whisper; attention biases are dropped (simplification), MHA is the
kv==heads degenerate case of the shared GQA path.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import layers as L

F32 = jnp.float32


class EncDecCache(NamedTuple):
    self_k: jax.Array       # [L, B, C, KV, hd]
    self_v: jax.Array
    cross_k: jax.Array      # [L, B, enc_len, KV, hd]
    cross_v: jax.Array
    length: jax.Array


def sinusoid_positions(n: int, d: int) -> np.ndarray:
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * dim / d)
    return np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)


def _ln_init(d, dt):
    return {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)}


def _gelu_mlp_init(rng, d, d_ff, dt):
    k1, k2 = jax.random.split(rng)
    return {"fc1": L.dense_init(k1, d, d_ff, dt),
            "fc2": L.dense_init(k2, d_ff, d, dt)}


def _gelu_mlp(p, x):
    return jax.nn.gelu(x @ p["fc1"]) @ p["fc2"]


def _xattn_init(rng, cfg, dt):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    return {"wq": L.dense_init(ks[0], d, h * hd, dt),
            "wk": L.dense_init(ks[1], d, h * hd, dt),
            "wv": L.dense_init(ks[2], d, h * hd, dt),
            "wo": L.dense_init(ks[3], h * hd, d, dt)}


_LN_SPEC = {"scale": P(None), "bias": P(None)}
_MLP_SPEC = {"fc1": P(None, L.MODEL), "fc2": P(L.MODEL, None)}
_XATTN_SPEC = {"wq": P(None, L.MODEL), "wk": P(None, L.MODEL),
               "wv": P(None, L.MODEL), "wo": P(L.MODEL, None)}


class EncDecLM:
    """Whisper-small shaped encoder-decoder with the standard protocol."""

    MAX_DEC_POS = 32768

    def __init__(self, cfg: ModelConfig, *, remat: str = "block"):
        self.cfg = cfg
        self.remat = remat

    # -- params -------------------------------------------------------------

    def _enc_layer_init(self, rng):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(rng, 2)
        return {"ln1": _ln_init(cfg.d_model, dt),
                "attn": L.gqa_init(ks[0], cfg),
                "ln2": _ln_init(cfg.d_model, dt),
                "mlp": _gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt)}

    def _dec_layer_init(self, rng):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(rng, 3)
        return {"ln1": _ln_init(cfg.d_model, dt),
                "self_attn": L.gqa_init(ks[0], cfg),
                "ln2": _ln_init(cfg.d_model, dt),
                "cross_attn": _xattn_init(ks[1], cfg, dt),
                "ln3": _ln_init(cfg.d_model, dt),
                "mlp": _gelu_mlp_init(ks[2], cfg.d_model, cfg.d_ff, dt)}

    def init_params(self, rng) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(rng, 5)
        enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": L.embed_init(ks[2], cfg.vocab, cfg.d_model, cfg.dtype),
            "pos_dec": (jax.random.normal(
                ks[3], (self.MAX_DEC_POS, cfg.d_model), F32) * 0.01
            ).astype(dt),
            "enc_layers": jax.vmap(self._enc_layer_init)(enc_keys),
            "dec_layers": jax.vmap(self._dec_layer_init)(dec_keys),
            "enc_norm": _ln_init(cfg.d_model, dt),
            "dec_norm": _ln_init(cfg.d_model, dt),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        enc_spec = {"ln1": _LN_SPEC, "attn": L.gqa_specs(cfg),
                    "ln2": _LN_SPEC, "mlp": _MLP_SPEC}
        dec_spec = {"ln1": _LN_SPEC, "self_attn": L.gqa_specs(cfg),
                    "ln2": _LN_SPEC, "cross_attn": _XATTN_SPEC,
                    "ln3": _LN_SPEC, "mlp": _MLP_SPEC}
        stack = lambda t: jax.tree_util.tree_map(
            lambda s: P(None, *s), t, is_leaf=lambda x: isinstance(x, P))
        return {
            "embed": L.embed_specs(),
            "pos_dec": P(None, None),
            "enc_layers": stack(enc_spec),
            "dec_layers": stack(dec_spec),
            "enc_norm": _LN_SPEC,
            "dec_norm": _LN_SPEC,
        }

    # -- encoder ------------------------------------------------------------

    def encode(self, params, frame_embeds):
        """frame_embeds [B, T_enc, d] -> encoder states [B, T_enc, d]."""
        cfg = self.cfg
        t_enc = frame_embeds.shape[1]
        pos = jnp.asarray(sinusoid_positions(t_enc, cfg.d_model),
                          frame_embeds.dtype)
        x = frame_embeds + pos[None]
        chunk = _divisor_chunk(t_enc)

        def body(x, lp):
            h = L.layernorm(x, lp["ln1"]["scale"], lp["ln1"]["bias"],
                            cfg.norm_eps)
            q, k, v = L.gqa_qkv(lp["attn"], cfg, h,
                                jnp.arange(t_enc)[None, :])
            a = L.chunked_attention(q, k, v, causal=False,
                                    q_chunk=chunk, kv_chunk=chunk)
            b, s, hh, hd = a.shape
            x = x + a.reshape(b, s, hh * hd) @ lp["attn"]["wo"]
            h = L.layernorm(x, lp["ln2"]["scale"], lp["ln2"]["bias"],
                            cfg.norm_eps)
            return x + _gelu_mlp(lp["mlp"], h), ()

        if self.remat == "block":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return L.layernorm(x, params["enc_norm"]["scale"],
                           params["enc_norm"]["bias"], cfg.norm_eps)

    # -- decoder ------------------------------------------------------------

    def _cross_kv(self, lp, enc_states):
        cfg = self.cfg
        b, t, _ = enc_states.shape
        h, hd = cfg.n_heads, cfg.resolved_head_dim
        k = (enc_states @ lp["cross_attn"]["wk"]).reshape(b, t, h, hd)
        v = (enc_states @ lp["cross_attn"]["wv"]).reshape(b, t, h, hd)
        return k, v

    def _dec_layer(self, lp, x, positions, enc_states=None, cross_kv=None,
                   self_cache=None, kv_len=None):
        cfg = self.cfg
        b, s, _ = x.shape
        h_n, hd = cfg.n_heads, cfg.resolved_head_dim
        # causal self-attention
        h = L.layernorm(x, lp["ln1"]["scale"], lp["ln1"]["bias"],
                        cfg.norm_eps)
        q, k, v = L.gqa_qkv(lp["self_attn"], cfg, h, positions)
        if self_cache is not None:
            a = L.decode_attention_append(q, self_cache[0], self_cache[1],
                                          k, v, kv_len)
        else:
            a = L.chunked_attention(q, k, v, causal=True,
                                    q_chunk=min(cfg.attn_chunk, s),
                                    kv_chunk=min(cfg.attn_chunk, s))
        x = x + a.reshape(b, s, h_n * hd) @ lp["self_attn"]["wo"]
        # cross-attention
        h = L.layernorm(x, lp["ln2"]["scale"], lp["ln2"]["bias"],
                        cfg.norm_eps)
        qx = (h @ lp["cross_attn"]["wq"]).reshape(b, s, h_n, hd)
        kx, vx = (cross_kv if cross_kv is not None
                  else self._cross_kv(lp, enc_states))
        t_enc = kx.shape[1]
        if s == 1:
            a = L.decode_attention(qx, kx, vx, jnp.asarray(t_enc))
        else:
            a = L.chunked_attention(qx, kx, vx, causal=False,
                                    q_chunk=min(cfg.attn_chunk, s),
                                    kv_chunk=_divisor_chunk(t_enc))
        x = x + a.reshape(b, s, h_n * hd) @ lp["cross_attn"]["wo"]
        h = L.layernorm(x, lp["ln3"]["scale"], lp["ln3"]["bias"],
                        cfg.norm_eps)
        return x + _gelu_mlp(lp["mlp"], h), (k, v)

    def _dec_embed(self, params, tokens, start):
        x = L.embed_lookup(params["embed"], tokens)
        pos = jax.lax.dynamic_slice_in_dim(params["pos_dec"], start,
                                           tokens.shape[1], 0)
        return x + pos[None].astype(x.dtype)

    # -- public -------------------------------------------------------------

    def loss(self, params, tokens, *, frame_embeds=None, **_):
        logits, _ = self.forward(params, tokens, frame_embeds=frame_embeds)
        return _xent(logits[:, :-1], tokens[:, 1:]), {}

    def forward(self, params, tokens, *, frame_embeds=None, prefix_embeds=None):
        """Teacher-forced decode over the full token stream."""
        if frame_embeds is None:
            frame_embeds = prefix_embeds
        cfg = self.cfg
        enc = self.encode(params, frame_embeds)
        x = self._dec_embed(params, tokens, 0)
        positions = jnp.arange(tokens.shape[1])[None, :]

        def body(x, lp):
            x, _ = self._dec_layer(lp, x, positions, enc_states=enc)
            return x, ()

        if self.remat == "block":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        x = L.layernorm(x, params["dec_norm"]["scale"],
                        params["dec_norm"]["bias"], cfg.norm_eps)
        return L.unembed(x, params["embed"], self.cfg.vocab), jnp.zeros((), F32)

    def prefill(self, params, tokens, *, frame_embeds=None, prefix_embeds=None):
        if frame_embeds is None:
            frame_embeds = prefix_embeds
        cfg = self.cfg
        enc = self.encode(params, frame_embeds)
        x = self._dec_embed(params, tokens, 0)
        positions = jnp.arange(tokens.shape[1])[None, :]

        def body(x, lp):
            x, kv = self._dec_layer(lp, x, positions, enc_states=enc)
            ck, cv = self._cross_kv(lp, enc)
            return x, (kv[0], kv[1], ck, cv)

        if self.remat == "block":
            body = jax.checkpoint(body)
        x, (sk, sv, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
        x = L.layernorm(x[:, -1:], params["dec_norm"]["scale"],
                        params["dec_norm"]["bias"], cfg.norm_eps)
        logits = L.unembed(x, params["embed"], self.cfg.vocab)[:, 0]
        cache = EncDecCache(self_k=sk, self_v=sv, cross_k=ck, cross_v=cv,
                            length=jnp.asarray(tokens.shape[1], jnp.int32))
        return logits, cache

    def decode(self, params, cache: EncDecCache, tokens, *, write=True):
        cfg = self.cfg
        x = self._dec_embed(params, tokens, cache.length)
        positions = jnp.reshape(cache.length, (1, 1))
        kv_len = cache.length

        def body(x, xs):
            lp, sk, sv, ck, cv = xs
            x, kv = self._dec_layer(lp, x, positions, cross_kv=(ck, cv),
                                    self_cache=(sk, sv), kv_len=kv_len)
            return x, kv

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["dec_layers"], cache.self_k, cache.self_v,
                      cache.cross_k, cache.cross_v))
        x = L.layernorm(x, params["dec_norm"]["scale"],
                        params["dec_norm"]["bias"], cfg.norm_eps)
        logits = L.unembed(x, params["embed"], self.cfg.vocab)[:, 0]
        if write:
            pos = cache.length
            sk = jax.lax.dynamic_update_slice(
                cache.self_k, nk.astype(cache.self_k.dtype), (0, 0, pos, 0, 0))
            sv = jax.lax.dynamic_update_slice(
                cache.self_v, nv.astype(cache.self_v.dtype), (0, 0, pos, 0, 0))
            cache = cache._replace(self_k=sk, self_v=sv, length=pos + 1)
        else:
            cache = cache._replace(length=cache.length + 1)
        return logits, cache

    def init_cache(self, batch: int, capacity: int) -> EncDecCache:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        hd = cfg.resolved_head_dim
        sshape = (cfg.n_layers, batch, capacity, cfg.n_kv_heads, hd)
        cshape = (cfg.n_layers, batch, cfg.encoder_len, cfg.n_heads, hd)
        return EncDecCache(
            self_k=jnp.zeros(sshape, dt), self_v=jnp.zeros(sshape, dt),
            cross_k=jnp.zeros(cshape, dt), cross_v=jnp.zeros(cshape, dt),
            length=jnp.asarray(0, jnp.int32))

    def cache_specs(self) -> EncDecCache:
        s = P(None, L.BATCH, None, L.MODEL, None)
        return EncDecCache(self_k=s, self_v=s, cross_k=s, cross_v=s,
                           length=P())


def _divisor_chunk(n: int, target: int = 768) -> int:
    """Largest divisor of n that is <= target (attention chunk for enc len)."""
    best = 1
    for c in range(1, min(n, target) + 1):
        if n % c == 0:
            best = c
    return best


def _xent(logits, targets):
    lse = jax.nn.logsumexp(logits.astype(F32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(F32), targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
