"""Shared model primitives: norms, RoPE, chunked attention, MLP, embeddings.

Conventions
-----------
* Parameters are plain nested dicts of jnp arrays; every init function has a
  matching ``*_specs`` producing a PartitionSpec pytree of the same shape
  (logical sharding: feature dims on ``model``, batch on ``data``/``pod``).
* Activations flow in the config dtype (bf16 default); softmax/norm statistics
  are computed in fp32.
* Attention is flash-style: an online-softmax scan over KV chunks (and over Q
  chunks for long sequences) so the score matrix never materializes beyond
  ``[B, H, q_chunk, kv_chunk]`` — required for the 32k prefill cells.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# Mesh-axis aliases used by every spec function.
BATCH = ("pod", "data")   # batch-sharded activations
MODEL = "model"           # tensor-parallel features

F32 = jnp.float32

NEG_INF = -1e30


def _ambient_mesh():
    """The mesh installed by ``with mesh:`` (None outside any context)."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def shard_hint(x: jax.Array, *entries) -> jax.Array:
    """Activation sharding constraint, ambient-mesh aware.

    GSPMD occasionally gives up around data-dependent ops (sorts, gathers)
    and replicates large intermediates; a constraint at the right boundary
    restores the intended layout. ``entries`` follow PartitionSpec
    semantics but are filtered against the axes the *current* mesh actually
    has, and any entry whose axis sizes don't divide the dim is dropped —
    so model code can state intent unconditionally and stay runnable on
    the single-CPU test mesh.
    """
    m = _ambient_mesh()
    if m is None:
        return x
    names = dict(m.shape)
    fixed = []
    for i, e in enumerate(entries[:x.ndim]):
        if isinstance(e, tuple):
            e = tuple(a for a in e if a in names)
            e = e if e else None
        elif e is not None and e not in names:
            e = None
        if e is not None:
            size = 1
            for a in (e if isinstance(e, tuple) else (e,)):
                size *= names[a]
            if size > 1 and x.shape[i] % size != 0:
                e = None
        fixed.append(e)
    if all(e is None for e in fixed):
        return x
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def dense_init(rng, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.uniform(rng, (d_in, d_out), F32, -scale, scale)
            ).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(F32))).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(F32) + bias.astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), F32)          # [hd/2]
    angles = positions.astype(F32)[..., None] * freqs         # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                       # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _online_softmax_block(q, k, v, mask, m_prev, l_prev, acc_prev):
    """One flash-attention block update. q:[B,H,Tq,hd] k,v:[B,H,Tk,hd]."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=F32)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_new = acc_prev * alpha[..., None] + jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v, preferred_element_type=F32)
    return m_new, l_new, acc_new


def chunked_attention(
    q: jax.Array,            # [B, Sq, H, hd]
    k: jax.Array,            # [B, Skv, KV, hd]
    v: jax.Array,            # [B, Skv, KV, hd]
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,   # valid KV prefix length (decode)
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """GQA flash-style attention; returns [B, Sq, H, hd].

    KV heads are broadcast to Q heads by grouping. ``q_offset`` is the global
    position of q[0] (prefill continuation / decode); ``kv_len`` masks the
    unwritten tail of a preallocated KV cache.
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]          # value head dim may differ (MLA)
    groups = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # [B, S, KV, hd] -> [B, KV*G, S, hd] with q heads grouped per KV head.
    qh = (q.transpose(0, 2, 1, 3) * scale).astype(q.dtype)     # [B,H,Sq,hd]
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), groups, axis=1)   # [B,H,Skv,hd]
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), groups, axis=1)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # odd lengths (e.g. MTP's S-1 stream) fall back to a single chunk
    if sq % q_chunk:
        q_chunk = sq
    if skv % kv_chunk:
        kv_chunk = skv
    nq, nk = sq // q_chunk, skv // kv_chunk

    q_blocks = qh.reshape(b, h, nq, q_chunk, hd).transpose(2, 0, 1, 3, 4)
    k_blocks = kh.reshape(b, h, nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    v_blocks = vh.reshape(b, h, nk, kv_chunk, hdv).transpose(2, 0, 1, 3, 4)

    q_pos_base = jnp.asarray(q_offset, jnp.int32)

    def q_block_body(_, qi):
        qb = q_blocks[qi]
        q_pos = q_pos_base + qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, ki):
            m, l, acc = carry
            kb, vb = k_blocks[ki], v_blocks[ki]
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if kv_len is not None:
                mask &= k_pos[None, :] < kv_len
            m, l, acc = _online_softmax_block(
                qb, kb, vb, mask[None, None], m, l, acc)
            return (m, l, acc), ()

        init = (
            jnp.full((b, h, q_chunk), NEG_INF, F32),
            jnp.zeros((b, h, q_chunk), F32),
            jnp.zeros((b, h, q_chunk, hdv), F32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_body, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return (), out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block_body, (), jnp.arange(nq))
    # outs: [nq, B, H, q_chunk, hdv] -> [B, Sq, H, hdv]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, hdv)
    return out


def decode_attention_append(
    q: jax.Array,            # [B, 1, H, hd]
    k_cache: jax.Array,      # [B, S, KV, hd]
    v_cache: jax.Array,
    k_new: jax.Array,        # [B, 1, KV, hd] — current token's key
    v_new: jax.Array,
    kv_len: jax.Array,       # [] — valid cache prefix length
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Decode attention over (cache ∪ current token) without copying the
    cache: the self term is concatenated on the (tiny) score axis only."""
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    groups = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = (q[:, 0].astype(F32) * scale).reshape(b, kv, groups, hd)
    s_cache = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(F32))
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None, :] < jnp.reshape(kv_len, (-1, 1))
    s_cache = jnp.where(mask[:, None, None, :], s_cache, NEG_INF)
    s_self = jnp.einsum("bkgd,bkd->bkg", qg, k_new[:, 0].astype(F32))
    s_all = jnp.concatenate([s_cache, s_self[..., None]], axis=-1)
    p = jax.nn.softmax(s_all, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p[..., :-1], v_cache.astype(F32))
    out += p[..., -1][..., None] * v_new[:, 0].astype(F32)[:, :, None, :]
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def decode_attention(
    q: jax.Array,            # [B, 1, H, hd]
    k_cache: jax.Array,      # [B, S, KV, hd]
    v_cache: jax.Array,
    kv_len: jax.Array,       # [] or [B] — valid prefix length
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against a preallocated KV cache."""
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    groups = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qh = q[:, 0].astype(F32) * scale                         # [B, H, hd]
    qg = qh.reshape(b, kv, groups, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(F32))
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None, :] < jnp.reshape(kv_len, (-1, 1))        # [B or 1, S]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(F32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (params + apply)
# ---------------------------------------------------------------------------

def gqa_init(rng, cfg) -> dict:
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kv * hd, dt),
        "wv": dense_init(ks[2], d, kv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def gqa_specs(cfg) -> dict:
    p = {
        "wq": P(None, MODEL),
        "wk": P(None, MODEL),
        "wv": P(None, MODEL),
        "wo": P(MODEL, None),
    }
    if cfg.qk_norm:
        p["q_norm"] = P(None)
        p["k_norm"] = P(None)
    return p


def gqa_qkv(params, cfg, x, positions):
    """Project + RoPE. Returns q [B,S,H,hd], k/v [B,S,KV,hd]."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if cfg.pos_kind == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attend(params, cfg, x, positions, *, causal=True, q_offset=0,
               kv_cache=None, kv_len=None):
    """Full GQA block. With ``kv_cache=(k,v)`` and S==1 runs decode path.

    Returns (out [B,S,d], (k_new, v_new)) — new KV for cache maintenance.
    """
    b, s, _ = x.shape
    q, k, v = gqa_qkv(params, cfg, x, positions)
    if kv_cache is not None:
        kc, vc = kv_cache
        if s != 1:
            raise ValueError("cache path expects single-token decode")
        out = decode_attention_append(q, kc, vc, k, v, kv_len)
    else:
        out = chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                                kv_len=kv_len, q_chunk=cfg.attn_chunk,
                                kv_chunk=cfg.attn_chunk)
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    return out.reshape(b, s, h * hd) @ params["wo"], (k, v)


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(rng, cfg) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(rng, 6)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, dt),
        "wq_b": dense_init(ks[1], m.q_lora_rank, h * qk_head, dt),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                            h * (m.qk_nope_head_dim + m.v_head_dim), dt),
        "wo": dense_init(ks[4], h * m.v_head_dim, d, dt),
        "q_a_norm": jnp.zeros((m.q_lora_rank,), dt),
        "kv_a_norm": jnp.zeros((m.kv_lora_rank,), dt),
    }


def mla_specs(cfg) -> dict:
    return {
        "wq_a": P(None, None),
        "wq_b": P(None, MODEL),
        "wkv_a": P(None, None),
        "wkv_b": P(None, MODEL),
        "wo": P(MODEL, None),
        "q_a_norm": P(None),
        "kv_a_norm": P(None),
    }


def mla_attend(params, cfg, x, positions, *, causal=True, q_offset=0,
               kv_cache=None, kv_len=None):
    """MLA block. The cache stores the *compressed* latent + rope key —
    [B, S, kv_lora + rope_dim] — which is MLA's entire point (DESIGN.md §5).

    Returns (out, cache_row [B, S, kv_lora + rope]).
    """
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q_lat = rmsnorm(x @ params["wq_a"], params["q_a_norm"], cfg.norm_eps)
    q = (q_lat @ params["wq_b"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ params["wkv_a"]                        # [B,S,kv_lora+rope]
    c_kv = rmsnorm(kv_a[..., :m.kv_lora_rank], params["kv_a_norm"],
                   cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)               # [B,S,1,rope]
    cache_row = jnp.concatenate([c_kv, k_rope[..., 0, :]], axis=-1)

    scale = 1.0 / math.sqrt(nope + rope_d)

    if kv_cache is not None:
        # Absorbed decode: attention runs in the compressed latent space —
        # q_nope is folded through W_kv_b's key half so scores contract
        # directly against the [B, S, kv_lora] cache, and the output latent
        # is expanded through the value half. No per-step K/V rematerialize.
        if s != 1:
            raise ValueError("cache path expects single-token decode")
        full = kv_cache                               # [B, Smax, lora+rope]
        c_all, kr_all = full[..., :m.kv_lora_rank], full[..., m.kv_lora_rank:]
        wkv_b = params["wkv_b"].reshape(m.kv_lora_rank, h, nope + vd)
        wk_b, wv_b = wkv_b[..., :nope], wkv_b[..., nope:]
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, wk_b)     # [B,1,H,lora]
        s_lat = jnp.einsum("bshl,btl->bhst", q_lat.astype(F32),
                           c_all.astype(F32))
        s_rope = jnp.einsum("bshr,btr->bhst", q_rope.astype(F32),
                            kr_all.astype(F32))
        s_cache = (s_lat + s_rope) * scale
        pos = jnp.arange(c_all.shape[1])
        mask = pos[None, :] < jnp.reshape(kv_len, (-1, 1))
        s_cache = jnp.where(mask[:, None, None, :], s_cache, NEG_INF)
        # self term from the current token's own cache row
        c_new, kr_new = (cache_row[..., :m.kv_lora_rank],
                         cache_row[..., m.kv_lora_rank:])
        s_self = (jnp.einsum("bshl,bsl->bhs", q_lat.astype(F32),
                             c_new.astype(F32))
                  + jnp.einsum("bshr,bsr->bhs", q_rope.astype(F32),
                               kr_new.astype(F32))) * scale
        p = jax.nn.softmax(
            jnp.concatenate([s_cache, s_self[..., None]], axis=-1), axis=-1)
        out_lat = jnp.einsum("bhst,btl->bshl", p[..., :-1],
                             c_all.astype(F32))
        out_lat += p[..., -1].transpose(0, 2, 1)[..., None] \
            * c_new.astype(F32)[:, :, None, :]
        out = jnp.einsum("bshl,lhv->bshv", out_lat.astype(x.dtype), wv_b)
    else:
        c_all, kr_all = c_kv, k_rope[..., 0, :]
        kv = (c_all @ params["wkv_b"]).reshape(b, s, h, nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                      k_nope.shape[:-1] + (rope_d,))],
            axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(qfull, k, v, causal=causal, q_offset=q_offset,
                                kv_len=kv_len, q_chunk=cfg.attn_chunk,
                                kv_chunk=cfg.attn_chunk, scale=scale)
    return out.reshape(b, s, h * vd) @ params["wo"], cache_row


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(rng, d: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(rng, 3)
    dt = jnp.dtype(dtype)
    return {
        "gate": dense_init(ks[0], d, d_ff, dt),
        "up": dense_init(ks[1], d, d_ff, dt),
        "down": dense_init(ks[2], d_ff, d, dt),
    }


def mlp_specs() -> dict:
    return {"gate": P(None, MODEL), "up": P(None, MODEL),
            "down": P(MODEL, None)}


def mlp_apply(params, x):
    return (jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
            ) @ params["down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

VOCAB_PAD = 256     # table rows pad to this multiple (axis divisibility)


def pad_vocab(v: int) -> int:
    return -(-v // VOCAB_PAD) * VOCAB_PAD


def embed_init(rng, vocab: int, d: int, dtype) -> jax.Array:
    """[pad_vocab(V), d] table; rows >= V are never gathered and their
    logits are masked in :func:`unembed`."""
    return (jax.random.normal(rng, (pad_vocab(vocab), d), F32)
            * 0.02).astype(dtype)


def embed_specs() -> P:
    return P(MODEL, None)   # vocab-sharded: the PIR DB layout (DESIGN.md §4)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array,
            n_valid: Optional[int] = None) -> jax.Array:
    """Logits against a (possibly tied, vocab-padded) [V_pad, d] table.

    ``n_valid`` masks the padding rows to -inf so softmax/CE/argmax see
    exactly the true vocabulary.
    """
    logits = jnp.einsum("bsd,vd->bsv", x.astype(F32), table.astype(F32))
    if n_valid is not None and n_valid < table.shape[0]:
        valid = jnp.arange(table.shape[0]) < n_valid
        logits = jnp.where(valid, logits, NEG_INF)
    return logits
