"""Model zoo dispatch: ModelConfig.family -> model implementation.

Every model exposes the same functional protocol:

  init_params(rng) -> params        param_specs() -> PartitionSpec pytree
  loss(params, tokens, **aux_inputs) -> (scalar, metrics)
  forward(params, tokens, **aux)    -> (logits, aux_loss)
  prefill(params, tokens, **aux)    -> (last_logits, cache)
  decode(params, cache, tokens)     -> (logits, cache')
  init_cache(batch, capacity)       cache_specs()

``aux_inputs`` carries the modality-frontend stubs: ``prefix_embeds`` for
VLM patch embeddings, ``frame_embeds`` for audio frames (precomputed by the
client per the assignment — the frontend itself is not modeled).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.hybrid import Zamba2Model
from repro.models.transformer import TransformerLM
from repro.models.xlstm import XLSTMModel

FAMILIES = ("dense", "moe", "vlm", "audio", "ssm", "hybrid")


def build_model(cfg: ModelConfig, *, remat: str = "block"):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg, remat=remat)
    if cfg.family == "audio":
        return EncDecLM(cfg, remat=remat)
    if cfg.family == "ssm":
        return XLSTMModel(cfg, remat=remat)
    if cfg.family == "hybrid":
        return Zamba2Model(cfg, remat=remat)
    raise ValueError(f"unknown family {cfg.family!r}")


def input_specs(cfg: ModelConfig, shape: ShapeConfig
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """ShapeDtypeStruct stand-ins + logical PartitionSpecs for step inputs.

    Returns (structs, pspecs): tokens (+ modality stubs). ``decode`` shapes
    get a single-token stream; the KV cache spec is produced separately via
    ``jax.eval_shape(model.init_cache, ...)`` by the launcher.
    """
    from jax.sharding import PartitionSpec as P
    b = shape.global_batch
    dt = np.dtype("int32")
    batch_axes = ("pod", "data")
    structs: Dict[str, Any] = {}
    pspecs: Dict[str, Any] = {}

    if shape.kind == "decode":
        structs["tokens"] = jax.ShapeDtypeStruct((b, 1), dt)
        pspecs["tokens"] = P(batch_axes, None)
        return structs, pspecs

    s = shape.seq_len
    if cfg.family == "vlm":
        n_front = cfg.n_frontend_tokens
        structs["tokens"] = jax.ShapeDtypeStruct((b, s - n_front), dt)
        structs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, n_front, cfg.d_model), np.dtype(cfg.dtype))
        pspecs["tokens"] = P(batch_axes, None)
        pspecs["prefix_embeds"] = P(batch_axes, None, None)
    elif cfg.family == "audio":
        structs["tokens"] = jax.ShapeDtypeStruct((b, s), dt)
        structs["frame_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_len, cfg.d_model), np.dtype(cfg.dtype))
        pspecs["tokens"] = P(batch_axes, None)
        pspecs["frame_embeds"] = P(batch_axes, None, None)
    else:
        structs["tokens"] = jax.ShapeDtypeStruct((b, s), dt)
        pspecs["tokens"] = P(batch_axes, None)
    return structs, pspecs
