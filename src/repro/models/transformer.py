"""Decoder-only transformer LM: dense / MoE / VLM families, GQA or MLA.

Layer stacks are ``lax.scan`` over parameter pytrees stacked on a leading
layer axis — HLO size stays O(1) in depth (critical for 61–81-layer configs
compiling on this container) and the remat policy wraps the scanned body.

Entry points (all functional, pjit-ready):
  init_params / param_specs     parameters + PartitionSpec pytree
  forward(tokens)               full-sequence causal logits (train)
  prefill(tokens)               logits at last position + filled KV cache
  decode(cache, token, pos)     one-token step against the cache
  init_cache(batch, capacity)   preallocated cache pytree

MoE models split the stack into a dense prefix (DeepSeek's ``first_dense``)
and an MoE trunk, each its own scan. The MTP flag adds DeepSeek-V3's depth-1
multi-token-prediction head (extra scanned-out layer + tied unembed) whose
loss is averaged into the training objective.

VLM ("vlm" family): the anyres tiling frontend is a stub per the assignment —
``prefix_embeds [B, n_frontend_tokens, d]`` arrive precomputed and are
concatenated ahead of the token embeddings; loss masks the prefix.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import AttentionKind, ModelConfig
from repro.models import layers as L
from repro.models import moe as M

F32 = jnp.float32


class KVCache(NamedTuple):
    """Preallocated decode cache. GQA: k/v [Layers, B, C, KV, hd];
    MLA: k holds the compressed rows [Layers, B, C, lora+rope], v is ()."""
    k: Any
    v: Any
    length: jax.Array       # [] int32 — valid prefix


def _layer_init(rng, cfg: ModelConfig, *, moe_layer: bool) -> dict:
    ks = jax.random.split(rng, 3)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
    }
    if cfg.attention == AttentionKind.MLA:
        p["attn"] = L.mla_init(ks[0], cfg)
    else:
        p["attn"] = L.gqa_init(ks[0], cfg)
    if moe_layer:
        p["ffn"] = M.moe_init(ks[1], cfg)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.dense_d_ff:
            d_ff = cfg.moe.dense_d_ff
        p["ffn"] = L.mlp_init(ks[1], cfg.d_model, d_ff, cfg.dtype)
    return p


def _layer_specs(cfg: ModelConfig, *, moe_layer: bool) -> dict:
    p = {"ln1": P(None), "ln2": P(None)}
    if cfg.attention == AttentionKind.MLA:
        p["attn"] = L.mla_specs(cfg)
    else:
        p["attn"] = L.gqa_specs(cfg)
    p["ffn"] = M.moe_specs(cfg) if moe_layer else L.mlp_specs()
    return p


def _stack_specs(spec_tree, n_layers: int):
    """Prepend the (unsharded) layer-stack axis to every leaf spec."""
    del n_layers
    return jax.tree_util.tree_map(
        lambda s: P(None, *s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


class TransformerLM:
    """Functional model wrapper for families: dense | moe | vlm."""

    def __init__(self, cfg: ModelConfig, *, remat: str = "block"):
        self.cfg = cfg
        self.remat = remat
        self.n_dense = cfg.moe.first_dense if cfg.moe else cfg.n_layers
        self.n_moe = cfg.n_layers - self.n_dense

    # -- parameters ---------------------------------------------------------

    def init_params(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 6)
        p: dict = {"embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model,
                                         cfg.dtype)}
        if self.n_dense:
            lk = jax.random.split(ks[1], self.n_dense)
            p["dense_layers"] = jax.vmap(
                lambda r: _layer_init(r, cfg, moe_layer=False))(lk)
        if self.n_moe:
            lk = jax.random.split(ks[2], self.n_moe)
            p["moe_layers"] = jax.vmap(
                lambda r: _layer_init(r, cfg, moe_layer=True))(lk)
        p["final_norm"] = jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype))
        if not cfg.tie_embeddings:
            p["unembed"] = L.embed_init(ks[3], cfg.vocab, cfg.d_model,
                                        cfg.dtype)
        if cfg.mtp:
            p["mtp"] = {
                "proj": L.dense_init(ks[4], 2 * cfg.d_model, cfg.d_model,
                                     jnp.dtype(cfg.dtype)),
                "norm": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
                "layer": _layer_init(ks[5], cfg, moe_layer=False),
            }
        return p

    def param_specs(self) -> dict:
        cfg = self.cfg
        p: dict = {"embed": L.embed_specs()}
        if self.n_dense:
            p["dense_layers"] = _stack_specs(
                _layer_specs(cfg, moe_layer=False), self.n_dense)
        if self.n_moe:
            p["moe_layers"] = _stack_specs(
                _layer_specs(cfg, moe_layer=True), self.n_moe)
        p["final_norm"] = P(None)
        if not cfg.tie_embeddings:
            p["unembed"] = L.embed_specs()
        if cfg.mtp:
            p["mtp"] = {
                "proj": P(None, None),
                "norm": P(None),
                "layer": _layer_specs(cfg, moe_layer=False),
            }
        return p

    # -- layer body ---------------------------------------------------------

    def _attend(self, lp, x, positions, *, kv_cache=None, kv_len=None,
                q_offset=0):
        cfg = self.cfg
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if cfg.attention == AttentionKind.MLA:
            return L.mla_attend(lp["attn"], cfg, h, positions,
                                kv_cache=kv_cache, kv_len=kv_len,
                                q_offset=q_offset)
        return L.gqa_attend(lp["attn"], cfg, h, positions,
                            kv_cache=kv_cache, kv_len=kv_len,
                            q_offset=q_offset)

    def _ffn(self, lp, x, *, moe_layer: bool):
        cfg = self.cfg
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if moe_layer:
            y, aux = M.moe_apply(lp["ffn"], cfg, h)
            return y, aux
        return L.mlp_apply(lp["ffn"], h), jnp.zeros((), F32)

    def _layer(self, lp, x, positions, *, moe_layer: bool, kv_cache=None,
               kv_len=None):
        attn_out, kv_new = self._attend(lp, x, positions, kv_cache=kv_cache,
                                        kv_len=kv_len)
        x = x + attn_out
        ffn_out, aux = self._ffn(lp, x, moe_layer=moe_layer)
        return x + ffn_out, kv_new, aux

    def _scan_stack(self, stacked, x, positions, *, moe_layer: bool,
                    cache=None, kv_len=None, want_cache: bool = True):
        """Scan a stacked layer group. Returns (x, stacked kv rows, aux)."""
        def body(carry, xs):
            x, aux = carry
            x = L.shard_hint(x, L.BATCH, None, None)
            if cache is None:
                lp = xs
                x, kv_new, a = self._layer(lp, x, positions,
                                           moe_layer=moe_layer)
            else:
                lp, layer_cache = xs
                x, kv_new, a = self._layer(lp, x, positions,
                                           moe_layer=moe_layer,
                                           kv_cache=layer_cache,
                                           kv_len=kv_len)
            if not want_cache:
                kv_new = ()     # don't stack KV the caller will discard
            return (x, aux + a), kv_new

        if self.remat == "block":
            body = jax.checkpoint(body)
        xs = stacked if cache is None else (stacked, cache)
        (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), F32)), xs)
        return x, kvs, aux

    # -- embeddings ---------------------------------------------------------

    def _embed(self, params, tokens, prefix_embeds):
        x = L.embed_lookup(params["embed"], tokens)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        return x

    def _unembed_table(self, params):
        return params["embed"] if self.cfg.tie_embeddings else params["unembed"]

    # -- public entry points -------------------------------------------------

    def forward(self, params, tokens, *, prefix_embeds=None):
        """Full-sequence causal pass. Returns (logits [B,S,V] f32, aux)."""
        x = self._embed(params, tokens, prefix_embeds)
        positions = jnp.arange(x.shape[1])[None, :]
        aux = jnp.zeros((), F32)
        if self.n_dense:
            x, _, a = self._scan_stack(params["dense_layers"], x, positions,
                                       moe_layer=False, want_cache=False)
            aux += a
        if self.n_moe:
            x, _, a = self._scan_stack(params["moe_layers"], x, positions,
                                       moe_layer=True, want_cache=False)
            aux += a
        x = L.rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        logits = L.unembed(x, self._unembed_table(params), self.cfg.vocab)
        return logits, aux

    def loss(self, params, tokens, *, prefix_embeds=None,
             aux_weight: float = 0.01):
        """Next-token CE (+ MoE aux + optional MTP). Returns (loss, metrics)."""
        logits, aux = self.forward(params, tokens,
                                   prefix_embeds=prefix_embeds)
        n_prefix = 0 if prefix_embeds is None else prefix_embeds.shape[1]
        pred = logits[:, n_prefix:-1]
        tgt = tokens[:, 1:]
        ce = _xent(pred, tgt)
        total = ce + aux_weight * aux
        metrics = {"ce": ce, "aux": aux}
        if self.cfg.mtp:
            mtp_ce = self._mtp_loss(params, tokens, logits, n_prefix)
            total = total + 0.3 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        return total, metrics

    def _mtp_loss(self, params, tokens, logits, n_prefix):
        """DeepSeek-V3 depth-1 MTP: h'_t = Layer(W[h_t ; emb(x_{t+1})]),
        predicting x_{t+2}; unembed is shared."""
        del logits
        cfg = self.cfg
        mp = params["mtp"]
        x = self._embed(params, tokens, None)
        positions = jnp.arange(x.shape[1])[None, :]
        # cheap re-embed of trunk output is avoided: reuse final hidden via a
        # second pass is too costly — MTP consumes the *embedding* stream
        # shifted by one plus a single extra layer (faithful to depth-1 MTP).
        h = L.rmsnorm(x[:, :-1], mp["norm"], cfg.norm_eps)
        nxt = x[:, 1:]
        fused = jnp.concatenate([h, nxt], axis=-1) @ mp["proj"]
        fused, _, _ = self._layer(mp["layer"], fused, positions[:, :-1],
                                  moe_layer=False)
        mtp_logits = L.unembed(fused, self._unembed_table(params), self.cfg.vocab)
        return _xent(mtp_logits[:, :-1], tokens[:, 2:])

    def prefill(self, params, tokens, *, prefix_embeds=None):
        """Causal pass returning last-position logits + the filled cache."""
        cfg = self.cfg
        x = self._embed(params, tokens, prefix_embeds)
        positions = jnp.arange(x.shape[1])[None, :]
        caches = []
        aux = jnp.zeros((), F32)
        if self.n_dense:
            x, kv, a = self._scan_stack(params["dense_layers"], x, positions,
                                        moe_layer=False)
            caches.append(kv)
            aux += a
        if self.n_moe:
            x, kv, a = self._scan_stack(params["moe_layers"], x, positions,
                                        moe_layer=True)
            caches.append(kv)
            aux += a
        x = L.rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x, self._unembed_table(params), self.cfg.vocab)[:, 0]
        cache = self._assemble_cache(caches, x.shape[0], tokens, prefix_embeds)
        return logits, cache

    def _assemble_cache(self, caches, batch, tokens, prefix_embeds):
        seq = tokens.shape[1] + (0 if prefix_embeds is None
                                 else prefix_embeds.shape[1])
        if self.cfg.attention == AttentionKind.MLA:
            rows = jnp.concatenate(caches, axis=0)       # [L, B, S, lora+rope]
            return KVCache(k=rows, v=(), length=jnp.asarray(seq, jnp.int32))
        ks = jnp.concatenate([c[0] for c in caches], axis=0)
        vs = jnp.concatenate([c[1] for c in caches], axis=0)
        return KVCache(k=ks, v=vs, length=jnp.asarray(seq, jnp.int32))

    def init_cache(self, batch: int, capacity: int) -> KVCache:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        n_l = cfg.n_layers
        if cfg.attention == AttentionKind.MLA:
            m = cfg.mla
            rows = jnp.zeros((n_l, batch, capacity,
                              m.kv_lora_rank + m.qk_rope_head_dim), dt)
            return KVCache(k=rows, v=(), length=jnp.asarray(0, jnp.int32))
        hd = cfg.resolved_head_dim
        shape = (n_l, batch, capacity, cfg.n_kv_heads, hd)
        return KVCache(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
                       length=jnp.asarray(0, jnp.int32))

    def cache_specs(self, mesh_axes=("data", "model")) -> KVCache:
        """PartitionSpecs for the cache pytree (batch on data axes)."""
        if self.cfg.attention == AttentionKind.MLA:
            return KVCache(k=P(None, L.BATCH, None, None), v=(),
                           length=P())
        return KVCache(k=P(None, L.BATCH, None, L.MODEL, None),
                       v=P(None, L.BATCH, None, L.MODEL, None),
                       length=P())

    def decode(self, params, cache: KVCache, tokens, *, write: bool = True):
        """One decode step. tokens [B, 1]. Returns (logits [B,V], cache').

        ``write=True`` appends the new KV rows at ``cache.length`` (requires
        spare capacity); ``write=False`` (dry-run cells at full capacity)
        still attends over cache ∪ self via the score-append path.
        """
        cfg = self.cfg
        x = self._embed(params, tokens, None)
        positions = jnp.reshape(cache.length, (1, 1))
        kv_len = cache.length
        aux = jnp.zeros((), F32)
        new_rows = []
        offset = 0
        for name, moe_layer, n in (("dense_layers", False, self.n_dense),
                                   ("moe_layers", True, self.n_moe)):
            if not n:
                continue
            if cfg.attention == AttentionKind.MLA:
                layer_cache = cache.k[offset:offset + n]
            else:
                layer_cache = (cache.k[offset:offset + n],
                               cache.v[offset:offset + n])
            x, kvs, a = self._scan_stack(params[name], x, positions,
                                         moe_layer=moe_layer,
                                         cache=layer_cache, kv_len=kv_len)
            new_rows.append(kvs)
            aux += a
            offset += n
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x, self._unembed_table(params), self.cfg.vocab)[:, 0]
        if write:
            cache = self._write_rows(cache, new_rows)
        else:
            cache = cache._replace(length=cache.length + 1)
        return logits, cache

    def _write_rows(self, cache: KVCache, new_rows) -> KVCache:
        pos = cache.length
        if self.cfg.attention == AttentionKind.MLA:
            rows = jnp.concatenate(new_rows, axis=0)    # [L, B, 1, lora+rope]
            k = jax.lax.dynamic_update_slice(
                cache.k, rows.astype(cache.k.dtype), (0, 0, pos, 0))
            return KVCache(k=k, v=(), length=pos + 1)
        ks = jnp.concatenate([r[0] for r in new_rows], axis=0)
        vs = jnp.concatenate([r[1] for r in new_rows], axis=0)
        k = jax.lax.dynamic_update_slice(
            cache.k, ks.astype(cache.k.dtype), (0, 0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, vs.astype(cache.v.dtype), (0, 0, pos, 0, 0))
        return KVCache(k=k, v=v, length=pos + 1)


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean CE in f32. logits [B, S, V], targets [B, S] int."""
    lse = jax.nn.logsumexp(logits.astype(F32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(F32), targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
