from repro.models.registry import build_model, input_specs, FAMILIES

__all__ = ["build_model", "input_specs", "FAMILIES"]
