"""Mixture-of-experts FFN with sort-based capacity dispatch.

Two execution paths, chosen by sequence length:

``dispatch`` (train / prefill)
    Per-sequence sort-based dispatch: tokens of each sequence are routed,
    sorted by expert id, packed into a capacity-bounded buffer
    ``[B, E, C, d]``, run through a batched expert GEMM, and combined back.
    FLOPs scale as ``B·E·C·d·d_e ≈ capacity_factor × active`` — the roofline
    ratio MODEL_FLOPS/HLO_FLOPs stays honest (a dense one-hot dispatch à la
    Mesh-TF would be quadratic in tokens). Grouping by sequence keeps every
    scatter/gather *within* a batch shard, so GSPMD needs no data-dependent
    cross-shard movement: the only collectives are the expert-parallel ones
    on the E axis.

``gather`` (decode, S == 1)
    One token per sequence: gathering top-k expert weight slices per token
    costs exactly the active-parameter bytes — the regime where decode is
    weight-bandwidth-bound anyway — and avoids a 1-token-deep buffer over
    all E experts (which would inflate decode FLOPs by E/k).

Router: softmax over top-k logits (renormalized), optional shared experts
(DeepSeek-style always-on), aux-free sigmoid bias omitted — load-balance loss
is returned for the training objective.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import BATCH, MODEL, dense_init

F32 = jnp.float32


def moe_init(rng, cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 7)
    p = {
        "router": dense_init(ks[0], d, m.n_experts, jnp.float32),
        "gate": _expert_init(ks[1], m.n_experts, d, m.d_expert, dt),
        "up": _expert_init(ks[2], m.n_experts, d, m.d_expert, dt),
        "down": _expert_init(ks[3], m.n_experts, m.d_expert, d, dt),
    }
    if m.n_shared:
        ff = m.n_shared * m.d_expert
        p["shared"] = {
            "gate": dense_init(ks[4], d, ff, dt),
            "up": dense_init(ks[5], d, ff, dt),
            "down": dense_init(ks[6], ff, d, dt),
        }
    return p


def _expert_init(rng, e, d_in, d_out, dt):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.uniform(rng, (e, d_in, d_out), F32, -scale, scale)
            ).astype(dt)


def moe_specs(cfg) -> dict:
    m = cfg.moe
    p = {
        "router": P(None, None),
        # expert parallelism: experts sharded over the model axis
        "gate": P(MODEL, None, None),
        "up": P(MODEL, None, None),
        "down": P(MODEL, None, None),
    }
    if m.n_shared:
        p["shared"] = {"gate": P(None, MODEL), "up": P(None, MODEL),
                       "down": P(MODEL, None)}
    return p


def _route(params, cfg, x_flat):
    """Top-k routing. x_flat [T, d] -> (probs [T, K], idx [T, K], aux_loss)."""
    m = cfg.moe
    logits = (x_flat.astype(F32) @ params["router"]).astype(F32)  # [T, E]
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs_full, m.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary: E * sum_e f_e * p_e.
    t = x_flat.shape[0]
    density = jnp.zeros((m.n_experts,), F32).at[top_i.reshape(-1)].add(
        1.0 / (t * m.top_k))
    mean_p = jnp.mean(probs_full, axis=0)
    aux = m.n_experts * jnp.sum(density * mean_p)
    return top_p, top_i, aux


def _expert_ffn(params, buf):
    """buf [..., E, C, d] -> [..., E, C, d] through per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", buf, params["gate"])) \
        * jnp.einsum("...ecd,edf->...ecf", buf, params["up"])
    return jnp.einsum("...ecf,efd->...ecd", h, params["down"])


def moe_apply_dispatch(params, cfg, x) -> Tuple[jax.Array, jax.Array]:
    """Sort-based capacity dispatch. x: [B, S, d] -> ([B, S, d], aux).

    Deliberately *scatter-free*: packing into the [E, C, d] buffer and the
    combine back to token order are both expressed as gathers over the
    expert-sorted permutation. GSPMD partitions batched gathers along the
    (sharded) sequence-batch dim; scatter-adds here made it replicate the
    whole dispatch buffer per device (observed 255 GiB/device on the
    grok-1 train cell before this rewrite). ``shard_hint``s pin the big
    intermediates to (batch over data, experts over model).
    """
    from repro.models.layers import BATCH, MODEL, shard_hint
    m = cfg.moe
    b, s, d = x.shape
    tk = s * m.top_k
    capacity = max(8, int(math.ceil(tk / m.n_experts * m.capacity_factor)))
    capacity = min(capacity, tk)

    def per_seq(xs):                       # xs: [S, d]
        top_p, top_i, aux = _route(params, cfg, xs)
        flat_e = top_i.reshape(-1)                          # [S*K]
        flat_p = top_p.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(s), m.top_k)         # source token
        order = jnp.argsort(flat_e)
        se, sp, st = flat_e[order], flat_p[order], flat_t[order]
        # rank within expert group
        group_start = jnp.searchsorted(se, jnp.arange(m.n_experts),
                                       side="left")
        pos = jnp.arange(tk) - group_start[se]
        keep = pos < capacity
        # pack [E, C, d] by GATHER: slot (e, c) reads sorted row
        # group_start[e] + c, masked where that overruns e's group.
        slot_src = group_start[:, None] + jnp.arange(capacity)[None, :]
        slot_valid = slot_src < jnp.append(group_start[1:], tk)[:, None]
        slot_src_c = jnp.clip(slot_src, 0, tk - 1)
        tok_for_slot = st[slot_src_c]                       # [E, C]
        rows = xs[tok_for_slot]                             # gather [E,C,d]
        buf = jnp.where(slot_valid[..., None], rows, 0).astype(x.dtype)
        # combine back: sorted index i lives in slot (se[i], pos[i])
        return buf, (se, sp, st, pos, keep, aux)

    buf, (se, sp, st, pos, keep, aux) = jax.vmap(per_seq)(x)
    buf = shard_hint(buf, BATCH, MODEL, None, None)
    out_buf = _expert_ffn(params, buf)                      # [B, E, C, d]
    out_buf = shard_hint(out_buf, BATCH, MODEL, None, None)

    def combine(out_buf_b, se_b, sp_b, st_b, pos_b, keep_b):
        pos_c = jnp.clip(pos_b, 0, capacity - 1)
        back = out_buf_b[se_b, pos_c]                       # gather [S*K, d]
        back = jnp.where(keep_b[:, None], back, 0) \
            * sp_b[:, None].astype(x.dtype)
        # token t's K slots are contiguous in the inverse permutation
        inv = jnp.argsort(st_b * tk + jnp.arange(tk))       # stable by token
        back_tok = back[inv].reshape(s, m.top_k, d)
        return jnp.sum(back_tok, axis=1)

    out = jax.vmap(combine)(out_buf, se, sp, st, pos, keep)
    out = shard_hint(out, BATCH, None, None)
    out = out + _shared_ffn(params, x)
    return out.astype(x.dtype), jnp.mean(aux)


def moe_apply_gather(params, cfg, x) -> Tuple[jax.Array, jax.Array]:
    """Decode path: gather top-k expert weight slices per token. x [B,1,d].

    The per-token weight gather costs exactly the active-parameter bytes —
    the quantity decode is bound by anyway. Hints keep the gathered slices
    sharded (tokens over data, expert-ffn dim over model).
    """
    from repro.models.layers import BATCH, MODEL, shard_hint
    m = cfg.moe
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    top_p, top_i, aux = _route(params, cfg, x_flat)          # [T, K]
    wg = shard_hint(params["gate"][top_i], BATCH, None, None, MODEL)
    wu = shard_hint(params["up"][top_i], BATCH, None, None, MODEL)
    wd = shard_hint(params["down"][top_i], BATCH, None, MODEL, None)
    h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", x_flat, wg)) \
        * jnp.einsum("td,tkdf->tkf", x_flat, wu)
    y = jnp.einsum("tkf,tkfd->tkd", h, wd)
    out = jnp.sum(y * top_p[..., None].astype(y.dtype), axis=1)
    out = out.reshape(b, s, d) + _shared_ffn(params, x)
    return out.astype(x.dtype), aux


def _shared_ffn(params, x):
    if "shared" not in params:
        return jnp.zeros_like(x)
    sp = params["shared"]
    return (jax.nn.silu(x @ sp["gate"]) * (x @ sp["up"])) @ sp["down"]


def moe_apply(params, cfg, x) -> Tuple[jax.Array, jax.Array]:
    """Route to the right execution shape.

    * S > 1 (train/prefill): per-sequence sort dispatch.
    * S == 1, batch >= E/K (decode at serving batch sizes): *batch-global*
      dispatch — all B tokens form one dispatch group, so each expert's
      weights are read once per layer. The per-token gather alternative
      materializes a weight copy per (token, expert): measured 11.8 TiB/dev
      of fusion traffic on the deepseek decode cell (128 tokens × 8 experts
      × 14.7M-param experts × 58 layers) before this routing.
    * tiny decode batches: per-token gather (reads ≤ B·K experts, fewer
      than a full sweep).
    """
    m = cfg.moe
    if x.shape[1] == 1:
        b = x.shape[0]
        if b * m.top_k >= m.n_experts:
            y, aux = moe_apply_dispatch(params, cfg,
                                        x.reshape(1, b, x.shape[2]))
            return y.reshape(b, 1, x.shape[2]), aux
        return moe_apply_gather(params, cfg, x)
    return moe_apply_dispatch(params, cfg, x)
