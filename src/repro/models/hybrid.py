"""Zamba2-style hybrid: a deep Mamba2 trunk with one weight-*shared*
attention block invoked every ``shared_attn_every`` layers.

zamba2-7b: 81 Mamba2 blocks (d_state 64) + a shared GQA-attention/MLP block
(d_ff 14336) re-applied after every 6th Mamba block — 13 invocations with
the *same* weights (Zamba2's weight-tied global mixer). The Mamba trunk is
grouped into scans of 6 so HLO holds one Mamba body + 13 shared-block calls.

Decode carries 81 O(1) Mamba states plus 13 KV caches (one per shared-block
invocation depth — weights are tied, activations are not). The KV read per
decode step is bounded (13 × seq reads vs 81 for a full transformer), which
is the hybrid's ``long_500k`` story.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S

F32 = jnp.float32


class HybridCache(NamedTuple):
    conv: jax.Array          # [81, B, K-1, d_conv_ch]
    state: jax.Array         # [81, B, H, P, N]
    attn_k: jax.Array        # [n_shared, B, C, KV, hd]
    attn_v: jax.Array
    length: jax.Array


class Zamba2Model:
    def __init__(self, cfg: ModelConfig, *, remat: str = "block"):
        self.cfg = cfg
        self.remat = remat
        self.every = cfg.ssm.shared_attn_every or 6
        self.n_groups = cfg.n_layers // self.every
        self.tail = cfg.n_layers - self.n_groups * self.every

    def init_params(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 6)
        layer_keys = jax.random.split(ks[0], cfg.n_layers)
        mamba = jax.vmap(lambda r: {
            "norm": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
            "mix": S.mamba2_init(r, cfg),
        })(layer_keys)
        dt = jnp.dtype(cfg.dtype)
        shared = {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": L.gqa_init(ks[1], cfg),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.dtype),
        }
        return {
            "embed": L.embed_init(ks[3], cfg.vocab, cfg.d_model, cfg.dtype),
            "mamba_layers": mamba,
            "shared": shared,
            "final_norm": jnp.zeros((cfg.d_model,), dt),
            "unembed": L.embed_init(ks[4], cfg.vocab, cfg.d_model, cfg.dtype),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        mamba_spec = {"norm": P(None), "mix": S.mamba2_specs(cfg)}
        stack = jax.tree_util.tree_map(
            lambda s: P(None, *s), mamba_spec,
            is_leaf=lambda x: isinstance(x, P))
        return {
            "embed": L.embed_specs(),
            "mamba_layers": stack,
            "shared": {"ln1": P(None), "attn": L.gqa_specs(cfg),
                       "ln2": P(None), "mlp": L.mlp_specs()},
            "final_norm": P(None),
            "unembed": L.embed_specs(),
        }

    # -- pieces --------------------------------------------------------------

    def _slice(self, tree, lo, hi):
        return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)

    def _mamba_group(self, group_params, x, caches=None, want_cache=False):
        """Scan a group of Mamba layers. caches: (conv [g,...], state [g,...])"""
        def body(x, xs):
            if caches is None:
                lp = xs
                h = L.rmsnorm(x, lp["norm"], self.cfg.norm_eps)
                y, c = S.mamba2_apply(lp["mix"], self.cfg, h)
            else:
                lp, conv, st = xs
                h = L.rmsnorm(x, lp["norm"], self.cfg.norm_eps)
                y, c = S.mamba2_apply(lp["mix"], self.cfg, h,
                                      cache=(conv, st))
            # don't materialize per-layer states the caller will discard
            if caches is None and not want_cache:
                c = ()
            return x + y, c

        if self.remat == "block":
            body = jax.checkpoint(body)
        xs = group_params if caches is None else (group_params,) + caches
        x, cs = jax.lax.scan(body, x, xs)
        return x, cs

    def _shared_block(self, params, x, positions, kv_cache=None, kv_len=None):
        sp = params["shared"]
        h = L.rmsnorm(x, sp["ln1"], self.cfg.norm_eps)
        a, kv = L.gqa_attend(sp["attn"], self.cfg, h, positions,
                             kv_cache=kv_cache, kv_len=kv_len)
        x = x + a
        h = L.rmsnorm(x, sp["ln2"], self.cfg.norm_eps)
        return x + L.mlp_apply(sp["mlp"], h), kv

    # -- public --------------------------------------------------------------

    def _run(self, params, tokens, *, collect_cache=False, cache=None):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], tokens)
        s = tokens.shape[1]
        decode = cache is not None and s == 1
        positions = (jnp.reshape(cache.length, (1, 1)) if decode
                     else jnp.arange(s)[None, :])
        kv_len = cache.length if decode else None

        convs, states, aks, avs = [], [], [], []
        g = self.every
        for gi in range(self.n_groups + (1 if self.tail else 0)):
            lo = gi * g
            hi = min(lo + g, cfg.n_layers)
            gp = self._slice(params["mamba_layers"], lo, hi)
            gc = (None if cache is None else
                  (cache.conv[lo:hi], cache.state[lo:hi]))
            x, cs = self._mamba_group(gp, x, caches=gc,
                                      want_cache=collect_cache)
            if collect_cache or decode:
                convs.append(cs[0])
                states.append(cs[1])
            if hi - lo == g and gi < self.n_groups:     # shared block
                if decode:
                    kvc = (cache.attn_k[gi], cache.attn_v[gi])
                    x, kv = self._shared_block(params, x, positions,
                                               kv_cache=kvc, kv_len=kv_len)
                else:
                    x, kv = self._shared_block(params, x, positions)
                if collect_cache or decode:
                    aks.append(kv[0])
                    avs.append(kv[1])
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        extra = (convs, states, aks, avs)
        return x, extra

    def forward(self, params, tokens, **_):
        x, _ = self._run(params, tokens)
        return L.unembed(x, params["unembed"], self.cfg.vocab), jnp.zeros((), F32)

    def loss(self, params, tokens, **_):
        logits, _ = self.forward(params, tokens)
        return _xent(logits[:, :-1], tokens[:, 1:]), {}

    def prefill(self, params, tokens, **_):
        x, (convs, states, aks, avs) = self._run(params, tokens,
                                                 collect_cache=True)
        logits = L.unembed(x[:, -1:], params["unembed"], self.cfg.vocab)[:, 0]
        cache = HybridCache(
            conv=jnp.concatenate(convs, axis=0),
            state=jnp.concatenate(states, axis=0),
            attn_k=jnp.stack(aks), attn_v=jnp.stack(avs),
            length=jnp.asarray(tokens.shape[1], jnp.int32))
        return logits, cache

    def decode(self, params, cache: HybridCache, tokens, *, write=True):
        x, (convs, states, aks, avs) = self._run(params, tokens, cache=cache)
        logits = L.unembed(x, params["unembed"], self.cfg.vocab)[:, 0]
        conv = jnp.concatenate(convs, axis=0)
        state = jnp.concatenate(states, axis=0)
        if write:
            pos = cache.length
            ak = jax.lax.dynamic_update_slice(
                cache.attn_k, jnp.stack(aks).astype(cache.attn_k.dtype),
                (0, 0, pos, 0, 0))
            av = jax.lax.dynamic_update_slice(
                cache.attn_v, jnp.stack(avs).astype(cache.attn_v.dtype),
                (0, 0, pos, 0, 0))
        else:
            ak, av = cache.attn_k, cache.attn_v
        return logits, HybridCache(conv=conv, state=state, attn_k=ak,
                                   attn_v=av, length=cache.length + 1)

    def init_cache(self, batch: int, capacity: int) -> HybridCache:
        cfg = self.cfg
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        n_heads = d_inner // s.headdim
        dt = jnp.dtype(cfg.dtype)
        hd = cfg.resolved_head_dim
        return HybridCache(
            conv=jnp.zeros((cfg.n_layers, batch, s.d_conv - 1,
                            d_inner + 2 * s.d_state), dt),
            state=jnp.zeros((cfg.n_layers, batch, n_heads, s.headdim,
                             s.d_state), F32),
            attn_k=jnp.zeros((self.n_groups, batch, capacity,
                              cfg.n_kv_heads, hd), dt),
            attn_v=jnp.zeros((self.n_groups, batch, capacity,
                              cfg.n_kv_heads, hd), dt),
            length=jnp.asarray(0, jnp.int32))

    def cache_specs(self) -> HybridCache:
        return HybridCache(
            conv=P(None, L.BATCH, None, L.MODEL),
            state=P(None, L.BATCH, None, None, None),
            attn_k=P(None, L.BATCH, None, L.MODEL, None),
            attn_v=P(None, L.BATCH, None, L.MODEL, None),
            length=P())


def _xent(logits, targets):
    lse = jax.nn.logsumexp(logits.astype(F32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(F32), targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
