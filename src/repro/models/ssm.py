"""State-space and recurrent sequence mixers: Mamba2 (SSD), xLSTM blocks.

One chunkwise-parallel SSD core serves two architectures:

* **Mamba2** (zamba2-7b's mixer): selective state space with per-head scalar
  decay ``exp(Δt·A)``, input ``Δt·x⊗B``, readout ``C·S``.
* **mLSTM** (xlstm-350m): matrix-memory LSTM. Algebraically an SSD with
  data-dependent decay ``σ(f̃)`` and input gate ``σ(ĩ)``; the normalizer
  state n is carried as an extra (P+1)-th channel of the same recurrence.
  (Deviation from the paper's exponential input gating: we use sigmoid
  gates, trading the max-stabilizer machinery for bounded recurrences —
  noted in DESIGN.md §4.)

The SSD scan runs chunk-by-chunk (``lax.scan`` over chunks of length Q):
intra-chunk terms are a masked quadratic contraction (parallel, MXU-friendly,
[Q, Q] score blocks only), inter-chunk state flows through the scan carry —
this is the standard chunkwise-parallel formulation and is what makes
``long_500k`` decoding O(1)-state for these families.

sLSTM (xlstm's scalar-memory block) has true recurrent weight connections,
so it runs as a ``lax.scan`` over time steps with the standard exponential-
gating stabilizer state m.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import MODEL, dense_init, rmsnorm

F32 = jnp.float32


# ---------------------------------------------------------------------------
# SSD core (chunkwise-parallel scalar-decay state space)
# ---------------------------------------------------------------------------

class SSDState(NamedTuple):
    s: jax.Array       # [B, H, P, N] matrix state


def ssd_scan(
    x: jax.Array,        # [B, L, H, P]  (inputs, already gate/Δt-scaled)
    log_a: jax.Array,    # [B, L, H]     per-step log decay (<= 0)
    b_in: jax.Array,     # [B, L, N]     input direction (single group)
    c_out: jax.Array,    # [B, L, N]     readout direction
    *,
    chunk: int = 128,
    init_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunkwise-parallel scan of S_t = e^{log_a_t} S_{t-1} + x_t ⊗ b_t,
    y_t = S_t c_t. Returns (y [B, L, H, P], final state [B, H, P, N])."""
    bsz, l, h, p = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, l)
    if l % chunk:
        raise ValueError(f"L={l} not divisible by chunk={chunk}")
    nc = l // chunk

    xc = x.reshape(bsz, nc, chunk, h, p).astype(F32)
    ac = log_a.reshape(bsz, nc, chunk, h).astype(F32)
    bc = b_in.reshape(bsz, nc, chunk, n).astype(F32)
    cc = c_out.reshape(bsz, nc, chunk, n).astype(F32)

    s0 = (jnp.zeros((bsz, h, p, n), F32) if init_state is None
          else init_state.astype(F32))

    def chunk_body(s_prev, inputs):
        xq, aq, bq, cq = inputs                   # [B,Q,H,P],[B,Q,H],[B,Q,N]x2
        cum = jnp.cumsum(aq, axis=1)              # [B, Q, H] inclusive
        # intra-chunk: y[q] += Σ_{p<=q} e^{cum_q - cum_p} (c_q·b_p) x_p
        scores = jnp.einsum("bqn,bpn->bqp", cq, bq)[:, None]   # [B,1,Q,Q]
        decay = cum[:, :, None, :] - cum[:, None, :, :]        # [B,Q,Qp,H]
        decay = jnp.transpose(decay, (0, 3, 1, 2))             # [B,H,Q,Qp]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(mask[None, None], jnp.exp(decay) * scores, 0.0)
        y = jnp.einsum("bhqp,bphd->bqhd", w, xq)
        # inter-chunk: y[q] += e^{cum_q} c_q · S_prev
        y += jnp.einsum("bqh,bhdn,bqn->bqhd", jnp.exp(cum), s_prev, cq)
        # state update: S = e^{cum_Q} S_prev + Σ_q e^{cum_Q - cum_q} x_q ⊗ b_q
        total = cum[:, -1]                                     # [B, H]
        in_decay = jnp.exp(total[:, None] - cum)               # [B, Q, H]
        s_new = jnp.exp(total)[:, :, None, None] * s_prev + jnp.einsum(
            "bqh,bqhd,bqn->bhdn", in_decay, xq, bq)
        return s_new, y

    xs = (
        jnp.moveaxis(xc, 1, 0), jnp.moveaxis(ac, 1, 0),
        jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0),
    )
    s_fin, ys = jax.lax.scan(chunk_body, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, h, p)
    return y.astype(x.dtype), s_fin


def ssd_step(
    x: jax.Array,        # [B, H, P]
    log_a: jax.Array,    # [B, H]
    b_in: jax.Array,     # [B, N]
    c_out: jax.Array,    # [B, N]
    state: jax.Array,    # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the same recurrence. Returns (y [B,H,P], state)."""
    xf, af = x.astype(F32), log_a.astype(F32)
    s_new = jnp.exp(af)[..., None, None] * state.astype(F32) + jnp.einsum(
        "bhd,bn->bhdn", xf, b_in.astype(F32))
    y = jnp.einsum("bhdn,bn->bhd", s_new, c_out.astype(F32))
    return y.astype(x.dtype), s_new


# ---------------------------------------------------------------------------
# Causal depthwise conv (Mamba's width-4 front conv)
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, *, state: Optional[jax.Array] = None):
    """x [B, L, C], w [K, C] depthwise. Returns (y [B, L, C], tail [B, K-1, C])."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)              # [B, L+K-1, C]
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(y), xp[:, -(k - 1):]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_init(rng, cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    n_heads = d_inner // s.headdim
    n = s.d_state
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    zdim = 2 * d_inner + 2 * n + n_heads
    return {
        "in_proj": dense_init(ks[0], d, zdim, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_inner + 2 * n), F32)
                   * 0.1).astype(dt),
        "a_log": jnp.zeros((n_heads,), F32),       # A = -exp(a_log) ∈ [-1, 0)
        "dt_bias": jnp.full((n_heads,), math.log(math.e - 1), F32),
        "d_skip": jnp.ones((n_heads,), F32),
        "norm": jnp.zeros((d_inner,), dt),
        "out_proj": dense_init(ks[3], d_inner, d, dt),
    }


def mamba2_specs(cfg) -> dict:
    return {
        "in_proj": P(None, MODEL),
        "conv_w": P(None, MODEL),
        "a_log": P(None),
        "dt_bias": P(None),
        "d_skip": P(None),
        "norm": P(MODEL),
        "out_proj": P(MODEL, None),
    }


def _mamba2_split(cfg, proj):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n = s.d_state
    n_heads = d_inner // s.headdim
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:2 * d_inner + 2 * n]
    dt_raw = proj[..., 2 * d_inner + 2 * n:]
    return z, xbc, dt_raw, d_inner, n, n_heads


def mamba2_apply(params, cfg, x, *, cache=None):
    """x [B, L, d]. cache=None -> scan path; cache=(conv_tail, ssd_state)
    and L==1 -> decode step. Returns (out, new_cache)."""
    s = cfg.ssm
    bsz, l, d = x.shape
    proj = x @ params["in_proj"]
    z, xbc, dt_raw, d_inner, n, n_heads = _mamba2_split(cfg, proj)

    conv_state = None if cache is None else cache[0]
    xbc, conv_tail = causal_conv(xbc, params["conv_w"], state=conv_state)
    x_in = xbc[..., :d_inner].reshape(bsz, l, n_heads, s.headdim)
    b_in = xbc[..., d_inner:d_inner + n]
    c_out = xbc[..., d_inner + n:]

    dt_v = jax.nn.softplus(dt_raw.astype(F32) + params["dt_bias"])  # [B,L,H]
    a = -jnp.exp(params["a_log"])                                    # [H]
    log_a = dt_v * a
    x_scaled = x_in * dt_v[..., None].astype(x_in.dtype)

    ssd_state = None if cache is None else cache[1]
    if cache is not None and l == 1:
        y, state = ssd_step(x_scaled[:, 0], log_a[:, 0], b_in[:, 0],
                            c_out[:, 0], ssd_state)
        y = y[:, None]
    else:
        y, state = ssd_scan(x_scaled, log_a, b_in, c_out, chunk=s.chunk,
                            init_state=ssd_state)
    y = y + params["d_skip"][None, None, :, None].astype(y.dtype) * x_in
    y = y.reshape(bsz, l, d_inner)
    y = rmsnorm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["out_proj"], (conv_tail, state)


def mamba2_cache_init(cfg, batch: int) -> tuple:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    dt = jnp.dtype(cfg.dtype)
    conv = jnp.zeros((batch, s.d_conv - 1, d_inner + 2 * s.d_state), dt)
    state = jnp.zeros((batch, n_heads, s.headdim, s.d_state), F32)
    return conv, state


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block (matrix memory — SSD with sigmoid gates + normalizer)
# ---------------------------------------------------------------------------

def mlstm_init(rng, cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    h = cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_inner, dt),   # x branch, z gate
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_inner), F32)
                   * 0.1).astype(dt),
        "wqkv": dense_init(ks[2], d_inner, 3 * d_inner, dt),
        "wif": dense_init(ks[3], d_inner, 2 * h, dt),       # i, f gate logits
        "norm": jnp.zeros((d_inner,), dt),
        "out_proj": dense_init(ks[5], d_inner, d, dt),
    }


def mlstm_specs(cfg) -> dict:
    return {
        "in_proj": P(None, MODEL),
        "conv_w": P(None, MODEL),
        "wqkv": P(None, MODEL),
        "wif": P(None, None),
        "norm": P(MODEL),
        "out_proj": P(MODEL, None),
    }


def mlstm_apply(params, cfg, x, *, cache=None):
    """mLSTM mixer. Matrix memory C over (head, P=headdim, N=headdim);
    normalizer n rides as channel P (x side augmented with ones)."""
    s = cfg.ssm
    bsz, l, d = x.shape
    h = cfg.n_heads
    proj = x @ params["in_proj"]
    d_inner = proj.shape[-1] // 2
    xb, z = proj[..., :d_inner], proj[..., d_inner:]
    ph = d_inner // h

    conv_state = None if cache is None else cache[0]
    xb, conv_tail = causal_conv(xb, params["conv_w"], state=conv_state)

    qkv = xb @ params["wqkv"]
    q = qkv[..., :d_inner].reshape(bsz, l, h, ph)
    k = qkv[..., d_inner:2 * d_inner].reshape(bsz, l, h, ph)
    v = qkv[..., 2 * d_inner:].reshape(bsz, l, h, ph)
    gates = (xb @ params["wif"]).astype(F32).reshape(bsz, l, h, 2)
    i_g = jax.nn.sigmoid(gates[..., 0])
    log_f = jax.nn.log_sigmoid(gates[..., 1])

    # heads fold into the SSD batch dim (per-head b/c directions).
    scale = 1.0 / math.sqrt(ph)
    v_aug = jnp.concatenate(
        [v * i_g[..., None].astype(v.dtype),
         i_g[..., None].astype(v.dtype)], axis=-1)           # [B,L,H,P+1]
    vb = v_aug.transpose(0, 2, 1, 3).reshape(bsz * h, l, 1, ph + 1)
    kb = k.transpose(0, 2, 1, 3).reshape(bsz * h, l, ph).astype(F32)
    qb = (q.transpose(0, 2, 1, 3).reshape(bsz * h, l, ph) * scale).astype(F32)
    ab = log_f.transpose(0, 2, 1).reshape(bsz * h, l, 1)

    state0 = None if cache is None else cache[1]
    if cache is not None and l == 1:
        y, state = ssd_step(vb[:, 0], ab[:, 0], kb[:, 0], qb[:, 0], state0)
        y = y[:, None]
    else:
        y, state = ssd_scan(vb, ab, kb, qb, chunk=s.chunk, init_state=state0)
    y = y.reshape(bsz, h, l, ph + 1).transpose(0, 2, 1, 3)   # [B,L,H,P+1]
    num, den = y[..., :ph], y[..., ph]
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None].astype(num.dtype)
    y = y.reshape(bsz, l, d_inner)
    y = rmsnorm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["out_proj"], (conv_tail, state)


def mlstm_cache_init(cfg, batch: int) -> tuple:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    h = cfg.n_heads
    ph = d_inner // h
    dt = jnp.dtype(cfg.dtype)
    conv = jnp.zeros((batch, s.d_conv - 1, d_inner), dt)
    state = jnp.zeros((batch * h, 1, ph + 1, ph), F32)
    return conv, state


# ---------------------------------------------------------------------------
# xLSTM: sLSTM block (scalar memory, true recurrence -> scan over time)
# ---------------------------------------------------------------------------

def slstm_init(rng, cfg) -> dict:
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, dt),        # i, f, z, o pre-acts
        "r_rec": (dense_init(ks[1], d, 4 * d, jnp.float32) * 0.1),
        "norm": jnp.zeros((d,), dt),
        "out_proj": dense_init(ks[3], d, d, dt),
    }


def slstm_specs(cfg) -> dict:
    return {"w_in": P(None, MODEL), "r_rec": P(None, MODEL),
            "norm": P(None), "out_proj": P(None, None)}


def slstm_apply(params, cfg, x, *, cache=None):
    """x [B, L, d] -> ([B, L, d], cache). Exponential gating w/ stabilizer."""
    bsz, l, d = x.shape
    pre_all = (x @ params["w_in"]).astype(F32)        # [B, L, 4d]
    r = params["r_rec"].astype(F32)

    if cache is None:
        c0 = jnp.zeros((bsz, d), F32)
        n0 = jnp.full((bsz, d), 1e-6, F32)
        h0 = jnp.zeros((bsz, d), F32)
        m0 = jnp.zeros((bsz, d), F32)
    else:
        c0, n0, h0, m0 = cache

    def cell(carry, pre_t):
        c, n, h, m = carry
        pre = pre_t + h @ r                            # recurrent connection
        ig, fg, zg, og = jnp.split(pre, 4, axis=-1)
        log_f = jax.nn.log_sigmoid(fg)
        m_new = jnp.maximum(log_f + m, ig)
        c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(ig - m_new) * jnp.tanh(zg)
        n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(ig - m_new)
        h_new = jax.nn.sigmoid(og) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    (c, n, h, m), hs = jax.lax.scan(cell, (c0, n0, h0, m0),
                                    jnp.moveaxis(pre_all, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)         # [B, L, d]
    y = rmsnorm(y, params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], (c, n, h, m)


def slstm_cache_init(cfg, batch: int) -> tuple:
    d = cfg.d_model
    return (jnp.zeros((batch, d), F32), jnp.full((batch, d), 1e-6, F32),
            jnp.zeros((batch, d), F32), jnp.zeros((batch, d), F32))
