"""xLSTM language model: alternating mLSTM / sLSTM residual blocks.

xlstm-350m: 24 blocks, no separate FFN (``d_ff=0`` — up/down projections
live inside the blocks per the xLSTM paper). The block pattern comes from
``cfg.ssm.block_pattern`` (e.g. 7 mLSTM : 1 sLSTM). Blocks have hetero-
geneous parameter structure, so the stack is a (short, 24-deep) Python loop
rather than a scan — HLO stays small because each block is narrow.

Decode state is O(1) in sequence length (matrix memory + conv tail for
mLSTM; scalar quadruple for sLSTM), which is why this arch *runs* the
``long_500k`` cell that full-attention models skip.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S

F32 = jnp.float32


class XLSTMCache(NamedTuple):
    blocks: Tuple            # per-layer block caches
    length: jax.Array


class XLSTMModel:
    def __init__(self, cfg: ModelConfig, *, remat: str = "block"):
        self.cfg = cfg
        self.remat = remat
        pattern = cfg.ssm.block_pattern or ("mlstm", "slstm")
        self.kinds = [pattern[i % len(pattern)] for i in range(cfg.n_layers)]

    def init_params(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, cfg.n_layers + 3)
        blocks = []
        for i, kind in enumerate(self.kinds):
            init = S.mlstm_init if kind == "mlstm" else S.slstm_init
            blocks.append({
                "norm": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
                "mix": init(ks[i], cfg),
            })
        return {
            "embed": L.embed_init(ks[-3], cfg.vocab, cfg.d_model, cfg.dtype),
            "blocks": blocks,
            "final_norm": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
            "unembed": L.embed_init(ks[-2], cfg.vocab, cfg.d_model,
                                    cfg.dtype),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        blocks = []
        for kind in self.kinds:
            spec = S.mlstm_specs(cfg) if kind == "mlstm" else S.slstm_specs(cfg)
            blocks.append({"norm": P(None), "mix": spec})
        return {
            "embed": L.embed_specs(),
            "blocks": blocks,
            "final_norm": P(None),
            "unembed": L.embed_specs(),
        }

    def _block(self, kind, bp, x, cache=None):
        apply = S.mlstm_apply if kind == "mlstm" else S.slstm_apply
        h = L.rmsnorm(x, bp["norm"], self.cfg.norm_eps)
        y, new_cache = apply(bp["mix"], self.cfg, h, cache=cache)
        return x + y, new_cache

    def forward(self, params, tokens, *, prefix_embeds=None):
        del prefix_embeds
        x = L.embed_lookup(params["embed"], tokens)
        for kind, bp in zip(self.kinds, params["blocks"]):
            blk = self._block
            if self.remat == "block":
                blk = jax.checkpoint(blk, static_argnums=(0,))
            x, _ = blk(kind, bp, x)
        x = L.rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        return L.unembed(x, params["unembed"], self.cfg.vocab), jnp.zeros((), F32)

    def loss(self, params, tokens, **_):
        logits, _ = self.forward(params, tokens)
        return _xent(logits[:, :-1], tokens[:, 1:]), {}

    def prefill(self, params, tokens, **_):
        x = L.embed_lookup(params["embed"], tokens)
        caches: List[Any] = []
        for kind, bp in zip(self.kinds, params["blocks"]):
            x, c = self._block(kind, bp, x)
            caches.append(c)
        x = L.rmsnorm(x[:, -1:], params["final_norm"], self.cfg.norm_eps)
        logits = L.unembed(x, params["unembed"], self.cfg.vocab)[:, 0]
        return logits, XLSTMCache(blocks=tuple(caches),
                                  length=jnp.asarray(tokens.shape[1],
                                                     jnp.int32))

    def decode(self, params, cache: XLSTMCache, tokens, *, write=True):
        del write                      # recurrent state always advances
        x = L.embed_lookup(params["embed"], tokens)
        new = []
        for kind, bp, c in zip(self.kinds, params["blocks"], cache.blocks):
            x, nc = self._block(kind, bp, x, cache=c)
            new.append(nc)
        x = L.rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        logits = L.unembed(x, params["unembed"], self.cfg.vocab)[:, 0]
        return logits, XLSTMCache(blocks=tuple(new),
                                  length=cache.length + 1)

    def init_cache(self, batch: int, capacity: int) -> XLSTMCache:
        del capacity                   # O(1) state — the SSM selling point
        caches = []
        for kind in self.kinds:
            if kind == "mlstm":
                caches.append(S.mlstm_cache_init(self.cfg, batch))
            else:
                caches.append(S.slstm_cache_init(self.cfg, batch))
        return XLSTMCache(blocks=tuple(caches),
                          length=jnp.asarray(0, jnp.int32))

    def cache_specs(self) -> XLSTMCache:
        blocks = []
        for kind in self.kinds:
            if kind == "mlstm":
                blocks.append((P(L.BATCH, None, None),
                               P(L.BATCH, None, None, None)))
            else:
                blocks.append(tuple(P(L.BATCH, None) for _ in range(4)))
        return XLSTMCache(blocks=tuple(blocks), length=P())


def _xent(logits, targets):
    lse = jax.nn.logsumexp(logits.astype(F32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(F32), targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
