"""Version compatibility shims for the JAX API surface this repo uses.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check kwarg (``check_rep`` -> ``check_vma``)
across the JAX versions this repo supports. Route every call through here.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

try:  # newest API: top-level jax.shard_map with check_vma
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: bool = True) -> Callable:
    kwargs = {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
