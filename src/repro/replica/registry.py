"""Replica membership + health: who is joined, who is suspect.

Thin policy layer over ``runtime/fault.HeartbeatRegistry`` (injectable
clock, so tests drive suspicion deterministically). Two independent
signals make a replica suspect:

* **silence** — the scheduler loop's per-iteration heartbeat stopped
  arriving for longer than ``timeout`` (stuck, dead, or wedged thread);
* **observed failure** — the router saw a query future fail with that
  replica's :class:`~repro.replica.replica.ReplicaLost` and quarantined
  it immediately (``report_failure``), without waiting a timeout.

A replica that *leaves* is removed outright (``HeartbeatRegistry.remove``)
— departure is not failure, and a lingering last-beat entry would
otherwise poison ``suspects()`` forever.
"""
from __future__ import annotations

import time
from typing import Callable, List, Set

from repro.runtime.fault import HeartbeatRegistry


class ReplicaRegistry:
    """Membership + liveness for the replica fleet."""

    def __init__(self, timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.heartbeats = HeartbeatRegistry(timeout=timeout, clock=clock)
        self._failed: Set[str] = set()
        #: optional ChaosInjector (repro.chaos) consulted at the
        #: heartbeat seam — a dropped beat never reaches last_seen, so
        #: the replica ages toward suspicion exactly like a wedged one
        self.chaos = None

    # -- membership -------------------------------------------------------

    def join(self, replica) -> None:
        """Register a replica and wire its scheduler's heartbeat hook.

        Rejoin clears any previous quarantine: the operator restarting a
        failed replica IS the recovery signal."""
        rid = replica.id
        self._failed.discard(rid)
        self.heartbeats.beat(rid)
        replica.set_heartbeat(lambda: self.beat(rid))

    def leave(self, rid: str) -> bool:
        """Retire a departing replica entirely (not a failure)."""
        self._failed.discard(rid)
        return self.heartbeats.remove(rid)

    def members(self) -> List[str]:
        return list(self.heartbeats.last_seen)

    def __contains__(self, rid: str) -> bool:
        return rid in self.heartbeats.last_seen

    # -- liveness ----------------------------------------------------------

    def beat(self, rid: str) -> None:
        """Record one liveness beat; beats from replicas that already left
        are dropped (a drained scheduler's last loop iterations must not
        resurrect the membership entry)."""
        if rid in self.heartbeats.last_seen:
            if (self.chaos is not None
                    and self.chaos.should_drop("heartbeat", rid)):
                return
            self.heartbeats.beat(rid)

    def report_failure(self, rid: str) -> None:
        """Quarantine immediately on an observed failure — the router
        calls this the moment a future fails with ``ReplicaLost``, so
        routing stops picking the replica without waiting out the
        heartbeat timeout."""
        if rid in self.heartbeats.last_seen:
            self._failed.add(rid)

    def suspects(self) -> List[str]:
        """Heartbeat-silent ∪ observed-failed (members only)."""
        out = set(self.heartbeats.suspects()) | self._failed
        return sorted(out & set(self.heartbeats.last_seen))

    def healthy(self) -> List[str]:
        bad = set(self.suspects())
        return [r for r in self.heartbeats.last_seen if r not in bad]
