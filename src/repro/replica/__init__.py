"""The replica plane: multi-replica serving behind a front-tier router.

IM-PIR's throughput story is replication — many independent clusters,
each scanning its own full copy of the database (paper Take-away 5).
This package lifts that topology one tier: N :class:`ServeReplica`
deployments (own sub-mesh, own compiled steps, own ``ShardedDatabase``)
behind one :class:`Router` doing power-of-two-choices balancing,
health-driven failover with zero lost queries, and bounded-staleness
epoch propagation (DESIGN.md §11).
"""
from repro.replica.metrics import export_json, replica_snapshot, snapshot
from repro.replica.registry import ReplicaRegistry
from repro.replica.replica import ReplicaLost, ServeReplica, make_pir
from repro.replica.router import Router, Session

__all__ = [
    "ReplicaLost", "ReplicaRegistry", "Router", "ServeReplica", "Session",
    "export_json", "make_pir", "replica_snapshot", "snapshot",
]
