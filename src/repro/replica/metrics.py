"""Replica-plane observability: one JSON-serializable snapshot.

Everything the operator dashboards need from the fleet, computed from
state the router and schedulers already keep (no new instrumentation on
the dispatch path): per-replica QPS, queue depth, epoch lag, latency
percentiles; router-level failover and resubmission counters. The bench
(``benchmarks/bench_replicas.py``) and the example embed these snapshots
in their artifacts.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import numpy as np


def _percentile(xs: List[float], q: float) -> Optional[float]:
    if not xs:
        return None
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


def replica_snapshot(router, rid: str) -> Dict:
    """One replica's row: health, epoch position, load, service stats."""
    replica = router.replicas[rid]
    stats = replica.stats
    suspects = set(router.registry.suspects())
    lat = list(stats.latencies)
    return {
        "id": rid,
        "state": ("lost" if replica.lost
                  else "suspect" if rid in suspects else "healthy"),
        "running": replica.running,
        "epoch": router.epochs.get(rid, replica.epoch),
        "epoch_lag": router.epoch_lag(rid),
        "queue_depth": replica.queue_depth,
        "answered": stats.answered,
        "batches": stats.batches,
        "pad_fraction": round(stats.pad_fraction, 4),
        "qps": round(stats.qps, 3),
        "p50_latency_s": _percentile(lat, 50),
        "p99_latency_s": _percentile(lat, 99),
    }


def snapshot(router) -> Dict:
    """The fleet snapshot: per-replica rows + router counters."""
    with router._lock:
        rids = list(router.replicas)
    rows = [replica_snapshot(router, rid) for rid in rids]
    answered = sum(r["answered"] for r in rows)
    return {
        "replicas": rows,
        "router": {
            "n_replicas": len(rows),
            "healthy": router.registry.healthy(),
            "suspects": router.registry.suspects(),
            "published_epoch": router.published_epoch,
            "max_epoch_lag": max((r["epoch_lag"] for r in rows), default=0),
            "staleness_bound": router.staleness_bound,
            "answered": answered,
            "failovers": router.failovers,
            "resubmitted": router.resubmitted,
            "integrity_failures": getattr(router, "integrity_failures", 0),
            "hedges": getattr(router, "hedges", 0),
            "deadline_expired": getattr(router, "deadline_expired", 0),
            "retry": {
                "attempts": router.retry_stats.attempts,
                "retried": router.retry_stats.retried,
                "slept_s": round(router.retry_stats.slept_s, 6),
            },
        },
    }


def export_json(router, path: str) -> str:
    """Write :func:`snapshot` to ``path`` (dirs created); returns the
    absolute path."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(snapshot(router), f, indent=2, sort_keys=True)
        f.write("\n")
    return os.path.abspath(path)
