"""One serve replica: a PIR deployment facade bound to its own sub-mesh.

The IM-PIR topology, one tier up (paper Take-away 5): the paper scales PIR
throughput by scanning the database with many independent PIM clusters,
each holding a full replica. This module re-expresses that at cluster
level — each :class:`ServeReplica` owns a full :class:`ShardedDatabase`
replica placed on its own sub-mesh (``runtime/elastic.carve_submeshes``),
its own compiled serve-step family, and its own ``QueryScheduler``; the
front tier (``replica/router.py``) spreads offered load across them.

A replica is deliberately *thin*: it adapts the existing deployment
facades (``MultiServerPIR`` / ``SingleServerPIR``) to the lifecycle the
router needs — join (``start`` + plan-cache warm start), serve
(``submit`` / ``resubmit``), leave (``drain_handoff``), die (``kill``),
and observe (``queue_depth``, ``subscribe_epochs``, heartbeat hook). All
query semantics (protocols, buckets, epoch tagging) stay in the layers
below.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.config import PIRConfig
from repro.core import protocol as protocol_mod
from repro.runtime.serve_loop import (AnswerFuture, MultiServerPIR,
                                      SingleServerPIR)


class ReplicaLost(RuntimeError):
    """Terminal failure of one replica: its in-flight and queued futures
    resolve with this, and the router's done-callbacks resubmit them (by
    index) to a healthy peer. Carries the replica id for attribution."""

    def __init__(self, replica_id: str, reason: str = "replica lost"):
        super().__init__(f"{reason}: {replica_id}")
        self.replica_id = replica_id


def make_pir(db_words, cfg: PIRConfig, mesh, **kwargs):
    """The right deployment facade for ``cfg.protocol``'s party count
    (hint protocols need ``SingleServerPIR``'s client-state plumbing)."""
    proto = protocol_mod.for_config(cfg)
    cls = SingleServerPIR if proto.n_parties(cfg) == 1 else MultiServerPIR
    return cls(db_words, cfg, mesh, **kwargs)


class ServeReplica:
    """One replica of the serving plane: facade + scheduler + database.

    ``db_words`` is a HOST array (each replica places its own device
    copy on its own mesh — sharing a placed ``ShardedDatabase`` would
    couple replica lifetimes through the double buffer).
    """

    def __init__(self, replica_id: str, db_words, cfg: PIRConfig, mesh,
                 warm_plans: Optional[Dict[int, Any]] = None,
                 **pir_kwargs):
        self.id = replica_id
        self.mesh = mesh
        # warm start must precede facade construction: PIRServer resolves
        # (and compiles) its primary bucket eagerly in __init__, so plans
        # recorded after that would never be consulted (a healthy peer's
        # export_plans() goes here — the rejoin-hot path)
        if warm_plans:
            from repro import engine
            engine.record_plans(cfg, warm_plans)
        if "chaos" in pir_kwargs:
            # scope chaos events to this replica by default, so a plan
            # targeting "r0" only corrupts/kills r0's serve path
            pir_kwargs.setdefault("chaos_scope", replica_id)
        self.pir = make_pir(db_words, cfg, mesh, **pir_kwargs)
        self._lost: Optional[BaseException] = None

    # -- delegated surfaces ---------------------------------------------

    @property
    def cfg(self) -> PIRConfig:
        return self.pir.cfg

    @property
    def db(self):
        return self.pir.db

    @property
    def epoch(self) -> int:
        return self.pir.epoch

    @property
    def scheduler(self):
        return self.pir.scheduler

    @property
    def stats(self):
        return self.pir.scheduler.stats

    @property
    def queue_depth(self) -> int:
        """Unresolved real queries on this replica (the router's
        power-of-two-choices load signal)."""
        return self.pir.scheduler.queue_depth

    @property
    def running(self) -> bool:
        return self.pir.scheduler.running

    @property
    def lost(self) -> bool:
        return self._lost is not None

    # -- serve ----------------------------------------------------------

    def submit(self, index: int, *,
               deadline_s: Optional[float] = None) -> AnswerFuture:
        """Keygen + enqueue one private retrieval of ``db[index]``."""
        return self.pir.submit(index, deadline_s=deadline_s)

    def resubmit(self, item: Any, future: AnswerFuture) -> AnswerFuture:
        """Re-enqueue an already-keygen'd payload under its existing
        future — the graceful-handoff path. Key material is replica-
        agnostic (same cfg/protocol ⇒ same party structure; the LWE
        public matrix A is PRG-expanded from the config seed), so a
        payload drained from one replica answers identically on any
        peer at the same epoch."""
        return self.pir.scheduler.submit(item, future=future)

    # -- lifecycle -------------------------------------------------------

    def start(self):
        self._lost = None
        self.pir.start()

    def close(self):
        """Graceful stop: flush + answer everything, then join."""
        self.pir.close()

    def drain_handoff(self) -> List[Tuple[Any, AnswerFuture]]:
        """Graceful leave: stop intake, return undispatched (item, future)
        pairs FIFO for resubmission elsewhere; dispatched work completes
        here (see ``QueryScheduler.drain_handoff``)."""
        pairs = self.pir.scheduler.drain_handoff()
        # let the session thread finish its in-flight batches and exit
        self.pir.scheduler.stop()
        return pairs

    def kill(self, reason: str = "injected fault") -> ReplicaLost:
        """Hard death: every outstanding future on this replica fails
        with :class:`ReplicaLost` (first-wins vs completing batches),
        which is what triggers the router's per-query failover."""
        exc = ReplicaLost(self.id, reason)
        self._lost = exc
        self.pir.scheduler.kill(exc)
        return exc

    # -- observation hooks ----------------------------------------------

    def set_heartbeat(self, fn: Optional[Callable[[], None]]):
        """Liveness hook, called once per scheduler loop iteration; the
        registry wires this at join so heartbeat silence == a stuck or
        dead session thread, not merely an idle one."""
        self.pir.scheduler.heartbeat = fn

    def subscribe_epochs(self, fn: Callable[[int], None]) -> Callable:
        """``fn(epoch)`` after every publish on this replica's database;
        returns the unsubscribe callable. The router's bounded-staleness
        eligibility reads the epochs observed here."""
        return self.db.subscribe(lambda delta: fn(delta.epoch))

    # -- epoch propagation ----------------------------------------------

    def apply_delta(self, rows, vals) -> int:
        """Stage + publish one public update delta; returns the new
        epoch. The router fans the identical delta out to every replica
        (and replays missed ones at rejoin), so replicas starting from
        the same epoch-0 contents converge to identical epoch numbering
        AND contents — determinism of the delta stream is the same
        property that keeps k parties' answer shares consistent."""
        self.db.stage(rows, vals)
        return self.db.publish()

    # -- plan-cache warm start -------------------------------------------

    def export_plans(self) -> Dict[int, Any]:
        """{bucket: resolved ExecutionPlan} this replica serves with.

        Resolution is cached per bucket and never compiles, so exporting
        is cheap; a peer records these via :func:`warm_start` before its
        first serve-fn build."""
        bucketed = self.pir.servers[0].bucketed
        return {b: bucketed.plan_for_bucket(b) for b in bucketed.buckets}

    def warm_start(self, plans: Dict[int, Any], *,
                   persist: bool = False) -> int:
        """Seed the process-wide plan cache with a healthy peer's plans
        (``engine.record_plans``): this replica's serve fns then resolve
        to measured plans (provenance ``tuned``/``warm``, never the
        heuristic) without re-paying tuning — the rejoin-hot path.
        Returns the number of cache entries written."""
        from repro import engine
        return engine.record_plans(self.cfg, plans, persist=persist)

    def plan_report(self) -> Dict[int, dict]:
        """Per-bucket plan provenance (asserted by the rejoin-hot test)."""
        return self.pir.servers[0].plan_report()
