"""Batched-query PIR as an int8 GEMM — the MXU operational-intensity lever.

Beyond-paper rationale (DESIGN.md §2)
-------------------------------------
The paper's dpXOR reads the whole DB *per query*: operational intensity is a
fixed ~1 op/byte, pinned to the memory roofline (its Fig. 3b). With additive
Z_256 shares, a batch of Q queries against the same DB shard is one matrix
product ``shares[Q, R] × db[R, L]`` — the DB is read once per *batch*,
multiplying intensity by Q and moving the scan toward the compute roofline.
UPMEM DPUs have no matrix unit, so the paper cannot make this move; the TPU's
MXU executes int8×int8→int32 natively.

Correctness over Z_256: answers only matter mod 256 and 2^8 | 2^32, so int32
accumulation (and any wraparound) preserves the residue; the client reduces
mod 256 at reconstruction.

Kernel: classic three-loop blocked matmul. Grid = (Q tiles, L tiles, R
tiles); R is the innermost (sequential) accumulation dimension so each
``[TQ, TL]`` output block stays resident in VMEM while ``[TQ, TR]`` share
and ``[TR, TL]`` DB tiles stream through.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.engine.backend import resolve_interpret

I32 = jnp.int32


def _matmul_kernel(s_ref, d_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        s_ref[...],
        d_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=I32,
    )


def pir_matmul(
    shares: jax.Array,
    db_bytes: jax.Array,
    *,
    tile_q: int = 8,
    tile_r: int = 1024,
    tile_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """``shares[Q, R] i8 × db[R, L] i8 -> [Q, L] i32`` partial PIR answers.

    Tile defaults target the MXU's 128-multiple alignment on the reduction
    and lane dims; Q (query batch) may be small, so it rides the sublane
    dim. ``interpret=None`` resolves against the engine backend probe
    (``REPRO_FORCE_BACKEND``), outside the jit boundary.
    """
    return _pir_matmul_jit(shares, db_bytes, tile_q=tile_q, tile_r=tile_r,
                           tile_l=tile_l,
                           interpret=resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("tile_q", "tile_r", "tile_l", "interpret")
)
def _pir_matmul_jit(
    shares: jax.Array,
    db_bytes: jax.Array,
    *,
    tile_q: int,
    tile_r: int,
    tile_l: int,
    interpret: bool,
) -> jax.Array:
    q, r = shares.shape
    r2, l = db_bytes.shape
    if r != r2:
        raise ValueError(f"reduction mismatch {shares.shape} x {db_bytes.shape}")
    tile_q, tile_r, tile_l = min(tile_q, q), min(tile_r, r), min(tile_l, l)
    for name, dim, t in (("Q", q, tile_q), ("R", r, tile_r), ("L", l, tile_l)):
        if dim % t:
            raise ValueError(f"{name}={dim} not divisible by tile {t}")
    grid = (q // tile_q, l // tile_l, r // tile_r)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_r), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_r, tile_l), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_l), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, l), I32),
        interpret=interpret,
    )(shares.astype(jnp.int8), db_bytes.astype(jnp.int8))


def lwe_matmul(
    ct: jax.Array,
    db_bytes32: jax.Array,
    *,
    tile_q: int = 8,
    tile_r: int = 1024,
    tile_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """``ct[Q, R] i32 × db[R, L] i32 -> [Q, L] i32`` LWE PIR answers.

    Same blocked three-loop program as :func:`pir_matmul` — identical grid
    and BlockSpecs, int32 operands instead of int8. Correctness over Z_q
    with q = 2^32: int32 accumulation wraps mod 2^32, so the GEMM computes
    the Z_q contraction exactly (DESIGN.md §10). Streams are 4× wider than
    the int8 path, which is why the engine registers a separate descriptor
    with its own VMEM footprint model.
    """
    return _lwe_matmul_jit(ct, db_bytes32, tile_q=tile_q, tile_r=tile_r,
                           tile_l=tile_l,
                           interpret=resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("tile_q", "tile_r", "tile_l", "interpret")
)
def _lwe_matmul_jit(
    ct: jax.Array,
    db_bytes32: jax.Array,
    *,
    tile_q: int,
    tile_r: int,
    tile_l: int,
    interpret: bool,
) -> jax.Array:
    q, r = ct.shape
    r2, l = db_bytes32.shape
    if r != r2:
        raise ValueError(f"reduction mismatch {ct.shape} x {db_bytes32.shape}")
    tile_q, tile_r, tile_l = min(tile_q, q), min(tile_r, r), min(tile_l, l)
    for name, dim, t in (("Q", q, tile_q), ("R", r, tile_r), ("L", l, tile_l)):
        if dim % t:
            raise ValueError(f"{name}={dim} not divisible by tile {t}")
    grid = (q // tile_q, l // tile_l, r // tile_r)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_r), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_r, tile_l), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_l), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, l), I32),
        interpret=interpret,
    )(ct.astype(I32), db_bytes32.astype(I32))
