"""GGM level-expansion Pallas kernel — DPF evaluation's inner loop on TPU.

Paper analogue
--------------
IM-PIR keeps DPF evaluation (the GGM tree, AES-128 via AES-NI) on the *host*
CPU because UPMEM DPUs have no crypto units (paper §3.2); after the PIM
offload this becomes the dominant cost (76.45% of query latency, Table 1).
The TPU adaptation replaces AES with a ChaCha-style ARX permutation whose
add/rotate/xor structure is exactly the VPU's 32-bit SIMD shape, so one
breadth-first tree level — ``[n,4]u32 seeds -> [2n,4]u32 + control bits`` —
is a single lane-parallel kernel invocation.

Layout
------
Seeds enter *word-transposed*: ``seeds_t[4, n]`` — the 4 seed words are
sublanes, the n tree nodes are lanes (n is the long axis). The ChaCha state
is then 16 row vectors of length TILE; every quarter-round op is a full-width
VPU op. Outputs: ``children_t[8, n]`` (rows 0:4 left child seed, 4:8 right)
and ``tbits[2, n]`` (left/right control bits), with the BGI correction words
already applied (masked by the parent t-bit).

Bit-exactness: this kernel must produce the same stream as
``repro.crypto.chacha.ggm_double`` (the jnp reference used by key
generation); tests/test_kernels.py asserts exact equality over shape sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.crypto.chacha import SIGMA
from repro.engine.backend import resolve_interpret

U32 = jnp.uint32


def _rotl(x, n):
    return (x << np.uint32(n)) | (x >> np.uint32(32 - n))


def _quarter(a, b, c, d):
    a = a + b
    d = _rotl(d ^ a, 16)
    c = c + d
    b = _rotl(b ^ c, 12)
    a = a + b
    d = _rotl(d ^ a, 8)
    c = c + d
    b = _rotl(b ^ c, 7)
    return a, b, c, d


def _chacha_rows(seed_rows, counter: int, rounds: int):
    """ChaCha permutation over row-vector lanes; mirrors crypto.chacha.

    seed_rows: list of 4 ``[TILE]`` u32 vectors. Returns 16 ``[TILE]`` rows.
    """
    tile = seed_rows[0].shape
    const = [jnp.full(tile, np.uint32(c)) for c in SIGMA]
    ctr_words = [counter & 0xFFFFFFFF, 0x5049522D, 0x494D5049, 0x52212121]
    ctr = [jnp.full(tile, np.uint32(c)) for c in ctr_words]
    state = const + seed_rows + seed_rows + ctr

    def double_round(_, xs):
        x = list(xs)
        # column rounds
        for i in range(4):
            x[i], x[4 + i], x[8 + i], x[12 + i] = _quarter(
                x[i], x[4 + i], x[8 + i], x[12 + i]
            )
        # diagonal rounds
        for i in range(4):
            a, b, c, d = i, 4 + (i + 1) % 4, 8 + (i + 2) % 4, 12 + (i + 3) % 4
            x[a], x[b], x[c], x[d] = _quarter(x[a], x[b], x[c], x[d])
        return tuple(x)

    # Rolled (not Python-unrolled) double rounds: every iteration is the
    # same ARX dataflow, and callers like the fused megakernel instantiate
    # this permutation once per tree level — unrolled, the XLA:CPU graph
    # of the interpret-mode emulation grew superlinearly in rounds × levels
    # (the additive fused body hit a >15 min, >20 GB compile at rounds=12).
    x = jax.lax.fori_loop(0, rounds // 2, double_round, tuple(state))
    return [xi + si for xi, si in zip(x, state)]


def _ggm_expand_kernel(seeds_ref, t_ref, cw_seed_ref, cw_t_ref,
                       child_ref, tout_ref, *, rounds: int):
    """Expand one tile of GGM nodes: seeds [4,T] -> children [8,T], t [2,T]."""
    seed_rows = [seeds_ref[i, :] for i in range(4)]
    out = _chacha_rows(seed_rows, counter=0, rounds=rounds)
    t = t_ref[0, :]
    mask = jnp.uint32(0) - t                       # 0x0 / 0xFFFFFFFF
    t_l = (out[8] & U32(1)) ^ (t & cw_t_ref[0, 0])
    t_r = (out[9] & U32(1)) ^ (t & cw_t_ref[1, 0])
    for i in range(4):
        cw = cw_seed_ref[i, 0]
        child_ref[i, :] = out[i] ^ (mask & cw)          # left child word i
        child_ref[4 + i, :] = out[4 + i] ^ (mask & cw)  # right child word i
    tout_ref[0, :] = t_l
    tout_ref[1, :] = t_r


def ggm_expand_level(
    seeds_t: jax.Array,
    t_bits: jax.Array,
    cw_seed: jax.Array,
    cw_t: jax.Array,
    *,
    rounds: int = 12,
    tile: int = 1024,
    interpret: bool | None = None,
):
    """One corrected GGM level for ``n`` nodes (lane-parallel).

    Args:
      seeds_t: ``[4, n] uint32`` word-transposed node seeds.
      t_bits:  ``[n] uint32`` node control bits.
      cw_seed: ``[4] uint32`` level seed correction word.
      cw_t:    ``[2] uint32`` level (tL, tR) control corrections.
      interpret: ``None`` resolves against the engine backend probe
        (``REPRO_FORCE_BACKEND``), outside the jit boundary.

    Returns ``(children_t [8, n], t_children [2, n])`` — lane j's children
    are column j of each half; the caller interleaves to leaf order.
    """
    return _ggm_expand_level_jit(seeds_t, t_bits, cw_seed, cw_t,
                                 rounds=rounds, tile=tile,
                                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("rounds", "tile", "interpret"))
def _ggm_expand_level_jit(
    seeds_t: jax.Array,
    t_bits: jax.Array,
    cw_seed: jax.Array,
    cw_t: jax.Array,
    *,
    rounds: int,
    tile: int,
    interpret: bool,
):
    n = seeds_t.shape[1]
    tile = min(tile, n)
    if n % tile:
        raise ValueError(f"n={n} not divisible by tile={tile}")
    grid = (n // tile,)
    kernel = functools.partial(_ggm_expand_kernel, rounds=rounds)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((4, 1), lambda i: (0, 0)),
            pl.BlockSpec((2, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((8, tile), lambda i: (0, i)),
            pl.BlockSpec((2, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((8, n), U32),
            jax.ShapeDtypeStruct((2, n), U32),
        ],
        interpret=interpret,
    )(
        seeds_t.astype(U32),
        t_bits.astype(U32)[None, :],
        cw_seed.astype(U32)[:, None],
        cw_t.astype(U32)[:, None],
    )
