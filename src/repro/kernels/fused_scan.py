"""Fused GGM-expand + DB-scan Pallas megakernel with double-buffered DMA.

Paper analogue
--------------
IM-PIR's core win is doing the oblivious scan where the bytes live: each
UPMEM bank scans its MRAM-resident chunk in place instead of hauling the
database across the memory bus (paper §3.3). The TPU analogue is this
kernel: the DB shard stays in HBM and streams through VMEM tiles exactly
once per *batch*, while the DPF selection vector for that tile is expanded
on the fly from per-chunk GGM subtree roots — so the one-hot expansion
never exists in HBM at all (the earlier "fused" path kept bits out of HBM
but still round-tripped each chunk's fold through separate XLA ops).

Structure (DESIGN.md §13)
-------------------------
One ``pallas_call`` with no grid. The DB input lives in ``pltpu.ANY``
memory space (HBM on TPU); a ``[depth, ...]`` VMEM scratch holds the
rotating DMA buffers, paired with a ``[depth]`` DMA-semaphore array:

  prologue:  start async copies for tiles 0..depth-1
  tile i:    wait slot (i % depth)  ->  expand the tile's GGM leaves
             from its chunk roots   ->  accumulate the select-reduction
             ->  start the copy for tile i+depth into the freed slot

The same ``fori_loop`` program runs under interpret mode (bit-exact CPU
validation — ``pltpu.emit_pipeline`` cannot, which is why the rotation is
manual) and compiles to genuinely overlapped DMA on real TPUs.

Inputs are *chunk roots*: the host precomputes each query's GGM descent
down to depth ``log_n - chunk_log`` (``dpf.eval_roots_batch`` — shared
across all chunks, unlike the chunked-jnp path which re-descends per
chunk) and ships ``[Q, n_chunks]`` subtree seeds + control bits plus the
last ``chunk_log`` levels of correction words. The kernel breadth-expands
those ``chunk_log`` levels in VMEM with the same ChaCha rounds as
``kernels/ggm_expand.py`` (bit-exactness with ``crypto.chacha`` is what
makes the byte-parity suite possible), interleaving children so leaf j of
the tile lands in lane j.

Two accumulation bodies share the expansion:

  xor       bits -> full-word masks -> AND with the [W, tile_r] DB tile
            -> lane-halving XOR fold (exactly ``dpxor``'s reduction), so
            the answer is bit-identical to the materialized path.
  additive  leaf seeds -> payload-conversion PRG (counter=1) -> Z_256
            shares with int8 *sign semantics* reproduced in-kernel
            (share - 256 where share >= 128) -> int32 dot against the
            int8 DB tile: bit-identical int32 to the materialized GEMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.engine.backend import resolve_interpret
from repro.kernels.dpxor import _fold_xor_lanes
from repro.kernels.ggm_expand import _chacha_rows

U32 = jnp.uint32


def _interleave(left: jax.Array, right: jax.Array) -> jax.Array:
    """[Q, m] x2 -> [Q, 2m] with children interleaved to leaf order."""
    q, m = left.shape
    return jnp.stack([left, right], axis=-1).reshape(q, 2 * m)


def _expand_tile(seed_rows, t, cws_ref, cwt_ref, *, clog: int, rounds: int):
    """Breadth-expand ``clog`` corrected GGM levels for one DB tile.

    seed_rows: list of 4 ``[Q, m]`` u32 chunk-root seed words; t: ``[Q, m]``
    control bits. cws_ref ``[clog, 4, Q]`` / cwt_ref ``[clog, 2, Q]`` carry
    the per-query correction words for the *last* clog tree levels.
    Returns (leaf seed_rows [Q, m << clog] x4, leaf t [Q, m << clog]).
    """
    for lvl in range(clog):
        out = _chacha_rows(seed_rows, counter=0, rounds=rounds)
        mask = U32(0) - t                                    # [Q, m]
        new_rows = []
        for w in range(4):
            cw = cws_ref[lvl, w, :][:, None]                 # [Q, 1]
            new_rows.append(_interleave(out[w] ^ (mask & cw),
                                        out[4 + w] ^ (mask & cw)))
        t_l = (out[8] & U32(1)) ^ (t & cwt_ref[lvl, 0, :][:, None])
        t_r = (out[9] & U32(1)) ^ (t & cwt_ref[lvl, 1, :][:, None])
        seed_rows = new_rows
        t = _interleave(t_l, t_r)
    return seed_rows, t


def _fused_xor_kernel(roots_ref, troots_ref, cws_ref, cwt_ref, db_ref,
                      out_ref, buf_ref, sem_ref, *, tile_r: int, clog: int,
                      depth: int, rounds: int, n_tiles: int):
    """XOR body: db_t [W, R] (ANY) -> out [Q, W] (VMEM)."""
    cpt = tile_r >> clog                   # chunk roots per tile
    q, w_words = out_ref.shape

    def copy_in(i, slot):
        return pltpu.make_async_copy(
            db_ref.at[:, pl.ds(i * tile_r, tile_r)],
            buf_ref.at[slot], sem_ref.at[slot])

    for s in range(min(depth, n_tiles)):   # prologue: fill the pipeline
        copy_in(s, s).start()

    def body(i, acc):
        slot = jax.lax.rem(i, depth)
        copy_in(i, slot).wait()
        c0 = i * cpt
        seed_rows = [roots_ref[w, :, pl.ds(c0, cpt)] for w in range(4)]
        t = troots_ref[:, pl.ds(c0, cpt)]
        _, bits = _expand_tile(seed_rows, t, cws_ref, cwt_ref,
                               clog=clog, rounds=rounds)
        mask = U32(0) - bits                               # [Q, tile_r]
        db_tile = buf_ref[slot]                            # [W, tile_r]
        masked = mask[:, None, :] & db_tile[None, :, :]    # [Q, W, tile_r]
        acc = acc ^ _fold_xor_lanes(masked)[..., 0]

        @pl.when(i + depth < n_tiles)
        def _():                           # refill the slot just freed
            copy_in(i + depth, slot).start()
        return acc

    acc0 = jnp.zeros((q, w_words), U32)
    out_ref[...] = jax.lax.fori_loop(0, n_tiles, body, acc0)


def _fused_add_kernel(roots_ref, troots_ref, cws_ref, cwt_ref, cwf_ref,
                      db_ref, out_ref, buf_ref, sem_ref, *, tile_r: int,
                      clog: int, depth: int, rounds: int, n_tiles: int,
                      party: int):
    """Additive body: db [R, L] i8 (ANY) -> out [Q, L] i32 (VMEM)."""
    cpt = tile_r >> clog
    q, n_bytes = out_ref.shape

    def copy_in(i, slot):
        return pltpu.make_async_copy(
            db_ref.at[pl.ds(i * tile_r, tile_r), :],
            buf_ref.at[slot], sem_ref.at[slot])

    for s in range(min(depth, n_tiles)):
        copy_in(s, s).start()

    def body(i, acc):
        slot = jax.lax.rem(i, depth)
        copy_in(i, slot).wait()
        c0 = i * cpt
        seed_rows = [roots_ref[w, :, pl.ds(c0, cpt)] for w in range(4)]
        t = troots_ref[:, pl.ds(c0, cpt)]
        seed_rows, t = _expand_tile(seed_rows, t, cws_ref, cwt_ref,
                                    clog=clog, rounds=rounds)
        # payload conversion: word 0 of the counter=1 block (prg_bits)
        conv = _chacha_rows(seed_rows, counter=1, rounds=rounds)[0]
        cwf = cwf_ref[0, :][:, None] & U32(0xFF)           # [Q, 1]
        share = ((conv & U32(0xFF)) + t * cwf) & U32(0xFF)
        if party == 1:
            share = (U32(256) - share) & U32(0xFF)
        # int8 sign semantics, reproduced so the int32 accumulation is
        # bit-identical to the materialized int8 GEMM
        s32 = share.astype(jnp.int32)
        s32 = jnp.where(share >= U32(128), s32 - 256, s32)
        db32 = buf_ref[slot].astype(jnp.int32)             # [tile_r, L]
        acc = acc + jax.lax.dot_general(
            s32, db32, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

        @pl.when(i + depth < n_tiles)
        def _():
            copy_in(i + depth, slot).start()
        return acc

    acc0 = jnp.zeros((q, n_bytes), jnp.int32)
    out_ref[...] = jax.lax.fori_loop(0, n_tiles, body, acc0)


def _check_args(r, c, clog, tile_r, depth):
    if tile_r <= 0 or tile_r & (tile_r - 1):
        raise ValueError(f"tile_r must be a power of two, got {tile_r}")
    if r % tile_r:
        raise ValueError(f"rows {r} not divisible by tile_r {tile_r}")
    if (1 << clog) > tile_r:
        raise ValueError(f"chunk 2^{clog} exceeds tile_r {tile_r}: "
                         "legalize chunk_log <= log2(tile_r) first")
    if c << clog != r:
        raise ValueError(f"{c} chunk roots x 2^{clog} leaves != rows {r}")
    if depth < 1:
        raise ValueError(f"buffer depth must be >= 1, got {depth}")


def fused_scan_xor_t(db_t: jax.Array, roots_t: jax.Array,
                     t_roots: jax.Array, cw_seed_t: jax.Array,
                     cw_t_t: jax.Array, *, tile_r: int, depth: int,
                     rounds: int = 12,
                     interpret: bool | None = None) -> jax.Array:
    """Fused expand+XOR-scan over a word-transposed DB shard.

    Args:
      db_t:      ``[W, R] uint32`` word-transposed DB shard.
      roots_t:   ``[4, Q, C] uint32`` chunk-root seed words.
      t_roots:   ``[Q, C] uint32`` chunk-root control bits.
      cw_seed_t: ``[clog, 4, Q] uint32`` seed CWs for the last clog levels.
      cw_t_t:    ``[clog, 2, Q] uint32`` (tL, tR) CWs for the same levels.
      tile_r:    DB rows per DMA tile (power of two dividing R).
      depth:     rotating DMA buffer count (2 = classic double buffer).
      interpret: ``None`` resolves against the engine backend probe
        (``REPRO_FORCE_BACKEND``), outside the jit boundary.

    Returns ``[Q, W] uint32`` per-query XOR answers, bit-identical to the
    materialized ``eval_bits`` + ``dpxor`` path.
    """
    return _fused_scan_xor_jit(db_t, roots_t, t_roots, cw_seed_t, cw_t_t,
                               tile_r=tile_r, depth=depth, rounds=rounds,
                               interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("tile_r", "depth", "rounds",
                                             "interpret"))
def _fused_scan_xor_jit(db_t: jax.Array, roots_t: jax.Array,
                        t_roots: jax.Array, cw_seed_t: jax.Array,
                        cw_t_t: jax.Array, *, tile_r: int, depth: int,
                        rounds: int, interpret: bool) -> jax.Array:
    w, r = db_t.shape
    clog = cw_seed_t.shape[0]
    q, c = t_roots.shape
    _check_args(r, c, clog, tile_r, depth)
    n_tiles = r // tile_r
    if clog == 0:
        # Degenerate point: the roots already are the leaves, so no CW
        # levels ship. Zero-sized operands break interpret-mode block
        # padding; pad to one (never-read) level instead.
        cw_seed_t = jnp.zeros((1, 4, q), U32)
        cw_t_t = jnp.zeros((1, 2, q), U32)
    kernel = functools.partial(
        _fused_xor_kernel, tile_r=tile_r, clog=clog,
        depth=min(depth, n_tiles), rounds=rounds, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),    # roots_t
            pl.BlockSpec(memory_space=pltpu.ANY),    # t_roots
            pl.BlockSpec(memory_space=pltpu.ANY),    # cw_seed_t
            pl.BlockSpec(memory_space=pltpu.ANY),    # cw_t_t
            pl.BlockSpec(memory_space=pltpu.ANY),    # db_t (streamed)
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((q, w), U32),
        scratch_shapes=[
            pltpu.VMEM((min(depth, n_tiles), w, tile_r), U32),
            pltpu.SemaphoreType.DMA((min(depth, n_tiles),)),
        ],
        interpret=interpret,
    )(roots_t.astype(U32), t_roots.astype(U32), cw_seed_t.astype(U32),
      cw_t_t.astype(U32), db_t.astype(U32))


def fused_scan_add(db_bytes: jax.Array, roots_t: jax.Array,
                   t_roots: jax.Array, cw_seed_t: jax.Array,
                   cw_t_t: jax.Array, cw_final: jax.Array, *, party: int,
                   tile_r: int, depth: int, rounds: int = 12,
                   interpret: bool | None = None) -> jax.Array:
    """Fused expand+select-add over an int8 byte-view DB shard.

    ``db_bytes [R, L] int8``; ``cw_final [Q] uint32`` is the payload
    correction word; other args as :func:`fused_scan_xor_t`. Returns
    ``[Q, L] int32`` — bit-identical to ``eval_bytes_batch`` + the int8
    GEMM (``answer_additive_matmul``).
    """
    return _fused_scan_add_jit(db_bytes, roots_t, t_roots, cw_seed_t,
                               cw_t_t, cw_final, party=party,
                               tile_r=tile_r, depth=depth, rounds=rounds,
                               interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("tile_r", "depth", "rounds",
                                             "party", "interpret"))
def _fused_scan_add_jit(db_bytes: jax.Array, roots_t: jax.Array,
                        t_roots: jax.Array, cw_seed_t: jax.Array,
                        cw_t_t: jax.Array, cw_final: jax.Array, *,
                        party: int, tile_r: int, depth: int, rounds: int,
                        interpret: bool) -> jax.Array:
    r, l = db_bytes.shape
    clog = cw_seed_t.shape[0]
    q, c = t_roots.shape
    _check_args(r, c, clog, tile_r, depth)
    n_tiles = r // tile_r
    if clog == 0:
        # See fused_scan_xor_t: pad the zero-level CW operands.
        cw_seed_t = jnp.zeros((1, 4, q), U32)
        cw_t_t = jnp.zeros((1, 2, q), U32)
    kernel = functools.partial(
        _fused_add_kernel, tile_r=tile_r, clog=clog,
        depth=min(depth, n_tiles), rounds=rounds, n_tiles=n_tiles,
        party=party)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 6,
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((q, l), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((min(depth, n_tiles), tile_r, l), jnp.int8),
            pltpu.SemaphoreType.DMA((min(depth, n_tiles),)),
        ],
        interpret=interpret,
    )(roots_t.astype(U32), t_roots.astype(U32), cw_seed_t.astype(U32),
      cw_t_t.astype(U32), cw_final.astype(U32)[None, :],
      db_bytes.astype(jnp.int8))
