"""Pure-jnp oracles for every Pallas kernel (exact-match references).

Each function computes the same contraction as its kernel with plain jnp
ops — no tiling, no grids — and is the ground truth for the shape/dtype
sweep tests. All three kernels are integer-exact, so tests assert equality,
not approximate closeness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto.chacha import ggm_double

U32 = jnp.uint32


def dpxor_ref(db_words: jax.Array, bits: jax.Array) -> jax.Array:
    """[R, W] u32 DB, [Q, R] u32 bits -> [Q, W] u32 select-XOR answers."""
    mask = (U32(0) - bits.astype(U32))[:, :, None]        # [Q, R, 1]
    masked = mask & db_words.astype(U32)[None, :, :]      # [Q, R, W]
    return jax.lax.reduce(
        masked, jnp.uint32(0), jax.lax.bitwise_xor, (1,)
    )


def ggm_expand_ref(seeds: jax.Array, t_bits: jax.Array, cw_seed: jax.Array,
                   cw_t: jax.Array, *, rounds: int = 12):
    """One corrected GGM level in leaf-major layout.

    seeds [n, 4], t_bits [n] -> (children [2n, 4] interleaved L/R, t [2n]).
    Mirrors core.dpf._expand_level (the construction used by gen_keys).
    """
    s_l, t_l, s_r, t_r = ggm_double(seeds, rounds=rounds)
    mask = t_bits.astype(U32)[:, None] * cw_seed.astype(U32)[None, :]
    s_l = s_l ^ mask
    s_r = s_r ^ mask
    t_l = t_l ^ (t_bits & cw_t[0])
    t_r = t_r ^ (t_bits & cw_t[1])
    n = seeds.shape[0]
    children = jnp.stack([s_l, s_r], axis=1).reshape(2 * n, 4)
    t_out = jnp.stack([t_l, t_r], axis=1).reshape(2 * n)
    return children, t_out


def pir_matmul_ref(shares: jax.Array, db_bytes: jax.Array) -> jax.Array:
    """[Q, R] i8 × [R, L] i8 -> [Q, L] i32 (the additive-share contraction)."""
    return jax.lax.dot_general(
        shares.astype(jnp.int8),
        db_bytes.astype(jnp.int8),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
