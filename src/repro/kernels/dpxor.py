"""dpXOR Pallas kernel — the paper's Algorithm 1 ④-⑤ on TPU.

Paper analogue
--------------
Each UPMEM DPU holds a DB chunk in MRAM and runs a two-stage parallel
reduction: tasklets XOR-fold disjoint row ranges into partials (stage 1,
``TASKLETXOR``), then a master tasklet folds the partials (stage 2,
``MASTERXOR``). MRAM→WRAM DMA streams the rows through the 64 KB scratchpad.

TPU mapping (DESIGN.md §2)
--------------------------
  MRAM chunk        -> HBM-resident DB shard
  WRAM staging      -> VMEM tiles via BlockSpec (``TILE_R`` rows per grid step)
  tasklet partials  -> the VMEM accumulator updated across sequential grid
                       steps (stage 1); the in-tile halving fold (stage 2)
  24 tasklets       -> the VPU's lane parallelism inside one tile

Layout: the kernel consumes the DB *word-transposed* — ``db_t[W, R]`` — so
that the long row axis ``R`` is the TPU lane dimension (records are W≈8
words; leaving W in lanes would waste 15/16 of each 8×128 vreg). The fold
over selected rows is a lane-dimension halving reduction, which lowers to
cheap vector shifts.

Masking: selection bits b∈{0,1} become full-word masks ``0 - b`` (0x0 or
0xFFFFFFFF), so "include row j iff Eval(k,j)=1" is a single AND — the
branchless form of the paper's ``if v[j] = 1`` (Algorithm 1 line 33).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.engine.backend import resolve_interpret

U32 = jnp.uint32


def _fold_xor_lanes(x: jax.Array) -> jax.Array:
    """XOR-fold the (power-of-two) last axis by repeated halving.

    [..., 2m] -> [..., 1]. The halving schedule is the vectorized form of the
    paper's two-stage reduction: each halving step is "all tasklets fold in
    parallel"; the final scalar is the master-tasklet result.
    """
    n = x.shape[-1]
    while n > 1:
        half = n // 2
        x = jax.lax.bitwise_xor(x[..., :half], x[..., half:])
        n = half
    return x


def _dpxor_kernel(bits_ref, db_ref, out_ref, *, tile_r: int):
    """One grid step: fold ``tile_r`` rows of the DB into the accumulator.

    bits_ref: [Q, TILE_R] u32 selection bits for this row tile.
    db_ref:   [W, TILE_R] u32 word-transposed DB tile (VMEM).
    out_ref:  [Q, W]      u32 accumulator; same block for every grid step.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bits = bits_ref[...]                      # [Q, TILE_R]
    db_t = db_ref[...]                        # [W, TILE_R]
    mask = jnp.uint32(0) - bits               # 0x00000000 / 0xFFFFFFFF
    # [Q, 1, TILE_R] & [1, W, TILE_R] -> [Q, W, TILE_R]
    masked = mask[:, None, :] & db_t[None, :, :]
    out_ref[...] ^= _fold_xor_lanes(masked)[..., 0]


def dpxor_t(
    db_t: jax.Array,
    bits: jax.Array,
    *,
    tile_r: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched select-XOR scan over a word-transposed DB shard.

    Args:
      db_t:  ``[W, R] uint32`` — DB shard, words-major (R = rows, power of 2).
      bits:  ``[Q, R] uint32`` — per-query selection bits (DPF leaf bits).
      tile_r: rows staged through VMEM per grid step (the WRAM-analogue).
      interpret: run the kernel body in interpret mode (CPU validation);
        ``None`` resolves against the engine's backend probe
        (``REPRO_FORCE_BACKEND``) *before* entering the jitted body, so the
        env-dependent answer never freezes into a trace cache.

    Returns ``[Q, W] uint32`` — per-query XOR subresults (the DPU's s_d).
    """
    return _dpxor_t_jit(db_t, bits, tile_r=tile_r,
                        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("tile_r", "interpret"))
def _dpxor_t_jit(
    db_t: jax.Array,
    bits: jax.Array,
    *,
    tile_r: int,
    interpret: bool,
) -> jax.Array:
    w, r = db_t.shape
    q = bits.shape[0]
    if bits.shape[1] != r:
        raise ValueError(f"bits {bits.shape} mismatch with db {db_t.shape}")
    tile_r = min(tile_r, r)
    if r % tile_r:
        raise ValueError(f"rows {r} not divisible by tile_r {tile_r}")
    if tile_r & (tile_r - 1):
        raise ValueError("tile_r must be a power of two")
    grid = (r // tile_r,)
    return pl.pallas_call(
        functools.partial(_dpxor_kernel, tile_r=tile_r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, tile_r), lambda i: (0, i)),
            pl.BlockSpec((w, tile_r), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((q, w), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, w), U32),
        interpret=interpret,
    )(bits.astype(U32), db_t.astype(U32))
