"""Public jit'd entry points for the Pallas kernels.

These wrappers own layout conversion (row-major DB <-> the kernels' word-
transposed form), tile selection, and the interpret-mode switch: on the CPU
container every kernel body executes in Pallas interpret mode (bit-exact
Python evaluation); on a real TPU backend ``interpret=False`` compiles the
same BlockSpec program to Mosaic.

The PIR server (core/server.py) calls these when ``use_kernels=True``; the
pure-jnp forms in kernels/ref.py remain the oracles and the GSPMD dry-run
path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.engine.backend import (default_interpret, legal_tile, on_tpu,
                                  resolve_interpret)
from repro.kernels.dpxor import dpxor_t
from repro.kernels.fused_scan import fused_scan_add, fused_scan_xor_t
from repro.kernels.ggm_expand import ggm_expand_level
from repro.kernels.pir_matmul import lwe_matmul, pir_matmul

U32 = jnp.uint32


def _on_tpu() -> bool:
    """Compat alias — backend probing now lives in ``engine/backend.py``
    (one probe for plan selection AND interpret defaults, overridable via
    ``REPRO_FORCE_BACKEND``)."""
    return on_tpu()


# ``default_interpret``/``resolve_interpret`` are re-exported from
# engine.backend unchanged: real Mosaic only on an (effective) TPU backend.
# Since the fused-scan PR every kernel module's own entry point resolves
# ``interpret=None`` through the same probe (outside its jit boundary), so
# these wrappers just pass the request through.
__all__ = ["default_interpret", "resolve_interpret", "dpxor",
           "dpxor_transposed", "fused_scan_xor", "fused_scan_bytes", "fused_tile",
           "ggm_expand", "ggm_eval_leaves", "lwe_gemm", "pir_gemm"]


# ---------------------------------------------------------------------------
# dpXOR
# ---------------------------------------------------------------------------

def dpxor(db_words: jax.Array, bits: jax.Array, *, tile_r: int = 2048,
          interpret: bool | None = None) -> jax.Array:
    """Select-XOR scan, row-major DB: [R, W] u32 × [Q, R] bits -> [Q, W].

    Transposes to the kernel's word-major layout; production servers keep
    the DB pre-transposed and call :func:`dpxor_transposed` to avoid paying
    the transpose per query batch.

    ``tile_r`` is a *request*: the engine legalizes it to the largest
    power-of-two divisor of the row count (``engine.legal_tile``) — the
    old ``min(tile_r, R)`` clamp produced illegal tiles on
    non-power-of-two row counts.
    """
    return dpxor_t(db_words.T, bits,
                   tile_r=legal_tile(db_words.shape[0], tile_r, pow2=True),
                   interpret=interpret)


def dpxor_transposed(db_t: jax.Array, bits: jax.Array, *, tile_r: int = 2048,
                     interpret: bool | None = None) -> jax.Array:
    """Select-XOR scan on a pre-transposed [W, R] DB shard."""
    return dpxor_t(db_t, bits,
                   tile_r=legal_tile(db_t.shape[1], tile_r, pow2=True),
                   interpret=interpret)


# ---------------------------------------------------------------------------
# Fused GGM-expand + scan megakernel (kernels/fused_scan.py)
# ---------------------------------------------------------------------------

def fused_tile(rows: int, tile_r: int, clog: int) -> tuple[int, int]:
    """Legalize the megakernel's (tile_r, chunk_log) request for a shard.

    tile_r legalizes to the largest power-of-two divisor of the row count;
    chunk_log clamps so one DB tile always holds whole chunks (the kernel
    expands each tile's leaves from its own chunk roots — a chunk spanning
    tiles would need cross-tile expansion state).
    """
    tile = legal_tile(rows, tile_r, pow2=True)
    return tile, min(clog, tile.bit_length() - 1)


def fused_scan_xor(db_words: jax.Array, roots: jax.Array, t_roots: jax.Array,
                   cw_seed_lv: jax.Array, cw_t_lv: jax.Array, *,
                   tile_r: int = 2048, depth: int = 2, rounds: int = 12,
                   interpret: bool | None = None) -> jax.Array:
    """Fused expand+XOR megakernel, row-major DB entry point.

    Args:
      db_words:   ``[R, W] uint32`` row-major DB shard.
      roots:      ``[Q, C, 4] uint32`` chunk-root seeds
                  (``dpf.eval_roots_batch`` with ``stop_log = log2(R/C)``).
      t_roots:    ``[Q, C] uint32`` chunk-root control bits.
      cw_seed_lv: ``[Q, clog, 4] uint32`` — the *last* clog levels of each
                  key's ``cw_seed`` (``key.cw_seed[:, log_n-clog:, :]``).
      cw_t_lv:    ``[Q, clog, 2] uint32`` — same slice of ``cw_t``.
      tile_r:     requested DMA tile (legalized; must hold whole chunks —
                  callers legalize chunk_log via the same rule, see
                  ``core/protocol.py _fused_pallas_inputs``).
      depth:      rotating DMA buffer count.
    """
    tile, _ = fused_tile(db_words.shape[0], tile_r, cw_seed_lv.shape[1])
    return fused_scan_xor_t(
        db_words.T, jnp.transpose(roots, (2, 0, 1)), t_roots,
        jnp.transpose(cw_seed_lv, (1, 2, 0)),
        jnp.transpose(cw_t_lv, (1, 2, 0)),
        tile_r=tile, depth=depth, rounds=rounds, interpret=interpret)


def fused_scan_bytes(db_bytes: jax.Array, roots: jax.Array,
                     t_roots: jax.Array, cw_seed_lv: jax.Array,
                     cw_t_lv: jax.Array, cw_final: jax.Array, *, party: int,
                     tile_r: int = 2048, depth: int = 2, rounds: int = 12,
                     interpret: bool | None = None) -> jax.Array:
    """Fused expand+select-add megakernel over the int8 byte view.

    Same chunk-root inputs as :func:`fused_scan_xor` plus ``cw_final [Q]``
    (payload correction word) and the static ``party``; returns
    ``[Q, L] int32`` bit-identical to the materialized int8 GEMM.
    """
    tile, _ = fused_tile(db_bytes.shape[0], tile_r, cw_seed_lv.shape[1])
    return fused_scan_add(
        db_bytes, jnp.transpose(roots, (2, 0, 1)), t_roots,
        jnp.transpose(cw_seed_lv, (1, 2, 0)),
        jnp.transpose(cw_t_lv, (1, 2, 0)), cw_final,
        party=party, tile_r=tile, depth=depth, rounds=rounds,
        interpret=interpret)


# ---------------------------------------------------------------------------
# GGM expansion
# ---------------------------------------------------------------------------

def ggm_expand(seeds: jax.Array, t_bits: jax.Array, cw_seed: jax.Array,
               cw_t: jax.Array, *, rounds: int = 12, tile: int = 65536,
               interpret: bool | None = None):
    """One corrected GGM level, leaf-major: [n,4] -> ([2n,4], [2n]).

    Wraps the lane-parallel kernel with the transpose + child interleave so
    callers see the same contract as ``core.dpf._expand_level``.

    Note on ``tile``: on the CPU container, XLA compile time of the
    interpret-mode emulation grows superlinearly in (chacha rounds × grid
    steps), so the default tile keeps grid=1 for any realistic test size.
    On TPU (interpret=False) the intended production tile is 512–2048 lanes
    (VMEM: 16 state rows × tile × 4 B ≲ 128 KB per step).
    """
    n = seeds.shape[0]
    children_t, t2 = ggm_expand_level(
        seeds.T, t_bits, cw_seed, cw_t,
        rounds=rounds, tile=legal_tile(n, tile), interpret=interpret,
    )
    # children_t: [8, n] (rows 0:4 = left seed words, 4:8 = right).
    left = children_t[0:4, :].T                   # [n, 4]
    right = children_t[4:8, :].T                  # [n, 4]
    children = jnp.stack([left, right], axis=1).reshape(2 * n, 4)
    t_out = jnp.stack([t2[0, :], t2[1, :]], axis=1).reshape(2 * n)
    return children, t_out


def ggm_eval_leaves(key_root: jax.Array, key_t0: jax.Array,
                    cw_seed: jax.Array, cw_t: jax.Array, log_n: int,
                    *, rounds: int = 12, interpret: bool | None = None):
    """Full-domain GGM leaf expansion driven by the Pallas level kernel.

    key_root [4], key_t0 scalar, cw_seed [log_n, 4], cw_t [log_n, 2]
    -> (seeds [2^log_n, 4], t_bits [2^log_n]).
    """
    seeds = key_root[None, :]
    t = jnp.asarray(key_t0, U32)[None]
    for level in range(log_n):
        seeds, t = ggm_expand(seeds, t, cw_seed[level], cw_t[level],
                              rounds=rounds, interpret=interpret)
    return seeds, t


# ---------------------------------------------------------------------------
# PIR matmul
# ---------------------------------------------------------------------------

def pir_gemm(shares: jax.Array, db_bytes: jax.Array, *, tile_q: int = 8,
             tile_r: int = 1024, tile_l: int = 128,
             interpret: bool | None = None) -> jax.Array:
    """Batched additive-PIR contraction: [Q, R] i8 × [R, L] i8 -> [Q, L] i32.

    Requested tiles legalize to the largest divisor of their dimension
    (``engine.legal_tile``), so non-power-of-two shapes pick a working
    tiling instead of tripping ``pir_matmul``'s divisibility check.
    """
    q, r = shares.shape
    l = db_bytes.shape[1]
    return pir_matmul(
        shares, db_bytes,
        tile_q=legal_tile(q, tile_q), tile_r=legal_tile(r, tile_r),
        tile_l=legal_tile(l, tile_l),
        interpret=interpret,
    )


def lwe_gemm(ct: jax.Array, db_bytes32: jax.Array, *, tile_q: int = 8,
             tile_r: int = 1024, tile_l: int = 128,
             interpret: bool | None = None) -> jax.Array:
    """Single-server LWE contraction: [Q, R] i32 × [R, L] i32 -> [Q, L] i32.

    int32 twin of :func:`pir_gemm` (same blocked program, 4-byte streams);
    the accumulate wraps mod 2^32 = mod q, so this is the exact Z_q GEMM
    of the lwe-simple-1 answer step.
    """
    q, r = ct.shape
    l = db_bytes32.shape[1]
    return lwe_matmul(
        ct, db_bytes32,
        tile_q=legal_tile(q, tile_q), tile_r=legal_tile(r, tile_r),
        tile_l=legal_tile(l, tile_l),
        interpret=interpret,
    )
