"""Typed configuration dataclasses.

Design notes
------------
* Configs are frozen dataclasses so they can be hashed into jit static args
  and embedded in checkpoint manifests.
* ``ModelConfig`` is a superset config: family-specific blocks (MoE, MLA, SSM)
  are optional sub-configs, ``None`` when absent. The model zoo dispatches on
  ``family``.
* Everything serializes to/from plain dicts (``to_dict``/``from_dict``) for
  the checkpoint manifest and the dry-run JSONL records.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


class AttentionKind(str, enum.Enum):
    GQA = "gqa"          # grouped-query attention (MHA when kv == heads)
    MLA = "mla"          # DeepSeek multi-head latent attention
    NONE = "none"        # attention-free block stacks (pure SSM)


def _asdict(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj):
        return {k: _asdict(v) for k, v in dataclasses.asdict(obj).items()}
    if isinstance(obj, enum.Enum):
        return obj.value
    return obj


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block parameters."""
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden width
    n_shared: int = 0              # always-on shared experts (DeepSeek style)
    capacity_factor: float = 1.25  # per-expert token capacity multiplier
    router_dtype: str = "float32"
    # layers [0, first_dense) use a dense FFN instead of MoE (DeepSeek-V3: 3)
    first_dense: int = 0
    dense_d_ff: int = 0            # width of those dense layers (0 = d_ff)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2/V3) dimensions."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent block parameters (Mamba2, xLSTM)."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk: int = 256               # chunkwise-parallel scan block length
    # zamba2: a weight-shared attention block every `shared_attn_every` layers
    shared_attn_every: int = 0
    # xlstm: block pattern, e.g. ("mlstm", "slstm") alternating
    block_pattern: Tuple[str, ...] = ()


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    attention: AttentionKind = AttentionKind.GQA
    qk_norm: bool = False
    pos_kind: str = "rope"         # rope | learned (whisper decoder)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # enc-dec (whisper): encoder depth/length; 0 = decoder-only
    n_encoder_layers: int = 0
    encoder_len: int = 0
    # modality frontend stub: number of prefix embedding tokens fed by client
    n_frontend_tokens: int = 0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    mtp: bool = False              # DeepSeek multi-token-prediction head
    dtype: str = "bfloat16"
    # attention score chunking (flash-style scan) block size
    attn_chunk: int = 1024

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def n_params(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline terms)."""
        d, v = self.d_model, self.vocab
        hd = self.resolved_head_dim
        n_emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attention == AttentionKind.MLA and self.mla is not None:
            m = self.mla
            qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_head
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        elif self.attention == AttentionKind.GQA:
            per_layer += d * self.n_heads * hd          # q
            per_layer += 2 * d * self.n_kv_heads * hd   # k, v
            per_layer += self.n_heads * hd * d          # o
        if self.ssm is not None and self.family in ("ssm", "hybrid"):
            s = self.ssm
            d_inner = s.expand * d
            if self.family == "ssm":
                # xlstm: mLSTM ≈ in 2·d·di + qkv 3·di² + out di·d; sLSTM 9d²
                per_layer_ssm = 2 * d * d_inner + 3 * d_inner * d_inner \
                    + d_inner * d
            else:
                # mamba2: in_proj + conv + out_proj
                hd = s.headdim or max(1, d_inner // max(self.n_heads, 1))
                nh = d_inner // hd
                per_layer_ssm = d * (2 * d_inner + 2 * s.d_state + nh)
                per_layer_ssm += d_inner * d + s.d_conv * (
                    d_inner + 2 * s.d_state)
            # hybrid: the mamba trunk is every layer; the GQA params
            # computed above belong to the single weight-shared block
            self_shared_attn = per_layer if self.family == "hybrid" else 0
            per_layer = per_layer_ssm
        if self.moe is not None:
            m = self.moe
            n_moe_layers = self.n_layers - m.first_dense
            ff = 3 * d * m.d_expert
            per_layer_moe = m.n_experts * ff + m.n_shared * ff + d * m.n_experts
            dense_ff = 3 * d * (m.dense_d_ff or self.d_ff)
            total_ffn = n_moe_layers * per_layer_moe + m.first_dense * dense_ff
        elif self.family == "hybrid":
            # FFN + attention live in the single weight-shared block:
            # counted once (weight-tied), not per layer
            total_ffn = 3 * d * self.d_ff + self_shared_attn
        elif self.family == "audio":
            total_ffn = (self.n_layers + self.n_encoder_layers) \
                * 2 * d * self.d_ff          # GELU two-matrix MLP
        elif self.d_ff > 0:
            total_ffn = self.n_layers * 3 * d * self.d_ff
        else:
            total_ffn = 0
        layers = self.n_layers + self.n_encoder_layers
        return n_emb + layers * per_layer + total_ffn + layers * 2 * d

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        d = self.d_model
        ff = 3 * d * m.d_expert
        total = self.n_params()
        n_moe_layers = self.n_layers - m.first_dense
        inactive = n_moe_layers * (m.n_experts - m.top_k) * ff
        return total - inactive

    def to_dict(self) -> dict:
        return _asdict(self)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    def to_dict(self) -> dict:
        return _asdict(self)


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def to_dict(self) -> dict:
        return _asdict(self)


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # cross-pod gradient compression (int8 + error feedback)
    compress_grads: bool = False

    def to_dict(self) -> dict:
        return _asdict(self)


#: deprecated ``mode=`` strings -> protocol registry names (core/protocol.py)
_PIR_MODE_PROTOCOLS = {"xor": "xor-dpf-2", "additive": "additive-dpf-2"}


def _implied_share_kind(protocol_name: str) -> str:
    """Best-effort share algebra from a protocol *name* (naming convention:
    additive schemes carry 'additive' in their registry name). The
    registered ``PIRProtocol.share_kind`` attribute is authoritative —
    this fallback exists only where the config layer cannot (or should
    not yet) touch the registry."""
    if "additive" in protocol_name:
        return "additive"
    if "lwe" in protocol_name:
        return "lwe"
    return "xor"


@dataclass(frozen=True)
class PIRConfig:
    """Paper-side configuration: one PIR database + protocol choices.

    ``protocol`` names an entry in the protocol registry
    (``core/protocol.py``): ``xor-dpf-2`` (paper-faithful two-server XOR),
    ``additive-dpf-2`` (Z_256 shares, int8-GEMM path), ``xor-dpf-k``
    (k-server XOR, k = ``n_servers``). The old ``mode="xor"|"additive"``
    string is a **deprecated** constructor alias kept for backward
    compatibility: a non-empty ``mode`` maps to the matching registry name
    (with a ``DeprecationWarning``) and, when it disagrees with a
    carried-over ``protocol`` (the ``dataclasses.replace(cfg, mode=...)``
    idiom), the explicit ``mode`` wins. After construction ``mode`` is
    normalized back to ``""`` — read :attr:`share_kind` (or ``protocol``)
    instead; storing only ``protocol`` is what keeps ``replace()`` working
    in both directions.
    """
    n_items: int                   # N: number of DB records (power of two)
    item_bytes: int = 32           # L: record payload (paper: 32-byte hashes)
    mode: str = ""                 # DEPRECATED constructor alias; always ""
    protocol: str = ""             # registry name; "" -> xor-dpf-2 (or mode)
    n_servers: int = 2             # parties (xor-dpf-k reads this as k)
    clusters: int = 1              # DPU clusters (paper §3.4)
    batch_queries: int = 32        # concurrent queries per step
    prf: str = "chacha12"          # chacha12 | chacha8 (pluggable ARX PRG)
    fused_kernel: bool = False     # fused GGM-expand + dpXOR (beyond paper)
    # verified reconstruction: store a per-row u32 checksum column next to
    # the payload so reconstruct() can detect corrupted shares and raise
    # IntegrityError instead of returning garbage (DESIGN.md §12). Widens
    # every stored record by 4 bytes; item_bytes stays the *logical* width.
    checksum: bool = False
    # batch PIR (DESIGN.md §14): m > 0 enables the cuckoo-bucketed
    # composite — m records per round over B = ceil(cuckoo_c·m) buckets,
    # cuckoo_hashes candidate buckets per index. cuckoo_seed is public
    # (data placement, not key material). 0 keeps single-query serving.
    batch_m: int = 0
    cuckoo_c: float = 2.0
    cuckoo_hashes: int = 3
    cuckoo_seed: int = 0x5EEDBA11

    def __post_init__(self):
        mode, proto = self.mode, self.protocol
        if mode and mode not in _PIR_MODE_PROTOCOLS:
            raise ValueError(
                f"unknown PIR mode {mode!r}; use protocol= with one of the "
                f"registry names instead")
        if mode:
            import warnings
            warnings.warn(
                "PIRConfig(mode=...) is deprecated; use "
                f"protocol={_PIR_MODE_PROTOCOLS[mode]!r}",
                DeprecationWarning, stacklevel=3)
            # the explicit mode wins unless the protocol already agrees on
            # the share algebra (e.g. mode="xor" + protocol="xor-dpf-k")
            if not proto or _implied_share_kind(proto) != mode:
                proto = _PIR_MODE_PROTOCOLS[mode]
        elif not proto:
            proto = "xor-dpf-2"
        object.__setattr__(self, "protocol", proto)
        object.__setattr__(self, "mode", "")

    @property
    def share_kind(self) -> str:
        """The share algebra: ``xor`` | ``additive`` | ``lwe``.

        Consults the registered protocol (the authoritative source) when
        available; falls back to the naming convention ONLY when the
        protocol plane is absent (``ImportError``) or the name is not
        (yet) registered (``KeyError``), since configs are constructible
        standalone. Anything else — a real protocol-plane bug — must
        surface, not silently degrade to name sniffing.
        """
        try:
            from repro.core.protocol import get
            return get(self.protocol).share_kind
        except (ImportError, KeyError):
            return _implied_share_kind(self.protocol)

    @property
    def log_n(self) -> int:
        return (self.n_items - 1).bit_length()

    @property
    def db_bytes(self) -> int:
        return self.n_items * self.item_bytes

    def to_dict(self) -> dict:
        return _asdict(self)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    # gradient-accumulation microbatches per step (1 = none)
    microbatches: int = 1
    remat: str = "block"           # none | block (remat each scanned layer)
    # FSDP/ZeRO-3: shard stacked-layer param dims over `data`; under scan
    # GSPMD gathers one layer's weights just-in-time per iteration.
    # Required for grok-1/deepseek-v3 (params exceed TP-only HBM).
    fsdp: bool = False
    private_embed: bool = False    # serve embeddings through PIR
    pir: Optional[PIRConfig] = None
    seed: int = 0

    def to_dict(self) -> dict:
        return _asdict(self)
