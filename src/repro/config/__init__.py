"""Configuration system: typed dataclasses + a named registry.

Every run is described by a ``RunConfig`` = (model, shape, mesh, runtime knobs).
Architecture files under ``repro.configs`` register their full and smoke
configurations here; the launchers resolve them by name (``--arch``).
"""
from repro.config.base import (
    AttentionKind,
    MeshConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    PIRConfig,
    RunConfig,
    ShapeConfig,
    SSMConfig,
)

__all__ = [
    "AttentionKind",
    "MeshConfig",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "OptimizerConfig",
    "PIRConfig",
    "RunConfig",
    "ShapeConfig",
    "SSMConfig",
]
