"""Bit/byte <-> uint32-word packing helpers.

The PIR database stores records as uint32 words (the TPU's natural integer
lane width); DPF selection vectors are packed 32 bits/word for the bit-sliced
kernels. All functions are jnp-traceable.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def bytes_to_words(b: jnp.ndarray) -> jnp.ndarray:
    """``[..., 4k] uint8 -> [..., k] uint32`` (little-endian)."""
    if b.shape[-1] % 4:
        raise ValueError(f"byte length {b.shape[-1]} not a multiple of 4")
    b = b.astype(jnp.uint32).reshape(b.shape[:-1] + (b.shape[-1] // 4, 4))
    sh = jnp.asarray([0, 8, 16, 24], dtype=jnp.uint32)
    return jnp.sum(b << sh, axis=-1, dtype=jnp.uint32)


def words_to_bytes(w: jnp.ndarray) -> jnp.ndarray:
    """``[..., k] uint32 -> [..., 4k] uint8`` (little-endian)."""
    sh = jnp.asarray([0, 8, 16, 24], dtype=jnp.uint32)
    b = (w[..., None] >> sh) & jnp.uint32(0xFF)
    return b.astype(jnp.uint8).reshape(w.shape[:-1] + (w.shape[-1] * 4,))


def pack_bits_to_words(bits: jnp.ndarray) -> jnp.ndarray:
    """``[..., 32k] {0,1} -> [..., k] uint32``; bit j of word w = bit 32w+j."""
    n = bits.shape[-1]
    if n % 32:
        raise ValueError(f"bit length {n} not a multiple of 32")
    bits = bits.astype(jnp.uint32).reshape(bits.shape[:-1] + (n // 32, 32))
    sh = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << sh, axis=-1, dtype=jnp.uint32)


def unpack_words_to_bits(words: jnp.ndarray) -> jnp.ndarray:
    """``[..., k] uint32 -> [..., 32k] uint32 in {0,1}``."""
    sh = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> sh) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,))


def words_to_bytes_i8(w: jnp.ndarray) -> jnp.ndarray:
    """``[..., k] uint32 -> [..., 4k] int8`` byte view (little-endian).

    The MXU-facing form of :func:`words_to_bytes`: identical byte values,
    reinterpreted as int8 so the additive protocols' GEMM contracts them
    natively (only the value mod 256 matters downstream).
    """
    return words_to_bytes(w).astype(jnp.int8)


def words_to_bytes_i32(w: jnp.ndarray) -> jnp.ndarray:
    """``[..., k] uint32 -> [..., 4k] int32`` byte view (little-endian).

    The LWE-facing form: byte *values* 0..255 widened (not reinterpreted)
    to int32, because the mod-2^32 GEMM needs the true byte magnitudes —
    the int8 view's negative reinterpretation of bytes >= 128 would offset
    the Z_q contraction by a non-multiple of q.
    """
    return words_to_bytes(w).astype(jnp.int32)


def np_bytes_to_words(b: np.ndarray) -> np.ndarray:
    """Host-side (numpy) variant for DB construction."""
    assert b.shape[-1] % 4 == 0
    return b.reshape(b.shape[:-1] + (-1, 4)).astype(np.uint32) @ (
        np.uint32(1) << np.arange(0, 32, 8, dtype=np.uint32)
    ).astype(np.uint32)


def np_words_to_bytes(w: np.ndarray) -> np.ndarray:
    """Host-side (numpy) inverse of :func:`np_bytes_to_words`.

    Forces little-endian word order so the view matches the device packing
    on any host; returns a fresh contiguous uint8 array.
    """
    le = np.ascontiguousarray(w, dtype="<u4")
    return le.view(np.uint8).reshape(w.shape[:-1] + (w.shape[-1] * 4,))
