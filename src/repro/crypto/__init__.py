from repro.crypto.chacha import chacha_block, ggm_double, prg_bits, PRG_ROUNDS
from repro.crypto.packing import (
    pack_bits_to_words,
    unpack_words_to_bits,
    bytes_to_words,
    words_to_bytes,
)

__all__ = [
    "chacha_block",
    "ggm_double",
    "prg_bits",
    "PRG_ROUNDS",
    "pack_bits_to_words",
    "unpack_words_to_bits",
    "bytes_to_words",
    "words_to_bytes",
]
