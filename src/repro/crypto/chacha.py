"""ChaCha-style ARX pseudorandom generator, vectorized for the TPU VPU.

Why not AES (the paper's PRF)
-----------------------------
IM-PIR evaluates the GGM tree on the *host* CPU because UPMEM DPUs have no
crypto acceleration and AES's byte-table / GF(2^8) structure is hostile to
32-bit RISC cores (paper §3.2). A TPU has no AES unit either — but its VPU is
a very wide 32-bit integer SIMD engine, which is exactly the shape of an
ARX (add-rotate-xor) cipher. We therefore instantiate the DPF's length-
doubling PRG with a 12-round ChaCha permutation over 32-bit lanes: every
operation below is a `jnp.uint32` add/xor/rotate that vectorizes over an
arbitrary batch of GGM nodes. This moves DPF evaluation on-device and
eliminates the paper's post-offload bottleneck (DPF eval = 76.45% of query
latency, Table 1).

An AES-128 reference (FIPS-197, pure numpy) lives in ``repro.crypto.aes_ref``
to document construction parity; the PRG is pluggable via ``rounds``.

Layout
------
A GGM seed is 128 bits = ``[..., 4] uint32``. One ChaCha block keyed by the
seed yields 512 bits; the DPF consumes:

  out[0:4]  -> left child seed      out[4:8]  -> right child seed
  out[8]&1  -> left control bit     out[9]&1  -> right control bit
  out[10:]  -> payload-conversion words (additive modes)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# "expa nd 3 2-by te k" — the standard ChaCha constants.
SIGMA = np.array([0x61707865, 0x3320646E, 0x79622D32, 0x6B206574], dtype=np.uint32)

PRG_ROUNDS = {"chacha8": 8, "chacha12": 12, "chacha20": 20}


def _rotl32(x: jax.Array, n: int) -> jax.Array:
    return (x << np.uint32(n)) | (x >> np.uint32(32 - n))


def _quarter(a, b, c, d):
    a = a + b
    d = _rotl32(d ^ a, 16)
    c = c + d
    b = _rotl32(b ^ c, 12)
    a = a + b
    d = _rotl32(d ^ a, 8)
    c = c + d
    b = _rotl32(b ^ c, 7)
    return a, b, c, d


def _double_round(x):
    # column rounds
    x[0], x[4], x[8], x[12] = _quarter(x[0], x[4], x[8], x[12])
    x[1], x[5], x[9], x[13] = _quarter(x[1], x[5], x[9], x[13])
    x[2], x[6], x[10], x[14] = _quarter(x[2], x[6], x[10], x[14])
    x[3], x[7], x[11], x[15] = _quarter(x[3], x[7], x[11], x[15])
    # diagonal rounds
    x[0], x[5], x[10], x[15] = _quarter(x[0], x[5], x[10], x[15])
    x[1], x[6], x[11], x[12] = _quarter(x[1], x[6], x[11], x[12])
    x[2], x[7], x[8], x[13] = _quarter(x[2], x[7], x[8], x[13])
    x[3], x[4], x[9], x[14] = _quarter(x[3], x[4], x[9], x[14])
    return x


@partial(jax.jit, static_argnames=("rounds", "counter"))
def chacha_block(key4: jax.Array, *, counter: int = 0, rounds: int = 12) -> jax.Array:
    """ChaCha block function keyed by a 128-bit seed.

    key4: ``[..., 4] uint32``. The 128-bit seed fills both key halves of the
    ChaCha state (the "HChaCha-style" 128-bit-key layout); the counter and
    nonce words are compile-time constants so distinct GGM uses (child
    expansion vs payload conversion) are domain-separated by ``counter``.

    Returns ``[..., 16] uint32`` — one 512-bit block per seed.
    """
    if rounds % 2:
        raise ValueError("rounds must be even")
    key4 = key4.astype(jnp.uint32)
    batch = key4.shape[:-1]
    const = jnp.broadcast_to(jnp.asarray(SIGMA), batch + (4,))
    ctr = jnp.broadcast_to(
        jnp.asarray([counter & 0xFFFFFFFF, 0x5049522D, 0x494D5049, 0x52212121],
                    dtype=jnp.uint32),
        batch + (4,),
    )
    state = jnp.concatenate([const, key4, key4, ctr], axis=-1)
    # Rolled (not Python-unrolled) double rounds: GGM evaluation instantiates
    # this block once per tree level inside scans/vmaps, and the unrolled ARX
    # graph made XLA compile times grow superlinearly in rounds × levels
    # (eval_bits_batch at log_n=6 took ~45 s to compile on CPU). The loop
    # carry is the 16-row state tuple; op order — hence the keystream — is
    # bit-identical to the unrolled form.
    x = jax.lax.fori_loop(
        0, rounds // 2,
        lambda _, xs: tuple(_double_round(list(xs))),
        tuple(state[..., i] for i in range(16)))
    out = jnp.stack(x, axis=-1) + state
    return out


def ggm_double(seeds: jax.Array, *, rounds: int = 12):
    """GGM node doubling: ``[n, 4]u32 -> (sL, tL, sR, tR)``.

    The core PRG of the DPF tree (paper Eq. 3's ``PRF_s``), vectorized over
    all nodes of one level. Returns left/right child seeds ``[n, 4]`` and
    control bits ``[n]`` (uint32 in {0, 1}).
    """
    blk = chacha_block(seeds, counter=0, rounds=rounds)
    s_l = blk[..., 0:4]
    s_r = blk[..., 4:8]
    t_l = blk[..., 8] & np.uint32(1)
    t_r = blk[..., 9] & np.uint32(1)
    return s_l, t_l, s_r, t_r


def prg_bits(seeds: jax.Array, n_words: int, *, rounds: int = 12) -> jax.Array:
    """Payload-conversion PRG: expand each seed to ``n_words`` uint32 words.

    Domain-separated from child expansion by the block counter. Used to mask
    multi-word payload shares (``convert`` in the DPF literature).
    """
    outs = []
    need = n_words
    ctr = 1
    while need > 0:
        blk = chacha_block(seeds, counter=ctr, rounds=rounds)
        take = min(16, need)
        outs.append(blk[..., :take])
        need -= take
        ctr += 1
    return jnp.concatenate(outs, axis=-1) if len(outs) > 1 else outs[0]
