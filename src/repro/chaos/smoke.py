"""Chaos smoke: two seeded fault scenarios on the real serve stack.

Run:  PYTHONPATH=src python -m repro.chaos --smoke

Both scenarios drive a 2-replica LWE fleet (the cheap-compile
configuration the replica demos use) through the front-tier router with
a :class:`~repro.chaos.ChaosInjector` wired into one replica, and assert
the two halves of the robustness contract:

* **detection** — the injected fault surfaces as the right signal
  (``InjectedFault`` for a kill, ``IntegrityError`` for a corrupted
  answer share), never as a silently wrong record;
* **recovery** — every query submitted before the fault still resolves
  byte-correct against the plaintext oracle, served by the surviving
  replica after failover.

Scenario A injects a ``kill`` at the ``scheduler.dispatch`` seam of
replica r0 (its session thread dies mid-batch). Scenario B runs the
checksummed config (``pir-smoke-chk``) and injects a ``corrupt`` at the
``replica.serve_step`` seam: verified reconstruction raises
``IntegrityError``, the router quarantines r0 as unfit to serve, and
resubmits to r1. Scripts/ci_check.sh runs this as a gate.
"""
from __future__ import annotations

import numpy as np

from repro.chaos import ChaosInjector, FaultEvent, FaultPlan


def _fleet(cfg, injector, rng):
    """2 replicas behind a router; the injector is wired into r0 only."""
    from repro.core import pir
    from repro.replica import Router, ServeReplica
    from repro.runtime.elastic import carve_submeshes

    db_host = pir.make_database(rng, cfg.n_items, cfg.item_bytes)
    oracle = pir.db_as_bytes(db_host).copy()
    meshes = carve_submeshes(2, model_axis=1)
    router = Router(rng=np.random.default_rng(1), base_delay=0.01,
                    max_delay=0.2, chaos=injector)
    kw = dict(n_queries=4, buckets=(4,), max_wait_s=0.002)
    router.attach(ServeReplica("r0", db_host, cfg, meshes[0],
                               chaos=injector, **kw))
    router.attach(ServeReplica("r1", db_host, cfg, meshes[1], **kw))
    return router, oracle


def _drive_pinned(router, oracle, indices, deadline_s=240.0):
    """Pin a session onto the victim replica, offer the load, assert
    every answer resolves byte-correct (possibly after failover)."""
    session = router.session("chaos-smoke")
    session.replica = "r0"
    futs = [router.submit(i, session=session, deadline_s=deadline_s)
            for i in indices]
    for i, f in zip(indices, futs):
        ans = np.asarray(f.result())
        assert np.array_equal(ans, oracle[i]), \
            f"D[{i}] wrong after recovery — silent corruption"
    return futs


def _teardown(router):
    for r in list(router.replicas.values()):
        if not r.lost:
            r.close()


def scenario_kill() -> dict:
    """A: seeded kill of r0's dispatch; failover must lose nothing."""
    from repro.configs.pir import PIR_SMOKE_REPL

    plan = FaultPlan(seed=7, events=(
        FaultEvent(seam="scheduler.dispatch", action="kill",
                   target="r0", at=0),))
    injector = ChaosInjector(plan)
    router, oracle = _fleet(PIR_SMOKE_REPL, injector,
                            np.random.default_rng(0))
    try:
        indices = [3, 999, 42, PIR_SMOKE_REPL.n_items - 1, 17, 2048, 0, 7]
        _drive_pinned(router, oracle, indices)
        assert "kill" in injector.fired_actions("scheduler.dispatch"), \
            "the planned kill never fired"
        assert router.failovers > 0, "kill detected but no failover ran"
        return {"fired": injector.fired_actions(),
                "failovers": router.failovers,
                "answers": len(indices)}
    finally:
        _teardown(router)


def scenario_corrupt() -> dict:
    """B: corrupt one answer share on the checksummed config; verified
    reconstruction must raise IntegrityError (detection), the router
    must quarantine r0 and re-serve on r1 (recovery)."""
    from repro.configs.pir import PIR_SMOKE_CHK

    plan = FaultPlan(seed=11, events=(
        FaultEvent(seam="replica.serve_step", action="corrupt",
                   target="r0", at=0),))
    injector = ChaosInjector(plan)
    router, oracle = _fleet(PIR_SMOKE_CHK, injector,
                            np.random.default_rng(2))
    try:
        indices = [5, 1234, PIR_SMOKE_CHK.n_items - 1, 64]
        _drive_pinned(router, oracle, indices)
        assert "corrupt" in injector.fired_actions("replica.serve_step"), \
            "the planned corruption never fired"
        assert router.integrity_failures > 0, \
            "corruption fired but reconstruction never raised " \
            "IntegrityError (silent corruption path)"
        assert "r0" in router.registry.suspects(), \
            "integrity failure must quarantine the corrupting replica"
        return {"fired": injector.fired_actions(),
                "integrity_failures": router.integrity_failures,
                "suspects": router.registry.suspects(),
                "answers": len(indices)}
    finally:
        _teardown(router)


def main() -> int:
    a = scenario_kill()
    print(f"chaos smoke A (kill@scheduler.dispatch): "
          f"{a['answers']} answers byte-correct after "
          f"{a['failovers']} failovers, fired={a['fired']}")
    b = scenario_corrupt()
    print(f"chaos smoke B (corrupt@replica.serve_step, checksummed): "
          f"{b['answers']} answers byte-correct, "
          f"integrity_failures={b['integrity_failures']}, "
          f"quarantined={b['suspects']}")
    print("chaos smoke OK: detection + recovery verified on both "
          "scenarios")
    return 0
