"""CLI: ``python -m repro.chaos --smoke`` runs the seeded fault
scenarios (kill + share corruption) against the real serve stack."""
from __future__ import annotations

import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.chaos",
        description="chaos-plane smoke scenarios (repro/chaos/smoke.py)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the seeded kill + corruption scenarios")
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("nothing to do (pass --smoke)")
    from repro.chaos.smoke import main as smoke_main
    return smoke_main()


if __name__ == "__main__":
    raise SystemExit(main())
