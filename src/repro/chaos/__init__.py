"""The chaos plane (DESIGN.md §12): deterministic, replayable fault injection.

A ``FaultPlan`` is a *seeded schedule* of ``FaultEvent``s; a
``ChaosInjector`` executes it against named **seams** — fixed hook points
the serve stack consults when (and only when) an injector is wired in:

==================== ======================================================
seam                 where it fires
==================== ======================================================
scheduler.dispatch   ``QueryScheduler._launch``, before a batch dispatches
replica.serve_step   the facade dispatch closure, on the answer shares
router.resubmit      ``Router._dispatch`` on failover/hedge resubmits
db.publish           ``ShardedDatabase.publish`` / ``Router.publish`` fan-out
heartbeat            the registry-wired heartbeat delivery
plan_cache.load      ``engine.cache.PlanCache`` disk load
==================== ======================================================

Actions: ``corrupt`` (flip bits in one answer share), ``kill`` (raise
:class:`InjectedFault` at the seam), ``stall``/``delay`` (sleep
``duration_s``), ``drop`` (suppress the seam's effect — a heartbeat, a
publish fan-out, a cache load). Matching is by visit count: the injector
keeps a per-``(seam, target)`` counter and an event fires on visits
``[at, at + count)``. Everything derives from the plan's single seed —
replaying the same plan against the same workload reproduces the same
failure scenario bit-for-bit, which is what makes the chaos property
tests and the ``python -m repro.chaos --smoke`` scenarios debuggable.

The injector is *passive*: code paths that were never handed one pay a
single ``is None`` check. No repro module imports are needed here, so any
plane can depend on chaos without cycles.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["ACTIONS", "SEAMS", "ChaosInjector", "FaultEvent", "FaultPlan",
           "InjectedFault"]

#: the named hook points (see module docstring / DESIGN.md §12)
SEAMS = ("scheduler.dispatch", "replica.serve_step", "router.resubmit",
         "db.publish", "heartbeat", "plan_cache.load")

#: what an event does when it fires
ACTIONS = ("corrupt", "kill", "stall", "drop", "delay")


class InjectedFault(RuntimeError):
    """A chaos-injected failure (the ``kill`` action). Deliberately a
    ``RuntimeError`` so it rides the same retry/failover paths a real
    replica crash would."""


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    seam        which hook point (one of :data:`SEAMS`)
    action      one of :data:`ACTIONS`
    target      scope id (replica id, subscriber id, ...); ``None``
                matches any target at that seam
    at          0-based visit count of (seam, target) at which it fires
    count       fires for this many consecutive visits (drop N heartbeats)
    duration_s  sleep length for ``stall``/``delay``
    """
    seam: str
    action: str
    target: Optional[str] = None
    at: int = 0
    count: int = 1
    duration_s: float = 0.0

    def __post_init__(self):
        if self.seam not in SEAMS:
            raise ValueError(f"unknown seam {self.seam!r}; known: {SEAMS}")
        if self.action not in ACTIONS:
            raise ValueError(
                f"unknown action {self.action!r}; known: {ACTIONS}")
        if self.at < 0 or self.count < 1 or self.duration_s < 0:
            raise ValueError(f"degenerate fault event: {self}")


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, immutable fault schedule — the unit of replay.

    The seed drives both :meth:`random` (which events exist) and the
    injector's corruption randomness (which bits flip), so a plan value
    fully determines the failure scenario.
    """
    seed: int
    events: Tuple[FaultEvent, ...] = ()

    @classmethod
    def random(cls, seed: int, *,
               targets: Sequence[Optional[str]] = (None,),
               seams: Sequence[str] = ("replica.serve_step", "heartbeat",
                                       "scheduler.dispatch"),
               actions: Sequence[str] = ("corrupt", "kill", "drop"),
               n_events: int = 4, max_at: int = 8) -> "FaultPlan":
        """Draw a reproducible plan: same arguments -> same schedule."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(int(n_events)):
            seam = seams[int(rng.integers(len(seams)))]
            action = actions[int(rng.integers(len(actions)))]
            if action == "corrupt":
                seam = "replica.serve_step"   # the only share-bearing seam
            elif action == "drop":
                seam = "heartbeat" if seam == "replica.serve_step" else seam
            target = targets[int(rng.integers(len(targets)))]
            events.append(FaultEvent(
                seam=seam, action=action, target=target,
                at=int(rng.integers(max_at))))
        return cls(seed=seed, events=tuple(events))


@dataclass
class _Fired:
    """One log entry: what fired, where, on which visit."""
    seam: str
    target: Optional[str]
    action: str
    visit: int


class ChaosInjector:
    """Executes a :class:`FaultPlan` at the serve stack's chaos seams.

    Thread-safe enough for the serve stack's usage (counters are bumped
    under the GIL from short critical paths); determinism comes from the
    per-(seam, target) visit counters — concurrency across *different*
    targets cannot reorder a target's own schedule.
    """

    def __init__(self, plan: FaultPlan, *,
                 sleep: Callable[[float], None] = time.sleep):
        self.plan = plan
        self.sleep = sleep
        self.rng = np.random.default_rng(plan.seed)
        self._counts: dict = {}
        self.fired: List[_Fired] = []

    # -- core matching --------------------------------------------------

    def fire(self, seam: str, target: Optional[str] = None
             ) -> Tuple[FaultEvent, ...]:
        """Consume one visit of ``(seam, target)`` and return the events
        that fire on it (logged in :attr:`fired`); sleeps out any
        ``stall``/``delay`` durations. Interpretation of ``kill`` /
        ``drop`` / ``corrupt`` is the caller's (or a helper's) job."""
        key = (seam, target)
        n = self._counts.get(key, 0)
        self._counts[key] = n + 1
        hits = tuple(
            ev for ev in self.plan.events
            if ev.seam == seam
            and (ev.target is None or ev.target == target)
            and ev.at <= n < ev.at + ev.count)
        for ev in hits:
            self.fired.append(_Fired(seam, target, ev.action, n))
            if ev.action in ("stall", "delay") and ev.duration_s > 0:
                self.sleep(ev.duration_s)
        return hits

    # -- seam helpers ----------------------------------------------------

    def visit(self, seam: str, target: Optional[str] = None
              ) -> Tuple[FaultEvent, ...]:
        """``fire`` + raise :class:`InjectedFault` on a ``kill`` event —
        the default hook for seams whose only hard failure is a crash."""
        hits = self.fire(seam, target)
        for ev in hits:
            if ev.action == "kill":
                raise InjectedFault(
                    f"chaos kill at {seam}"
                    f"{'' if target is None else ':' + str(target)}")
        return hits

    def should_drop(self, seam: str, target: Optional[str] = None) -> bool:
        """``fire`` + report whether the seam's effect should be
        suppressed this visit (heartbeat delivery, publish fan-out)."""
        return any(ev.action == "drop" for ev in self.fire(seam, target))

    def corrupt_shares(self, seam: str, target: Optional[str], shares):
        """``visit`` + on a ``corrupt`` event, flip bits in one share.

        The corruption XORs one element of one share with the
        repeated-byte mask ``0x80...80`` (top bit of every byte). That
        choice is deliberate — it is detectable under *every* registered
        share algebra: it flips payload bits under XOR folding, shifts a
        byte by 128 mod 256 under additive Z_256 shares, and shifts an
        LWE answer's residual by ~Delta/2 (never a clean multiple of
        Delta, which would alias to a valid plaintext). Which share and
        which element are drawn from the plan's seeded RNG.
        """
        hits = self.visit(seam, target)
        if not any(ev.action == "corrupt" for ev in hits):
            return shares
        out = list(shares)
        k = int(self.rng.integers(len(out)))
        arr = np.array(np.asarray(out[k]))          # host copy, mutable
        flat = arr.reshape(-1)
        pos = int(self.rng.integers(flat.size))
        u = flat.view(np.dtype(f"u{arr.dtype.itemsize}"))
        mask = int.from_bytes(b"\x80" * arr.dtype.itemsize, "little")
        u[pos] ^= np.asarray(mask, u.dtype)
        out[k] = arr
        return tuple(out)

    # -- introspection ---------------------------------------------------

    def fired_actions(self, seam: Optional[str] = None) -> List[str]:
        """Actions that fired (optionally at one seam), in order."""
        return [f.action for f in self.fired
                if seam is None or f.seam == seam]
