"""Client + reference-server PIR primitives (paper §2.3, §3, Algorithm 1).

Roles
-----
Client:  ``query_gen`` (Gen + per-party key split, dispatched through the
         protocol registry — ``core/protocol.py``), ``reconstruct_*``
         primitives (r1 ⊕ r2 / r1 + r2).
Server:  ``answer_*`` — the all-for-one scan. Single-device reference forms
         live here; the sharded production form (shard_map over the
         data=clusters / model=DB-shards mesh) lives in ``core.server``,
         parameterized by a registered ``PIRProtocol``.

Share schemes (see ``core/protocol.py`` for the full protocol plane)
--------------------------------------------------------------------
xor-dpf-2       paper-faithful: selection bits t(j) weight an XOR fold over
                DB rows (Figure 2 / Algorithm 1's dpXOR). Bit-exact.
additive-dpf-2  Z_256 byte shares; the batched-query form is an int8 matrix
                product (queries × DB) that the MXU executes natively — the
                beyond-paper operational-intensity lever (DESIGN.md §2).
xor-dpf-k       k-server XOR shares (beyond-paper; DESIGN.md §7.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import PIRConfig
from repro.core import dpf

U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Database
# ---------------------------------------------------------------------------

def make_database(rng: np.random.Generator, n_items: int, item_bytes: int = 32,
                  *, checksum: bool = False) -> np.ndarray:
    """Random PIR DB of ``n_items`` records, each ``item_bytes`` long.

    Mirrors the paper's evaluation DB (random 32-byte/256-bit hashes, §5.2).
    Stored as uint32 words: ``[N, item_bytes // 4]``. ``checksum=True``
    appends the verified-reconstruction checksum column (one u32 per row,
    ``repro.db.spec.row_checksum``) — the *stored* layout checksummed
    configs serve from; eager tests and oracles use it to build share
    inputs that match what the serve stack holds.
    """
    if item_bytes % 4:
        raise ValueError("item_bytes must be a multiple of 4")
    words = rng.integers(0, 1 << 32, size=(n_items, item_bytes // 4),
                         dtype=np.uint32)
    if checksum:
        from repro.db.spec import row_checksum
        words = np.concatenate(
            [words, row_checksum(words)[:, None]], axis=1)
    return words


def db_as_bytes(db_words: np.ndarray) -> np.ndarray:
    """[N, W] uint32 -> [N, 4W] uint8 view for the int8-matmul path.

    Compat wrapper over the database plane's host packing primitive
    (works on any [R, W] slice, not just full power-of-two DBs);
    production code keeps the byte view device-resident
    (``ShardedDatabase.view("bytes")``) instead of re-packing on the host.
    """
    from repro.crypto.packing import np_words_to_bytes
    return np_words_to_bytes(np.asarray(db_words))


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

@dataclass
class Query:
    """A client query: one key pytree per party (k of them).

    Two-server schemes keep the familiar shape ``keys == (k0, k1)``; the
    k-server protocols extend the tuple (one entry per non-colluding party).
    """
    index: int
    keys: Tuple[dpf.DPFKey, ...]


def query_gen(rng: np.random.Generator, index: int, cfg: PIRConfig) -> Query:
    """GENERATEANDSENDKEYS (Algorithm 1 ①-②), via the config's protocol.

    Thin compat wrapper over ``core.protocol``: the registered
    ``PIRProtocol`` named by ``cfg.protocol`` owns key generation.
    """
    from repro.core import protocol as protocol_mod
    proto = protocol_mod.for_config(cfg)
    return Query(index=index, keys=proto.query_gen(rng, index, cfg))


def batch_queries(rng: np.random.Generator, indices: Sequence[int],
                  cfg: PIRConfig) -> Tuple[dpf.DPFKey, ...]:
    """Generate and stack a batch of queries into per-party batched pytrees.

    Returns one batched key pytree per party (two for the 2-server
    protocols, ``cfg.n_servers`` for ``xor-dpf-k``).
    """
    qs = [query_gen(rng, i, cfg) for i in indices]
    n_parties = len(qs[0].keys)
    return tuple(dpf.stack_keys([q.keys[p] for q in qs])
                 for p in range(n_parties))


def reconstruct_xor(r0: jax.Array, r1: jax.Array) -> jax.Array:
    """D[i] = r1 XOR r2 (Algorithm 1, client ⑦)."""
    return jnp.bitwise_xor(r0, r1)


def reconstruct_additive(r0: jax.Array, r1: jax.Array) -> jax.Array:
    """D[i] bytes = (r0 + r1) mod 256 (int32 partial sums from the matmul)."""
    return ((r0.astype(jnp.int32) + r1.astype(jnp.int32)) % 256).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Server: reference (single-shard) answer paths
# ---------------------------------------------------------------------------

def xor_fold(rows: jax.Array, axis: int = 0) -> jax.Array:
    """XOR-reduce along ``axis`` (the paper's MASTERXOR stage)."""
    return jax.lax.reduce(rows, np.uint32(0), jax.lax.bitwise_xor, (axis,))


def dpxor(db_words: jax.Array, bits: jax.Array) -> jax.Array:
    """Select-XOR scan: r = ⊕_{j : bits[j]=1} D[j]  (Algorithm 1 ④-⑤).

    Pure-jnp reference; the Pallas kernel (kernels/dpxor.py) implements the
    tiled two-stage parallel-reduction form of the same contraction.
    """
    masked = jnp.where((bits != 0)[:, None], db_words, U32(0))
    return xor_fold(masked, 0)


def answer_xor(db_words: jax.Array, key: dpf.DPFKey) -> jax.Array:
    """Full single-server answer, one query: Eval + dpXOR."""
    n = db_words.shape[0]
    log_n = (n - 1).bit_length()
    _, t = dpf.eval_range(key, 0, log_n)
    return dpxor(db_words, t[:n])


def answer_xor_batch(db_words: jax.Array, keys: dpf.DPFKey) -> jax.Array:
    """Batched XOR answers: [Q, W]."""
    return jax.vmap(lambda k: answer_xor(db_words, k))(keys)


def answer_additive_matmul(db_bytes_i8: jax.Array, shares_u8: jax.Array
                           ) -> jax.Array:
    """Batched additive answers as one int8 GEMM.

    shares_u8: [Q, N] Z_256 shares; db_bytes_i8: [N, L] DB bytes (int8 view).
    Returns int32 partial results [Q, L]; only their value mod 256 matters,
    and int32 wraparound preserves it (2^8 | 2^32).
    """
    return jax.lax.dot_general(
        shares_u8.astype(jnp.int8), db_bytes_i8.astype(jnp.int8),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def answer_additive_batch(db_bytes_i8: jax.Array, keys: dpf.DPFKey
                          ) -> jax.Array:
    """Eval byte shares for each key then contract against the DB."""
    n = db_bytes_i8.shape[0]
    log_n = (n - 1).bit_length()
    shares = dpf.eval_bytes_batch(keys, 0, log_n)[:, :n]
    return answer_additive_matmul(db_bytes_i8, shares)


# ---------------------------------------------------------------------------
# Phase-split forms (paper Table 1 instrumentation)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("log_n",))
def phase_eval_bits(keys: dpf.DPFKey, log_n: int) -> jax.Array:
    """Phase ②: DPF evaluation only — materializes Eval(k, ·) bit vectors."""
    return dpf.eval_bits_batch(keys, 0, log_n)


@jax.jit
def phase_dpxor(db_words: jax.Array, bits: jax.Array) -> jax.Array:
    """Phase ④-⑤: dpXOR only, given precomputed selection bits [Q, N]."""
    return jax.vmap(lambda b: dpxor(db_words, b))(bits)
