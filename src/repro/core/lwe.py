"""LWE machinery for the single-server SimplePIR-style protocol (DESIGN.md §10).

Scheme (linear SimplePIR over DB rows)
--------------------------------------
Everything lives in Z_q with q = 2^32, so "mod q" is native int32/uint32
wraparound and the server's hot loop is a plain int32 GEMM.

  client secret   s  in Z_q^n
  public matrix   A  in Z_q^{N x n}   -- regenerated from ``a_seed`` by both
                                         sides; NEVER shipped
  query           ct = A.s + e + Delta * onehot(alpha)   in Z_q^N
  server answer   ans = ct^T . D     (D = byte matrix [N, item_bytes], 0..255)
  server hint     H  = A^T . D       in Z_q^{n x item_bytes}
  reconstruct     noisy = ans - s^T.H = e^T.D + Delta * D[alpha]
                  m = round(noisy / Delta) mod p        (modulus switch)

with plaintext modulus p = 256 (one DB byte per slot) and scale
Delta = q / p = 2^24. Reconstruction is exact iff the accumulated noise
|e^T.d| stays below Delta/2 = q/(2p) for every DB column d; because
q = Delta * p exactly, the rounding also absorbs the negative wrap
(noise in (-Delta/2, 0) decodes to the same byte).

Checkable invariants, not comments
----------------------------------
``LWEParams.validate(n_items)`` asserts the subgaussian tail bound

    TAIL * sigma * (p - 1) * sqrt(N)  <  q / (2 p)

(e^T.d is a sigma-subgaussian combination with ||d||_2 <= (p-1) sqrt(N)),
so a parameter set that cannot decode a given DB size *raises* instead of
silently corrupting records. ``params_for`` picks the first table row whose
``max_items`` covers the DB and re-validates it.

The shipped parameters are demonstration-grade: they make correctness and
the noise budget *testable* on this container, they are not a security
review (see DESIGN.md §10 for what a production deployment would change).

Arithmetic notes
----------------
Host math runs in numpy uint64: 2^32 | 2^64, so uint64 wraparound preserves
congruence mod q and a final ``& 0xFFFFFFFF`` lands in Z_q. Device math uses
int32 ``dot_general`` with ``preferred_element_type=int32`` — XLA's int32
accumulate wraps mod 2^32 natively, i.e. it *is* the Z_q contraction.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

LWE_Q = 1 << 32          # ciphertext modulus: native 32-bit wraparound
LWE_P = 256              # plaintext modulus: one DB byte per slot
TAIL = 8.0               # subgaussian tail factor for the noise bound

_MASK = np.uint64(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LWEParams:
    """One LWE parameter set; all correctness conditions are methods.

    n        secret dimension (hint rows)
    sigma    Gaussian error stddev (rounded to integers at sample time)
    p        plaintext modulus; must divide q so Delta = q/p is exact
    a_seed   PRG seed both sides use to regenerate A (never shipped)
    """
    n: int
    sigma: float
    p: int = LWE_P
    a_seed: int = 0x1317

    @property
    def q(self) -> int:
        return LWE_Q

    @property
    def delta(self) -> int:
        """Plaintext scale Delta = q/p (exact by the q % p == 0 invariant)."""
        return LWE_Q // self.p

    @property
    def noise_budget(self) -> int:
        """Decoding succeeds iff |accumulated noise| < q/(2p) = Delta/2."""
        return LWE_Q // (2 * self.p)

    def noise_bound(self, n_items: int) -> float:
        """Tail bound on |e^T.d|: TAIL * sigma * (p-1) * sqrt(N)."""
        return TAIL * self.sigma * (self.p - 1) * float(np.sqrt(n_items))

    def validate(self, n_items: int) -> "LWEParams":
        """Raise unless this parameter set decodes a DB of ``n_items`` rows.

        This IS the correctness-bound assertion the protocol relies on:
        any (n, q, p, sigma) combination that reaches the serve path has
        passed it, so modulus switching is exact, not approximate.
        """
        if LWE_Q % self.p:
            raise ValueError(f"p={self.p} must divide q=2^32 for exact Delta")
        if self.n < 1 or self.sigma <= 0:
            raise ValueError(f"degenerate LWE parameters: n={self.n}, "
                             f"sigma={self.sigma}")
        bound = self.noise_bound(n_items)
        if bound >= self.noise_budget:
            raise ValueError(
                f"LWE noise bound {bound:.3g} >= budget q/(2p)="
                f"{self.noise_budget} for N={n_items}: parameters "
                f"(n={self.n}, sigma={self.sigma}, p={self.p}) cannot "
                f"guarantee exact reconstruction at this DB size")
        return self


# Demonstration-grade ladder: (max_items, params). First row whose
# max_items covers the DB wins; each row satisfies validate(max_items).
# sigma shrinks as N grows to keep TAIL*sigma*(p-1)*sqrt(N) < 2^23 —
# production SimplePIR would instead use the sqrt(N) x sqrt(N) matrix
# layout to keep sigma cryptographically sized (DESIGN.md §10).
PARAM_TABLE: Tuple[Tuple[int, LWEParams], ...] = (
    (1 << 16, LWEParams(n=128, sigma=6.4)),
    (1 << 20, LWEParams(n=512, sigma=3.2)),
    (1 << 25, LWEParams(n=1024, sigma=0.5)),
)


def params_for(n_items: int) -> LWEParams:
    """Select + validate the parameter row covering a DB of ``n_items``."""
    for max_items, params in PARAM_TABLE:
        if n_items <= max_items:
            return params.validate(n_items)
    raise ValueError(
        f"no LWE parameter set covers N={n_items} "
        f"(table max {PARAM_TABLE[-1][0]}); extend PARAM_TABLE with a "
        f"row that passes LWEParams.validate({n_items})")


# ---------------------------------------------------------------------------
# Public matrix A (seeded; regenerated, never shipped)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=8)
def _matrix_a_cached(a_seed: int, n: int, n_items: int) -> np.ndarray:
    rng = np.random.default_rng(a_seed)
    return rng.integers(0, LWE_Q, size=(n_items, n), dtype=np.uint64)


def matrix_a(params: LWEParams, n_items: int) -> np.ndarray:
    """A in Z_q^{N x n} as uint64 (values < 2^32), PRG-expanded from a_seed.

    Cached per (seed, n, N): the client and the hint builder regenerate the
    same matrix locally; it never crosses the wire.
    """
    return _matrix_a_cached(params.a_seed, params.n, n_items)


# ---------------------------------------------------------------------------
# Ciphertext pytree + client state
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class LWECiphertext:
    """Batched LWE query ciphertexts: ``ct`` is int32 ``[..., N]``.

    A pytree with (log_n, n) as static aux data so per-bucket jitted serve
    fns specialize on the DB size / parameter row, mirroring DPFKey.
    """
    ct: jax.Array          # [..., N] int32 (Z_q elements, two's complement)
    log_n: int
    n: int                 # secret dimension (for key_specs parity checks)

    def tree_flatten(self):
        return (self.ct,), (self.log_n, self.n)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(ct=leaves[0], log_n=aux[0], n=aux[1])


@dataclass
class LWEClientState:
    """Per-query client secret; stays on the client, never serialized."""
    s: np.ndarray          # [n] uint64 (values < 2^32)
    index: int


# ---------------------------------------------------------------------------
# Client: encrypt / reconstruct (host-side numpy, uint64 wraparound)
# ---------------------------------------------------------------------------

def encrypt(rng: np.random.Generator, index: int, n_items: int,
            params: LWEParams) -> Tuple[LWECiphertext, LWEClientState]:
    """ct = A.s + e + Delta*onehot(index) mod q, with fresh (s, e)."""
    if not 0 <= index < n_items:
        raise ValueError(f"index {index} out of range for N={n_items}")
    a = matrix_a(params, n_items)
    s = rng.integers(0, LWE_Q, size=params.n, dtype=np.uint64)
    e = np.rint(rng.normal(0.0, params.sigma, size=n_items)).astype(np.int64)
    ct = (a @ s) + e.astype(np.uint64)     # uint64 wrap preserves mod 2^32
    ct[index] += np.uint64(params.delta)
    ct32 = (ct & _MASK).astype(np.uint32).view(np.int32)
    state = LWEClientState(s=s, index=index)
    return LWECiphertext(ct=jnp.asarray(ct32), log_n=(n_items - 1).bit_length(),
                         n=params.n), state


def decode(answers_i32: np.ndarray, secrets: np.ndarray, hint: np.ndarray,
           params: LWEParams) -> Tuple[np.ndarray, np.ndarray]:
    """Modulus-switching reconstruction for a batch of queries.

    answers_i32: [Q, L] int32 server answers (ct^T.D mod q)
    secrets:     [Q, n] uint64 client secrets
    hint:        [n, L] hint matrix H = A^T.D mod q (uint64 values < 2^32)

    Returns (records [Q, L] uint8, noise [Q, L] int64) where ``noise`` is
    the recovered centered error e^T.D — callers assert it under the
    noise budget (the sampled form of ``LWEParams.validate``).
    """
    ans = np.asarray(answers_i32).view(np.uint32).astype(np.uint64)
    noisy = (ans - (secrets.astype(np.uint64) @ hint)) & _MASK
    delta = np.uint64(params.delta)
    m = (((noisy + delta // np.uint64(2)) // delta) % np.uint64(params.p))
    # centered residual noise: noisy - Delta*m, wrapped into (-q/2, q/2]
    err = (noisy - delta * m) & _MASK
    err = err.astype(np.int64)
    err[err >= LWE_Q // 2] -= LWE_Q
    return m.astype(np.uint8), err


# ---------------------------------------------------------------------------
# Server: hint oracle + device builders
# ---------------------------------------------------------------------------

def hint_np(params: LWEParams, db_bytes_u8: np.ndarray) -> np.ndarray:
    """Numpy hint oracle: H = A^T.D mod q as uint64 (values < 2^32)."""
    a = matrix_a(params, len(db_bytes_u8))
    return (a.T @ db_bytes_u8.astype(np.uint64)) & _MASK


def hint_build_fn(params: LWEParams, n_items: int):
    """Device hint builder: words view [N, W] uint32 -> H [n, L] int32.

    The contraction runs as an int32 GEMM (wraps mod 2^32 = mod q); A is
    regenerated host-side from the seed and closed over as an int32 view.
    """
    a_t = jnp.asarray(matrix_a(params, n_items).astype(np.uint32)
                      .view(np.int32).T)                  # [n, N]

    def build(words: jax.Array) -> jax.Array:
        from repro.crypto.packing import words_to_bytes
        d = words_to_bytes(words).astype(jnp.int32)       # [N, L] 0..255
        return jax.lax.dot_general(a_t, d, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)

    return build


def hint_delta_fn(params: LWEParams, n_items: int):
    """Device hint delta: H += A[rows]^T.(D_new - D_old) mod q.

    Exact (not approximate): int32 wraparound keeps every partial term in
    Z_q, so delta-updated hints match a full recompute byte-for-byte.
    ``rows`` must be deduplicated and unpadded — a repeated row would
    subtract its old value twice.
    """
    a32 = jnp.asarray(matrix_a(params, n_items).astype(np.uint32)
                      .view(np.int32))                    # [N, n]

    def delta(hint: jax.Array, rows: np.ndarray, old_words: jax.Array,
              new_words: jax.Array) -> jax.Array:
        from repro.crypto.packing import words_to_bytes
        d_old = words_to_bytes(old_words).astype(jnp.int32)
        d_new = words_to_bytes(new_words).astype(jnp.int32)
        a_rows = a32[jnp.asarray(np.asarray(rows, np.int32))]   # [R, n]
        upd = jax.lax.dot_general(a_rows.T, d_new - d_old,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return hint + upd      # int32 add wraps mod q

    return delta
