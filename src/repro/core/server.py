"""Sharded PIR server — the paper's Figure 5 dataflow on a TPU mesh.

Topology mapping (DESIGN.md §2):

  model axis  = the DPUs of one cluster. The DB is sharded over it in the
                paper's linear layout: shard d holds rows
                [d·B_d, (d+1)·B_d), B_d = N / |model|.
  data (and pod) axes = DPU clusters (paper §3.4): the DB is *replicated*
                across them and the query batch is sharded across them, so
                clusters answer disjoint queries in parallel.

Per-device step (inside shard_map) — Algorithm 1 with the host CPU removed:

  ① eval own DPF leaf range   (paper: host CPU + CPU→DPU copy ②③)
  ② select-XOR scan over the local DB rows            (paper: DPU dpXOR ④)
  ③ XOR all-reduce of 32 B subresults over `model`    (paper: DPU→CPU copy
     + host aggregation ⑤⑥ — here an all_gather+fold or a ppermute
     butterfly, selectable for the §Perf collective study)

Three server paths, lowered from the same factory:

  baseline   paper-faithful phase split: materialize Eval(k,·) bits, then
             scan. This is the §Perf *baseline* row.
  fused      chunked expand+scan (lax.scan over subtree blocks): selection
             bits never round-trip through HBM. Beyond-paper.
  matmul     batched queries as one int8 GEMM on the MXU (additive mode).
             Beyond-paper; turns the memory-bound scan compute-bound.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import PIRConfig
from repro.core import dpf
from repro.core.pir import dpxor, xor_fold

U32 = jnp.uint32


def _cluster_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _shard_axis(mesh: jax.sharding.Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def _axis_size(mesh, names) -> int:
    n = 1
    for a in names if isinstance(names, tuple) else (names,):
        if a is not None:
            n *= mesh.shape[a]
    return n


def key_specs(cfg: PIRConfig, n_queries: int) -> dpf.DPFKey:
    """ShapeDtypeStruct stand-ins for a batched key pytree (dry-run input)."""
    log_n = cfg.log_n
    mk = lambda *s: jax.ShapeDtypeStruct((n_queries,) + s, np.uint32)
    cw_final = None if cfg.mode == "xor" else mk(1)
    return dpf.DPFKey(
        party=0, log_n=log_n,
        root_seed=mk(4), cw_seed=mk(log_n, 4), cw_t=mk(log_n, 2),
        cw_final=cw_final, rounds=12,
    )


def _key_pspec(keys_like: dpf.DPFKey, cluster: Tuple[str, ...]) -> dpf.DPFKey:
    """PartitionSpecs matching the batched-key pytree (batch axis sharded)."""
    def spec(leaf):
        rank = len(leaf.shape)
        return P(cluster, *([None] * (rank - 1)))
    return jax.tree_util.tree_map(spec, keys_like)


def xor_allreduce_gather(partial_res: jax.Array, axis: str) -> jax.Array:
    """XOR all-reduce via all_gather + local fold (paper's host aggregation)."""
    gathered = jax.lax.all_gather(partial_res, axis)          # [P, ...]
    return xor_fold(gathered, 0)


def xor_allreduce_butterfly(partial_res: jax.Array, axis: str, size: int
                            ) -> jax.Array:
    """XOR all-reduce via a recursive-doubling butterfly (log P ppermutes).

    Collective-study alternative for §Perf: moves the same bytes in log P
    rounds of pairwise exchange instead of one P-way gather.
    """
    x = partial_res
    n = size
    shift = 1
    while shift < n:
        perm = [(i, i ^ shift) for i in range(n)]
        x = x ^ jax.lax.ppermute(x, axis, perm)
        shift <<= 1
    return x


@dataclass
class ServeFns:
    """Compiled server entry points for one party."""
    serve: Callable            # (db, keys) -> per-query answer shares
    mesh: jax.sharding.Mesh
    db_sharding: NamedSharding
    cfg: PIRConfig
    n_local_queries: int       # queries per cluster per step


def build_serve_fn(
    cfg: PIRConfig,
    mesh: jax.sharding.Mesh,
    *,
    n_queries: int,
    path: str = "baseline",          # baseline | fused | matmul
    chunk_log: int = 12,             # fused: leaves per expand+scan chunk
    collective: str = "gather",      # gather | butterfly
) -> ServeFns:
    """Build the sharded serve function for one step of ``n_queries``."""
    cluster = _cluster_axes(mesh)
    shard = _shard_axis(mesh)
    n_clusters = _axis_size(mesh, cluster)
    n_shards = _axis_size(mesh, shard)
    if n_queries % max(n_clusters, 1):
        raise ValueError(f"{n_queries} queries not divisible by {n_clusters} clusters")
    if cfg.n_items % max(n_shards, 1):
        raise ValueError("DB size not divisible by shard count")
    rows_local = cfg.n_items // n_shards
    log_local = int(math.log2(rows_local))
    if 1 << log_local != rows_local:
        raise ValueError("per-shard row count must be a power of two")
    words = cfg.item_bytes // 4

    db_spec = P(shard, None)
    keys_spec_builder = lambda keys: _key_pspec(keys, cluster)
    out_spec = P(cluster, None)

    def local_step(db_local, keys_local):
        sidx = jax.lax.axis_index(shard) if shard else 0

        if path == "baseline":
            # Phase ②③: materialize selection bits for the local leaf range
            # (the paper's host-side Eval + CPU→DPU share copy).
            bits = dpf.eval_bits_batch(keys_local, sidx, log_local)
            # Phase ④⑤: select-XOR scan (DPU dpXOR, two-stage reduction).
            partial_res = jax.vmap(lambda b: dpxor(db_local, b))(bits)

        elif path == "fused":
            # Chunked expand+scan: per chunk, descend to the chunk subtree
            # and fold its rows immediately — bits never hit HBM.
            n_chunks = max(1, rows_local >> chunk_log)
            clog = min(chunk_log, log_local)
            db_c = db_local.reshape(n_chunks, rows_local // n_chunks, words)

            def one_query(key):
                def body(acc, c):
                    blk = sidx * n_chunks + c
                    _, t = dpf.eval_range(key, blk, clog)
                    acc = acc ^ dpxor(db_c[c], t)
                    return acc, ()
                acc0 = jnp.zeros((words,), U32)
                acc, _ = jax.lax.scan(body, acc0, jnp.arange(n_chunks, dtype=jnp.uint32))
                return acc

            partial_res = jax.vmap(one_query)(keys_local)

        elif path == "matmul":
            # Additive Z_256 shares -> one int8 GEMM for the whole batch.
            shares = dpf.eval_bytes_batch(keys_local, sidx, log_local)
            db_bytes = _words_to_bytes_i8(db_local)
            part = jax.lax.dot_general(
                shares.astype(jnp.int8), db_bytes,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            if shard:
                part = jax.lax.psum(part, shard)     # additive: native psum
            return part

        else:
            raise ValueError(f"unknown path {path!r}")

        # Aggregation ⑤⑥: XOR all-reduce of 32 B subresults over shards.
        if shard:
            if collective == "butterfly":
                partial_res = xor_allreduce_butterfly(partial_res, shard, n_shards)
            else:
                partial_res = xor_allreduce_gather(partial_res, shard)
        return partial_res

    def serve(db, keys):
        ks = keys_spec_builder(keys)
        fn = jax.shard_map(
            local_step, mesh=mesh,
            in_specs=(db_spec, ks), out_specs=out_spec,
            check_vma=False,
        )
        return fn(db, keys)

    return ServeFns(
        serve=serve,
        mesh=mesh,
        db_sharding=NamedSharding(mesh, db_spec),
        cfg=cfg,
        n_local_queries=n_queries // max(n_clusters, 1),
    )


def _words_to_bytes_i8(w: jax.Array) -> jax.Array:
    sh = jnp.asarray([0, 8, 16, 24], dtype=U32)
    b = (w[..., None] >> sh) & U32(0xFF)
    return b.reshape(w.shape[:-1] + (w.shape[-1] * 4,)).astype(jnp.int8)


class PIRServer:
    """One logical PIR server (one of the n non-colluding parties).

    Owns the device-resident DB shards and a compiled serve step. The DB is
    preloaded once (paper §3.3 "database preloading": transfer cost excluded
    from query latency) and donated to devices.
    """

    def __init__(
        self,
        party: int,
        db_words: np.ndarray,
        cfg: PIRConfig,
        mesh: jax.sharding.Mesh,
        *,
        n_queries: int = 32,
        path: str = "baseline",
        collective: str = "gather",
    ):
        self.party = party
        self.cfg = cfg
        self.mesh = mesh
        self.path = path
        self.fns = build_serve_fn(
            cfg, mesh, n_queries=n_queries, path=path, collective=collective
        )
        self.db = jax.device_put(jnp.asarray(db_words), self.fns.db_sharding)
        self._jitted = jax.jit(self.fns.serve)

    def answer(self, keys: dpf.DPFKey) -> jax.Array:
        """Answer a batch of queries (keys stacked on the leading axis)."""
        return self._jitted(self.db, keys)

    def lower(self, n_queries: int):
        """Lower (no execution) against ShapeDtypeStructs — dry-run entry."""
        keys = key_specs(self.cfg, n_queries)
        db_spec = jax.ShapeDtypeStruct(
            (self.cfg.n_items, self.cfg.item_bytes // 4), np.uint32
        )
        return jax.jit(self.fns.serve).lower(db_spec, keys)
