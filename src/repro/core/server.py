"""Sharded PIR server — the paper's Figure 5 dataflow on a TPU mesh.

Topology mapping (DESIGN.md §2):

  model axis  = the DPUs of one cluster. The DB is sharded over it in the
                paper's linear layout: shard d holds rows
                [d·B_d, (d+1)·B_d), B_d = N / |model|.
  data (and pod) axes = DPU clusters (paper §3.4): the DB is *replicated*
                across them and the query batch is sharded across them, so
                clusters answer disjoint queries in parallel.

Per-device step (inside shard_map) — Algorithm 1 with the host CPU removed:

  ① eval own DPF leaf range   (paper: host CPU + CPU→DPU copy ②③)
  ② select-XOR scan over the local DB rows            (paper: DPU dpXOR ④)
  ③ XOR all-reduce of 32 B subresults over `model`    (paper: DPU→CPU copy
     + host aggregation ⑤⑥ — here an all_gather+fold or a ppermute
     butterfly, selectable for the §Perf collective study)

Three server paths, lowered from the same factory:

  baseline   paper-faithful phase split: materialize Eval(k,·) bits, then
             scan. This is the §Perf *baseline* row.
  fused      chunked expand+scan (lax.scan over subtree blocks): selection
             bits never round-trip through HBM. Beyond-paper.
  matmul     batched queries as one int8 GEMM on the MXU (additive mode).
             Beyond-paper; turns the memory-bound scan compute-bound.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.config import PIRConfig
from repro.core import dpf
from repro.core.pir import dpxor, xor_fold
from repro.crypto.chacha import PRG_ROUNDS

U32 = jnp.uint32


def _cluster_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _shard_axis(mesh: jax.sharding.Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def _axis_size(mesh, names) -> int:
    n = 1
    for a in names if isinstance(names, tuple) else (names,):
        if a is not None:
            n *= mesh.shape[a]
    return n


def key_specs(cfg: PIRConfig, n_queries: int, *, party: int = 0
              ) -> dpf.DPFKey:
    """ShapeDtypeStruct stand-ins for a batched key pytree (dry-run input).

    ``party`` and the PRG round count are pytree *aux data*, so they must
    match the real keys exactly for treedef-sensitive uses (e.g. the
    per-bucket ``jit`` in_shardings).
    """
    log_n = cfg.log_n
    mk = lambda *s: jax.ShapeDtypeStruct((n_queries,) + s, np.uint32)
    cw_final = None if cfg.mode == "xor" else mk(1)
    return dpf.DPFKey(
        party=party, log_n=log_n,
        root_seed=mk(4), cw_seed=mk(log_n, 4), cw_t=mk(log_n, 2),
        cw_final=cw_final, rounds=PRG_ROUNDS.get(cfg.prf, 12),
    )


def _key_pspec(keys_like: dpf.DPFKey, cluster: Tuple[str, ...]) -> dpf.DPFKey:
    """PartitionSpecs matching the batched-key pytree (batch axis sharded)."""
    def spec(leaf):
        rank = len(leaf.shape)
        return P(cluster, *([None] * (rank - 1)))
    return jax.tree_util.tree_map(spec, keys_like)


def xor_allreduce_gather(partial_res: jax.Array, axis: str) -> jax.Array:
    """XOR all-reduce via all_gather + local fold (paper's host aggregation)."""
    gathered = jax.lax.all_gather(partial_res, axis)          # [P, ...]
    return xor_fold(gathered, 0)


def xor_allreduce_butterfly(partial_res: jax.Array, axis: str, size: int
                            ) -> jax.Array:
    """XOR all-reduce via a recursive-doubling butterfly (log P ppermutes).

    Collective-study alternative for §Perf: moves the same bytes in log P
    rounds of pairwise exchange instead of one P-way gather.
    """
    x = partial_res
    n = size
    shift = 1
    while shift < n:
        perm = [(i, i ^ shift) for i in range(n)]
        x = x ^ jax.lax.ppermute(x, axis, perm)
        shift <<= 1
    return x


@dataclass
class ServeFns:
    """Compiled server entry points for one party."""
    serve: Callable            # (db, keys) -> per-query answer shares
    mesh: jax.sharding.Mesh
    db_sharding: NamedSharding
    cfg: PIRConfig
    n_local_queries: int       # queries per cluster per step
    # batched-key pytree -> NamedSharding pytree (for async host staging)
    key_shardings: Optional[Callable] = None


def build_serve_fn(
    cfg: PIRConfig,
    mesh: jax.sharding.Mesh,
    *,
    n_queries: int,
    path: str = "baseline",          # baseline | fused | matmul
    chunk_log: int = 12,             # fused: leaves per expand+scan chunk
    collective: str = "gather",      # gather | butterfly
) -> ServeFns:
    """Build the sharded serve function for one step of ``n_queries``."""
    cluster = _cluster_axes(mesh)
    shard = _shard_axis(mesh)
    n_clusters = _axis_size(mesh, cluster)
    n_shards = _axis_size(mesh, shard)
    if n_queries % max(n_clusters, 1):
        raise ValueError(f"{n_queries} queries not divisible by {n_clusters} clusters")
    if cfg.n_items % max(n_shards, 1):
        raise ValueError("DB size not divisible by shard count")
    rows_local = cfg.n_items // n_shards
    log_local = int(math.log2(rows_local))
    if 1 << log_local != rows_local:
        raise ValueError("per-shard row count must be a power of two")
    words = cfg.item_bytes // 4

    db_spec = P(shard, None)
    keys_spec_builder = lambda keys: _key_pspec(keys, cluster)
    out_spec = P(cluster, None)

    def local_step(db_local, keys_local):
        sidx = jax.lax.axis_index(shard) if shard else 0

        if path == "baseline":
            # Phase ②③: materialize selection bits for the local leaf range
            # (the paper's host-side Eval + CPU→DPU share copy).
            bits = dpf.eval_bits_batch(keys_local, sidx, log_local)
            # Phase ④⑤: select-XOR scan (DPU dpXOR, two-stage reduction).
            partial_res = jax.vmap(lambda b: dpxor(db_local, b))(bits)

        elif path == "fused":
            # Chunked expand+scan: per chunk, descend to the chunk subtree
            # and fold its rows immediately — bits never hit HBM.
            n_chunks = max(1, rows_local >> chunk_log)
            clog = min(chunk_log, log_local)
            db_c = db_local.reshape(n_chunks, rows_local // n_chunks, words)

            def one_query(key):
                def body(acc, c):
                    blk = sidx * n_chunks + c
                    _, t = dpf.eval_range(key, blk, clog)
                    acc = acc ^ dpxor(db_c[c], t)
                    return acc, ()
                acc0 = jnp.zeros((words,), U32)
                acc, _ = jax.lax.scan(body, acc0, jnp.arange(n_chunks, dtype=jnp.uint32))
                return acc

            partial_res = jax.vmap(one_query)(keys_local)

        elif path == "matmul":
            # Additive Z_256 shares -> one int8 GEMM for the whole batch.
            shares = dpf.eval_bytes_batch(keys_local, sidx, log_local)
            db_bytes = _words_to_bytes_i8(db_local)
            part = jax.lax.dot_general(
                shares.astype(jnp.int8), db_bytes,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            if shard:
                part = jax.lax.psum(part, shard)     # additive: native psum
            return part

        else:
            raise ValueError(f"unknown path {path!r}")

        # Aggregation ⑤⑥: XOR all-reduce of 32 B subresults over shards.
        if shard:
            if collective == "butterfly":
                partial_res = xor_allreduce_butterfly(partial_res, shard, n_shards)
            else:
                partial_res = xor_allreduce_gather(partial_res, shard)
        return partial_res

    def serve(db, keys):
        ks = keys_spec_builder(keys)
        fn = shard_map(
            local_step, mesh=mesh,
            in_specs=(db_spec, ks), out_specs=out_spec,
            check_vma=False,
        )
        return fn(db, keys)

    def key_shardings(keys_like: dpf.DPFKey):
        """NamedSharding pytree for a batched key pytree (host staging)."""
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), _key_pspec(keys_like, cluster),
            is_leaf=lambda x: isinstance(x, P))

    return ServeFns(
        serve=serve,
        mesh=mesh,
        db_sharding=NamedSharding(mesh, db_spec),
        cfg=cfg,
        n_local_queries=n_queries // max(n_clusters, 1),
        key_shardings=key_shardings,
    )


def _words_to_bytes_i8(w: jax.Array) -> jax.Array:
    sh = jnp.asarray([0, 8, 16, 24], dtype=U32)
    b = (w[..., None] >> sh) & U32(0xFF)
    return b.reshape(w.shape[:-1] + (w.shape[-1] * 4,)).astype(jnp.int8)


def bucket_for(buckets: Sequence[int], n: int) -> int:
    """The padding rule (DESIGN.md §6): smallest bucket >= n.

    Returns the largest bucket when n exceeds it — the caller then chunks
    (``PIRServer.answer``) or cuts batches no larger than it (the
    scheduler). ``buckets`` must be sorted ascending.
    """
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


def default_buckets(n_clusters: int = 1, max_bucket: int = 32
                    ) -> Tuple[int, ...]:
    """Power-of-two batch buckets, each divisible by the cluster count.

    The serve step shards the query batch over clusters, so every compiled
    batch size must be a multiple of ``n_clusters``; buckets are the
    doubling ladder from ``n_clusters`` up to ``max_bucket`` (DESIGN.md §6).
    """
    n_clusters = max(n_clusters, 1)
    b = n_clusters
    out = []
    while b <= max(max_bucket, n_clusters):
        out.append(b)
        b *= 2
    return tuple(out)


class BucketedServeFns:
    """Lower-once-per-bucket cache of compiled serve steps for one party.

    Ragged traffic never recompiles: a batch of Q queries is padded up to
    the smallest bucket >= Q (``dpf.pad_keys``) and answered by that
    bucket's cached ``jax.jit`` step. ``n_compiles`` counts cache misses so
    tests/benches can assert reuse.
    """

    def __init__(self, cfg: PIRConfig, mesh: jax.sharding.Mesh, *,
                 buckets: Sequence[int], path: str = "baseline",
                 collective: str = "gather", party: int = 0):
        n_clusters = _axis_size(mesh, _cluster_axes(mesh))
        for b in buckets:
            if b % max(n_clusters, 1):
                raise ValueError(
                    f"bucket {b} not divisible by {n_clusters} clusters")
        self.cfg = cfg
        self.mesh = mesh
        self.path = path
        self.collective = collective
        self.party = party
        self.buckets = tuple(sorted(set(buckets)))
        self.n_compiles = 0
        self._cache: dict = {}   # bucket -> (ServeFns, jitted serve)

    def bucket_for(self, n: int) -> int:
        return bucket_for(self.buckets, n)

    def fns_for(self, bucket: int) -> Tuple[ServeFns, Callable]:
        if bucket not in self._cache:
            fns = build_serve_fn(self.cfg, self.mesh, n_queries=bucket,
                                 path=self.path, collective=self.collective)
            # explicit in_shardings: host-resident and pre-staged
            # (device_put) key batches hit the SAME executable — without
            # this, staging would silently fork a second ~identical
            # compile per bucket (observed +70 s on the dev container)
            keys_like = key_specs(self.cfg, bucket, party=self.party)
            in_sh = (fns.db_sharding, fns.key_shardings(keys_like))
            self._cache[bucket] = (fns, jax.jit(fns.serve, in_shardings=in_sh))
            self.n_compiles += 1
        return self._cache[bucket]

    def stage(self, keys: dpf.DPFKey) -> dpf.DPFKey:
        """Pad a batched key pytree to its bucket and device_put it.

        This is the host-side half of the double-buffered serve pipeline:
        staging batch k+1's keys overlaps batch k's device compute.
        Batches larger than the largest bucket pass through unstaged —
        ``answer`` chunks (and pads per chunk) at dispatch.
        """
        if dpf.n_queries_of(keys) > self.buckets[-1]:
            return keys
        bucket = self.bucket_for(dpf.n_queries_of(keys))
        fns, _ = self.fns_for(bucket)
        padded = dpf.pad_keys(keys, bucket)
        if fns.key_shardings is not None:
            padded = jax.device_put(padded, fns.key_shardings(padded))
        return padded

    def answer(self, db: jax.Array, keys: dpf.DPFKey) -> jax.Array:
        """Answer a batch of any size; returns exactly [Q, W] shares.

        Q pads up to its bucket (pad answers computed and sliced off);
        batches beyond the largest bucket are chunked. The result is
        asynchronous (no block until the caller consumes it).
        """
        q = dpf.n_queries_of(keys)
        max_b = self.buckets[-1]
        if q <= max_b:
            return self._answer_one(db, keys)
        chunks = []
        for lo in range(0, q, max_b):
            hi = min(lo + max_b, q)
            part = jax.tree_util.tree_map(lambda x: x[lo:hi], keys)
            chunks.append(self._answer_one(db, part))
        return jnp.concatenate(chunks, axis=0)

    def _answer_one(self, db: jax.Array, keys: dpf.DPFKey) -> jax.Array:
        q = dpf.n_queries_of(keys)
        bucket = self.bucket_for(q)
        _, jitted = self.fns_for(bucket)
        return jitted(db, dpf.pad_keys(keys, bucket))[:q]


class PIRServer:
    """One logical PIR server (one of the n non-colluding parties).

    Owns the device-resident DB shards and a *family* of compiled serve
    steps, one per batch bucket (lower-once-per-bucket). The DB is
    preloaded once (paper §3.3 "database preloading": transfer cost excluded
    from query latency) and donated to devices.
    """

    def __init__(
        self,
        party: int,
        db_words: np.ndarray,
        cfg: PIRConfig,
        mesh: jax.sharding.Mesh,
        *,
        n_queries: int = 32,
        path: str = "baseline",
        collective: str = "gather",
        buckets: Optional[Sequence[int]] = None,
    ):
        self.party = party
        self.cfg = cfg
        self.mesh = mesh
        self.path = path
        n_clusters = _axis_size(mesh, _cluster_axes(mesh))
        if buckets is None:
            buckets = default_buckets(n_clusters,
                                      max_bucket=max(n_queries, 1))
        if n_queries not in buckets:
            buckets = tuple(sorted(set(buckets) | {n_queries}))
        self.bucketed = BucketedServeFns(
            cfg, mesh, buckets=buckets, path=path, collective=collective,
            party=party)
        self.n_queries = n_queries
        self.fns = self.bucketed.fns_for(n_queries)[0]
        self.db = jax.device_put(jnp.asarray(db_words), self.fns.db_sharding)

    @property
    def n_compiles(self) -> int:
        return self.bucketed.n_compiles

    @property
    def buckets(self) -> Tuple[int, ...]:
        return self.bucketed.buckets

    def stage_keys(self, keys: dpf.DPFKey) -> dpf.DPFKey:
        """Pad + device_put a key batch ahead of dispatch (pipelining)."""
        return self.bucketed.stage(keys)

    def answer(self, keys: dpf.DPFKey) -> jax.Array:
        """Answer a batch of queries (keys stacked on the leading axis).

        Any batch size works: Q is padded up to its bucket (answers for pad
        slots are computed and discarded) and batches beyond the largest
        bucket are chunked. Returns exactly [Q, W] answer shares.
        """
        return self.bucketed.answer(self.db, keys)

    def lower(self, n_queries: int):
        """Lower (no execution) against ShapeDtypeStructs — dry-run entry."""
        keys = key_specs(self.cfg, n_queries)
        db_spec = jax.ShapeDtypeStruct(
            (self.cfg.n_items, self.cfg.item_bytes // 4), np.uint32
        )
        fns = self.bucketed.fns_for(self.bucketed.bucket_for(n_queries))[0]
        return jax.jit(fns.serve).lower(db_spec, keys)
