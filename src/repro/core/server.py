"""Sharded PIR server — the paper's Figure 5 dataflow on a TPU mesh.

Topology mapping (DESIGN.md §2):

  model axis  = the DPUs of one cluster. The DB is sharded over it in the
                paper's linear layout: shard d holds rows
                [d·B_d, (d+1)·B_d), B_d = N / |model|.
  data (and pod) axes = DPU clusters (paper §3.4): the DB is *replicated*
                across them and the query batch is sharded across them, so
                clusters answer disjoint queries in parallel.

Per-device step (inside shard_map) — Algorithm 1 with the host CPU removed:

  ① eval own DPF leaf range   (paper: host CPU + CPU→DPU copy ②③)
  ② select-XOR scan / GEMM over the local DB rows      (paper: DPU dpXOR ④)
  ③ reduce 32 B subresults over `model`                (paper: DPU→CPU copy
     + host aggregation ⑤⑥)

What runs in steps ①–③ is no longer decided here: the *protocol plane*
(``core/protocol.py``) owns it. A registered ``PIRProtocol`` supplies the
per-shard answer contraction (``answer_local``), the cross-shard reduction
algebra (``reduce`` — XOR all-reduce for the XOR schemes, psum for
additive), and the key pytree shapes (``key_specs``); an ``ExecutionPlan``
picks the kernel path (materialized vs fused expansion, jnp oracle vs the
Pallas bodies, gather vs butterfly collective). The *database plane*
(``db/``, DESIGN.md §8) owns what the data looks like and where it lives:
``DatabaseSpec`` centralizes shape/packing math, ``ShardedDatabase`` owns
chunked mesh placement, the per-protocol views (u32 words / int8 bytes —
declared via ``PIRProtocol.db_view``) and epoched online updates. This
module only owns the mesh plumbing: shard_map specs and the
lower-once-per-bucket compile cache. Legacy
``path="baseline"|"fused"|"matmul"`` strings map onto plans via
``protocol.resolve_plan``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.config import PIRConfig
from repro.core import dpf
from repro.core import protocol as protocol_mod
from repro.core.protocol import ExecutionPlan, PIRProtocol
from repro.db import DatabaseSpec, ShardedDatabase

U32 = jnp.uint32


def _cluster_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _shard_axis(mesh: jax.sharding.Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def _axis_size(mesh, names) -> int:
    n = 1
    for a in names if isinstance(names, tuple) else (names,):
        if a is not None:
            n *= mesh.shape[a]
    return n


def key_specs(cfg: PIRConfig, n_queries: int, *, party: int = 0,
              protocol: Optional[PIRProtocol] = None) -> dpf.DPFKey:
    """ShapeDtypeStruct stand-ins for a batched key pytree (dry-run input).

    Delegates to the config's protocol — key pytree shapes (payload
    correction words, the k-server component axis) are scheme-defined.
    ``party`` and the PRG round count are pytree *aux data*, so they must
    match the real keys exactly for treedef-sensitive uses (e.g. the
    per-bucket ``jit`` in_shardings).
    """
    proto = protocol if protocol is not None else protocol_mod.for_config(cfg)
    return proto.key_specs(cfg, n_queries, party=party)


def _key_pspec(keys_like, cluster: Tuple[str, ...]):
    """PartitionSpecs matching the batched-key pytree (batch axis sharded)."""
    def spec(leaf):
        rank = len(leaf.shape)
        return P(cluster, *([None] * (rank - 1)))
    return jax.tree_util.tree_map(spec, keys_like)


@dataclass
class ServeFns:
    """Compiled server entry points for one party.

    ``serve`` takes the device array of this protocol's declared DB view
    (``ShardedDatabase.view(protocol.db_view)``) — never a raw host array.
    """
    serve: Callable            # (db_view, keys) -> per-query answer shares
    mesh: jax.sharding.Mesh
    db_sharding: NamedSharding
    cfg: PIRConfig
    n_local_queries: int       # queries per cluster per step
    plan: ExecutionPlan
    protocol: PIRProtocol
    # batched-key pytree -> NamedSharding pytree (for async host staging)
    key_shardings: Optional[Callable] = None

    def plan_report(self) -> dict:
        """Provenance + predicted-bytes row for the resolved plan
        (engine-plane reporting, DESIGN.md §9): the modeled HBM traffic of
        one device's contraction — ``n_local_queries`` against its own
        DB shard."""
        from repro import engine
        n_shards = _axis_size(self.mesh, _shard_axis(self.mesh))
        return engine.plan_report(self.cfg, self.plan, self.n_local_queries,
                                  n_shards=n_shards)


class LoweredServe(NamedTuple):
    """``PIRServer.lower`` result: the jax lowering plus plan provenance.

    ``lowered`` keeps the full jax API (``.compile()``, ``.as_text()``);
    ``plan``/``report`` surface which kernel path this bucket resolved to
    and the engine's predicted step bytes (DESIGN.md §9).
    """
    lowered: object
    plan: ExecutionPlan
    report: dict

    def compile(self):
        return self.lowered.compile()

    def as_text(self, *a, **k):
        return self.lowered.as_text(*a, **k)


def build_serve_fn(
    cfg: PIRConfig,
    mesh: jax.sharding.Mesh,
    *,
    n_queries: int,
    path: Optional[str] = "baseline",  # legacy plan names; None/"auto" selects
    chunk_log: int = 12,               # fused: leaves per expand+scan chunk
    collective: str = "gather",        # gather | butterfly
    protocol: Optional[PIRProtocol] = None,
    plan: Optional[ExecutionPlan] = None,
) -> ServeFns:
    """Build the sharded serve function for one step of ``n_queries``.

    The protocol defaults to the one named by ``cfg.protocol``; the plan
    defaults to the legacy ``path`` mapping (or ``plan_for`` selection when
    ``path`` is None/"auto"). No share-scheme branching happens here — the
    protocol owns the contraction and reduction.
    """
    proto = protocol if protocol is not None else protocol_mod.for_config(cfg)
    if path == "matmul" and proto.share_kind != "additive":
        # the GEMM path contracts additive Z_256 shares; silently falling
        # back to the XOR scan would mislabel benchmarks/tests
        raise ValueError(
            f"path='matmul' requires an additive protocol; "
            f"{proto.name!r} is {proto.share_kind} — use "
            f"protocol='additive-dpf-2'")
    if plan is None:
        plan = protocol_mod.resolve_plan(path, cfg, n_queries,
                                         chunk_log=chunk_log,
                                         collective=collective)
    cluster = _cluster_axes(mesh)
    shard = _shard_axis(mesh)
    n_clusters = _axis_size(mesh, cluster)
    n_shards = _axis_size(mesh, shard)
    if n_queries % max(n_clusters, 1):
        raise ValueError(f"{n_queries} queries not divisible by {n_clusters} clusters")
    # per-shard row math (divisibility, power-of-two) lives in the spec
    rows_local = DatabaseSpec.from_config(cfg).rows_per_shard(n_shards)
    log_local = int(math.log2(rows_local))

    db_spec = P(shard, None)
    keys_spec_builder = lambda keys: _key_pspec(keys, cluster)
    out_spec = P(cluster, None)

    def local_step(db_local, keys_local):
        sidx = jax.lax.axis_index(shard) if shard else 0
        # ①② the protocol's per-shard contraction under the chosen plan
        partial_res = proto.answer_local(db_local, keys_local, sidx,
                                         log_local, plan)
        # ③ aggregation ⑤⑥ over DB shards, in the protocol's share algebra
        if shard:
            partial_res = proto.reduce(partial_res, shard, n_shards, plan)
        return partial_res

    def serve(db, keys):
        ks = keys_spec_builder(keys)
        fn = shard_map(
            local_step, mesh=mesh,
            in_specs=(db_spec, ks), out_specs=out_spec,
            check_vma=False,
        )
        return fn(db, keys)

    def key_shardings(keys_like):
        """NamedSharding pytree for a batched key pytree (host staging)."""
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), _key_pspec(keys_like, cluster),
            is_leaf=lambda x: isinstance(x, P))

    return ServeFns(
        serve=serve,
        mesh=mesh,
        db_sharding=NamedSharding(mesh, db_spec),
        cfg=cfg,
        n_local_queries=n_queries // max(n_clusters, 1),
        plan=plan,
        protocol=proto,
        key_shardings=key_shardings,
    )


def bucket_for(buckets: Sequence[int], n: int) -> int:
    """The padding rule (DESIGN.md §6): smallest bucket >= n.

    Returns the largest bucket when n exceeds it — the caller then chunks
    (``PIRServer.answer``) or cuts batches no larger than it (the
    scheduler). ``buckets`` must be sorted ascending.
    """
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


def default_buckets(n_clusters: int = 1, max_bucket: int = 32
                    ) -> Tuple[int, ...]:
    """Power-of-two batch buckets, each divisible by the cluster count.

    The serve step shards the query batch over clusters, so every compiled
    batch size must be a multiple of ``n_clusters``; buckets are the
    doubling ladder from ``n_clusters`` up to ``max_bucket`` (DESIGN.md §6).
    """
    n_clusters = max(n_clusters, 1)
    b = n_clusters
    out = []
    while b <= max(max_bucket, n_clusters):
        out.append(b)
        b *= 2
    return tuple(out)


class BucketedServeFns:
    """Lower-once-per-bucket cache of compiled serve steps for one party.

    Ragged traffic never recompiles: a batch of Q queries is padded up to
    the smallest bucket >= Q (``PIRProtocol.pad``) and answered by that
    bucket's cached ``jax.jit`` step. ``n_compiles`` counts cache misses so
    tests/benches can assert reuse. When ``path`` is None/"auto", each
    bucket's plan comes from the engine plane (plan-cache hit → measured
    tuned plan, miss → the ``plan_for`` heuristic) — so e.g. small and
    large buckets of the same server family may take different kernel
    paths. Plan resolution happens HERE, once per bucket at build time
    (``plan_for_bucket``); dispatch never touches the tuner or cache I/O.
    """

    def __init__(self, cfg: PIRConfig, mesh: jax.sharding.Mesh, *,
                 buckets: Sequence[int], path: Optional[str] = "baseline",
                 collective: str = "gather", party: int = 0,
                 protocol: Optional[PIRProtocol] = None,
                 chunk_log: int = 12):
        n_clusters = _axis_size(mesh, _cluster_axes(mesh))
        for b in buckets:
            if b % max(n_clusters, 1):
                raise ValueError(
                    f"bucket {b} not divisible by {n_clusters} clusters")
        self.cfg = cfg
        self.mesh = mesh
        self.path = path
        self.collective = collective
        self.chunk_log = chunk_log
        self.party = party
        self.protocol = (protocol if protocol is not None
                         else protocol_mod.for_config(cfg))
        self.buckets = tuple(sorted(set(buckets)))
        self.n_compiles = 0
        self._cache: dict = {}   # bucket -> (ServeFns, jitted serve)
        self._plans: dict = {}   # bucket -> resolved ExecutionPlan

    def bucket_for(self, n: int) -> int:
        return bucket_for(self.buckets, n)

    def plan_for_bucket(self, bucket: int) -> ExecutionPlan:
        """The bucket's resolved plan — one engine/heuristic resolution per
        bucket, cached, shared with the compiled step (``fns_for``)."""
        if bucket not in self._plans:
            self._plans[bucket] = protocol_mod.resolve_plan(
                self.path, self.cfg, bucket, chunk_log=self.chunk_log,
                collective=self.collective)
        return self._plans[bucket]

    def plan_report(self) -> dict:
        """{bucket: plan provenance + predicted bytes} for every bucket —
        resolved without compiling anything (runtime/launch reporting)."""
        from repro import engine
        n_shards = _axis_size(self.mesh, _shard_axis(self.mesh))
        n_clusters = max(_axis_size(self.mesh, _cluster_axes(self.mesh)), 1)
        return {b: engine.plan_report(self.cfg, self.plan_for_bucket(b),
                                      b // n_clusters, n_shards=n_shards)
                for b in self.buckets}

    def fns_for(self, bucket: int) -> Tuple[ServeFns, Callable]:
        if bucket not in self._cache:
            fns = build_serve_fn(self.cfg, self.mesh, n_queries=bucket,
                                 path=self.path, collective=self.collective,
                                 chunk_log=self.chunk_log,
                                 protocol=self.protocol,
                                 plan=self.plan_for_bucket(bucket))
            # explicit in_shardings: host-resident and pre-staged
            # (device_put) key batches hit the SAME executable — without
            # this, staging would silently fork a second ~identical
            # compile per bucket (observed +70 s on the dev container)
            keys_like = self.protocol.key_specs(self.cfg, bucket,
                                                party=self.party)
            in_sh = (fns.db_sharding, fns.key_shardings(keys_like))
            self._cache[bucket] = (fns, jax.jit(fns.serve, in_shardings=in_sh))
            self.n_compiles += 1
        return self._cache[bucket]

    def stage(self, keys) -> dpf.DPFKey:
        """Pad a batched key pytree to its bucket and device_put it.

        This is the host-side half of the double-buffered serve pipeline:
        staging batch k+1's keys overlaps batch k's device compute.
        Batches larger than the largest bucket pass through unstaged —
        ``answer`` chunks (and pads per chunk) at dispatch.
        """
        if self.protocol.n_queries(keys) > self.buckets[-1]:
            return keys
        bucket = self.bucket_for(self.protocol.n_queries(keys))
        fns, _ = self.fns_for(bucket)
        padded = self.protocol.pad(keys, bucket)
        if fns.key_shardings is not None:
            padded = jax.device_put(padded, fns.key_shardings(padded))
        return padded

    def answer(self, db: Union[jax.Array, ShardedDatabase], keys
               ) -> jax.Array:
        """Answer a batch of any size; returns exactly [Q, ...] shares.

        ``db`` is either the protocol's view array or a
        :class:`ShardedDatabase` (resolved to ``protocol.db_view`` at
        dispatch, so a freshly published epoch is picked up per batch).
        Q pads up to its bucket (pad answers computed and sliced off);
        batches beyond the largest bucket are chunked. The result is
        asynchronous (no block until the caller consumes it).
        """
        if isinstance(db, ShardedDatabase):
            db = db.view(self.protocol.db_view)
        q = self.protocol.n_queries(keys)
        max_b = self.buckets[-1]
        if q <= max_b:
            return self._answer_one(db, keys)
        chunks = []
        for lo in range(0, q, max_b):
            hi = min(lo + max_b, q)
            part = jax.tree_util.tree_map(lambda x: x[lo:hi], keys)
            chunks.append(self._answer_one(db, part))
        return jnp.concatenate(chunks, axis=0)

    def _answer_one(self, db: jax.Array, keys) -> jax.Array:
        q = self.protocol.n_queries(keys)
        bucket = self.bucket_for(q)
        _, jitted = self.fns_for(bucket)
        return jitted(db, self.protocol.pad(keys, bucket))[:q]


class PIRServer:
    """One logical PIR server (one of the n non-colluding parties).

    References a :class:`ShardedDatabase` (the database plane owns
    placement, views and epochs — paper §3.3 "database preloading":
    transfer cost excluded from query latency) and owns a *family* of
    compiled serve steps, one per batch bucket (lower-once-per-bucket).
    The database may be *shared* across parties (``MultiServerPIR`` does
    exactly that — the DB contents are public, only the key material is
    per-party), so k parties no longer cost k host/device copies. The
    share scheme comes from the injected ``PIRProtocol`` (default: the
    one ``cfg.protocol`` names).

    ``db_words`` (a raw host array, wrapped into a private
    ``ShardedDatabase``) is the legacy construction path; new code passes
    ``database=``.
    """

    def __init__(
        self,
        party: int,
        db_words: Optional[np.ndarray] = None,
        cfg: PIRConfig = None,
        mesh: jax.sharding.Mesh = None,
        *,
        database: Optional[ShardedDatabase] = None,
        n_queries: int = 32,
        path: Optional[str] = "baseline",
        collective: str = "gather",
        buckets: Optional[Sequence[int]] = None,
        protocol: Optional[PIRProtocol] = None,
    ):
        if (db_words is None) == (database is None):
            raise ValueError(
                "pass exactly one of db_words= (legacy host array) or "
                "database= (ShardedDatabase)")
        if cfg is None or mesh is None:
            raise ValueError("cfg= and mesh= are required (the database "
                             "does not substitute for them)")
        if database is not None:
            # fail at construction, not as a shape/sharding error deep
            # inside the first compiled serve step
            expect = DatabaseSpec.from_config(cfg)
            if database.spec != expect:
                raise ValueError(
                    f"database spec {database.spec} does not match the "
                    f"config's {expect}")
            if database.mesh != mesh:
                raise ValueError(
                    "database was placed on a different mesh than the "
                    "serve steps will run on")
        self.party = party
        self.cfg = cfg
        self.mesh = mesh
        self.path = path
        n_clusters = _axis_size(mesh, _cluster_axes(mesh))
        if buckets is None:
            buckets = default_buckets(n_clusters,
                                      max_bucket=max(n_queries, 1))
        if n_queries not in buckets:
            buckets = tuple(sorted(set(buckets) | {n_queries}))
        self.bucketed = BucketedServeFns(
            cfg, mesh, buckets=buckets, path=path, collective=collective,
            party=party, protocol=protocol)
        self.protocol = self.bucketed.protocol
        self.n_queries = n_queries
        self.fns = self.bucketed.fns_for(n_queries)[0]
        self.db = (database if database is not None
                   else ShardedDatabase(db_words, cfg, mesh))

    @property
    def n_compiles(self) -> int:
        return self.bucketed.n_compiles

    @property
    def buckets(self) -> Tuple[int, ...]:
        return self.bucketed.buckets

    @property
    def db_epoch(self) -> int:
        """Current epoch of the (possibly shared) database."""
        return self.db.epoch

    def stage_keys(self, keys) -> dpf.DPFKey:
        """Pad + device_put a key batch ahead of dispatch (pipelining)."""
        return self.bucketed.stage(keys)

    def plan_report(self) -> dict:
        """Per-bucket plan provenance (tuned vs heuristic vs forced) +
        predicted step bytes — the engine plane's reporting surface."""
        return self.bucketed.plan_report()

    def answer(self, keys) -> jax.Array:
        """Answer a batch of queries (keys stacked on the leading axis).

        Any batch size works: Q is padded up to its bucket (answers for pad
        slots are computed and discarded) and batches beyond the largest
        bucket are chunked. The database view is re-fetched per call, so
        an epoch published between batches is served immediately; a batch
        already dispatched finishes against the epoch it captured.
        Returns exactly [Q, ...] answer shares.
        """
        return self.bucketed.answer(self.db, keys)

    def lower(self, n_queries: int) -> "LoweredServe":
        """Lower (no execution) against ShapeDtypeStructs — dry-run entry.

        Returns the lowered artifact *with its plan*: dry-run consumers
        report which kernel path a bucket compiled to and whether it was
        ``tuned`` (plan-cache hit), ``heuristic``, or ``forced``
        (legacy ``path=``), next to the HLO cost numbers.
        """
        keys = self.protocol.key_specs(self.cfg, n_queries, party=self.party)
        db_spec = DatabaseSpec.from_config(self.cfg).view_struct(
            self.protocol.db_view)
        fns = self.bucketed.fns_for(self.bucketed.bucket_for(n_queries))[0]
        return LoweredServe(lowered=jax.jit(fns.serve).lower(db_spec, keys),
                            plan=fns.plan, report=fns.plan_report())
