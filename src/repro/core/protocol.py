"""The protocol plane: pluggable PIR schemes + kernel-path execution plans.

The paper's architecture (§3) is multi-*server* PIR, but everything that
varies between schemes used to hide inside a ``mode="xor"|"additive"``
string branched on across three layers. This module is the seam that
replaces it (DESIGN.md §7):

``PIRProtocol``  what the *parties* compute — key generation, the per-shard
                 answer contraction, the cross-shard reduction algebra, and
                 client-side reconstruction. One implementation per share
                 scheme; a registry (mirroring ``models/registry.py``
                 dispatch) maps names to instances.

``ExecutionPlan``  *how* one answer step runs — which expansion strategy
                 (materialize selection bits vs fused chunked expand+scan),
                 which scan kernel (pure-jnp oracle vs the Pallas
                 ``dpxor``/``pir_matmul`` bodies), and which aggregation
                 collective. Picked per (db size, batch bucket, backend) by
                 :func:`plan_for`, or forced via the legacy ``path`` strings.

Registered protocols
--------------------
xor-dpf-2       the paper's two-server XOR scheme: one GGM DPF pair,
                selection bits weight an XOR fold over DB rows.
additive-dpf-2  two-server Z_256 additive shares; a query batch is one
                int8 GEMM against the byte-viewed DB (the MXU
                operational-intensity lever, beyond-paper).
xor-dpf-k       k>=2 servers, k-of-k XOR shares (beyond-paper, 1-private):
                one real DPF pair (parties 0, 1) blinded by a ring of
                pairwise-shared GGM mask seeds — party i expands masks
                m(s_i) and m(s_{(i+1) mod k}), so every seed is held by
                exactly two parties and every mask cancels in the
                XOR over all k answers while each single server sees only
                pseudorandom selection vectors. Every party scans the full
                DB (equal work), and reconstruction is XOR over all k
                answer shares. k = ``PIRConfig.n_servers``.
lwe-simple-1    single-server SimplePIR-style LWE PIR (beyond-paper,
                DESIGN.md §10): the client ships one LWE-encrypted one-hot
                vector, the server answers with an int32 GEMM over the byte
                DB, and reconstruction subtracts ``s^T.H`` against a
                preprocessed hint ``H = A^T.DB`` (seeded A, never shipped)
                before a modulus switch. No non-collusion assumption;
                reconstruction needs per-query client state + the hint, so
                sessions go through ``reconstruct_with``/``query_gen_full``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import PIRConfig
from repro.core import dpf
from repro.core.pir import answer_additive_matmul, dpxor, xor_fold
from repro.crypto.chacha import PRG_ROUNDS
from repro.db.spec import IntegrityError, verify_records

U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Execution plans: the kernel-path axis, decoupled from the share scheme
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExecutionPlan:
    """How one compiled answer step executes (DESIGN.md §7.3, §9).

    expand     "materialize": phase-split — Eval(k,·) selection vectors are
               written out, then scanned (the paper's host-eval structure).
               "fused": chunked expand+scan; selection bits never round-trip
               through HBM (XOR protocols; the GEMM ignores it).
               "fused-pallas": the megakernel (``kernels/fused_scan.py``) —
               one Pallas program expands each DB tile's GGM leaves from
               precomputed chunk roots and folds the tile immediately,
               streaming the DB through double-buffered DMA. Available for
               XOR *and* additive protocols (the additive body reproduces
               the int8 GEMM bit-exactly in-kernel).
    scan       "jnp": the pure-jnp oracle contraction (also the GSPMD
               dry-run path). "pallas": the tiled kernel bodies —
               ``kernels/dpxor.py`` for XOR scans, ``kernels/pir_matmul.py``
               for the additive GEMM.
    chunk_log  fused path: log2 leaves per expand+scan chunk.
    collective "gather" | "butterfly": XOR all-reduce shape over the DB-shard
               axis (additive protocols psum natively and ignore this).

    Tile fields (the engine plane, DESIGN.md §9): the VMEM tilings that
    used to be hardcoded constants in ``kernels/ops.py``. Defaults are the
    pre-engine constants; the autotuner (``engine/tuner.py``) replaces
    them with measured winners. Requested tiles are *legalized* against
    the concrete shapes at kernel entry (``engine.legal_tile``), so a plan
    tuned at one shape stays valid at another.

    tile_r     rows staged through VMEM per grid step: the Pallas scan's
               row tile (``dpxor``, pre-engine 2048) / the GEMM's
               reduction tile (``pir_matmul``, pre-engine 1024).
    tile_q     GEMM query-batch tile (sublane dim).
    tile_l     GEMM record-byte tile (lane dim).
    depth      fused-pallas: rotating DMA buffer count (2 = classic double
               buffer; other paths ignore it).
    provenance "heuristic" (rule-picked fallback) | "tuned" (measured
               winner from the plan cache) | "forced" (legacy ``path=``
               string). Excluded from equality/hashing: two plans that
               execute identically compare equal regardless of how they
               were chosen.
    """
    expand: str = "materialize"
    scan: str = "jnp"
    chunk_log: int = 12
    collective: str = "gather"
    tile_r: int = 2048
    tile_q: int = 8
    tile_l: int = 128
    depth: int = 2
    provenance: str = field(default="heuristic", compare=False)

    @property
    def name(self) -> str:
        return f"{self.expand}/{self.scan}"

    def describe(self) -> Dict[str, object]:
        """Reporting form (dry-run JSONL, ``lower()`` provenance)."""
        return {"name": self.name, "expand": self.expand, "scan": self.scan,
                "chunk_log": self.chunk_log, "collective": self.collective,
                "tile_r": self.tile_r, "tile_q": self.tile_q,
                "tile_l": self.tile_l, "depth": self.depth,
                "provenance": self.provenance}


#: legacy ``path=`` strings -> plans (the pre-registry server API).
PATH_PLANS: Dict[str, ExecutionPlan] = {
    "baseline": ExecutionPlan(expand="materialize", scan="jnp"),
    "fused": ExecutionPlan(expand="fused", scan="jnp"),
    "matmul": ExecutionPlan(expand="materialize", scan="jnp"),
    "pallas": ExecutionPlan(expand="materialize", scan="pallas"),
    "fused-pallas": ExecutionPlan(expand="fused-pallas", scan="pallas"),
}


def resolve_plan(path: Optional[str], cfg: PIRConfig, n_queries: int, *,
                 chunk_log: int = 12, collective: str = "gather"
                 ) -> ExecutionPlan:
    """A plan from a legacy path string, or the engine when path is None.

    ``path=None/"auto"`` delegates to the engine plane (DESIGN.md §9):
    plan-cache hit → measured tuned plan; miss → the deterministic
    heuristic (:func:`plan_for`). Legacy strings stay forced plans
    (provenance ``"forced"``); additive protocols pin the GEMM reduction
    tile to its pre-engine kernel default.
    """
    if path is None or path == "auto":
        from repro import engine
        return engine.resolve(cfg, n_queries, chunk_log=chunk_log,
                              collective=collective)
    if path not in PATH_PLANS:
        raise ValueError(f"unknown path {path!r}; "
                         f"expected one of {sorted(PATH_PLANS)} or 'auto'")
    plan = replace(PATH_PLANS[path], chunk_log=chunk_log,
                   collective=collective, provenance="forced")
    if get(cfg.protocol).share_kind in ("additive", "lwe"):
        from repro.engine.kernels import GEMM_TILE_R_DEFAULT
        plan = replace(plan, tile_r=GEMM_TILE_R_DEFAULT)
    return plan


def plan_for(cfg: PIRConfig, n_queries: int, *,
             backend: Optional[str] = None,
             chunk_log: int = 12) -> ExecutionPlan:
    """Pick the kernel path per (db size, batch bucket, backend).

    Since the engine plane this is a thin alias of
    ``engine.heuristic_plan`` — the deterministic fallback the plan cache
    misses to. The selection rules (DESIGN.md §7.3) are unchanged:
      * additive protocols contract via the GEMM regardless — ``scan``
        chooses jnp dot vs the Pallas ``pir_matmul`` body;
      * XOR protocols materialize bits only while the per-query bit vector
        stays small (db <= 2^chunk_log rows — a global-size heuristic: a
        sharded mesh divides the per-device rows further, only making
        materialization cheaper); past that the fused chunked expand+scan
        keeps selection bits out of HBM;
      * the Pallas bodies run real Mosaic only on a TPU backend — on CPU
        they would execute in interpret mode, so the jnp oracle (which XLA
        compiles natively) is the fast CPU path;
      * batch bucket: single-query buckets skip the fused chunk machinery
        (nothing to amortize; the materialized form has the simpler HLO).
    """
    from repro.engine.tuner import heuristic_plan
    return heuristic_plan(cfg, n_queries, backend=backend,
                          chunk_log=chunk_log)


# ---------------------------------------------------------------------------
# Protocol interface
# ---------------------------------------------------------------------------

class PIRProtocol:
    """One PIR scheme: what each of the n parties computes.

    Implementations are stateless; all shapes come from the ``PIRConfig``
    and the key pytrees themselves. ``answer_local`` runs *inside*
    shard_map (one DB shard), so it must be pure traced jax.
    """

    name: str = ""
    share_kind: str = "xor"            # xor | additive | lwe (reduction algebra)
    #: which ShardedDatabase view the contraction consumes (db/spec.py
    #: VIEWS): "words" (u32, XOR scan) | "bytes" (int8, the GEMM) |
    #: "bytes32" (int32 bytes, the LWE GEMM). The database plane serves the
    #: declared view; protocols never convert inline inside the compiled step.
    db_view: str = "words"
    #: hint protocols (single-server LWE) need server-side preprocessing
    #: H(db) shipped to clients once per epoch; the session layer
    #: (``SingleServerPIR``) registers ``hint_builder`` with the database
    #: plane and routes reconstruction through ``reconstruct_with``.
    needs_hint: bool = False

    # -- client side ----------------------------------------------------
    def n_parties(self, cfg: PIRConfig) -> int:
        raise NotImplementedError

    def query_gen(self, rng: np.random.Generator, index: int,
                  cfg: PIRConfig) -> Tuple[dpf.DPFKey, ...]:
        """Gen: one per-party key pytree per party, for one query index."""
        raise NotImplementedError

    def query_gen_full(self, rng: np.random.Generator, index: int,
                       cfg: PIRConfig):
        """Gen with client state: ``(keys_tuple, state)``.

        Stateless protocols (all the DPF schemes) carry no client state;
        hint protocols return the per-query secret the reconstruction
        needs. Sessions that support hint protocols call this form.
        """
        return self.query_gen(rng, index, cfg), None

    def reconstruct(self, answers: Sequence[jax.Array]) -> jax.Array:
        """Combine all parties' answer shares into the record."""
        raise NotImplementedError

    def reconstruct_with(self, answers: Sequence[jax.Array], states, *,
                         cfg: Optional[PIRConfig] = None, hint=None):
        """Reconstruction with per-query client state + epoch hint.

        The general client-side entry point: stateless protocols ignore
        ``states``/``hint`` and defer to :meth:`reconstruct`; hint
        protocols require both. When the config enables verified
        reconstruction (``cfg.checksum``), the combined records are routed
        through :meth:`verify_reconstruction` — a corrupted answer share
        raises :class:`~repro.db.spec.IntegrityError` here instead of
        decoding to silent garbage (DESIGN.md §12).
        """
        rec = self.reconstruct(answers)
        if cfg is not None and getattr(cfg, "checksum", False):
            rec = self.verify_reconstruction(rec, cfg)
        return rec

    def verify_reconstruction(self, rec, cfg: PIRConfig) -> np.ndarray:
        """Check reconstructed stored-width records against their per-row
        checksum column and strip it, returning the logical payload.

        Works for every share algebra because the check runs on the
        *reconstructed* records, not the shares: XOR schemes hand in
        ``[Q, item_words+1]`` u32 rows, byte schemes (additive, LWE)
        ``[Q, item_bytes+4]`` byte rows with the checksum word little-
        endian in the trailing 4 bytes. Raises ``IntegrityError`` naming
        the offending batch indices on any mismatch.
        """
        return verify_records(np.asarray(rec), cfg.item_bytes)

    def record_struct(self, cfg: PIRConfig) -> Tuple[Tuple[int, ...], type]:
        """(shape tail, dtype) of one reconstructed record — XOR schemes
        return u32 words, additive schemes Z_256 bytes."""
        if self.share_kind == "additive":
            return (cfg.item_bytes,), np.uint8
        return (cfg.item_bytes // 4,), np.uint32

    # -- server side ----------------------------------------------------
    def key_specs(self, cfg: PIRConfig, n_queries: int, *, party: int = 0):
        """ShapeDtypeStruct stand-ins for a batched key pytree (dry-run
        input). Aux data (party, rounds) must match real keys exactly for
        treedef-sensitive uses (per-bucket jit in_shardings)."""
        raise NotImplementedError

    def answer_local(self, db_local: jax.Array, keys_local,
                     start_block, log_local: int,
                     plan: ExecutionPlan) -> jax.Array:
        """One shard's partial answers for a batch of keys.

        ``db_local`` is the [rows_local, ...] shard of this protocol's
        declared ``db_view`` (u32 words for XOR schemes, int8 bytes for
        additive); ``start_block`` its shard index (leaf range
        [start_block * rows_local, ...)).
        """
        raise NotImplementedError

    def reduce(self, partial_res: jax.Array, axis: str, n_shards: int,
               plan: ExecutionPlan) -> jax.Array:
        """Cross-shard reduction of partial answers over mesh axis ``axis``."""
        raise NotImplementedError

    # -- hint lifecycle (hint protocols only) ---------------------------
    def hint_builder(self, cfg: PIRConfig):
        """Device fn: words view ``[N, W]`` -> hint array (full rebuild)."""
        raise NotImplementedError(f"{self.name} has no hint")

    def hint_delta(self, cfg: PIRConfig):
        """Device fn: (hint, rows, old_words, new_words) -> updated hint,
        exact (byte-for-byte equal to a full rebuild). None if the
        protocol's hint only supports full recompute."""
        return None

    # -- batching (shared defaults) -------------------------------------
    def pad(self, keys, n_total: int):
        """Pad a batched key pytree up to its bucket (DESIGN.md §6 rule)."""
        return dpf.pad_keys(keys, n_total)

    def n_queries(self, keys) -> int:
        return dpf.n_queries_of(keys)


# ---------------------------------------------------------------------------
# Registry (models/registry.py idiom: names -> implementations)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, PIRProtocol] = {}


def register(proto: PIRProtocol) -> PIRProtocol:
    if not proto.name:
        raise ValueError("protocol must carry a name")
    _REGISTRY[proto.name] = proto
    return proto


def get(name: str) -> PIRProtocol:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown protocol {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def for_config(cfg: PIRConfig) -> PIRProtocol:
    """The protocol a config names (``PIRConfig.protocol``; the deprecated
    ``mode=`` strings are aliased to registry names by the config shim)."""
    return get(cfg.protocol)


# ---------------------------------------------------------------------------
# XOR scan helpers shared by the XOR protocols
# ---------------------------------------------------------------------------

def xor_allreduce_gather(partial_res: jax.Array, axis: str) -> jax.Array:
    """XOR all-reduce via all_gather + local fold (paper's host aggregation)."""
    gathered = jax.lax.all_gather(partial_res, axis)          # [P, ...]
    return xor_fold(gathered, 0)


def xor_allreduce_butterfly(partial_res: jax.Array, axis: str, size: int
                            ) -> jax.Array:
    """XOR all-reduce via a recursive-doubling butterfly (log P ppermutes).

    Collective-study alternative for §Perf: moves the same bytes in log P
    rounds of pairwise exchange instead of one P-way gather.
    """
    x = partial_res
    shift = 1
    while shift < size:
        perm = [(i, i ^ shift) for i in range(size)]
        x = x ^ jax.lax.ppermute(x, axis, perm)
        shift <<= 1
    return x


def _xor_scan(db_local: jax.Array, bits: jax.Array,
              plan: ExecutionPlan) -> jax.Array:
    """[R, W] db x [Q, R] bits -> [Q, W], jnp oracle or the Pallas body."""
    if plan.scan == "pallas":
        from repro.kernels import ops
        return ops.dpxor(db_local, bits, tile_r=plan.tile_r)
    return jax.vmap(lambda b: dpxor(db_local, b))(bits)


def _xor_reduce(partial_res: jax.Array, axis: str, n_shards: int,
                plan: ExecutionPlan) -> jax.Array:
    if plan.collective == "butterfly":
        return xor_allreduce_butterfly(partial_res, axis, n_shards)
    return xor_allreduce_gather(partial_res, axis)


def _dpf_key_specs(cfg: PIRConfig, n_queries: int, *, party: int,
                   with_payload: bool,
                   components: Optional[int] = None) -> dpf.DPFKey:
    """Batched DPFKey ShapeDtypeStructs, optionally with a component axis."""
    log_n = cfg.log_n
    lead = (n_queries,) if components is None else (n_queries, components)
    mk = lambda *s: jax.ShapeDtypeStruct(lead + s, np.uint32)
    return dpf.DPFKey(
        party=party, log_n=log_n,
        root_seed=mk(4), cw_seed=mk(log_n, 4), cw_t=mk(log_n, 2),
        cw_final=mk(1) if with_payload else None,
        rounds=PRG_ROUNDS.get(cfg.prf, 12),
    )


# ---------------------------------------------------------------------------
# xor-dpf-2: the paper's two-server scheme
# ---------------------------------------------------------------------------

class _XorProtocol(PIRProtocol):
    """Shared XOR share algebra: reduction collective + XOR reconstruct."""

    share_kind = "xor"

    def reduce(self, partial_res, axis, n_shards, plan):
        return _xor_reduce(partial_res, axis, n_shards, plan)

    def reconstruct(self, answers):
        out = answers[0]
        for a in answers[1:]:
            out = jnp.bitwise_xor(out, a)
        return out


class XorDpf2(_XorProtocol):
    """Two-server XOR PIR over one GGM DPF pair (paper §2.3, Algorithm 1)."""

    name = "xor-dpf-2"

    def n_parties(self, cfg: PIRConfig) -> int:
        return 2

    def query_gen(self, rng, index, cfg):
        rounds = PRG_ROUNDS[cfg.prf]
        return dpf.gen_keys(rng, index, cfg.log_n, rounds=rounds)

    def key_specs(self, cfg, n_queries, *, party=0):
        return _dpf_key_specs(cfg, n_queries, party=party, with_payload=False)

    def answer_local(self, db_local, keys_local, start_block, log_local,
                     plan):
        if plan.expand == "materialize":
            # Phase ②③ then ④⑤: Eval bits out, then the select-XOR scan.
            bits = dpf.eval_bits_batch(keys_local, start_block, log_local)
            return _xor_scan(db_local, bits, plan)
        if plan.expand == "fused":
            return _fused_xor_answer(db_local, keys_local, start_block,
                                     log_local, plan, _bits_of_key)
        if plan.expand == "fused-pallas":
            return _fused_pallas_xor_answer(db_local, keys_local,
                                            start_block, log_local, plan)
        raise ValueError(f"unknown expand {plan.expand!r}")


def _bits_of_key(key: dpf.DPFKey, block, log_range: int) -> jax.Array:
    """Selection bits of one plain DPF key over one leaf block."""
    _, t = dpf.eval_range(key, block, log_range)
    return dpf.leaf_bits(t)


def _fused_xor_answer(db_local, keys_local, start_block, log_local, plan,
                      bits_fn) -> jax.Array:
    """Chunked expand+scan (lax.scan over subtree blocks): per chunk,
    descend to the chunk subtree root and fold its rows immediately — the
    selection bits never round-trip through HBM."""
    rows_local = db_local.shape[0]
    words = db_local.shape[1]
    n_chunks = max(1, rows_local >> plan.chunk_log)
    clog = min(plan.chunk_log, log_local)
    db_c = db_local.reshape(n_chunks, rows_local // n_chunks, words)

    def one_query(key):
        def body(acc, c):
            blk = start_block * n_chunks + c
            bits = bits_fn(key, blk, clog)
            acc = acc ^ dpxor(db_c[c], bits)
            return acc, ()
        acc0 = jnp.zeros((words,), U32)
        acc, _ = jax.lax.scan(body, acc0,
                              jnp.arange(n_chunks, dtype=jnp.uint32))
        return acc

    return jax.vmap(one_query)(keys_local)


def _fused_pallas_inputs(keys_local, start_block, log_local: int,
                         rows_local: int, plan: ExecutionPlan):
    """Marshal batched DPF keys into the megakernel's chunk-root form.

    Legalizes (tile_r, chunk_log) exactly as the kernel entry point will
    (``ops.fused_tile`` — the slice of correction-word levels must agree
    with the expansion depth the kernel runs), descends every key once to
    the chunk-root level (shared across chunks, unlike the chunked-jnp
    path's per-chunk re-descent), and slices out the last ``clog`` levels
    of correction words the kernel needs in VMEM.
    """
    from repro.kernels import ops
    tile, clog = ops.fused_tile(rows_local, plan.tile_r,
                                min(plan.chunk_log, log_local))
    roots, t_roots = dpf.eval_roots_batch(keys_local, start_block,
                                          log_local, clog)
    log_n = keys_local.log_n
    cw_seed_lv = keys_local.cw_seed[:, log_n - clog:, :]
    cw_t_lv = keys_local.cw_t[:, log_n - clog:, :]
    return tile, roots, t_roots, cw_seed_lv, cw_t_lv


def _fused_pallas_xor_answer(db_local, keys_local, start_block, log_local,
                             plan: ExecutionPlan) -> jax.Array:
    """Megakernel XOR answer: expand-in-kernel + double-buffered DB stream.

    ``keys_local`` is a batched plain DPFKey pytree ([Q, ...] leaves).
    """
    from repro.kernels import ops
    tile, roots, t_roots, cw_s, cw_t = _fused_pallas_inputs(
        keys_local, start_block, log_local, db_local.shape[0], plan)
    return ops.fused_scan_xor(db_local, roots, t_roots, cw_s, cw_t,
                              tile_r=tile, depth=plan.depth)


def _fused_pallas_xor_k_answer(db_local, keys_local, start_block, log_local,
                               plan: ExecutionPlan) -> jax.Array:
    """Megakernel answer for component-stacked keys ([Q, C, ...] leaves).

    AND distributes over XOR, so running the kernel on the Q·C flattened
    pseudo-queries and XOR-folding the answers over the component axis
    equals scanning with the XOR-folded selection bits.
    """
    q = keys_local.root_seed.shape[0]
    flat = jax.tree_util.tree_map(
        lambda x: x.reshape((-1,) + x.shape[2:]), keys_local)
    ans = _fused_pallas_xor_answer(db_local, flat, start_block, log_local,
                                   plan)
    return xor_fold(ans.reshape((q, -1) + ans.shape[1:]), 1)


def _fused_pallas_add_answer(db_local, keys_local, start_block, log_local,
                             plan: ExecutionPlan) -> jax.Array:
    """Megakernel additive answer: in-kernel share conversion + select-add,
    bit-identical int32 to the materialized int8 GEMM."""
    from repro.kernels import ops
    tile, roots, t_roots, cw_s, cw_t = _fused_pallas_inputs(
        keys_local, start_block, log_local, db_local.shape[0], plan)
    return ops.fused_scan_bytes(db_local, roots, t_roots, cw_s, cw_t,
                                keys_local.cw_final[:, 0],
                                party=keys_local.party, tile_r=tile,
                                depth=plan.depth)


# ---------------------------------------------------------------------------
# additive-dpf-2: Z_256 shares -> one int8 GEMM per batch (beyond-paper)
# ---------------------------------------------------------------------------

class AdditiveDpf2(PIRProtocol):
    """Two-server additive PIR: Z_256 byte shares, batched-query GEMM.

    A batch of Q queries against one DB shard is one int8 matrix product
    ``shares[Q, R] x db[R, L]`` — the DB is read once per *batch*, not per
    query, multiplying operational intensity by Q (DESIGN.md §2,
    kernels/pir_matmul.py). Answers are int32 byte-columns; only their
    value mod 256 matters, so int32 wraparound preserves it. The int8
    byte view of the DB comes from the database plane (``db_view``) —
    it is resident and incrementally maintained, not re-derived from the
    word store inside every serve step.
    """

    name = "additive-dpf-2"
    share_kind = "additive"
    db_view = "bytes"

    def n_parties(self, cfg: PIRConfig) -> int:
        return 2

    def query_gen(self, rng, index, cfg):
        rounds = PRG_ROUNDS[cfg.prf]
        return dpf.gen_keys(
            rng, index, cfg.log_n,
            payload=np.array([1], np.uint32), payload_mod=256, rounds=rounds,
        )

    def key_specs(self, cfg, n_queries, *, party=0):
        return _dpf_key_specs(cfg, n_queries, party=party, with_payload=True)

    def answer_local(self, db_local, keys_local, start_block, log_local,
                     plan):
        # db_local is already the int8 byte view [rows_local, item_bytes]
        if plan.expand == "fused-pallas":
            return _fused_pallas_add_answer(db_local, keys_local,
                                            start_block, log_local, plan)
        shares = dpf.eval_bytes_batch(keys_local, start_block, log_local)
        if plan.scan == "pallas":
            from repro.kernels import ops
            return ops.pir_gemm(shares.astype(jnp.int8), db_local,
                                tile_q=plan.tile_q, tile_r=plan.tile_r,
                                tile_l=plan.tile_l)
        return answer_additive_matmul(db_local, shares)

    def reduce(self, partial_res, axis, n_shards, plan):
        return jax.lax.psum(partial_res, axis)   # additive: native psum

    def reconstruct(self, answers):
        acc = answers[0].astype(jnp.int32)
        for a in answers[1:]:
            acc = acc + a.astype(jnp.int32)
        return (acc % 256).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# xor-dpf-k: k >= 2 servers, k-of-k XOR shares (beyond-paper)
# ---------------------------------------------------------------------------

class XorDpfK(_XorProtocol):
    """k-server XOR PIR: one DPF pair blinded by a ring of shared masks.

    Construction (1-private, k-of-k reconstruct; DESIGN.md §7.2): draw
    mask seeds s_0..s_{k-1}; party i expands masks m(s_i) and
    m(s_{(i+1) mod k}) — plain (correction-free) GGM trees, so two parties
    holding the same seed derive the *same* pseudorandom selection vector.
    Parties 0 and 1 additionally hold the real DPF pair (d_0, d_1) for the
    queried index. Each seed appears at exactly two parties, so the XOR of
    all k selection vectors is Eval(d_0) ^ Eval(d_1) = e_alpha, while any
    single party sees only a DPF key and/or fresh random seeds — nothing
    about alpha. Every party's vector is dense pseudorandom, so all k
    servers do identical full-scan work (no idle replicas).

    Per-party keys are batched ``DPFKey`` pytrees with a leading *component*
    axis (3 components for parties 0/1: real key + two masks; 2 for the
    rest), evaluated per component and XOR-folded. k=2 degenerates to the
    two-server scheme (the shared masks cancel pairwise).
    """

    name = "xor-dpf-k"

    def n_parties(self, cfg: PIRConfig) -> int:
        if cfg.n_servers < 2:
            raise ValueError(f"xor-dpf-k needs n_servers >= 2, "
                             f"got {cfg.n_servers}")
        return cfg.n_servers

    @staticmethod
    def _n_components(party: int) -> int:
        return 3 if party < 2 else 2

    def query_gen(self, rng, index, cfg):
        k = self.n_parties(cfg)
        rounds = PRG_ROUNDS[cfg.prf]
        log_n = cfg.log_n
        d0, d1 = dpf.gen_keys(rng, index, log_n, rounds=rounds)
        seeds = [rng.integers(0, 1 << 32, size=4, dtype=np.uint32)
                 for _ in range(k)]
        zero_cw = jnp.zeros((log_n, 4), U32)
        zero_t = jnp.zeros((log_n, 2), U32)

        def mask_key(seed: np.ndarray) -> dpf.DPFKey:
            # zero correction words make eval_range a plain GGM PRG tree:
            # its leaf t-bits depend only on the seed, so both holders of a
            # seed derive identical (cancelling) masks.
            return dpf.DPFKey(party=0, log_n=log_n,
                              root_seed=jnp.asarray(seed),
                              cw_seed=zero_cw, cw_t=zero_t,
                              cw_final=None, rounds=rounds)

        keys = []
        for i in range(k):
            comps = [d0] if i == 0 else [d1] if i == 1 else []
            comps.append(mask_key(seeds[i]))
            comps.append(mask_key(seeds[(i + 1) % k]))
            # aux party must agree across stacked components
            comps = [replace_party(c, i) for c in comps]
            keys.append(dpf.stack_keys(comps))
        return tuple(keys)

    def key_specs(self, cfg, n_queries, *, party=0):
        return _dpf_key_specs(cfg, n_queries, party=party,
                              with_payload=False,
                              components=self._n_components(party))

    def answer_local(self, db_local, keys_local, start_block, log_local,
                     plan):
        if plan.expand == "materialize":
            bits = _component_bits_batch(keys_local, start_block, log_local)
            return _xor_scan(db_local, bits, plan)
        if plan.expand == "fused":
            return _fused_xor_answer(db_local, keys_local, start_block,
                                     log_local, plan, _component_bits)
        if plan.expand == "fused-pallas":
            return _fused_pallas_xor_k_answer(db_local, keys_local,
                                              start_block, log_local, plan)
        raise ValueError(f"unknown expand {plan.expand!r}")


def replace_party(key: dpf.DPFKey, party: int) -> dpf.DPFKey:
    """A key with its (aux) party id rewritten.

    The party id never enters mask evaluation (with zero correction words
    the initial t-bit multiplies nothing), but pytree aux data must agree
    for components to stack and for ``key_specs`` treedefs to match.
    """
    return dpf.DPFKey(party=party, log_n=key.log_n,
                      root_seed=key.root_seed, cw_seed=key.cw_seed,
                      cw_t=key.cw_t, cw_final=key.cw_final,
                      rounds=key.rounds)


def _component_bits(key: dpf.DPFKey, block, log_range: int) -> jax.Array:
    """XOR-fold of one query's component keys' selection bits (leaves [C,...])."""
    bs = jax.vmap(lambda c: _bits_of_key(c, block, log_range))(key)
    return xor_fold(bs, 0)


@partial(jax.jit, static_argnames=("log_range",))
def _component_bits_batch(keys: dpf.DPFKey, start_block, log_range: int
                          ) -> jax.Array:
    """[Q, C, ...] component keys -> [Q, 2^log_range] folded selection bits.

    jit'd (mirroring ``dpf.eval_bytes_batch``): the doubly-vmapped GGM walk
    is minutes of eager dispatch overhead otherwise.
    """
    return jax.vmap(lambda k: _component_bits(k, start_block, log_range))(keys)


# ---------------------------------------------------------------------------
# lwe-simple-1: single-server SimplePIR-style LWE PIR (beyond-paper)
# ---------------------------------------------------------------------------

class LweSimple1(PIRProtocol):
    """Single-server LWE PIR: encrypted one-hot query, int32 GEMM answer.

    The first protocol with no non-collusion assumption (DESIGN.md §10):
    privacy rests on LWE hardness, not on servers never comparing notes.
    The price is a preprocessed *hint* ``H = A^T.DB`` the client needs at
    reconstruction time — built by the database plane per epoch
    (``ShardedDatabase.register_hint``) and delta-updated on ``publish()``.

    Server hot loop: ``ct[Q, N] x db_bytes32[N, L] -> int32 [Q, L]`` —
    structurally the additive GEMM with int32 operands, so it slots into
    the same engine tile space (``lwe-gemm-*`` descriptors). int32
    accumulation wraps mod 2^32 = mod q natively: the GEMM *is* the Z_q
    contraction, and cross-shard psum (also wrapping) is the Z_q sum.

    Correctness is parameterized, not assumed: ``core/lwe.py`` selects
    (n, sigma) from a validated table and ``LWEParams.validate`` raises
    when the noise bound crosses q/(2p) — see the noise-budget property
    tests. Parameters are demonstration-grade, not a security review.
    """

    name = "lwe-simple-1"
    share_kind = "lwe"
    db_view = "bytes32"
    needs_hint = True

    def _params(self, cfg: PIRConfig):
        from repro.core import lwe
        return lwe.params_for(cfg.n_items)

    # -- client side ----------------------------------------------------
    def n_parties(self, cfg: PIRConfig) -> int:
        return 1

    def query_gen_full(self, rng, index, cfg):
        from repro.core import lwe
        ct, state = lwe.encrypt(rng, index, cfg.n_items, self._params(cfg))
        return (ct,), state

    def query_gen(self, rng, index, cfg):
        # keys without the secret: enough for serve-side tooling (tuner
        # measurement inputs); reconstruction requires query_gen_full.
        return self.query_gen_full(rng, index, cfg)[0]

    def reconstruct(self, answers):
        raise NotImplementedError(
            "lwe-simple-1 reconstruction needs per-query client state and "
            "the epoch hint: use reconstruct_with(answers, states, cfg=..., "
            "hint=...) — sessions route this via SingleServerPIR")

    def reconstruct_with(self, answers, states, *, cfg=None, hint=None):
        from repro.core import lwe
        if cfg is None or hint is None or any(s is None for s in states):
            raise ValueError("lwe-simple-1 reconstruct_with needs cfg=, "
                             "hint= and one client state per query")
        params = self._params(cfg)
        secrets = np.stack([s.s for s in states])
        hint_u64 = np.asarray(hint).view(np.uint32).astype(np.uint64)
        records, err = lwe.decode(np.asarray(answers[0]), secrets, hint_u64,
                                  params)
        # correctness-bound assertion. The recovered residual lands in
        # [-Delta/2, Delta/2) by construction, so comparing it to the
        # budget q/(2p) = Delta/2 would be vacuous; the checkable bound
        # is the analytic tail validate() enforces (well under Delta/2):
        # honest noise sits ~TAIL sigmas inside it, while a wrong hint /
        # mismatched epoch makes the residual near-uniform in the Delta
        # window and trips it with overwhelming probability.
        max_err = int(np.abs(err).max()) if err.size else 0
        bound = params.noise_bound(cfg.n_items)
        if max_err >= bound:
            raise IntegrityError(
                f"LWE noise overflow: recovered |e^T.D| = {max_err} >= "
                f"tail bound {bound:.4g} (budget q/(2p) = "
                f"{params.noise_budget}); the answers do not match this "
                f"hint/epoch — reconstruction is not trustworthy")
        if getattr(cfg, "checksum", False):
            # the noise check alone cannot catch a corruption that shifts
            # an answer by a multiple of Delta (it aliases to a clean
            # plaintext shift); the row checksum closes that gap
            records = self.verify_reconstruction(records, cfg)
        return jnp.asarray(records)

    def record_struct(self, cfg: PIRConfig):
        return (cfg.item_bytes,), np.uint8

    # -- server side ----------------------------------------------------
    def key_specs(self, cfg, n_queries, *, party=0):
        from repro.core.lwe import LWECiphertext
        return LWECiphertext(
            ct=jax.ShapeDtypeStruct((n_queries, cfg.n_items), np.int32),
            log_n=cfg.log_n, n=self._params(cfg).n)

    def answer_local(self, db_local, keys_local, start_block, log_local,
                     plan):
        # db_local is the int32 byte view [rows_local, item_bytes]; slice
        # this shard's ciphertext columns (start_block may be traced).
        rows_local = db_local.shape[0]
        ct = keys_local.ct
        start = start_block * rows_local
        ct_local = jax.lax.dynamic_slice_in_dim(ct, start, rows_local, axis=1)
        if plan.scan == "pallas":
            from repro.kernels import ops
            return ops.lwe_gemm(ct_local, db_local, tile_q=plan.tile_q,
                                tile_r=plan.tile_r, tile_l=plan.tile_l)
        return jax.lax.dot_general(ct_local, db_local,
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)

    def reduce(self, partial_res, axis, n_shards, plan):
        return jax.lax.psum(partial_res, axis)   # int32 psum wraps mod q

    # -- hint lifecycle -------------------------------------------------
    def hint_builder(self, cfg: PIRConfig):
        from repro.core import lwe
        return lwe.hint_build_fn(self._params(cfg), cfg.n_items)

    def hint_delta(self, cfg: PIRConfig):
        from repro.core import lwe
        return lwe.hint_delta_fn(self._params(cfg), cfg.n_items)

    # -- batching: LWECiphertext is not a DPFKey ------------------------
    def pad(self, keys, n_total: int):
        q = self.n_queries(keys)
        if n_total < q:
            raise ValueError(f"cannot pad {q} queries down to {n_total}")
        if n_total == q:
            return keys
        pad = n_total - q

        def pad_leaf(leaf):
            reps = (pad,) + (1,) * (leaf.ndim - 1)
            return jnp.concatenate([leaf, jnp.tile(leaf[-1:], reps)], axis=0)

        return jax.tree_util.tree_map(pad_leaf, keys)

    def n_queries(self, keys) -> int:
        return int(keys.ct.shape[0])


register(XorDpf2())
register(AdditiveDpf2())
register(XorDpfK())
register(LweSimple1())
