"""Distributed point functions (DPF) — the cryptographic core of IM-PIR.

Implements the two-party GGM-tree DPF of Gilboa–Ishai [35] with the
Boyle–Gilboa–Ishai correction-word optimization — the same construction the
paper adopts from Lam et al. [61] (§3.1–3.2): each key is a root seed plus
one correction word per tree level (the paper's "two 2-dimensional
codewords C0, C1 ∈ F_{2^λ}^{2×(log N + 1)}").

TPU adaptation (DESIGN.md §2): the paper evaluates the tree on the host CPU
with AES-NI because UPMEM DPUs cannot run AES efficiently and level-by-level
sharing would require inter-DPU communication. Here the PRG is an ARX
permutation (crypto/chacha.py) that vectorizes over 32-bit VPU lanes, so
full-domain evaluation runs *on-device*, breadth-first, one `ggm_double`
call per level — and, crucially, each database shard evaluates only its own
leaf range (`eval_range`): a path descent to the shard's subtree root
followed by local breadth-first expansion. No cross-shard communication,
which is exactly the property the paper could not get from UPMEM.

Output modes
------------
bits   leaf control bits t(j): t0(j) XOR t1(j) = 1{j == alpha}.
       This is the selection vector of the paper's dpXOR stage.
words  additive shares over Z_{2^32}^W: y0(j) + y1(j) = beta * 1{j == alpha}.
bytes  additive shares over Z_256: the MXU-friendly int8 form used by the
       batched-query matmul path (beyond-paper; see kernels/pir_matmul.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto.chacha import ggm_double, prg_bits

U32 = jnp.uint32


@jax.tree_util.register_pytree_node_class
@dataclass
class DPFKey:
    """One party's DPF key (a pytree; vmap-able over a batch of queries).

    Attributes:
      party:     0 or 1 (static).
      log_n:     tree depth = log2(domain size) (static).
      root_seed: [4] uint32 — 128-bit root seed.
      cw_seed:   [log_n, 4] uint32 — per-level seed correction words.
      cw_t:      [log_n, 2] uint32 — per-level (tL, tR) control corrections.
      cw_final:  [W] uint32 / int32 payload correction (None in bit mode).
      rounds:    PRG rounds (static).
    """
    party: int
    log_n: int
    root_seed: jax.Array
    cw_seed: jax.Array
    cw_t: jax.Array
    cw_final: Optional[jax.Array]
    rounds: int = 12

    def tree_flatten(self):
        children = (self.root_seed, self.cw_seed, self.cw_t, self.cw_final)
        aux = (self.party, self.log_n, self.rounds)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        party, log_n, rounds = aux
        root_seed, cw_seed, cw_t, cw_final = children
        return cls(party, log_n, root_seed, cw_seed, cw_t, cw_final, rounds)


# ---------------------------------------------------------------------------
# Key generation (client side; paper Algorithm 1, GENERATEANDSENDKEYS)
# ---------------------------------------------------------------------------

def gen_keys(
    rng: np.random.Generator,
    alpha: int,
    log_n: int,
    *,
    payload: Optional[np.ndarray] = None,
    payload_mod: int = 1 << 32,  # retained for API clarity; arithmetic is native u32 wrap
    rounds: int = 12,
) -> Tuple[DPFKey, DPFKey]:
    """Gen(1^λ, α, β) -> (k0, k1). See module docstring."""
    if not (0 <= alpha < (1 << log_n)):
        raise ValueError(f"alpha={alpha} out of domain 2^{log_n}")
    root = [
        jnp.asarray(rng.integers(0, 1 << 32, size=4, dtype=np.uint32)),
        jnp.asarray(rng.integers(0, 1 << 32, size=4, dtype=np.uint32)),
    ]
    s = [root[0], root[1]]
    t = [jnp.asarray(0, U32), jnp.asarray(1, U32)]
    cw_seeds, cw_ts = [], []
    for level in range(log_n):
        bit = (alpha >> (log_n - 1 - level)) & 1
        exp = [ggm_double(s[b], rounds=rounds) for b in (0, 1)]
        s_l = [e[0] for e in exp]
        t_l = [e[1] for e in exp]
        s_r = [e[2] for e in exp]
        t_r = [e[3] for e in exp]
        s_cw = (s_l[0] ^ s_l[1]) if bit else (s_r[0] ^ s_r[1])
        t_cw_l = t_l[0] ^ t_l[1] ^ U32(bit) ^ U32(1)
        t_cw_r = t_r[0] ^ t_r[1] ^ U32(bit)
        cw_seeds.append(s_cw)
        cw_ts.append(jnp.stack([t_cw_l, t_cw_r]))
        new_s, new_t = [], []
        for b in (0, 1):
            keep_s = s_r[b] if bit else s_l[b]
            keep_t = t_r[b] if bit else t_l[b]
            keep_t_cw = t_cw_r if bit else t_cw_l
            new_s.append(keep_s ^ (t[b] * s_cw))
            new_t.append(keep_t ^ (t[b] & keep_t_cw))
        s, t = new_s, new_t
    cw_seed = jnp.stack(cw_seeds) if log_n else jnp.zeros((0, 4), U32)
    cw_t = jnp.stack(cw_ts) if log_n else jnp.zeros((0, 2), U32)

    cw_final = None
    if payload is not None:
        # All payload arithmetic is native mod-2^32 uint32 wraparound; the
        # Z_256 byte mode masks with 0xFF at use time (256 | 2^32, so the
        # congruence survives the reduction).
        w = int(np.asarray(payload).shape[-1])
        conv = [prg_bits(s[b], w, rounds=rounds) for b in (0, 1)]
        beta = jnp.asarray(np.asarray(payload, dtype=np.uint32))
        diff = beta - conv[0] + conv[1]
        cw_final = jnp.where(t[1] == 1, (~diff) + U32(1), diff)

    return tuple(
        DPFKey(
            party=b,
            log_n=log_n,
            root_seed=root[b],
            cw_seed=cw_seed,
            cw_t=cw_t,
            cw_final=cw_final,
            rounds=rounds,
        )
        for b in (0, 1)
    )


# ---------------------------------------------------------------------------
# Evaluation (server side; paper Algorithm 1, EVALUATEDPF — here on-device)
# ---------------------------------------------------------------------------

def _expand_level(seeds, t_bits, cw_seed_l, cw_t_l, rounds):
    """One breadth-first level: [m,4] seeds -> [2m,4], leaf order preserved."""
    s_l, t_l, s_r, t_r = ggm_double(seeds, rounds=rounds)
    mask = t_bits[:, None] * cw_seed_l[None, :]
    s_l = s_l ^ mask
    s_r = s_r ^ mask
    t_l = t_l ^ (t_bits & cw_t_l[0])
    t_r = t_r ^ (t_bits & cw_t_l[1])
    # interleave children so leaf j sits at index j
    m = seeds.shape[0]
    seeds2 = jnp.stack([s_l, s_r], axis=1).reshape(2 * m, 4)
    t2 = jnp.stack([t_l, t_r], axis=1).reshape(2 * m)
    return seeds2, t2


def eval_range(
    key: DPFKey,
    start_block: jax.Array | int,
    log_range: int,
) -> Tuple[jax.Array, jax.Array]:
    """Evaluate leaves [start_block * 2^log_range, (start_block+1) * 2^log_range).

    Path-descend ``log_n - log_range`` levels (the bits of ``start_block``,
    MSB first), then breadth-first expand the shard-local subtree. This is
    the shard-parallel form of the paper's EVALUATEDPF: DB shard ``d`` only
    ever computes its own Eval(k, j) slice (paper §3.3 distributes these
    slices from the host; we never materialize the full vector anywhere).

    Returns (seeds [2^log_range, 4] u32, t_bits [2^log_range] u32).
    """
    if log_range > key.log_n:
        raise ValueError("log_range exceeds domain")
    depth = key.log_n - log_range
    start_block = jnp.asarray(start_block, U32)
    seeds = key.root_seed
    t = jnp.asarray(key.party, U32)
    for level in range(depth):
        bit = (start_block >> U32(depth - 1 - level)) & U32(1)
        s_l, t_l, s_r, t_r = ggm_double(seeds, rounds=key.rounds)
        s_cw = key.cw_seed[level]
        t_cw = key.cw_t[level]
        s_l = s_l ^ (t * s_cw)
        s_r = s_r ^ (t * s_cw)
        t_l = t_l ^ (t & t_cw[0])
        t_r = t_r ^ (t & t_cw[1])
        seeds = jnp.where(bit, s_r, s_l)
        t = jnp.where(bit, t_r, t_l)
    seeds = seeds[None, :]
    t = t[None]
    for level in range(depth, key.log_n):
        seeds, t = _expand_level(
            seeds, t, key.cw_seed[level], key.cw_t[level], key.rounds
        )
    return seeds, t


def eval_all(key: DPFKey) -> Tuple[jax.Array, jax.Array]:
    """Full-domain evaluation (single shard / reference path)."""
    return eval_range(key, 0, key.log_n)


def eval_to_depth(
    key: DPFKey,
    start_block: jax.Array | int,
    log_range: int,
    stop_log: int,
) -> Tuple[jax.Array, jax.Array]:
    """Partial evaluation: the shard's *internal* nodes at chunk granularity.

    Identical to :func:`eval_range` (same descent, same breadth expansion,
    so parity is by construction) but stops ``stop_log`` levels above the
    leaves: returns the corrected subtree-root seeds + control bits of the
    shard's ``2^(log_range - stop_log)`` chunks of ``2^stop_log`` leaves
    each. These are the inputs of the fused-scan megakernel
    (``kernels/fused_scan.py``), which expands the remaining ``stop_log``
    levels in VMEM — one descent shared across all chunks, unlike the
    chunked-jnp fused path which re-descends per chunk.

    Returns (seeds ``[2^(log_range - stop_log), 4]`` u32, t same-length).
    """
    if log_range > key.log_n:
        raise ValueError("log_range exceeds domain")
    if not (0 <= stop_log <= log_range):
        raise ValueError(f"stop_log={stop_log} outside [0, {log_range}]")
    depth = key.log_n - log_range
    start_block = jnp.asarray(start_block, U32)
    seeds = key.root_seed
    t = jnp.asarray(key.party, U32)
    for level in range(depth):
        bit = (start_block >> U32(depth - 1 - level)) & U32(1)
        s_l, t_l, s_r, t_r = ggm_double(seeds, rounds=key.rounds)
        s_cw = key.cw_seed[level]
        t_cw = key.cw_t[level]
        s_l = s_l ^ (t * s_cw)
        s_r = s_r ^ (t * s_cw)
        t_l = t_l ^ (t & t_cw[0])
        t_r = t_r ^ (t & t_cw[1])
        seeds = jnp.where(bit, s_r, s_l)
        t = jnp.where(bit, t_r, t_l)
    seeds = seeds[None, :]
    t = t[None]
    for level in range(depth, key.log_n - stop_log):
        seeds, t = _expand_level(
            seeds, t, key.cw_seed[level], key.cw_t[level], key.rounds
        )
    return seeds, t


@partial(jax.jit, static_argnames=("log_range", "stop_log"))
def eval_roots_batch(keys: DPFKey, start_block, log_range: int,
                     stop_log: int) -> Tuple[jax.Array, jax.Array]:
    """vmap'd :func:`eval_to_depth` over a batched key pytree.

    Returns (seeds ``[Q, C, 4]`` u32, t ``[Q, C]`` u32) where
    ``C = 2^(log_range - stop_log)`` chunk roots per query.
    """
    return jax.vmap(
        lambda k: eval_to_depth(k, start_block, log_range, stop_log))(keys)


def leaf_bits(t_bits: jax.Array) -> jax.Array:
    """Selection bits for the dpXOR stage (paper's Eval(k, j) values)."""
    return t_bits.astype(U32)


def leaf_words(
    key: DPFKey, seeds: jax.Array, t_bits: jax.Array, n_words: int
) -> jax.Array:
    """Additive payload shares over Z_{2^32}^W.

    y_b(j) = (-1)^b * (convert(s_j) + t_j * cw_final)  mod 2^32.
    Σ_b y_b(j) = β · 1{j == α}.
    """
    if key.cw_final is None:
        raise ValueError("key was generated without a payload")
    conv = prg_bits(seeds, n_words, rounds=key.rounds)
    share = conv + t_bits[:, None] * key.cw_final[None, :n_words]
    if key.party == 1:
        share = (~share) + U32(1)  # negate mod 2^32
    return share


def leaf_bytes(
    key: DPFKey, seeds: jax.Array, t_bits: jax.Array
) -> jax.Array:
    """Additive scalar shares over Z_256 (int8) — MXU matmul form.

    Requires the key to be generated with ``payload=[1]`` and
    ``payload_mod=256``; uses word 0 of the conversion PRG.
    """
    if key.cw_final is None:
        raise ValueError("key was generated without a payload")
    conv = prg_bits(seeds, 1, rounds=key.rounds)[:, 0] & U32(0xFF)
    share = (conv + t_bits * (key.cw_final[0] & U32(0xFF))) & U32(0xFF)
    if key.party == 1:
        share = (U32(256) - share) & U32(0xFF)
    return share.astype(jnp.uint8)


def eval_bits_batch(keys: DPFKey, start_block, log_range) -> jax.Array:
    """vmap'd selection-bit evaluation for a batch of stacked keys.

    ``keys``: DPFKey with leading query axis on all array leaves.
    Returns ``[Q, 2^log_range] uint32`` selection bits.
    """
    def one(k):
        _, t = eval_range(k, start_block, log_range)
        return leaf_bits(t)

    return jax.vmap(one)(keys)


def stack_keys(keys) -> DPFKey:
    """Stack a list of same-shape DPFKeys into one batched pytree."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *keys)


def n_queries_of(keys: DPFKey) -> int:
    """Leading (query) axis length of a batched key pytree."""
    return int(keys.root_seed.shape[0])


def pad_keys(keys: DPFKey, n_total: int) -> DPFKey:
    """Pad a batched key pytree to ``n_total`` queries along the batch axis.

    Pad slots replicate the last real key: every padded slot is a *valid*
    DPF key, so the serve step evaluates it like any other query and the
    extra answers are simply discarded by the caller (DESIGN.md §6 padding
    rule). Because each query's answer is an independent vmap lane, padding
    can never corrupt the real answers.
    """
    q = n_queries_of(keys)
    if n_total < q:
        raise ValueError(f"cannot pad {q} queries down to {n_total}")
    if n_total == q:
        return keys
    pad = n_total - q

    def pad_leaf(leaf):
        reps = (pad,) + (1,) * (leaf.ndim - 1)
        return jnp.concatenate([leaf, jnp.tile(leaf[-1:], reps)], axis=0)

    return jax.tree_util.tree_map(pad_leaf, keys)


@partial(jax.jit, static_argnames=("log_range",))
def eval_bytes_batch(keys: DPFKey, start_block, log_range: int) -> jax.Array:
    """vmap'd Z_256 additive shares: ``[Q, 2^log_range] int8``-compatible u8."""
    def one(k):
        seeds, t = eval_range(k, start_block, log_range)
        return leaf_bytes(k, seeds, t)

    return jax.vmap(one)(keys)
