"""Batch PIR cuckoo layer — m records per round for ~one bucketed scan.

The paper's throughput thesis is that PIR QPS is bounded by DB streaming
bandwidth; batching is the protocol-plane lever that multiplies *records*
per streamed byte (DESIGN.md §14). The classic construction (Angel et al.
style) splits one retrieval round in two:

Server side (public, query-independent)
    Every record is replicated into ALL of its ``n_hashes`` candidate
    buckets (simple hashing), so whichever bucket the client later picks
    for an index, that bucket's sub-database contains the record. With
    B = c·m buckets each holds ~``n_hashes``·N/B rows.

Client side (per batch, private)
    The m requested indices are *cuckoo hashed* into distinct buckets
    (per-bucket capacity 1, random-walk eviction): index i may only land
    in one of its candidate buckets h_0(i)..h_{H-1}(i), and no bucket
    takes two. Every bucket then receives exactly ONE inner-protocol
    query — a real one for its assigned index's slot, a *dummy* (random
    in-bucket slot) for unassigned buckets — so the per-round traffic is
    a constant B queries regardless of which indices were requested:
    bucket occupancy leaks nothing (the uniform-padding invariant the
    conformance tests pin).

Amortization: one round scans B · capacity ≈ 2·``n_hashes``·N rows (the
power-of-two capacity rounding costs up to 2×) and serves m records —
records per scanned row improve by ~m·B/(B·capacity)·N = m/4 at the
defaults, an *algorithmic* factor on top of whatever kernel serves each
bucket (the inner protocol + engine-tuned plan apply per bucket shape
unchanged).

``CuckooParams.validate`` enforces the analytic failure-probability bound
the same way ``LWEParams.validate`` enforces the noise bound: parameters
that cannot guarantee insertion success with overwhelming probability
raise instead of failing probabilistically at query time. Residual
failures (the bound is O(1/B), not zero) surface as :class:`CuckooFailure`
and the session layer retries the batch split in half — correctness is
never staked on the bound.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import PIRConfig

#: d-ary cuckoo hashing load threshold: below it a valid assignment exists
#: w.h.p. and random-walk insertion succeeds with failure prob O(1/B).
#: alpha*_3 ~= 0.9179 for 3 hash functions; we enforce a margin under it
#: (the bound degrades steeply as alpha -> alpha*).
ALPHA_MAX = 0.8

#: random-walk insertion: eviction steps per item before declaring failure
#: (O(log B) suffices below threshold; the generous constant keeps the
#: residual failure probability at the analytic O(1/B) order).
_WALK_STEPS_PER_ITEM = 64


class CuckooFailure(RuntimeError):
    """Cuckoo insertion exceeded its eviction budget for one batch.

    Probability is bounded by ``CuckooParams.failure_bound`` (O(1/B) below
    the load threshold); the session layer (``runtime/batch.py``) recovers
    by splitting the batch — never by weakening privacy.
    """

    def __init__(self, msg: str, index: Optional[int] = None):
        super().__init__(msg)
        self.index = index


@dataclass(frozen=True)
class CuckooParams:
    """Batch-PIR cuckoo parameters; correctness conditions are methods.

    m         batch size: requested indices per round (capacity of one
              cuckoo assignment).
    c         bucket expansion: B = max(ceil(c·m), 2) buckets. The default
              2.0 keeps B a power of two for power-of-two m, which halves
              the per-bucket capacity rounding waste.
    n_hashes  candidate buckets per index (the paper-standard 3).
    seed      domain-separation seed for the bucket hash family; public
              (the layout is server-side data placement, not key material).
    """
    m: int
    c: float = 2.0
    n_hashes: int = 3
    seed: int = 0x5EEDBA11

    @classmethod
    def from_config(cls, cfg: PIRConfig) -> "CuckooParams":
        return cls(m=cfg.batch_m, c=cfg.cuckoo_c,
                   n_hashes=cfg.cuckoo_hashes, seed=cfg.cuckoo_seed)

    @property
    def n_buckets(self) -> int:
        """B = ceil(c·m), floored at 2 (a 1-bucket table cannot pad)."""
        return max(int(math.ceil(self.c * self.m)), 2)

    @property
    def load_factor(self) -> float:
        """alpha = m / B — the axis the cuckoo threshold bounds."""
        return self.m / self.n_buckets

    def failure_bound(self) -> float:
        """Analytic order bound on one batch's insertion failure.

        Below the load threshold, random-walk d-ary cuckoo insertion of m
        items into B capacity-1 buckets fails with probability O(1/B)
        (the constant absorbed here is 1 — demonstration-grade like the
        LWE table, and the session's split-retry removes any correctness
        stake). Reported, and monotonicity-checked by the property tests.
        """
        return min(1.0, 1.0 / self.n_buckets)

    def validate(self) -> "CuckooParams":
        """Raise unless these parameters guarantee assignable batches.

        Mirrors ``LWEParams.validate``: the checkable inequality is the
        load margin alpha <= ALPHA_MAX < alpha*_3 — past the threshold a
        valid assignment stops existing w.h.p. and no amount of eviction
        walking recovers it, so such configs must fail at construction,
        not probabilistically at query time.
        """
        if self.m < 1:
            raise ValueError(
                f"batch size m must be >= 1, got {self.m} — set "
                f"PIRConfig.batch_m for the BatchPIR composite")
        if self.n_hashes < 2:
            raise ValueError(
                f"cuckoo hashing needs >= 2 hash functions, got "
                f"{self.n_hashes} (one choice cannot evict)")
        if self.c <= 0:
            raise ValueError(f"bucket expansion c must be > 0, got {self.c}")
        if self.load_factor > ALPHA_MAX:
            raise ValueError(
                f"cuckoo load factor m/B = {self.m}/{self.n_buckets} = "
                f"{self.load_factor:.3f} > {ALPHA_MAX} (margin under the "
                f"3-ary threshold ~0.918): insertion failure is no longer "
                f"O(1/B) — raise c (need c >= {1 / ALPHA_MAX:.2f})")
        return self


def bucket_hashes(indices, params: CuckooParams) -> np.ndarray:
    """Candidate buckets of each index: [...,] -> [..., n_hashes] int64.

    A murmur3-finalizer avalanche over (seed, hash id, index) mod B —
    deterministic, vectorized host math (the ``row_checksum`` idiom), and
    shared verbatim by the server layout and the client assignment, which
    is what makes the bucketed sub-databases queryable at all.
    """
    idx = np.asarray(indices, dtype=np.uint64)
    out = np.empty(idx.shape + (params.n_hashes,), dtype=np.int64)
    for j in range(params.n_hashes):
        salt = (params.seed + j * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        x = idx ^ np.uint64(salt)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
        out[..., j] = (x % np.uint64(params.n_buckets)).astype(np.int64)
    return out


@dataclass(frozen=True)
class CuckooLayout:
    """Server-side bucketed placement of one N-record database.

    Public, query-independent data placement: record i occupies one slot
    in EACH of its distinct candidate buckets. ``capacity`` is the
    power-of-two bucket height (max bucket load rounded up — the GGM tree
    domain of the inner per-bucket protocol), with unoccupied slots held
    as zero pad rows.

    bucket_rows  per bucket, the global row ids in slot order.
    slot_of      [N, n_hashes] int32 — the slot of record i inside bucket
                 ``hashes[i, j]`` (duplicate candidate buckets repeat the
                 first occurrence's slot, so lookup by (i, any j) works).
    """
    n_items: int
    params: CuckooParams
    capacity: int
    hashes: np.ndarray = field(repr=False)        # [N, H] candidate buckets
    slot_of: np.ndarray = field(repr=False)       # [N, H] in-bucket slots
    bucket_rows: Tuple[np.ndarray, ...] = field(repr=False)

    @property
    def n_buckets(self) -> int:
        return self.params.n_buckets

    @property
    def loads(self) -> np.ndarray:
        return np.array([len(r) for r in self.bucket_rows])

    @classmethod
    def build(cls, n_items: int, params: CuckooParams) -> "CuckooLayout":
        params.validate()
        cand = bucket_hashes(np.arange(n_items), params)       # [N, H]
        n, h = cand.shape
        # first-occurrence mask: an index whose hashes collide on one
        # bucket occupies that bucket's slot once, not twice
        first = np.ones((n, h), dtype=bool)
        for j in range(1, h):
            first[:, j] = np.all(cand[:, j:j + 1] != cand[:, :j], axis=1)
        rows_i, rows_j = np.nonzero(first)
        b_flat = cand[rows_i, rows_j]
        # slot = rank within bucket, records in ascending row-id order
        # (rows_i is already sorted; stable lexsort by bucket keeps it)
        order = np.argsort(b_flat, kind="stable")
        sorted_b = b_flat[order]
        group_start = np.searchsorted(sorted_b, np.arange(params.n_buckets))
        slots_sorted = np.arange(len(sorted_b)) \
            - np.repeat(group_start, np.diff(
                np.append(group_start, len(sorted_b))))
        slot_flat = np.empty(len(order), dtype=np.int64)
        slot_flat[order] = slots_sorted
        slot_of = np.full((n, h), -1, dtype=np.int32)
        slot_of[rows_i, rows_j] = slot_flat
        # duplicate candidates inherit the first occurrence's slot
        for j in range(1, h):
            for jj in range(j):
                dup = (~first[:, j]) & (cand[:, j] == cand[:, jj])
                slot_of[dup, j] = slot_of[dup, jj]
        loads = np.bincount(sorted_b, minlength=params.n_buckets)
        cap = 1 << max(int(loads.max()) - 1, 1).bit_length()
        bucket_rows = tuple(
            rows_i[order][group_start[b]:group_start[b] + loads[b]]
            for b in range(params.n_buckets))
        return cls(n_items=n_items, params=params, capacity=cap,
                   hashes=cand, slot_of=slot_of, bucket_rows=bucket_rows)

    def slot(self, index: int, bucket: int) -> int:
        """The slot of record ``index`` inside one of its candidate
        buckets (KeyError if the bucket is not a candidate)."""
        for j in range(self.params.n_hashes):
            if self.hashes[index, j] == bucket:
                return int(self.slot_of[index, j])
        raise KeyError(
            f"bucket {bucket} is not a candidate of index {index} "
            f"(candidates: {self.hashes[index].tolist()})")

    def occurrences(self, index: int) -> List[Tuple[int, int]]:
        """All (bucket, slot) placements of one record (deduplicated) —
        the write fan-out an online update of that record must cover."""
        seen: Dict[int, int] = {}
        for j in range(self.params.n_hashes):
            b = int(self.hashes[index, j])
            if b not in seen:
                seen[b] = int(self.slot_of[index, j])
        return sorted(seen.items())


def cuckoo_assign(indices: Sequence[int], layout: CuckooLayout,
                  rng: np.random.Generator) -> Dict[int, int]:
    """Assign each (unique) index to one distinct bucket: {bucket: index}.

    Random-walk insertion with per-bucket capacity 1: an index lands in a
    free candidate bucket if one exists, otherwise it evicts a random
    occupant and the walk continues with the evictee. Deterministic given
    ``rng``. Raises :class:`CuckooFailure` after the eviction budget —
    probability O(1/B) under ``validate()``-checked parameters.
    """
    idx = [int(i) for i in indices]
    if len(set(idx)) != len(idx):
        raise ValueError("cuckoo_assign needs unique indices "
                         "(deduplicate the batch first)")
    if len(idx) > layout.params.m:
        raise ValueError(
            f"batch of {len(idx)} exceeds m={layout.params.m}")
    table: Dict[int, int] = {}
    budget = _WALK_STEPS_PER_ITEM * max(len(idx), 1)
    for start in idx:
        cur = start
        for _ in range(budget):
            cands = [b for b, _ in layout.occurrences(cur)]
            free = [b for b in cands if b not in table]
            if free:
                table[int(rng.choice(free))] = cur
                break
            victim_bucket = int(rng.choice(cands))
            cur, table[victim_bucket] = table[victim_bucket], cur
        else:
            raise CuckooFailure(
                f"cuckoo insertion of index {cur} exceeded {budget} "
                f"evictions (batch of {len(idx)} into "
                f"{layout.n_buckets} buckets; analytic bound "
                f"{layout.params.failure_bound():.3g}) — split the batch",
                index=cur)
    return table


@dataclass
class RoundPlan:
    """One planned batch round: B real-or-dummy per-bucket inner queries.

    The client-side artifact the session dispatches: every bucket carries
    exactly one inner-protocol query per party (``keys[b]`` is the
    k-tuple), real for buckets the cuckoo assignment filled, dummy
    (uniformly random in-bucket slot) elsewhere. The *structure* is
    query-independent — ``len(slots) == n_buckets`` always — which is the
    no-occupancy-leak invariant tests assert.

    request_indices  the caller's batch, original order, duplicates kept.
    bucket_of        unique requested index -> assigned bucket.
    slots / real     per bucket: queried in-bucket slot, real-vs-dummy.
    keys             per bucket: the k per-party inner key pytrees.
    """
    request_indices: List[int]
    bucket_of: Dict[int, int]
    slots: List[int]
    real: List[bool]
    keys: List[Tuple]

    @property
    def n_buckets(self) -> int:
        return len(self.slots)

    def party_keys(self, party: int) -> List:
        """Per-bucket key pytrees of one party (collation order)."""
        return [k[party] for k in self.keys]


def plan_round(rng: np.random.Generator, indices: Sequence[int],
               layout: CuckooLayout, inner_cfg: PIRConfig,
               proto) -> RoundPlan:
    """Cuckoo-place a batch and generate its B per-bucket inner queries.

    Dummy queries run the *identical* keygen as real ones (a DPF key for a
    uniformly random slot of the bucket) — by DPF key pseudorandomness a
    server cannot distinguish which buckets carry real queries, so padding
    hides occupancy, not just count. Raises :class:`CuckooFailure` (see
    ``cuckoo_assign``) without consuming protocol keygen entropy.
    """
    request = [int(i) for i in indices]
    unique = list(dict.fromkeys(request))
    assign = cuckoo_assign(unique, layout, rng)
    bucket_of = {i: b for b, i in assign.items()}
    slots: List[int] = []
    real: List[bool] = []
    keys: List[Tuple] = []
    for b in range(layout.n_buckets):
        if b in assign:
            slots.append(layout.slot(assign[b], b))
            real.append(True)
        else:
            slots.append(int(rng.integers(layout.capacity)))
            real.append(False)
        keys.append(proto.query_gen(rng, slots[-1], inner_cfg))
    return RoundPlan(request_indices=request, bucket_of=bucket_of,
                     slots=slots, real=real, keys=keys)


def reassemble(plan: RoundPlan, bucket_records) -> np.ndarray:
    """Reorder per-bucket reconstructions into the request order.

    ``bucket_records``: per bucket, this round's reconstructed record
    (indexable by bucket id — list or [B, ...] array). Duplicated request
    indices fan out from their single assigned bucket; dummy buckets'
    records are discarded here.
    """
    rows = [np.asarray(bucket_records[plan.bucket_of[i]])
            for i in plan.request_indices]
    return np.stack(rows) if rows else np.empty((0,))
