"""Fault-tolerance machinery: heartbeats, retries, poison-step policy,
straggler detection. Pure-Python control plane (injectable clock so tests
can drive it deterministically); the data plane stays in jit'd steps.

At fleet scale the physical signals (process death, ICI timeouts) surface
through the runtime's job layer; what the *framework* owes the operator is
the policy layer implemented here:

* ``HeartbeatRegistry`` — participants check in each step; silence beyond
  ``timeout`` marks them suspect, driving elastic re-meshing.
* ``retry_step`` — transient-failure wrapper with exponential backoff.
* ``PoisonPolicy`` — NaN/Inf loss ⇒ skip the update (params unchanged),
  rewind to the last good checkpoint after ``max_consecutive`` poisons.
* ``StragglerMonitor`` — EWMA of step latency per participant; an entry
  ``factor``× slower than the median is flagged; the serve scheduler
  (``runtime.serve_loop.QueryScheduler``) re-shards a flagged cluster's
  queue to healthy clusters via ``shed_stragglers``, the train loop
  surfaces the flag to the scheduler (backup-worker dispatch).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


class HeartbeatRegistry:
    def __init__(self, timeout: float = 60.0, clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.last_seen: Dict[str, float] = {}

    def beat(self, participant: str):
        self.last_seen[participant] = self.clock()

    def remove(self, participant: str) -> bool:
        """Retire a departed participant entirely.

        A participant that *left* (elastic leave, replica decommission) is
        not a failure: without removal its last beat ages past ``timeout``
        and :meth:`suspects` reports it forever, poisoning every health
        check. Returns whether the participant was registered.
        """
        return self.last_seen.pop(participant, None) is not None

    #: alias — "forget a participant" reads better at some call sites
    forget = remove

    def suspects(self) -> List[str]:
        now = self.clock()
        return [p for p, t in self.last_seen.items()
                if now - t > self.timeout]

    def healthy(self) -> List[str]:
        bad = set(self.suspects())
        return [p for p in self.last_seen if p not in bad]


@dataclass
class RetryStats:
    """Out-param of :func:`retry_step`: the attempt accounting a caller
    needs for metrics (the replica router reports resubmission attempts
    and total backoff per failover, ``replica/metrics.py``)."""
    attempts: int = 0            # calls made (1 == first try succeeded)
    retried: int = 0             # failures that were retried
    slept_s: float = 0.0         # total backoff requested


def retry_step(fn: Callable, *args, retries: int = 3, base_delay: float = 0.5,
               max_delay: float = 30.0,
               sleep: Callable[[float], None] = time.sleep,
               retriable=(RuntimeError, OSError),
               stats: Optional[RetryStats] = None,
               jitter: float = 0.0,
               rng: Optional[np.random.Generator] = None, **kwargs):
    """Run ``fn`` with exponential backoff on transient failures.

    The per-attempt delay doubles from ``base_delay`` but is capped at
    ``max_delay`` — unbounded growth turns a long outage into hour-scale
    sleeps that outlive the outage itself. Pass a :class:`RetryStats` to
    receive the attempt count (metrics surface it per failover).

    ``jitter`` spreads retry storms: each delay is scaled by a uniform
    factor in ``[1 - jitter, 1 + jitter]`` (then re-capped at
    ``max_delay``). The factor comes from the *injectable* ``rng`` —
    seeded callers get bit-identical backoff schedules across replays,
    which the chaos plane relies on. ``jitter=0`` (default) keeps the
    historical exact-power-of-two delays; ``stats.slept_s`` always
    records the actual (jittered) sleep.
    """
    if jitter and rng is None:
        rng = np.random.default_rng()
    for attempt in range(retries + 1):
        if stats is not None:
            stats.attempts += 1
        try:
            return fn(*args, **kwargs)
        except retriable:
            if attempt == retries:
                raise
            delay = min(base_delay * (2 ** attempt), max_delay)
            if jitter:
                u = float(rng.uniform(-jitter, jitter))
                delay = min(delay * (1.0 + u), max_delay)
            if stats is not None:
                stats.retried += 1
                stats.slept_s += delay
            sleep(delay)


@dataclass
class PoisonPolicy:
    """Skip-and-rewind policy for non-finite losses."""
    max_consecutive: int = 3
    consecutive: int = 0
    total_skipped: int = 0

    def observe(self, loss: float) -> str:
        """Returns 'ok' | 'skip' | 'rewind'."""
        if math.isfinite(loss):
            self.consecutive = 0
            return "ok"
        self.consecutive += 1
        self.total_skipped += 1
        if self.consecutive >= self.max_consecutive:
            self.consecutive = 0
            return "rewind"
        return "skip"


@dataclass
class StragglerMonitor:
    factor: float = 2.0
    alpha: float = 0.2           # EWMA smoothing
    ewma: Dict[str, float] = field(default_factory=dict)

    def record(self, participant: str, latency: float):
        prev = self.ewma.get(participant)
        self.ewma[participant] = (latency if prev is None
                                  else (1 - self.alpha) * prev
                                  + self.alpha * latency)

    def stragglers(self) -> List[str]:
        if len(self.ewma) < 2:
            return []
        med = float(np.median(list(self.ewma.values())))
        return [p for p, v in self.ewma.items() if v > self.factor * med]

    def reassign(self, queues: Dict[str, list]) -> Dict[str, list]:
        """Move a straggler's queued work to the fastest healthy peers."""
        return self.shed_stragglers(queues)[0]

    def shed_stragglers(self, queues: Dict[str, list]
                        ) -> "Tuple[Dict[str, list], int]":
        """``reassign`` plus the number of items moved.

        The serve scheduler uses the count to account reassignments in its
        stats and to decide whether a re-balance pass did anything.
        """
        slow = set(self.stragglers())
        # donors: flagged lanes with queued work; receivers must exclude
        # EVERY flagged lane (an idle straggler is still slow — shedding
        # work onto it would re-create the problem)
        donors = [p for p in slow if queues.get(p)]
        fast = [p for p in queues if p not in slow]
        if not donors or not fast:
            return queues, 0
        out = {p: list(q) for p, q in queues.items()}
        moved = []
        for p in donors:
            moved.extend(out[p])
            out[p] = []
        for i, item in enumerate(moved):
            out[fast[i % len(fast)]].append(item)
        return out, len(moved)
