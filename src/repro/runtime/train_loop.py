"""Fault-tolerant training loop.

Wiring per step:
  data pipeline (stateless, step-keyed)  ->  pjit train_step  ->  metrics
  heartbeat + straggler EWMA             ->  policy hooks
  NaN/Inf loss                           ->  PoisonPolicy skip / rewind
  checkpoint cadence + SIGTERM           ->  async CheckpointManager

Rewind restores the last good checkpoint in-place (same mesh) — the
elastic path (different mesh) goes through ``runtime.elastic``.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.config import RunConfig
from repro.data.pipeline import TokenPipeline
from repro.runtime.fault import (HeartbeatRegistry, PoisonPolicy,
                                 StragglerMonitor, retry_step)
from repro.runtime.steps import TrainStep, make_train_step


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    keep_ckpts: int = 3


@dataclass
class TrainResult:
    losses: List[float] = field(default_factory=list)
    skipped_steps: int = 0
    rewinds: int = 0
    final_step: int = 0


class TrainLoop:
    def __init__(self, run: RunConfig, mesh, loop_cfg: TrainLoopConfig,
                 *, log: Callable[[str], None] = print):
        self.run = run
        self.mesh = mesh
        self.cfg = loop_cfg
        self.log = log
        self.ts: TrainStep = make_train_step(run, mesh)
        self.pipeline = TokenPipeline(run.model, run.shape, seed=run.seed)
        self.heartbeat = HeartbeatRegistry()
        self.poison = PoisonPolicy()
        self.straggler = StragglerMonitor()
        self.ckpt = (CheckpointManager(loop_cfg.ckpt_dir,
                                       keep=loop_cfg.keep_ckpts)
                     if loop_cfg.ckpt_dir else None)
        self._stop = False

    def _install_sigterm(self):
        def handler(signum, frame):
            self.log("[train] SIGTERM — checkpointing and stopping")
            self._stop = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass            # non-main thread (tests)

    def _save(self, step, params, opt_state, blocking=False):
        if self.ckpt is None:
            return
        self.ckpt.save(step, {"params": params, "opt": opt_state},
                       metadata={"config": self.run.to_dict()},
                       blocking=blocking)

    def _restore(self, params_like, opt_like):
        tree, meta = self.ckpt.restore(
            {"params": params_like, "opt": opt_like},
            shardings={"params": self.ts.param_shardings,
                       "opt": self.ts.opt_shardings})
        return tree["params"], tree["opt"], meta["step"]

    def run_loop(self, *, start_step: int = 0, resume: bool = False
                 ) -> TrainResult:
        self._install_sigterm()
        rng = jax.random.PRNGKey(self.run.seed)
        params, opt_state, ef = self.ts.init_state(rng)
        step = start_step
        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            params, opt_state, step = self._restore(params, opt_state)
            self.log(f"[train] resumed from step {step}")

        res = TrainResult()
        last_good = step
        while step < self.cfg.total_steps and not self._stop:
            t0 = time.monotonic()
            n_micro = self.run.microbatches
            batch = {}
            for k, v in self.pipeline.batch(step).items():
                if n_micro > 1:   # [micro, B/micro, ...] — see steps.py
                    v = v.reshape((n_micro, v.shape[0] // n_micro)
                                  + v.shape[1:])
                batch[k] = jax.numpy.asarray(v)

            def do_step():
                return self.ts.step(params, opt_state, ef, batch)

            out = retry_step(do_step, retries=2)
            new_params, new_opt, new_ef, metrics = out
            loss = float(metrics["loss"])
            verdict = self.poison.observe(loss)
            if verdict == "ok":
                params, opt_state, ef = new_params, new_opt, new_ef
                res.losses.append(loss)
            elif verdict == "skip":
                res.skipped_steps += 1
                self.log(f"[train] step {step}: non-finite loss — skipped")
            else:   # rewind
                res.rewinds += 1
                if self.ckpt and self.ckpt.latest_step() is not None:
                    self.ckpt.wait()
                    params, opt_state, last_good = self._restore(
                        params, opt_state)
                    step = last_good
                    self.log(f"[train] rewound to step {last_good}")
                    continue
            dt = time.monotonic() - t0
            self.heartbeat.beat("proc0")
            self.straggler.record("proc0", dt)
            if self.cfg.log_every and step % self.cfg.log_every == 0:
                self.log(f"[train] step {step} loss {loss:.4f} "
                         f"({dt*1e3:.0f} ms)")
            step += 1
            if self.ckpt_due(step):
                self._save(step, params, opt_state)
                last_good = step
        if self.ckpt:
            self._save(step, params, opt_state, blocking=True)
        res.final_step = step
        return res

    def ckpt_due(self, step: int) -> bool:
        return (self.ckpt is not None and self.cfg.ckpt_every
                and step % self.cfg.ckpt_every == 0)
