"""Elastic scaling: rebuild the mesh from the live device set and re-shard.

Policy (DESIGN.md §6): the ``model`` axis is pinned by the TP/DB-shard
layout (changing it means re-tiling weights), so elasticity happens on the
``data`` (and ``pod``) axes: lose a pod -> halve data parallelism, keep
going; gain one back -> grow. Checkpoints store full logical arrays keyed
by leaf path, so restoring onto a different mesh is just ``device_put``
under the new shardings (checkpoint/manager.py).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.config import MeshConfig


def plan_mesh(n_devices: int, *, model_axis: int,
              prefer_pods: int = 1) -> MeshConfig:
    """Choose the largest (pod, data, model) grid for the live device count.

    ``model_axis`` is fixed; data = n_devices // (model * pods), rounded to
    the largest power of two that fits (stragglers/failures rarely leave
    neat shapes — unused devices idle until the next resize)."""
    if n_devices < model_axis:
        raise ValueError(f"{n_devices} devices < model axis {model_axis}")
    per_pod = n_devices // prefer_pods
    data = 1
    while data * 2 * model_axis <= per_pod:
        data *= 2
    if prefer_pods > 1:
        return MeshConfig(shape=(prefer_pods, data, model_axis),
                          axes=("pod", "data", "model"))
    return MeshConfig(shape=(data, model_axis), axes=("data", "model"))


def rebuild_mesh(live_devices: Optional[Sequence] = None, *,
                 model_axis: int, prefer_pods: int = 1) -> Mesh:
    devs = list(live_devices if live_devices is not None else jax.devices())
    cfg = plan_mesh(len(devs), model_axis=model_axis,
                    prefer_pods=prefer_pods)
    n = cfg.n_devices
    grid = np.asarray(devs[:n]).reshape(cfg.shape)
    return Mesh(grid, cfg.axes)


def carve_submeshes(n_replicas: int, *, model_axis: int,
                    live_devices: Optional[Sequence] = None,
                    prefer_pods: int = 1) -> list:
    """One sub-mesh per serve replica, carved from the live device set.

    The replica plane's join/leave path: each replica owns a disjoint
    device group (``launch/mesh.split_devices``) re-meshed by
    :func:`rebuild_mesh` — so a replica leaving returns its devices to the
    pool and a rejoining one gets a fresh sub-mesh without perturbing its
    peers. Each sub-mesh keeps the pinned ``model`` (DB-shard) axis and
    grows its own ``data`` axis, so every replica holds a full DB replica
    sharded the same way (the IM-PIR cluster topology, one tier up).

    On a host with fewer than ``n_replicas * model_axis`` devices, the
    groups share the full device set (see ``split_devices``).
    """
    from repro.launch.mesh import split_devices
    devs = list(live_devices if live_devices is not None
                else jax.devices())
    groups = split_devices(n_replicas, devs, min_per_group=model_axis)
    return [rebuild_mesh(g, model_axis=model_axis, prefer_pods=prefer_pods)
            for g in groups]


def reshard(tree: Any, shardings: Any) -> Any:
    """Move a pytree onto new shardings (cross-mesh device_put)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings)
