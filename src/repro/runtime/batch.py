"""`BatchPIR` — the cuckoo-bucketed multi-query session (DESIGN.md §14).

The runtime half of the batch composite: glues the client-side cuckoo
plan (``core/batch.py``) to the bucketed database (``db/bucketed.py``)
through the SAME :class:`~repro.runtime.serve_loop.QueryScheduler` every
other deployment uses — one scheduler *item* is one :class:`RoundPlan`
(a whole m-record batch), and one dispatch fans its B per-bucket inner
queries out to all k parties.

Why this is the throughput lever (the perf accounting the bench pins):
a single-query round scans all N rows for 1 record; a batch round scans
B · capacity ≈ 2·n_hashes·N rows for m records — records per scanned row
improve by the *algorithmic* factor m·N/(B·capacity) ≈ m/4 at the
defaults, independent of (and multiplicative with) the kernel constants
the engine's measured plans buy per bucket.

Privacy: every round issues exactly ONE real-or-dummy inner query per
bucket (``plan_round``'s uniform padding), and dummies run the identical
keygen as real queries, so the servers' view — B DPF keys per party per
round — is independent of which m indices were requested. The inner
protocol's per-query privacy argument then applies per bucket unchanged.

Compile economics: all B buckets share one shape (``capacity`` rows), so
one :class:`BucketedServeFns` per party serves every bucket view with a
SINGLE compiled step — B × m amortization never multiplies compiles
(``examples/batch_query.py`` asserts ``n_compiles == 1`` per party).
"""
from __future__ import annotations

import threading
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import PIRConfig
from repro.core import dpf
from repro.core import protocol as protocol_mod
from repro.core.batch import (CuckooFailure, RoundPlan, plan_round,
                              reassemble)
from repro.core.protocol import PIRProtocol
from repro.core.server import BucketedServeFns
from repro.db import BucketedDatabase
from repro.runtime.serve_loop import (DEFAULT_MAX_WAIT_S, AnswerFuture,
                                      MultiServerPIR, QueryScheduler)


class BatchPIR(MultiServerPIR):
    """k-party batch deployment: m records per round over B cuckoo buckets.

    Same facade as :class:`MultiServerPIR` — ``query``/``submit``/
    ``update``/``publish``/session lifecycle — plus the batch plane:

      query_batch(indices)    synchronous m-record retrieval (the reason
                              this class exists); splits and retries on
                              the O(1/B)-probability cuckoo failure
      submit_batch(indices)   streaming form -> one AnswerFuture that
                              resolves to [m, ...] records in request
                              order, epoch-tagged like any other answer

    ``db_words`` may be the host array or a prebuilt
    :class:`BucketedDatabase` (replica-plane style pass-through).
    ``rounds`` is the scheduler's batch-size ladder in units of *rounds*
    (RoundPlans per dispatch) — the per-bucket query count of one
    dispatch is ``rounds × 1``, B buckets wide.
    """

    def __init__(self, db_words, cfg: PIRConfig, mesh,
                 *, path: Optional[str] = "fused",
                 rounds: Sequence[int] = (1,),
                 max_wait_s: float = DEFAULT_MAX_WAIT_S,
                 n_clusters: int = 1,
                 protocol: Optional[PIRProtocol] = None,
                 client_rng: Optional[np.random.Generator] = None,
                 default_deadline_s: Optional[float] = None):
        if cfg.batch_m < 1:
            raise ValueError(
                f"BatchPIR needs cfg.batch_m >= 1 (got {cfg.batch_m}); "
                f"use MultiServerPIR for single-query serving")
        self.cfg = cfg
        self.protocol = (protocol if protocol is not None
                         else protocol_mod.for_config(cfg))
        if self.protocol.needs_hint:
            raise ValueError(
                f"protocol {self.protocol.name!r} needs hint plumbing; "
                f"the batch composite serves the k-party protocols "
                f"(xor-dpf-2, xor-dpf-k, additive-dpf-2)")
        self.n_parties = self.protocol.n_parties(cfg)
        self.db = (db_words if isinstance(db_words, BucketedDatabase)
                   else BucketedDatabase(db_words, cfg, mesh))
        self.layout = self.db.layout
        #: what the inner protocol keygens/serves against: the bucket
        #: shape (capacity rows). Engine plan resolution and cache keys
        #: see THIS config's spec signature — per bucket shape, as if the
        #: bucket were a standalone database.
        self.inner_cfg = self.db.inner_cfg
        # one serve-fns family per party, shared by ALL B bucket views
        # (same shape + sharding -> one compiled step per rounds-bucket)
        self.serve = [
            BucketedServeFns(self.inner_cfg, mesh, buckets=rounds,
                             path=path, party=p, protocol=self.protocol)
            for p in range(self.n_parties)]
        self.rng = (client_rng if client_rng is not None
                    else np.random.default_rng())
        self._lock = threading.Lock()
        # one compiled step per party total (shared across buckets), so
        # the cold-session budget matches MultiServerPIR's per-party scale
        self.default_deadline_s = (default_deadline_s
                                   if default_deadline_s is not None
                                   else 120.0 * self.n_parties)
        self.chaos = None
        self.chaos_scope = None
        #: per-dispatch uniform-padding log: (n_rounds, per-bucket queries
        #: issued per round). The no-occupancy-leak invariant is that the
        #: second element is ALWAYS exactly ``db.n_buckets`` — tests
        #: assert it across adversarial index choices.
        self.dispatch_log: List[Tuple[int, int]] = []
        self.scheduler = self._make_scheduler(max_wait_s, n_clusters)

    # ------------------------------------------------------------------
    # scheduler wiring (items are RoundPlans)
    # ------------------------------------------------------------------

    def _make_scheduler(self, max_wait_s: float, n_clusters: int
                        ) -> QueryScheduler:
        serve = self.serve
        proto = self.protocol
        parties = range(self.n_parties)
        db = self.db
        inner_cfg = self.inner_cfg
        n_buckets = self.db.n_buckets
        log = self.dispatch_log

        def collate(plans: List[RoundPlan]):
            # per party, per cuckoo bucket: this batch's rounds stacked
            # into one key pytree [R, ...] — plans ride along for
            # finalize's reassembly (the scheduler threads the payload
            # through stage/dispatch untouched)
            keys = tuple(
                [dpf.stack_keys([plan.keys[b][p] for plan in plans])
                 for b in range(n_buckets)]
                for p in parties)
            return list(plans), keys

        def stage(payload):
            plans, keys = payload
            return plans, tuple(
                [serve[p].stage(keys[p][b]) for b in range(n_buckets)]
                for p in parties)

        def dispatch(staged):
            plans, keys = staged
            # one atomic capture of ALL B bucket views + the outer epoch:
            # every bucket of every party answers the same DB version
            epoch, views = db.snapshot((proto.db_view,))
            bviews = views[proto.db_view]
            # stack each party's B per-bucket answers on DEVICE (async,
            # off the host): finalize then pays ONE device->host transfer
            # per party instead of B tiny ones — at B=32+ the transfer
            # fan-out, not the scans, would dominate the round otherwise
            answers = tuple(
                jnp.stack([serve[p].answer(bviews[b], keys[p][b])
                           for b in range(n_buckets)])     # [B, Q, ...]
                for p in parties)
            # the server-observable per-round shape: B per-bucket queries,
            # whatever the m requested indices were
            log.append((len(plans), n_buckets))
            return plans, answers, epoch

        def finalize(raw, n):
            plans, answers, _ = raw
            host = [np.asarray(a) for a in answers]        # [B, Q, ...] x k
            out = []
            for r in range(n):
                # per party: this round's B per-bucket shares -> [B, ...]
                shares = [h[:, r] for h in host]
                # checksum verification rides through per bucket — dummy
                # buckets hit real (or zero-pad) rows whose checksums are
                # valid, so IntegrityError still means real corruption
                recs = np.asarray(proto.reconstruct_with(
                    shares, [None] * n_buckets, cfg=inner_cfg))
                out.append(reassemble(plans[r], recs))
            return out

        return QueryScheduler(
            collate=collate, stage=stage, dispatch=dispatch,
            finalize=finalize, buckets=serve[0].buckets,
            n_clusters=n_clusters, max_wait_s=max_wait_s,
            epoch_of=lambda raw: raw[2])

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------

    def submit_batch(self, indices: Sequence[int], *,
                     deadline_s: Optional[float] = None) -> AnswerFuture:
        """Retrieve up to m records in one round; resolves to
        ``[len(indices), ...]`` records in request order (duplicates
        allowed — they share one bucket query).

        Raises :class:`CuckooFailure` *synchronously* (before anything is
        enqueued) when this batch's indices cannot be cuckoo-placed —
        probability O(1/B); :meth:`query_batch` handles the split-retry.
        """
        request = [int(i) for i in indices]
        if not request:
            raise ValueError("submit_batch needs at least one index")
        if any(i < 0 or i >= self.cfg.n_items for i in request):
            raise ValueError(
                f"indices out of range [0, {self.cfg.n_items})")
        if len(set(request)) > self.layout.params.m:
            raise ValueError(
                f"batch of {len(set(request))} unique indices exceeds "
                f"m={self.layout.params.m}")
        fut = self._deadline_future(deadline_s)
        with self._lock:    # keygen + cuckoo walk share one client rng
            plan = plan_round(self.rng, request, self.layout,
                              self.inner_cfg, self.protocol)
        return self.scheduler.submit(plan, future=fut)

    def query_batch(self, indices: Sequence[int]) -> np.ndarray:
        """Synchronous batch retrieval of ``db[indices]`` — any length:
        chunks into m-sized rounds, splits-and-retries the rare cuckoo
        failure (a single index always places), reassembles in request
        order. Returns [len(indices), ...] records."""
        request = [int(i) for i in indices]
        if not request:
            tail, dtype = self.protocol.record_struct(self.cfg)
            return np.empty((0,) + tail, dtype)
        unique = list(dict.fromkeys(request))
        m = self.layout.params.m
        groups = [unique[i:i + m] for i in range(0, len(unique), m)]
        futs: List[Tuple[List[int], AnswerFuture]] = []
        while groups:
            g = groups.pop(0)
            try:
                futs.append((g, self.submit_batch(g)))
            except CuckooFailure:
                # Hall-violating index subset (analytic prob O(1/B)):
                # halve and retry — a 1-index batch always places, so
                # this terminates with every index served
                groups.insert(0, g[len(g) // 2:])
                groups.insert(0, g[:len(g) // 2])
        if not self.scheduler.running:
            self.scheduler.pump()
        rec_of = {}
        for g, f in futs:
            out = f.result()
            for i, rec in zip(g, out):
                rec_of[i] = rec
        return np.stack([rec_of[i] for i in request])

    def query(self, indices: Sequence[int]) -> np.ndarray:
        """Alias of :meth:`query_batch` — the batch composite serves every
        retrieval through the bucketed plane."""
        return self.query_batch(indices)

    def submit(self, index: int, *,
               deadline_s: Optional[float] = None) -> AnswerFuture:
        """Single-index streaming form, served as a 1-real-(B-1)-dummy
        round (the padded traffic shape is identical to a full batch —
        a lone streaming client leaks no less than a batching one)."""
        inner = self.submit_batch([index], deadline_s=deadline_s)
        fut = AnswerFuture(deadline=inner.deadline)

        def _unwrap(done: AnswerFuture):
            exc = done.exception()
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.epoch = done.epoch
                fut.set_result(done.result(timeout=0)[0])

        inner.add_done_callback(_unwrap)
        return fut
