"""pjit step builders: train_step / prefill_step / decode_step per RunConfig.

This is the distribution heart of the framework: logical PartitionSpecs from
the model zoo become NamedShardings on the production mesh, and the steps
are ``jax.jit``s with explicit in/out shardings and donated state.

Train step structure:
  microbatch scan (gradient accumulation)  ->  grads
  [optional] EF-int8 gradient compression hook (cross-pod trick)
  global-norm clip + AdamW/Adafactor update (donated params/opt state)

Serve steps:
  prefill: full causal pass -> (last logits, KV cache)
  decode:  one token against the cache (``write=False`` for the dry-run
           cells whose cache is at capacity; the serve loop uses write=True)
  pir:     the bucketed PIR answer-step family (one compiled step per batch
           bucket, DESIGN.md §6) consumed by runtime.serve_loop
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import PIRConfig, RunConfig
from repro.models import build_model, input_specs
from repro.optim import compression
from repro.optim.optimizer import opt_init, opt_update, spec_for_state

F32 = jnp.float32


def _named(mesh: Mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree.

    Empty-tuple subtrees (e.g. MLA's ``KVCache.v = ()``) stay empty so the
    jit sharding pytree keeps the argument's structure.
    """
    def conv(s):
        if isinstance(s, tuple) and not isinstance(s, P) and len(s) == 0:
            return ()
        if s is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, s)
    return jax.tree_util.tree_map(
        conv, spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None
        or (isinstance(x, tuple) and len(x) == 0))


def _filter_axes(spec_tree, mesh: Mesh):
    """Drop mesh axes a spec references but the mesh doesn't have (e.g.
    'pod' on the single-pod mesh)."""
    names = set(mesh.axis_names)

    def fix_entry(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    def fix(s):
        if not isinstance(s, P):
            return s
        return P(*(fix_entry(e) for e in s))

    return jax.tree_util.tree_map(
        fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _fix_divisibility(spec_tree, struct_tree, mesh: Mesh):
    """Repair specs whose dim sizes aren't divisible by their mesh axes.

    jit *argument* shardings must divide evenly. Where they don't (GQA
    kv=8 heads over a 16-way model axis, batch-1 long-context cells, grok's
    8 experts), relocate the axis to the rightmost unsharded divisible dim
    (e.g. kv-head axis -> head_dim) or, failing that, drop it (replicate).
    Deterministic, so every arg/out spec pair fixes identically.
    """
    def fix(spec, sds):
        if not isinstance(spec, P) or not hasattr(sds, "shape"):
            return spec
        shape = sds.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        entries = entries[:len(shape)]
        for i, e in enumerate(entries):
            if e is None:
                continue
            size = _axis_size(mesh, e)
            if size > 1 and shape[i] % size != 0:
                moved = False
                for j in range(len(shape) - 1, -1, -1):
                    if j != i and entries[j] is None \
                            and shape[j] % size == 0 and shape[j] > 1:
                        entries[j] = e
                        moved = True
                        break
                entries[i] = None
        return P(*entries)

    return jax.tree_util.tree_map(
        fix, spec_tree, struct_tree, is_leaf=lambda x: isinstance(x, P))


def _apply_fsdp(pspecs, struct_tree, mesh: Mesh, *, axis: str = "data"):
    """FSDP/ZeRO-3: shard a *feature* dim of stacked-layer weights over
    ``axis``, so the per-iteration ``lax.scan`` slice stays sharded and
    GSPMD all-gathers one layer's weights just-in-time inside the loop.

    Never shards dim 0 (the scan axis): GSPMD lowers a dynamic-slice over
    a sharded dim as gather-then-slice, which LICM hoists out of the loop
    — the whole f32 weight stack materializes per device (observed 28 ×
    24 GiB buffers on the grok-1 train cell with scan-dim FSDP).
    """
    if axis not in mesh.axis_names:
        return pspecs
    size = mesh.shape[axis]
    leafP = lambda x: isinstance(x, P)
    flat = jax.tree_util.tree_flatten_with_path(pspecs, is_leaf=leafP)[0]
    treedef = jax.tree_util.tree_structure(pspecs, is_leaf=leafP)
    structs = jax.tree_util.tree_flatten_with_path(
        struct_tree, is_leaf=lambda x: hasattr(x, "shape"))[0]
    shapes = {tuple(str(p) for p in path): s.shape for path, s in structs}
    out = []
    for path, spec in flat:
        key = tuple(str(p) for p in path)
        keys_str = "/".join(key)
        shape = shapes.get(key)
        stacked = "layers" in keys_str
        if stacked and isinstance(spec, P) and shape is not None \
                and len(shape) >= 3:
            entries = list(spec) + [None] * (len(shape) - len(spec))
            for i in range(1, len(shape)):      # skip the scan axis
                if entries[i] is None and shape[i] % size == 0 \
                        and shape[i] >= size:
                    entries[i] = axis
                    break
            spec = P(*entries)
        out.append(spec)
    return jax.tree_util.tree_unflatten(treedef, out)


class TrainStep(NamedTuple):
    step: Callable            # (params, opt_state, ef, batch) -> (...)
    init_state: Callable      # (rng) -> (params, opt_state, ef)
    model: Any
    param_shardings: Any
    opt_shardings: Any
    batch_shardings: Any
    input_structs: Dict[str, jax.ShapeDtypeStruct]


def make_train_step(run: RunConfig, mesh: Mesh) -> TrainStep:
    model = build_model(run.model, remat=run.remat)
    structs, batch_pspecs = input_specs(run.model, run.shape)
    pspecs = _filter_axes(model.param_specs(), mesh)
    batch_pspecs = _filter_axes(batch_pspecs, mesh)

    n_micro = run.microbatches
    if n_micro > 1:
        # microbatch axis leads; the data axes shard dim 1
        structs = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                (n_micro, s.shape[0] // n_micro) + s.shape[1:], s.dtype),
            structs)
        batch_pspecs = jax.tree_util.tree_map(
            lambda p: P(None, *p), batch_pspecs,
            is_leaf=lambda x: isinstance(x, P))

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    if run.fsdp:
        pspecs = _apply_fsdp(pspecs, params_shape, mesh)
    pspecs = _fix_divisibility(pspecs, params_shape, mesh)
    batch_pspecs = _fix_divisibility(batch_pspecs, structs, mesh)
    opt_struct = jax.eval_shape(
        functools.partial(opt_init, run.optimizer), params_shape)
    opt_pspecs = _fix_divisibility(
        _filter_axes(spec_for_state(run.optimizer, pspecs, params_shape),
                     mesh), opt_struct, mesh)

    param_sh = _named(mesh, pspecs)
    opt_sh = _named(mesh, opt_pspecs)
    batch_sh = _named(mesh, batch_pspecs)
    compress = run.optimizer.compress_grads

    def loss_fn(params, batch):
        loss, _ = model.loss(params, batch["tokens"],
                             **{k: v for k, v in batch.items()
                                if k != "tokens"})
        return loss

    spec_leaves = jax.tree_util.tree_flatten(
        pspecs, is_leaf=lambda x: isinstance(x, P))[0]

    def constrain_like_params(tree):
        """Pin a params-shaped tree (grads, accumulators) to the param
        PartitionSpecs. Without this GSPMD de-shards the FSDP (layer)
        dim of grad accumulators for scanned stacks — observed as
        24 GiB f32 full-stack gradient buffers on the grok-1 train cell."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        assert len(leaves) == len(spec_leaves)
        out = [jax.lax.with_sharding_constraint(x, s)
               if isinstance(s, P) else x
               for x, s in zip(leaves, spec_leaves)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def grads_of(params, batch):
        if n_micro == 1:
            loss, g = jax.value_and_grad(loss_fn)(params, batch)
            return loss, constrain_like_params(g)
        # gradient accumulation: batch arrives pre-split [micro, B/micro,...]
        # (splitting must happen OUTSIDE jit: an in-graph reshape of the
        # batch-sharded dim makes GSPMD partially replicate the whole step —
        # observed 4× flop inflation on the granite cell before this).
        def micro(carry, mb):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, b: a + b / n_micro, grad_acc, g)
            return (loss_acc + l / n_micro, constrain_like_params(acc)), ()

        zero = constrain_like_params(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, F32), params))
        (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros((), F32), zero),
                                        batch)
        return loss, grads

    def step(params, opt_state, ef, batch):
        loss, grads = grads_of(params, batch)
        if compress:
            # EF-int8 hook: quantize/dequantize with error feedback — the
            # numerical twin of the cross-pod compressed all-reduce (the
            # collective itself is GSPMD's; bytes accounting in §Roofline).
            q, s, ef = compression.compress_with_feedback(grads, ef)
            grads = jax.tree_util.tree_map(compression.dequantize, q, s)
        params, opt_state, om = opt_update(run.optimizer, grads, opt_state,
                                           params)
        metrics = {"loss": loss, **om}
        return params, opt_state, ef, metrics

    ef_sh = param_sh if compress else None

    jit_step = jax.jit(
        step,
        in_shardings=(param_sh, opt_sh, ef_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, ef_sh,
                       _named(mesh, {"loss": P(), "lr": P(),
                                     "grad_norm": P()})),
        donate_argnums=(0, 1, 2),
    )

    def init_state(rng):
        params = jax.jit(model.init_params, out_shardings=param_sh)(rng)
        opt_state = jax.jit(
            functools.partial(opt_init, run.optimizer),
            out_shardings=opt_sh)(params)
        ef = (jax.jit(compression.ef_init, out_shardings=ef_sh)(params)
              if compress else None)
        return params, opt_state, ef

    return TrainStep(step=jit_step, init_state=init_state, model=model,
                     param_shardings=param_sh, opt_shardings=opt_sh,
                     batch_shardings=batch_sh, input_structs=structs)


class ServeStep(NamedTuple):
    prefill: Callable
    decode: Callable
    model: Any
    param_shardings: Any
    cache_shardings: Any
    input_structs: Dict[str, jax.ShapeDtypeStruct]


def make_serve_step(run: RunConfig, mesh: Mesh, *,
                    decode_write: bool = False) -> ServeStep:
    model = build_model(run.model, remat="none")
    structs, batch_pspecs = input_specs(run.model, run.shape)
    pspecs = _filter_axes(model.param_specs(), mesh)
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    if run.fsdp:
        pspecs = _apply_fsdp(pspecs, params_shape, mesh)
    pspecs = _fix_divisibility(pspecs, params_shape, mesh)
    cache_struct = jax.eval_shape(functools.partial(
        model.init_cache, run.shape.global_batch, run.shape.seq_len))
    cache_pspecs = _fix_divisibility(
        _filter_axes(model.cache_specs(), mesh), cache_struct, mesh)
    batch_pspecs = _fix_divisibility(
        _filter_axes(batch_pspecs, mesh), structs, mesh)
    param_sh = _named(mesh, pspecs)
    cache_sh = _named(mesh, cache_pspecs)
    batch_sh = _named(mesh, batch_pspecs)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    logits_spec = (P(batch_axes, None)
                   if run.shape.global_batch % n_batch_shards == 0
                   else P())
    logits_sh = NamedSharding(mesh, logits_spec)

    def prefill(params, batch):
        return model.prefill(params, batch["tokens"],
                             **{k: v for k, v in batch.items()
                                if k != "tokens"})

    def decode(params, cache, tokens):
        return model.decode(params, cache, tokens, write=decode_write)

    jit_prefill = jax.jit(
        prefill, in_shardings=(param_sh, batch_sh),
        out_shardings=(logits_sh, cache_sh))
    tok_sh = batch_sh["tokens"]
    jit_decode = jax.jit(
        decode, in_shardings=(param_sh, cache_sh, tok_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,))

    return ServeStep(prefill=jit_prefill, decode=jit_decode, model=model,
                     param_shardings=param_sh, cache_shardings=cache_sh,
                     input_structs=structs)


class PIRStep(NamedTuple):
    """Compiled PIR serving entry points (one bucket family, one party).

    ``answer`` takes either a ``ShardedDatabase`` (the database plane
    resolves the protocol's declared view per dispatch — DESIGN.md §8) or
    that view's raw device array; ``db_view`` names which view the
    compiled steps contract against. ``plan_report`` surfaces each
    bucket's resolved plan — kernel path, provenance (tuned vs heuristic
    vs forced), predicted step bytes — resolved once at build time by the
    engine plane (DESIGN.md §9), never on the dispatch path.
    """
    answer: Callable           # (db, keys) -> [bucket, ...] shares (async)
    stage_keys: Callable       # keys -> padded + device_put keys
    buckets: Tuple[int, ...]
    db_sharding: NamedSharding
    n_compiles: Callable[[], int]    # cache-miss counter (tests/benches)
    db_view: str = "words"
    plan_report: Callable[[], Dict[int, dict]] = lambda: {}


def make_pir_serve_step(
    cfg: PIRConfig,
    mesh: Mesh,
    *,
    buckets: Optional[Sequence[int]] = None,
    path: Optional[str] = "fused",
    collective: str = "gather",
    party: int = 0,
    protocol=None,
) -> PIRStep:
    """Build the bucketed PIR answer-step family in the step-builder idiom.

    Mirrors ``make_train_step``/``make_serve_step``: configs in, compiled
    jit entry points with explicit shardings out. Each batch bucket lowers
    exactly once (``core.server.BucketedServeFns``); the scheduler pads
    ragged batches up to the covering bucket so odd-sized traffic never
    triggers recompilation (DESIGN.md §6). The share scheme comes from
    ``protocol`` (a ``core.protocol.PIRProtocol`` or ``cfg.protocol`` by
    default); ``path=None`` lets ``protocol.plan_for`` pick the kernel
    path per bucket.
    """
    from repro.core.server import BucketedServeFns, default_buckets
    from repro.launch.mesh import mesh_axis_size, pir_cluster_axes

    n_clusters = 1
    for a in pir_cluster_axes(mesh):
        n_clusters *= mesh_axis_size(mesh, a)
    if buckets is None:
        buckets = default_buckets(n_clusters)
    bucketed = BucketedServeFns(cfg, mesh, buckets=buckets, path=path,
                                collective=collective, party=party,
                                protocol=protocol)
    db_sharding = bucketed.fns_for(bucketed.buckets[0])[0].db_sharding
    return PIRStep(answer=bucketed.answer, stage_keys=bucketed.stage,
                   buckets=bucketed.buckets, db_sharding=db_sharding,
                   n_compiles=lambda: bucketed.n_compiles,
                   db_view=bucketed.protocol.db_view,
                   plan_report=bucketed.plan_report)
