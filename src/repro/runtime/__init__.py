from repro.runtime.steps import (make_pir_serve_step, make_serve_step,
                                 make_train_step)
__all__ = ["make_pir_serve_step", "make_serve_step", "make_train_step"]
