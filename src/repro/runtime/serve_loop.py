"""PIR serving runtime — the paper's Figure 8 multi-query workflow.

Pipeline stages (paper §3.4):
  ① client keys arrive (streaming per-client queries)   -> pending queue
  ② the scheduler coalesces them into *padded batches* drawn from a small
     set of bucket sizes, each bucket backed by a cached compiled serve
     step (core/server.BucketedServeFns) so ragged traffic never
     recompiles (DESIGN.md §6)
  ③ batches are assigned to DPU *clusters* (mesh data-axis groups, each
     holding a full DB replica sharded over `model`) round-robin
  ④ a double-buffered dispatch loop stages batch k+1's key pytree onto
     devices while batch k executes (host staging ∥ device compute)
  ⑤ answers return to the client through per-query futures; all k
     parties' shares are reconciled (``PIRProtocol.reconstruct``) off the
     dispatch critical path

Straggler mitigation: per-cluster latency EWMA; a flagged cluster's queued
work is re-sharded onto healthy clusters (``StragglerMonitor.shed_stragglers``,
wired into ``QueryScheduler.rebalance``) — the clustered replica topology is
exactly what makes this cheap (paper Take-away 5's structure, used for fault
tolerance too).
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.config import PIRConfig
from repro.core import dpf, pir
from repro.core import protocol as protocol_mod
from repro.core.protocol import PIRProtocol
from repro.core.server import PIRServer, bucket_for
from repro.db import ShardedDatabase
from repro.runtime.fault import StragglerMonitor

#: dispatch-queue depth of the double-buffered loop: one batch executing on
#: device, one being staged on the host. Deeper pipelines only add latency.
PIPELINE_DEPTH = 2

#: default batching window — how long a lone query may wait for companions
#: before the scheduler cuts an under-full (padded) batch.
DEFAULT_MAX_WAIT_S = 0.005


@dataclass
class ServeStats:
    answered: int = 0
    batches: int = 0
    padded: int = 0              # pad slots computed-and-discarded
    reassignments: int = 0       # queued batches moved off stragglers
    latencies: List[float] = field(default_factory=list)
    bucket_counts: Dict[int, int] = field(default_factory=dict)
    # serving window: earliest dispatch .. latest completion. Overlapped
    # (pipelined) batches make sum(latencies) exceed wall time, so QPS is
    # computed against this window, never against the latency sum.
    t_first: Optional[float] = None
    t_last: Optional[float] = None

    def observe_window(self, t0: float, t1: float):
        self.t_first = t0 if self.t_first is None else min(self.t_first, t0)
        self.t_last = t1 if self.t_last is None else max(self.t_last, t1)

    @property
    def wall_s(self) -> float:
        if self.t_first is None or self.t_last is None:
            return 0.0
        return self.t_last - self.t_first

    @property
    def qps(self) -> float:
        wall = self.wall_s
        return self.answered / wall if wall > 0 else 0.0

    @property
    def pad_fraction(self) -> float:
        slots = self.answered + self.padded
        return self.padded / slots if slots else 0.0


class QueryTimeout(TimeoutError):
    """``AnswerFuture.result`` ran out of time — with query context.

    The message names everything known about the query (session id,
    batch bucket, answer epoch, elapsed vs deadline) instead of a bare
    "answer not ready", so a timeout in a fleet log is attributable
    without a debugger. Still a ``TimeoutError``: existing handlers keep
    working.
    """

    def __init__(self, fut: Optional["AnswerFuture"] = None,
                 timeout: Optional[float] = None):
        parts = []
        if fut is not None:
            now = time.monotonic()
            ctx = getattr(fut, "context", {})
            if ctx.get("session") is not None:
                parts.append(f"session={ctx['session']}")
            if ctx.get("replica") is not None:
                parts.append(f"replica={ctx['replica']}")
            if ctx.get("bucket") is not None:
                parts.append(f"bucket={ctx['bucket']}")
            if getattr(fut, "epoch", None) is not None:
                parts.append(f"epoch={fut.epoch}")
            created = getattr(fut, "created", None)
            if created is not None:
                parts.append(f"elapsed={now - created:.3f}s")
            deadline = getattr(fut, "deadline", None)
            if deadline is not None:
                parts.append(f"deadline_over_by={now - deadline:+.3f}s")
        if timeout is not None:
            parts.append(f"timeout={timeout:.3f}s")
        detail = f" ({', '.join(parts)})" if parts else ""
        super().__init__(f"answer not ready{detail}")


class AnswerFuture:
    """Per-query result handle: ``submit(index) -> future`` (DESIGN.md §6).

    Thread-safe; ``result()`` blocks until the scheduler completes the
    batch carrying this query (or re-raises the batch's failure).
    ``epoch`` is the database epoch the answer was computed at (set with
    the result when the scheduler has an ``epoch_of`` source; ``None``
    otherwise) — clients of an online-updated DB read it to know which
    version their record reflects.

    ``deadline`` is an absolute ``time.monotonic()`` instant (or ``None``
    for no deadline): ``result()`` with no explicit timeout waits only
    until it, raising :class:`QueryTimeout`, and the replica router's
    reaper uses it to drive hedged resubmits (DESIGN.md §12.3).
    ``context`` accumulates attribution breadcrumbs (session id, bucket,
    routed replica) that the timeout message reports.

    Completion is **first-wins**: once resolved, later ``set_result`` /
    ``set_exception`` calls are ignored (they return ``False``). That is
    what makes a kill-vs-complete race benign — a replica being torn down
    while a batch finishes delivers whichever terminal event lands first,
    exactly once (``replica/router.py`` failover relies on this).
    """

    def __init__(self, *, deadline: Optional[float] = None):
        self._ev = threading.Event()
        self._lock = threading.Lock()
        self._value: Any = None
        self._exc: Optional[BaseException] = None
        self._callbacks: List[Callable[["AnswerFuture"], None]] = []
        self.epoch: Optional[int] = None
        self.deadline = deadline
        self.context: Dict[str, Any] = {}
        self.created = time.monotonic()

    def _resolve(self, value: Any, exc: Optional[BaseException]) -> bool:
        with self._lock:
            if self._ev.is_set():
                return False
            self._value, self._exc = value, exc
            callbacks, self._callbacks = self._callbacks, []
            self._ev.set()
        for cb in callbacks:        # outside the lock: callbacks may block
            cb(self)
        return True

    def set_result(self, value: Any) -> bool:
        return self._resolve(value, None)

    def set_exception(self, exc: BaseException) -> bool:
        return self._resolve(None, exc)

    def add_done_callback(self, fn: Callable[["AnswerFuture"], None]):
        """Call ``fn(self)`` when the future resolves (immediately if it
        already has). Runs on the resolving thread, outside any scheduler
        lock — the replica router chains failover resubmission here."""
        with self._lock:
            if not self._ev.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def done(self) -> bool:
        return self._ev.is_set()

    def exception(self) -> Optional[BaseException]:
        """The failure this future resolved with, or None (also None while
        still pending — pair with :meth:`done`)."""
        return self._exc

    def result(self, timeout: Optional[float] = None) -> Any:
        if timeout is None and self.deadline is not None:
            timeout = max(self.deadline - time.monotonic(), 0.0)
        if not self._ev.wait(timeout):
            raise QueryTimeout(self, timeout=timeout)
        if self._exc is not None:
            raise self._exc
        return self._value


@dataclass
class _Batch:
    """One formed (not yet padded) batch bound for a cluster lane."""
    items: List[Any]                  # raw per-query payloads
    futures: List[AnswerFuture]
    cluster: str
    payload: Any = None               # collated (stacked) keys
    staged: Any = None                # padded + device_put keys
    bucket: int = 0
    epoch: Optional[int] = None       # DB epoch captured at dispatch


class QueryScheduler:
    """Dynamic batcher + double-buffered dispatcher over cluster lanes.

    Parameterized by four callables so the same engine serves one party
    (share answering) or a k-party deployment (share reconciliation):

      collate(items)        stack raw per-query payloads -> batched pytree
      stage(payload)        pad to bucket + device_put (overlaps compute)
      dispatch(staged)      launch the compiled serve step (async, no block)
      finalize(raw, n)      block + convert the first n real answers

    An optional ``epoch_of(raw)`` callable extracts the database epoch a
    batch was computed at from that batch's *own* dispatch result (the
    dispatcher captures an atomic DB snapshot and threads its epoch
    through ``raw``), and the scheduler stamps it onto every future the
    batch resolves — batch-local, so concurrent dispatchers can never
    cross-tag. Across an epoch swap (``ShardedDatabase.publish``),
    batches already dispatched finish — and stay tagged — against the
    old epoch, while queued/pending batches are re-tagged to the epoch
    they actually compute against. Queries never drain or stall across a
    swap.

    Queries arrive via :meth:`submit` (returns an :class:`AnswerFuture`).
    Batches are cut when a full bucket's worth is pending, or when the
    oldest query has waited ``max_wait_s`` (then padded up to the smallest
    covering bucket). Work is spread round-robin over ``n_clusters``
    logical lanes; :meth:`rebalance` sheds a flagged straggler's queued
    batches onto healthy lanes.

    Drive it synchronously with :meth:`pump` (tests, benches) or as a
    background session with :meth:`start` / :meth:`stop`.
    """

    def __init__(
        self,
        *,
        collate: Callable[[List[Any]], Any],
        stage: Callable[[Any], Any],
        dispatch: Callable[[Any], Any],
        finalize: Callable[[Any, int], Sequence[Any]],
        buckets: Sequence[int],
        n_clusters: int = 1,
        max_wait_s: float = DEFAULT_MAX_WAIT_S,
        monitor: Optional[StragglerMonitor] = None,
        depth: int = PIPELINE_DEPTH,
        clock: Callable[[], float] = time.monotonic,
        epoch_of: Optional[Callable[[Any], Optional[int]]] = None,
        heartbeat: Optional[Callable[[], None]] = None,
        chaos=None,
        chaos_target: Optional[str] = None,
    ):
        self._collate = collate
        self._stage = stage
        self._dispatch = dispatch
        self._finalize = finalize
        self._epoch_of = epoch_of
        self.buckets = tuple(sorted(set(buckets)))
        self.n_clusters = max(n_clusters, 1)
        self.max_wait_s = max_wait_s
        self.monitor = monitor if monitor is not None else StragglerMonitor()
        self.depth = max(depth, 1)
        self.clock = clock
        #: liveness hook: called once per dispatch-loop iteration (and per
        #: pump), so a HeartbeatRegistry sees silence exactly when the
        #: session thread stops turning (killed, hung, or crashed). The
        #: replica plane assigns it at registry join.
        self.heartbeat = heartbeat
        #: chaos seam "scheduler.dispatch" (repro/chaos): consulted once
        #: per batch launch — a kill raises InjectedFault (failing the
        #: batch + the session, like a real dispatch crash), stall/delay
        #: sleep. None (production) costs one attribute check per launch.
        self.chaos = chaos
        self.chaos_target = chaos_target
        self.stats = ServeStats()

        self._cv = threading.Condition()
        self._pending: deque = deque()        # (item, future, t_submit)
        self.queues: Dict[str, List[_Batch]] = {
            f"cluster{i}": [] for i in range(self.n_clusters)}
        self._rr = 0                          # round-robin lane counter
        self._n_inflight = 0                  # real queries dispatched, unresolved
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._closed = False                  # terminal: set by stop()/death
        self._abort_exc: Optional[BaseException] = None   # set by kill()

    # ------------------------------------------------------------------
    # intake
    # ------------------------------------------------------------------

    def submit(self, item: Any, *, future: Optional[AnswerFuture] = None
               ) -> AnswerFuture:
        """Enqueue one query payload; returns its future.

        ``future`` re-enqueues work under an *existing* future — the
        replica router's failover handoff moves a dead replica's
        undispatched queries (item, future) onto a healthy scheduler
        without its clients ever seeing a new handle.

        Raises ``RuntimeError`` once the session is closed (``stop()`` was
        called on a running session, or its thread died) — enqueueing into
        a dead loop would leave the future unresolved forever.
        """
        fut = future if future is not None else AnswerFuture()
        with self._cv:
            if self._closed:
                raise RuntimeError(
                    "QueryScheduler is stopped; submit() after stop()/close()"
                    " would never be answered")
            self._pending.append((item, fut, self.clock()))
            if len(self._pending) >= self.buckets[-1]:
                self._cut_locked(self.buckets[-1])
            self._cv.notify()
        return fut

    @property
    def queue_depth(self) -> int:
        """Real queries accepted but not yet resolved: pending + cut into
        lane queues + dispatched in flight (pad slots excluded). The
        router's power-of-two-choices balancing reads this."""
        with self._cv:
            return (len(self._pending) + self._n_inflight
                    + sum(len(b.items) for lane in self.queues.values()
                          for b in lane))

    def drain_handoff(self) -> List[Tuple[Any, AnswerFuture]]:
        """Graceful leave: close intake and hand back every query that has
        NOT been dispatched, as FIFO ``(item, future)`` pairs.

        Batches already dispatched are not returned — they complete (and
        resolve their futures) here, against this scheduler's data plane.
        The caller re-enqueues the returned pairs elsewhere via
        ``submit(item, future=fut)``; the futures move with the work, so
        no client ever observes the migration. A running session thread
        finishes its in-flight work and exits (stop semantics without the
        join); the scheduler rejects new submits from this point on.
        """
        out: List[Tuple[Any, AnswerFuture]] = []
        with self._cv:
            self._closed = True
            self._stopping = True
            for lane in self.queues.values():
                for batch in lane:
                    out.extend(zip(batch.items, batch.futures))
                lane.clear()
            while self._pending:
                item, fut, _ = self._pending.popleft()
                out.append((item, fut))
            self._cv.notify_all()
        return out

    def kill(self, exc: BaseException):
        """Hard death (crash injection / fault handling): fail every
        outstanding future with ``exc`` and stop without draining.

        Queued and pending work is failed from the calling thread; a
        running session thread aborts its loop and fails its in-flight
        batches the same way, then exits. Races with completing batches
        resolve first-wins (:class:`AnswerFuture`): a batch that beats the
        kill delivers its answers, everything else fails — either way each
        future resolves exactly once, which is what lets the router's
        failover resubmit the losses with zero dropped queries.
        """
        victims: List[AnswerFuture] = []
        with self._cv:
            self._closed = True
            self._stopping = True
            self._abort_exc = exc
            for lane in self.queues.values():
                for batch in lane:
                    victims.extend(batch.futures)
                lane.clear()
            while self._pending:
                _, fut, _ = self._pending.popleft()
                victims.append(fut)
            self._cv.notify_all()
        for fut in victims:          # outside the lock: callbacks may block
            fut.set_exception(exc)

    def flush(self):
        """Cut every pending query into batches now (end-of-stream)."""
        with self._cv:
            while self._pending:
                self._cut_locked(min(len(self._pending), self.buckets[-1]))
            self._cv.notify()

    def bucket_for(self, n: int) -> int:
        return bucket_for(self.buckets, n)

    def _cut_locked(self, n: int):
        """Form one batch of ``n`` pending queries onto the next lane."""
        taken = [self._pending.popleft() for _ in range(n)]
        lane = f"cluster{self._rr % self.n_clusters}"
        self._rr += 1
        batch = _Batch(items=[t[0] for t in taken],
                       futures=[t[1] for t in taken],
                       cluster=lane)
        batch.bucket = self.bucket_for(n)
        for fut in batch.futures:    # timeout-attribution breadcrumb
            fut.context.setdefault("bucket", batch.bucket)
        self.queues[lane].append(batch)

    def _cut_ripe_locked(self) -> bool:
        """Cut under-full batches whose oldest query aged past max_wait_s."""
        cut = False
        while self._pending and \
                self.clock() - self._pending[0][2] >= self.max_wait_s:
            self._cut_locked(min(len(self._pending), self.buckets[-1]))
            cut = True
        return cut

    # ------------------------------------------------------------------
    # straggler shedding
    # ------------------------------------------------------------------

    def rebalance(self) -> int:
        """Move queued batches off flagged straggler lanes; returns moved."""
        with self._cv:
            new_queues, moved = self.monitor.shed_stragglers(self.queues)
            if moved:
                for lane, b_list in new_queues.items():
                    for b in b_list:
                        b.cluster = lane
                self.queues = new_queues
                self.stats.reassignments += moved
        return moved

    def _pop_batch_locked(self) -> Optional[_Batch]:
        for i in range(self.n_clusters):
            lane = f"cluster{(self._rr + i) % self.n_clusters}"
            if self.queues[lane]:
                return self.queues[lane].pop(0)
        return None

    # ------------------------------------------------------------------
    # dispatch engine
    # ------------------------------------------------------------------

    def _launch(self, batch: _Batch) -> Tuple[_Batch, Any, float]:
        """Collate + stage + dispatch one batch (device runs async).

        A failure anywhere in the launch path (including an injected
        chaos kill) fails the batch's futures before propagating — the
        batch has already left the lane queues, so nothing else would
        ever resolve them.
        """
        try:
            if self.chaos is not None:
                self.chaos.visit("scheduler.dispatch", self.chaos_target)
            batch.payload = self._collate(batch.items)
            batch.staged = self._stage(batch.payload)
            t0 = self.clock()
            raw = self._dispatch(batch.staged)
            if self._epoch_of is not None:
                # extracted from THIS batch's dispatch result: the
                # dispatcher snapshots the DB atomically and threads the
                # epoch it read through raw, so tag == data even across a
                # concurrent publish or a second dispatching thread (the
                # dispatched step holds the old epoch's immutable arrays
                # and finishes against them)
                batch.epoch = self._epoch_of(raw)
        except BaseException as e:
            for fut in batch.futures:
                fut.set_exception(e)
            raise
        with self._cv:
            self._n_inflight += len(batch.items)
        return batch, raw, t0

    def _complete(self, batch: _Batch, raw: Any, t0: float):
        try:
            answers = self._finalize(raw, len(batch.items))
            dt = self.clock() - t0
            for fut, ans in zip(batch.futures, answers):
                fut.epoch = batch.epoch      # before the result event fires
                fut.set_result(ans)
        except BaseException as e:       # propagate to the waiting clients
            for fut in batch.futures:
                fut.set_exception(e)
            raise
        finally:
            with self._cv:
                self._n_inflight -= len(batch.items)
        self.monitor.record(batch.cluster, dt)
        self.stats.observe_window(t0, t0 + dt)
        self.stats.latencies.append(dt)
        self.stats.batches += 1
        self.stats.answered += len(batch.items)
        self.stats.padded += batch.bucket - len(batch.items)
        self.stats.bucket_counts[batch.bucket] = \
            self.stats.bucket_counts.get(batch.bucket, 0) + 1
        self.rebalance()

    def pump(self) -> int:
        """Synchronously drain all pending + queued work, double-buffered.

        Stages/dispatches batch k+1 before blocking on batch k, so host-side
        key staging overlaps device compute. Returns #queries answered.
        """
        if self.heartbeat is not None:
            self.heartbeat()
        self.flush()
        answered0 = self.stats.answered
        inflight: deque = deque()
        while True:
            with self._cv:
                batch = self._pop_batch_locked()
            if batch is None and not inflight:
                break
            if batch is not None:
                inflight.append(self._launch(batch))
            while inflight and (len(inflight) >= self.depth
                                or batch is None):
                self._complete(*inflight.popleft())
        return self.stats.answered - answered0

    # ------------------------------------------------------------------
    # background session mode
    # ------------------------------------------------------------------

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self):
        """Run the dispatch loop as a background session thread.

        Reopens a stopped (or dead) session: the closed flag is cleared,
        so submit() works again until the next stop().
        """
        if self.running:
            return
        with self._cv:
            self._closed = False
            self._stopping = False
            self._abort_exc = None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="pir-scheduler")
        self._thread.start()

    def stop(self):
        """Flush, answer everything in flight, then join the thread.

        Terminal for the session: subsequent :meth:`submit` calls raise
        (``pump`` remains callable and is a no-op on the drained queues).
        A scheduler that was never started is untouched — the synchronous
        submit-then-pump mode stays available.
        """
        with self._cv:
            # snapshot under the lock: a concurrent stop() may null out
            # self._thread between our aliveness check and the join
            thread = self._thread
            if thread is None or not thread.is_alive():
                return
            # closed BEFORE the join: a submit racing with stop() must
            # raise, not slip into the queue after the drain check and
            # hang its client forever
            self._closed = True
            self._stopping = True
            self._cv.notify()
        thread.join()
        with self._cv:
            # a concurrent start() may have installed a fresh session
            # thread meanwhile — only clear our own dead one
            if self._thread is thread:
                self._thread = None

    def _run(self):
        inflight: deque = deque()
        try:
            while True:
                batch = None
                if self.heartbeat is not None:
                    self.heartbeat()
                with self._cv:
                    if self._abort_exc is not None:   # kill(): no draining
                        raise self._abort_exc
                    self._cut_ripe_locked()
                    if self._stopping:
                        while self._pending:
                            self._cut_locked(
                                min(len(self._pending), self.buckets[-1]))
                    if len(inflight) < self.depth:
                        batch = self._pop_batch_locked()
                    if (batch is None and not inflight and not self._pending
                            and self._stopping):
                        return
                    if batch is None and not inflight:
                        # idle: sleep until a submit arrives or one ripens
                        wait = self.max_wait_s
                        if self._pending:
                            age = self.clock() - self._pending[0][2]
                            wait = max(self.max_wait_s - age, 0.0)
                        self._cv.wait(timeout=wait if self._pending else None)
                        continue
                if batch is not None:
                    inflight.append(self._launch(batch))
                    continue  # keep the pipeline full before blocking
                self._complete(*inflight.popleft())
        except BaseException as e:
            # the session is dead: every outstanding future must resolve,
            # not hang its client until result() times out
            self._fail_outstanding(inflight, e)

    def _fail_outstanding(self, inflight, exc: BaseException):
        victims: List[AnswerFuture] = []
        for batch, _, _ in inflight:
            victims.extend(batch.futures)
        with self._cv:
            self._closed = True      # dead session: reject future submits
            self._n_inflight = 0
            for lane in self.queues.values():
                for batch in lane:
                    victims.extend(batch.futures)
                lane.clear()
            while self._pending:
                _, fut, _ = self._pending.popleft()
                victims.append(fut)
        for fut in victims:          # outside the lock: done-callbacks may
            fut.set_exception(exc)   # re-enter other schedulers (failover)


class PIRServeLoop:
    """Single-party serve loop over a cluster-sharded PIR server."""

    def __init__(self, server: PIRServer, *, n_clusters: int = 1):
        self.server = server
        self.n_clusters = n_clusters
        self.task_q: "queue.Queue" = queue.Queue()
        self.straggler = StragglerMonitor()
        self.stats = ServeStats()

    def submit(self, keys: dpf.DPFKey):
        """Enqueue a batch of stacked DPF keys (one cluster-step of work)."""
        self.task_q.put(keys)

    def drain(self) -> List[jax.Array]:
        """Serial baseline: answer every queued batch, blocking per batch.

        Kept as the §Perf comparison point for :meth:`drain_pipelined` —
        this is the paper's strictly synchronous Figure 8 loop.
        """
        out = []
        while not self.task_q.empty():
            keys = self.task_q.get()
            t0 = time.monotonic()
            ans = self.server.answer(keys)
            ans.block_until_ready()
            self._record(keys, t0, time.monotonic() - t0)
            out.append(ans)
        return out

    def drain_pipelined(self, depth: int = PIPELINE_DEPTH) -> List[jax.Array]:
        """Double-buffered drain: stage batch k+1 while batch k executes.

        Same answers as :meth:`drain` — staged batches are padded to their
        bucket, so the pad rows are sliced back off here; the
        ``block_until_ready`` bubble is overlapped with the next batch's
        host-side staging + dispatch.
        """
        out: List[jax.Array] = []
        inflight: deque = deque()
        while not self.task_q.empty() or inflight:
            if not self.task_q.empty() and len(inflight) < depth:
                keys = self.task_q.get()
                staged = self.server.stage_keys(keys)
                t0 = time.monotonic()
                inflight.append((keys, self.server.answer(staged), t0))
                continue
            keys, ans, t0 = inflight.popleft()
            ans = ans[: dpf.n_queries_of(keys)]      # drop pad-slot answers
            ans.block_until_ready()
            self._record(keys, t0, time.monotonic() - t0)
            out.append(ans)
        return out

    def _record(self, keys: dpf.DPFKey, t0: float, dt: float):
        self.stats.observe_window(t0, t0 + dt)
        self.stats.latencies.append(dt)
        self.stats.batches += 1
        self.stats.answered += dpf.n_queries_of(keys)
        self.straggler.record(
            f"cluster{self.stats.batches % max(self.n_clusters, 1)}", dt)


class MultiServerPIR:
    """End-to-end k-party deployment: client + k non-colluding servers.

    The facade over the protocol plane (``core/protocol.py``): the injected
    ``PIRProtocol`` (default: the one ``cfg.protocol`` names) decides the
    party count, per-party key generation, and reconstruction; one
    :class:`PIRServer` per party owns that party's compiled step family;
    one :class:`QueryScheduler` coalesces all clients' queries and fans
    every batch out to all k parties.

    The database is ONE shared :class:`ShardedDatabase` (DESIGN.md §8):
    its contents are public in the PIR model (privacy protects the query
    index), so k parties referencing the same placed views costs one
    host pass and one device residency instead of k of each. In a real
    deployment each party holds its own replica and applies the identical
    public ``update``/``publish`` delta stream — determinism of the delta
    is what keeps all parties' answer shares consistent; sharing the
    object here is the single-host degenerate case of that.

    All servers run the same binary on disjoint meshes in production; on
    this container they share the device but keep separate key material
    and compiled steps, preserving the protocol structure exactly.

    Two client APIs:

      query(indices)   synchronous batch retrieval (pumps the scheduler
                       inline when no session thread is running)
      submit(index)    streaming session form: returns an
                       :class:`AnswerFuture`; the scheduler coalesces
                       concurrent clients' queries into padded bucket
                       batches and reconciles all parties' answer shares
                       asynchronously. Call :meth:`start` for a background
                       session (or rely on ``query``/``pump``).

    Online updates: :meth:`update` stages public row writes,
    :meth:`publish` atomically swaps in the new epoch (O(rows) transfer,
    no serving stall); every resolved :class:`AnswerFuture` carries the
    ``epoch`` its answer was computed at.
    """

    #: hint protocols (``PIRProtocol.needs_hint``) thread per-query client
    #: state and an epoch hint through the scheduler; only subclasses that
    #: implement that plumbing (SingleServerPIR) may serve them.
    _supports_hint_protocols = False

    def __init__(self, db_words, cfg: PIRConfig, mesh,
                 *, path: Optional[str] = "fused", n_queries: int = 4,
                 buckets: Optional[Sequence[int]] = None,
                 max_wait_s: float = DEFAULT_MAX_WAIT_S,
                 n_clusters: int = 1,
                 protocol: Optional[PIRProtocol] = None,
                 client_rng: Optional[np.random.Generator] = None,
                 default_deadline_s: Optional[float] = None,
                 chaos=None, chaos_scope: Optional[str] = None):
        self.cfg = cfg
        self.protocol = (protocol if protocol is not None
                         else protocol_mod.for_config(cfg))
        if self.protocol.needs_hint and not self._supports_hint_protocols:
            raise ValueError(
                f"protocol {self.protocol.name!r} needs hint plumbing "
                f"(per-query client state + epoch hints) — use "
                f"SingleServerPIR, not {type(self).__name__}")
        self.n_parties = self.protocol.n_parties(cfg)
        # one shared database plane object for all k parties (a host
        # array is wrapped; an existing ShardedDatabase passes through)
        self.db = (db_words if isinstance(db_words, ShardedDatabase)
                   else ShardedDatabase(db_words, cfg, mesh))
        self.servers = [
            PIRServer(party=b, database=self.db, cfg=cfg, mesh=mesh,
                      n_queries=n_queries, path=path, buckets=buckets,
                      protocol=self.protocol)
            for b in range(self.n_parties)
        ]
        # key material (DPF keys, xor-dpf-k mask seeds) must not be
        # replayable: default to OS entropy; inject a seeded Generator
        # only for deterministic tests/benches
        self.rng = (client_rng if client_rng is not None
                    else np.random.default_rng())
        self._lock = threading.Lock()
        # per-query deadline default (DESIGN.md §12.3): every submit()
        # stamps an absolute deadline onto its AnswerFuture, which both
        # result() and the replica router's hedging reaper read. The
        # compile-aware default replaces the old hardcoded
        # ``_query_timeout_s``: first dispatch compiles one serve step per
        # party (~1 min each on the dev container), so a cold background
        # session needs the deadline to scale with the party count.
        self.default_deadline_s = (default_deadline_s
                                   if default_deadline_s is not None
                                   else 120.0 * self.n_parties)
        #: chaos plane wiring (repro/chaos; None in production): the
        #: injector is consulted at "scheduler.dispatch" (batch launch)
        #: and "replica.serve_step" (the answer shares of each dispatch,
        #: where the corrupt action flips bits). ``chaos_scope`` is this
        #: deployment's target id — the replica plane passes its replica
        #: id so plans can aim at one replica of a fleet.
        self.chaos = chaos
        self.chaos_scope = chaos_scope
        self.scheduler = self._make_scheduler(max_wait_s, n_clusters)

    def _make_scheduler(self, max_wait_s: float, n_clusters: int
                        ) -> QueryScheduler:
        servers = self.servers
        proto = self.protocol
        parties = range(self.n_parties)
        db = self.db
        cfg = self.cfg
        chaos, chaos_scope = self.chaos, self.chaos_scope

        def collate(items):
            # items: per-query tuples of per-party keys -> per-party batches
            return tuple(dpf.stack_keys([it[p] for it in items])
                         for p in parties)

        def stage(payload):
            return tuple(servers[p].stage_keys(payload[p]) for p in parties)

        def dispatch(staged):
            # one atomic (epoch, views) capture for the whole k-party
            # fan-out: every party answers against the SAME epoch, and the
            # epoch rides WITH the answers, so the tag can never disagree
            # with the data read — even across concurrent dispatchers
            epoch, views = db.snapshot((proto.db_view,))
            view = views[proto.db_view]
            answers = tuple(servers[p].bucketed.answer(view, staged[p])
                            for p in parties)
            if chaos is not None:   # seam: corrupt one party's shares
                answers = chaos.corrupt_shares("replica.serve_step",
                                               chaos_scope, answers)
            return answers, epoch

        def finalize(raw, n):
            answers, _ = raw
            # reconstruct_with routes through checksum verification when
            # cfg.checksum — a corrupted share raises IntegrityError here
            # (failing this batch's futures) instead of resolving garbage
            rec = np.asarray(proto.reconstruct_with(
                [r[:n] for r in answers], [None] * n, cfg=cfg))
            return list(rec)

        return QueryScheduler(
            collate=collate, stage=stage, dispatch=dispatch,
            finalize=finalize, buckets=servers[0].buckets,
            n_clusters=n_clusters, max_wait_s=max_wait_s,
            epoch_of=lambda raw: raw[1],
            chaos=chaos, chaos_target=chaos_scope)

    # -- streaming session API ------------------------------------------

    def start(self):
        """Run the scheduler as a background session thread."""
        self.scheduler.start()

    def close(self):
        self.scheduler.stop()

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.close()

    def _deadline_future(self, deadline_s: Optional[float]) -> AnswerFuture:
        """A fresh future carrying this query's absolute deadline."""
        d = self.default_deadline_s if deadline_s is None else deadline_s
        return AnswerFuture(
            deadline=None if d is None else time.monotonic() + d)

    def submit(self, index: int, *,
               deadline_s: Optional[float] = None) -> AnswerFuture:
        """Private retrieval of ``db[index]``; resolves to one record
        (``[W]`` u32 words for the XOR protocols, bytes for additive).
        The resolved future's ``epoch`` names the DB version answered.

        ``deadline_s`` (default: ``default_deadline_s``) becomes an
        absolute deadline on the returned future: ``result()`` with no
        explicit timeout waits only until it.
        """
        fut = self._deadline_future(deadline_s)
        with self._lock:     # client-side keygen shares one rng
            q = pir.query_gen(self.rng, index, self.cfg)
        return self.scheduler.submit(q.keys, future=fut)

    # -- online updates (public metadata; privacy model untouched) ------

    @property
    def epoch(self) -> int:
        """Current database epoch (bumped by :meth:`publish`)."""
        return self.db.epoch

    def update(self, rows, values) -> int:
        """Stage public row writes into the pending delta log.

        ``values``: [R, item_words] u32 or [R, item_bytes] u8. Nothing
        is served from the delta until :meth:`publish`. In a multi-host
        deployment every party stages the identical delta (it is public
        metadata), which is what keeps the k answer shares consistent.
        Returns the total staged entry count.
        """
        return self.db.stage(rows, values)

    def publish(self) -> int:
        """Swap staged updates in as the next epoch (O(rows) transfer).

        Serving never stalls: batches already dispatched finish against
        the previous epoch (their answers stay tagged with it); every
        later batch reads the new views. Returns the new current epoch.
        """
        return self.db.publish()

    # -- synchronous batch API ------------------------------------------

    def query(self, indices: Sequence[int]) -> np.ndarray:
        """Private retrieval of ``db[indices]``; returns [Q, ...] records
        (u32 words for XOR protocols, Z_256 bytes for additive)."""
        if not indices:
            tail, dtype = self.protocol.record_struct(self.cfg)
            return np.empty((0,) + tail, dtype)
        futs = [self.submit(i) for i in indices]
        if not self.scheduler.running:
            self.scheduler.pump()
        # each future carries its own deadline (set at submit); result()
        # derives the wait from it
        return np.stack([f.result() for f in futs])

    def query_batch(self, indices: Sequence[int]) -> np.ndarray:
        """Multi-query retrieval; same contract as :meth:`query`.

        Here each index is an independent full-DB-scan query (they only
        share the scheduler's padded-batch dispatch). The cuckoo-bucketed
        composite (``runtime/batch.py`` :class:`BatchPIR`) overrides this
        with the amortized m-records-per-round protocol — callers written
        against ``query_batch`` get the algorithmic speedup wherever the
        deployment provides it.
        """
        return self.query(indices)


class SingleServerPIR(MultiServerPIR):
    """Single-server deployment for hint protocols (``lwe-simple-1``).

    The no-collusion-assumption scenario (DESIGN.md §10): one server, and
    privacy rests on LWE hardness instead of parties never comparing
    notes. Reuses the whole multi-server machinery — ``ShardedDatabase``,
    ``PIRServer``'s bucketed compiled steps, the ``QueryScheduler`` — with
    the two deltas a hint protocol needs:

      * **client state**: :meth:`submit` generates ``(keys, state)`` via
        ``query_gen_full``; the per-query secret rides through the
        scheduler next to the keys (never serialized, never staged onto
        devices) and meets the answers again at finalize;
      * **client-side hint cache**: reconstruction needs the epoch's hint
        ``H = A^T.DB``. The facade plays the client here: it caches the
        hint keyed by the epoch each batch's answers were tagged with and
        re-fetches on a miss — a ``publish()`` bumps the epoch, so stale
        caches are invalidated exactly when the data changes
        (``hint_fetches`` counts the round trips; the server side
        maintains the hint itself incrementally via the registered delta).

    ``path`` defaults to ``None``: the plan is resolved through the engine
    plane (plan-cache hit -> tuned LWE GEMM tiles, miss -> heuristic).
    """

    _supports_hint_protocols = True

    def __init__(self, db_words, cfg: PIRConfig, mesh,
                 *args, path: Optional[str] = None,
                 protocol: Optional[PIRProtocol] = None, **kwargs):
        proto = (protocol if protocol is not None
                 else protocol_mod.for_config(cfg))
        k = proto.n_parties(cfg)
        if k != 1:
            raise ValueError(
                f"SingleServerPIR requires a 1-party protocol; "
                f"{proto.name!r} has {k} parties — use MultiServerPIR")
        # client-side hint cache: set up BEFORE super().__init__ builds
        # the scheduler (whose finalize closure reads it)
        self._hint_lock = threading.Lock()
        self._hint_cache: Dict[int, np.ndarray] = {}
        self.hint_fetches = 0
        super().__init__(db_words, cfg, mesh, *args, path=path,
                         protocol=proto, **kwargs)

    def _client_hint(self, epoch: int) -> np.ndarray:
        """The hint for one epoch, through the client-side cache."""
        with self._hint_lock:
            if epoch not in self._hint_cache:
                self.hint_fetches += 1
                self._hint_cache[epoch] = np.asarray(
                    self.db.hint(self.protocol.name, epoch=epoch))
                # two epochs of hysteresis, mirroring the server's
                # retired-view double buffer
                for e in sorted(self._hint_cache)[:-2]:
                    del self._hint_cache[e]
            return self._hint_cache[epoch]

    def _make_scheduler(self, max_wait_s: float, n_clusters: int
                        ) -> QueryScheduler:
        server = self.servers[0]
        proto = self.protocol
        cfg = self.cfg
        db = self.db
        chaos, chaos_scope = self.chaos, self.chaos_scope
        # server-side hint lifecycle: built lazily per epoch, delta-updated
        # on publish (db/sharded.py)
        db.register_hint(proto.name, proto.hint_builder(cfg),
                         proto.hint_delta(cfg))

        def collate(items):
            # items: ((keys,), state) per query — stack party-0 keys,
            # carry the client states alongside (host-only, never staged)
            keys = dpf.stack_keys([it[0][0] for it in items])
            return keys, [it[1] for it in items]

        def stage(payload):
            keys, states = payload
            return server.stage_keys(keys), states

        def dispatch(staged):
            keys, states = staged
            epoch, views = db.snapshot((proto.db_view,))
            ans = server.bucketed.answer(views[proto.db_view], keys)
            if chaos is not None:   # seam: corrupt the answer matrix
                (ans,) = chaos.corrupt_shares("replica.serve_step",
                                              chaos_scope, (ans,))
            return ans, epoch, states

        def finalize(raw, n):
            ans, epoch, states = raw
            hint = self._client_hint(epoch)
            rec = np.asarray(proto.reconstruct_with(
                [np.asarray(ans[:n])], states[:n], cfg=cfg, hint=hint))
            return list(rec)

        return QueryScheduler(
            collate=collate, stage=stage, dispatch=dispatch,
            finalize=finalize, buckets=server.buckets,
            n_clusters=n_clusters, max_wait_s=max_wait_s,
            epoch_of=lambda raw: raw[1],
            chaos=chaos, chaos_target=chaos_scope)

    def submit(self, index: int, *,
               deadline_s: Optional[float] = None) -> AnswerFuture:
        """Private retrieval of ``db[index]``; resolves to one record
        ([item_bytes] u8). The per-query LWE secret stays client-side:
        only the ciphertext enters the scheduler's device path."""
        fut = self._deadline_future(deadline_s)
        with self._lock:     # client-side keygen shares one rng
            keys, state = self.protocol.query_gen_full(self.rng, index,
                                                       self.cfg)
        return self.scheduler.submit((keys, state), future=fut)


class TwoServerPIR(MultiServerPIR):
    """Backward-compatible alias: the two-party deployment.

    Kept as a thin ``n_parties == 2`` facade over :class:`MultiServerPIR`
    (the pre-protocol-plane public API). New code should construct
    :class:`MultiServerPIR` with an explicit ``PIRConfig.protocol``.
    """

    def __init__(self, db_words: np.ndarray, cfg: PIRConfig, mesh,
                 *args, protocol: Optional[PIRProtocol] = None, **kwargs):
        # validate BEFORE building servers: k device-resident DB replicas
        # are too expensive to allocate just to throw away
        proto = (protocol if protocol is not None
                 else protocol_mod.for_config(cfg))
        k = proto.n_parties(cfg)
        if k != 2:
            raise ValueError(
                f"TwoServerPIR requires a 2-party protocol; "
                f"{proto.name!r} has {k} parties — use MultiServerPIR")
        super().__init__(db_words, cfg, mesh, *args, protocol=proto,
                         **kwargs)
