"""PIR serving runtime — the paper's Figure 8 multi-query workflow.

Pipeline stages (paper §3.4):
  ① client keys arrive (batch of DPF key pairs)        -> task queue
  ② worker threads run DPF evaluation                  (paper: host CPU;
     here it's fused into the device step — see core/server.py — so the
     "worker" stage just stages key pytrees onto devices)
  ③ scheduler assigns queries to DPU *clusters*        (mesh data-axis
     groups, each holding a full DB replica sharded over `model`)
  ④ clusters run dpXOR, subresults aggregate over the shard axis
  ⑤ answers return to the client

Straggler mitigation: per-cluster latency EWMA; a flagged cluster's queued
work is re-sharded onto healthy clusters (``StragglerMonitor.reassign``) —
the clustered replica topology is exactly what makes this cheap (paper
Take-away 5's structure, used for fault tolerance too).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.config import PIRConfig
from repro.core import dpf, pir
from repro.core.server import PIRServer
from repro.runtime.fault import StragglerMonitor


@dataclass
class ServeStats:
    answered: int = 0
    batches: int = 0
    reassignments: int = 0
    latencies: List[float] = field(default_factory=list)

    @property
    def qps(self) -> float:
        total = sum(self.latencies)
        return self.answered / total if total else 0.0


class PIRServeLoop:
    """Single-party serve loop over a cluster-sharded PIR server."""

    def __init__(self, server: PIRServer, *, n_clusters: int = 1):
        self.server = server
        self.n_clusters = n_clusters
        self.task_q: "queue.Queue" = queue.Queue()
        self.straggler = StragglerMonitor()
        self.stats = ServeStats()

    def submit(self, keys: dpf.DPFKey):
        """Enqueue a batch of stacked DPF keys (one cluster-step of work)."""
        self.task_q.put(keys)

    def drain(self) -> List[jax.Array]:
        """Answer every queued batch; returns per-batch answer shares."""
        out = []
        while not self.task_q.empty():
            keys = self.task_q.get()
            t0 = time.monotonic()
            ans = self.server.answer(keys)
            ans.block_until_ready()
            dt = time.monotonic() - t0
            self.stats.latencies.append(dt)
            self.stats.batches += 1
            self.stats.answered += keys.root_seed.shape[0]
            self.straggler.record(f"cluster{self.stats.batches % max(self.n_clusters, 1)}", dt)
            out.append(ans)
        return out


class TwoServerPIR:
    """End-to-end two-party deployment: client + two non-colluding servers.

    Both servers run the same binary on disjoint meshes in production; on
    this container they share the device but keep separate DB buffers and
    compiled steps, preserving the protocol structure exactly.
    """

    def __init__(self, db_words: np.ndarray, cfg: PIRConfig, mesh,
                 *, path: str = "fused", n_queries: int = 4):
        self.cfg = cfg
        self.servers = [
            PIRServer(party=b, db_words=db_words, cfg=cfg, mesh=mesh,
                      n_queries=n_queries, path=path)
            for b in (0, 1)
        ]
        self.rng = np.random.default_rng(0)

    def query(self, indices: Sequence[int]) -> np.ndarray:
        """Private retrieval of ``db[indices]``; returns [Q, W] words."""
        k0, k1 = pir.batch_queries(self.rng, indices, self.cfg)
        r0 = self.servers[0].answer(k0)
        r1 = self.servers[1].answer(k1)
        return np.asarray(pir.reconstruct_xor(r0, r1))
