"""Loop-aware HLO cost analysis (FLOPs / bytes / collective bytes).

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits a
``while`` body ONCE — a 61-layer ``lax.scan`` (and the microbatch
accumulation loop around it) is undercounted by orders of magnitude
(verified on this container: a scan of 10 matmuls reports 1 matmul's
flops). This module parses the *optimized* HLO text, recovers loop trip
counts from the condition computations (scan bounds lower to
``s32[] constant(N)`` compares), and folds per-computation costs through
the call graph with multiplicity:

  flops       2·K·prod(out) per dot (K from lhs_contracting_dims);
              prod(out) per elementwise/fusion-internal op (noise-level)
  bytes       fusion-boundary traffic: operand + output bytes of each
              top-level op (fusion internals are register/VMEM-resident);
              the standard HBM-traffic proxy
  collective  output bytes of all-gather / all-reduce / reduce-scatter /
              all-to-all / collective-permute, × enclosing trip counts

Shapes in the post-SPMD module are per-device, so all totals are
per-device per-step.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")


def _parse_shape(text: str) -> List[Tuple[str, List[int]]]:
    """'(bf16[2,3]{1,0}, s32[])' or 'f32[4,5]' -> [(dtype, dims), ...]"""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(shapes) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Instr:
    name: str
    shapes: List[Tuple[str, List[int]]]
    op: str
    operands: List[str]
    attrs: str
    args_raw: str = ""       # text inside the op's parens (param indices)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shape_of: Dict[str, List[Tuple[str, List[int]]]] = field(
        default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0           # MXU-class: dot/convolution only
    elem_flops: float = 0.0      # VPU-class: elementwise (reported aside)
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    unknown_loops: int = 0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.elem_flops += o.elem_flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        self.unknown_loops += o.unknown_loops
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.elem_flops * k, self.bytes * k,
                    self.coll_bytes * k,
                    {kk: v * k for kk, v in self.coll_by_kind.items()},
                    self.unknown_loops)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_marked: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry_marked = m.group(1)
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            # parameters look like instructions and match; anything else skip
            continue
        name, shape_txt, op, rest = m.groups()
        # operands: %tokens inside the first balanced paren group
        depth, i, args_end = 1, 0, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args_end = i
                    break
        arg_txt = rest[:args_end]
        attr_txt = rest[args_end + 1:]
        operands = re.findall(r"%([\w\.\-]+)", arg_txt)
        inst = Instr(name=name, shapes=_parse_shape(shape_txt), op=op,
                     operands=operands, attrs=attr_txt, args_raw=arg_txt)
        cur.instrs.append(inst)
        cur.shape_of[name] = inst.shapes
    if cur is not None:
        comps[cur.name] = cur
    if entry_marked:
        comps["__entry__"] = comps[entry_marked]
    return comps


_ZERO_COST_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "iota"}
_FLOP_PER_ELEM = {
    "exponential": 4, "log": 4, "rsqrt": 2, "sqrt": 2, "divide": 2,
    "power": 8, "tanh": 6, "logistic": 6,
}

# Ops a TPU-grade fuser absorbs into loop fusions: their intermediates live
# in VMEM/registers, not HBM. The CPU backend leaves many of them unfused,
# which inflated the memory term ~4× (and ~100× for the all-elementwise
# ChaCha chains of the DPF eval — whose Pallas kernel is exactly the
# "keep it in VMEM" statement). Bytes are charged only at fusion
# *boundaries*: dots, loops, data movement, collectives.
_FUSIBLE_OPS = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "sign",
    "and", "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "remainder", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt",
    "cbrt", "tanh", "logistic", "sine", "cosine", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "is-finite", "clamp",
    "maximum", "minimum", "compare", "select", "convert", "broadcast",
    "reshape", "reduce", "pad", "reverse", "map", "real", "imag",
}


def _dot_flops(inst: Instr, comp: Computation) -> float:
    out_elems = _numel(inst.shapes)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs)
    k = 1
    if m and inst.operands:
        lhs = comp.shape_of.get(inst.operands[0])
        if lhs:
            dims = lhs[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * k * out_elems


class HloCostAnalyzer:
    def __init__(self, text: str):
        self.text = text
        self.comps = parse_module(text)
        self._memo: Dict[str, Cost] = {}
        self._const_vals = self._parse_constants(text)

    @staticmethod
    def _parse_constants(text: str) -> Dict[Tuple[str, str], int]:
        """(comp, instr_name) -> integer constant value."""
        out = {}
        cur = None
        hdr = _COMP_HDR
        for line in text.splitlines():
            s = line.strip()
            m = hdr.match(s)
            if m and s.endswith("{"):
                cur = m.group(1)
                continue
            if s.startswith("}"):
                cur = None
                continue
            m = re.match(
                r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*[su](?:8|16|32|64)\[\]\s+"
                r"constant\((\d+)\)", s)
            if m and cur:
                out[(cur, m.group(1))] = int(m.group(2))
        return out

    def trip_count(self, cond_name: str) -> Optional[int]:
        vals = [v for (c, _), v in self._const_vals.items()
                if c == cond_name]
        return max(vals) if vals else None

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            return total
        self._memo[comp_name] = total      # cycle guard
        for inst in comp.instrs:
            total += self._instr_cost(inst, comp)
        return total

    def _called(self, inst: Instr, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", inst.attrs)
        return m.group(1) if m else None

    def _all_fusible(self, comp_name: str) -> bool:
        comp = self.comps.get(comp_name)
        if comp is None:
            return False
        return all(i.op in _FUSIBLE_OPS or i.op in _ZERO_COST_OPS
                   for i in comp.instrs)

    _SLICING = ("slice", "dynamic-slice", "gather", "bitcast", "reshape",
                "transpose", "copy")

    def _fusion_input_bytes(self, called: str, inst: Instr,
                            comp: Computation) -> float:
        """Effective operand traffic of a fusion: params consumed *only*
        via slicing ops charge the slice outputs, not the full operand."""
        sub = self.comps.get(called)
        if sub is None:
            return sum(_shape_bytes(comp.shape_of.get(o, []))
                       for o in inst.operands)
        # map operand position -> parameter instruction via parameter(N)
        order: List[Optional[Instr]] = [None] * len(inst.operands)
        for i2 in sub.instrs:
            if i2.op == "parameter":
                try:
                    idx = int(i2.args_raw.strip().rstrip(")"))
                except ValueError:
                    continue
                if idx < len(order):
                    order[idx] = i2
        consumers: Dict[str, List[Instr]] = {}
        for i2 in sub.instrs:
            for o in i2.operands:
                consumers.setdefault(o, []).append(i2)
        total = 0.0
        for idx, opnd in enumerate(inst.operands):
            full = _shape_bytes(comp.shape_of.get(opnd, []))
            p = order[idx] if idx < len(order) else None
            if p is not None:
                cons = consumers.get(p.name, [])
                if cons and all(x.op in self._SLICING for x in cons):
                    sliced = sum(_shape_bytes(x.shapes) for x in cons)
                    total += min(full, sliced)
                    continue
            total += full
        return total

    def _instr_cost(self, inst: Instr, comp: Computation) -> Cost:
        c = Cost()
        op = inst.op
        if op in _ZERO_COST_OPS:
            return c
        out_bytes = _shape_bytes(inst.shapes)
        in_bytes = sum(_shape_bytes(comp.shape_of.get(o, []))
                       for o in inst.operands)
        if op == "while":
            body = self._called(inst, "body")
            cond = self._called(inst, "condition")
            trips = self.trip_count(cond) if cond else None
            if trips is None:
                trips = 1
                c.unknown_loops += 1
            inner = Cost()
            if body:
                inner += self.cost_of(body)
            if cond:
                inner += self.cost_of(cond)
            c += inner.scaled(trips)
            return c
        if op == "fusion":
            called = self._called(inst, "calls")
            melts = False
            if called:
                sub = self.cost_of(called)
                c.flops += sub.flops
                c.elem_flops += sub.elem_flops
                c.coll_bytes += sub.coll_bytes
                for k, v in sub.coll_by_kind.items():
                    c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
                melts = self._all_fusible(called)
            if not melts:
                # operands consumed only through slice/gather inside the
                # fusion touch the sliced region, not the full array — a
                # scan body's dynamic-slice of stacked weights/caches gets
                # fused and would otherwise charge the whole stack per
                # iteration (observed 33 GiB/layer on deepseek decode).
                eff_in = (self._fusion_input_bytes(called, inst, comp)
                          if called else in_bytes)
                c.bytes += eff_in + out_bytes
            return c
        if op in ("call", "conditional", "custom-call"):
            for key in ("to_apply", "calls", "branch_computations"):
                called = self._called(inst, key)
                if called:
                    c += self.cost_of(called)
            c.bytes += in_bytes + out_bytes
            return c
        kind = next((k for k in COLLECTIVES if op.startswith(k)), None)
        if kind is not None:
            if not op.endswith("-done"):
                c.coll_bytes += out_bytes
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) \
                    + out_bytes
            c.bytes += in_bytes + out_bytes
            return c
        if op == "dot":
            c.flops += _dot_flops(inst, comp)
            c.bytes += in_bytes + out_bytes
            return c
        if op == "convolution":
            # rough: 2 * output elems * kernel elems
            kern = _numel(comp.shape_of.get(inst.operands[1], [])) \
                if len(inst.operands) > 1 else 1
            c.flops += 2.0 * _numel(inst.shapes) * kern
            c.bytes += in_bytes + out_bytes
            return c
        # indexed access reads/writes only the addressed region, not the
        # whole operand (a stacked-layer param sliced inside a scan would
        # otherwise count its full size every iteration)
        if op in ("dynamic-slice", "slice", "gather"):
            c.bytes += 2 * out_bytes
            c.elem_flops += _numel(inst.shapes)
            return c
        if op in ("dynamic-update-slice", "scatter"):
            upd = (_shape_bytes(comp.shape_of.get(inst.operands[1], []))
                   if len(inst.operands) > 1 else out_bytes)
            c.bytes += 2 * upd
            return c
        if op in _FUSIBLE_OPS:
            # intermediate of a fused elementwise chain: VMEM-resident on
            # the target; flops tracked, HBM bytes charged at boundaries
            c.elem_flops += _numel(inst.shapes) * _FLOP_PER_ELEM.get(op, 1)
            return c
        # boundary data movement (copy/transpose/concatenate/sort/...)
        c.elem_flops += _numel(inst.shapes)
        c.bytes += in_bytes + out_bytes
        return c

    def entry_cost(self) -> Cost:
        # entry computation: the one marked ENTRY, else the largest
        if "__entry__" in self.comps:
            return self.cost_of(self.comps["__entry__"].name)
        biggest = max(self.comps.values(), key=lambda c: len(c.instrs))
        return self.cost_of(biggest.name)


def analyze(compiled_text: str) -> Cost:
    return HloCostAnalyzer(compiled_text).entry_cost()
