"""Three-term roofline model from a compiled dry-run artifact.

Terms (per step, whole mesh):
  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = collective_bytes / (chips × link_bw)

Sources: ``compiled.cost_analysis()`` provides flops and bytes accessed;
collective bytes are NOT in cost_analysis — we parse the optimized HLO
(``compiled.as_text()``) and sum the *output* shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op (output size is the per-device payload each device must receive — the
standard bandwidth-term convention).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (constants per the assignment).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

# -- hardware constants (TPU v5e) --------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link per chip
VMEM_BYTES = 16 * 2**20      # on-chip vector memory per core (~16 MB);
                             # the engine's tile-feasibility bound
                             # (engine/kernels.py) prunes candidate plans
                             # whose per-grid-step working set exceeds it

# -- achieved-vs-peak bandwidth (kernel bench / plan_report) -----------------
#: peak memory bandwidth per backend, bytes/s. "tpu" is the v5e HBM figure
#: above; "gpu"/"cpu" are order-of-magnitude placeholders so fractions
#: computed off-TPU are honest about being against a *nominal* roof (the
#: bench labels such rows measured-cpu). CPU is set generously high so the
#: tuner's bandwidth-bound pruning (engine/tuner.py) can never reject a
#: candidate on the container that a real machine might still win with.
PEAK_BYTES_PER_S = {
    "tpu": HBM_BW,
    "gpu": 2.0e12,
    "cpu": 1.0e11,
}


def peak_bytes_per_s(backend=None) -> float:
    """Peak memory bandwidth for ``backend`` (None -> the engine probe)."""
    if backend is None:
        from repro.engine.backend import backend as probe
        backend = probe()
    return PEAK_BYTES_PER_S.get(backend, PEAK_BYTES_PER_S["cpu"])


def achieved_fraction(bytes_touched: float, wall_s: float, *,
                      backend=None) -> float:
    """Fraction of the backend's peak bandwidth a measured run achieved.

    ``bytes_touched / wall_s / peak`` — the roofline-verification number
    the megakernel bench reports per cell: how close the answer step runs
    to the memory roof the predicted-bytes model says it must pay.
    """
    if wall_s <= 0:
        return 0.0
    return bytes_touched / wall_s / peak_bytes_per_s(backend)


_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")

# e.g.  %x = bf16[4,128,256]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in optimized HLO."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        kind = None
        for k in _COLLECTIVE_OPS:
            # match the op name at the call position: "... = shape op-name("
            if f" {k}(" in s or f" {k}-start(" in s or f" {k}-done(" in s:
                kind = k
                break
        if kind is None:
            continue
        if f" {kind}-done(" in s:
            continue            # -start already counted the payload
        m = _SHAPE_RE.search(s)
        if not m:
            continue
        dtype, dims = m.group(1), m.group(2)
        b = shape_bytes(dtype, dims)
        # tuple-shaped outputs: count every element shape on the line
        if "(" in s.split("=")[1].split(kind)[0]:
            b = 0
            for dt, dm in re.findall(r"([a-z0-9]+)\[([0-9,]*)\]",
                                     s.split(f" {kind}")[0]):
                if dt in _DTYPE_BYTES:
                    b += shape_bytes(dt, dm)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    name: str
    n_chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float           # 6·N·D (or 6·N_active·D) per step
    collectives: Optional[CollectiveStats] = None
    hlo_elem_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.n_chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.n_chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        if self.step_time == 0:
            return 0.0
        return self.model_flops / (self.step_time * self.n_chips
                                   * PEAK_FLOPS)

    def to_dict(self) -> dict:
        return {
            "name": self.name, "n_chips": self.n_chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "hlo_elem_flops": self.hlo_elem_flops,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_step_s": self.step_time,
            "useful_flop_ratio": self.useful_flop_ratio,
            "mfu_bound": self.mfu,
            "collective_breakdown": (self.collectives.bytes_by_kind
                                     if self.collectives else {}),
        }


def cost_totals(cost: dict) -> Dict[str, float]:
    """Normalize cost_analysis output (it may be a dict or list of dicts)."""
    if isinstance(cost, (list, tuple)):
        merged: Dict[str, float] = {}
        for c in cost:
            for k, v in c.items():
                merged[k] = merged.get(k, 0.0) + v
        cost = merged
    return cost


def model_flops_for(n_params: int, n_tokens: int, *, training: bool) -> float:
    """6·N·D for a train step, 2·N·D for inference (per forward token)."""
    factor = 6.0 if training else 2.0
    return factor * n_params * n_tokens


def from_compiled(name: str, compiled, *, n_chips: int, model_flops: float,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms from the compiled artifact.

    Uses the loop-aware analyzer (analysis/hlo_cost.py) because XLA's
    builtin ``cost_analysis()`` counts ``while`` bodies once — a 61-layer
    scan would be undercounted ~100×. Totals are per-device per-step
    (post-SPMD shapes); the roofline terms divide by per-chip rates, so
    per-device totals are exactly what the terms want.
    """
    from repro.analysis import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_cost.analyze(text)
    stats = CollectiveStats(bytes_by_kind={k: int(v) for k, v
                                           in cost.coll_by_kind.items()})
    return Roofline(name=name, n_chips=n_chips,
                    hlo_flops=cost.flops * n_chips,
                    hlo_bytes=cost.bytes * n_chips,
                    collective_bytes=cost.coll_bytes * n_chips,
                    model_flops=model_flops, collectives=stats,
                    hlo_elem_flops=cost.elem_flops * n_chips)


def format_table(rows: List[dict]) -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    hdr = ("| cell | chips | t_compute | t_memory | t_collective | "
           "bottleneck | useful/HLO | MFU-bound |")
    sep = "|" + "---|" * 8
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['name']} | {r['n_chips']} | {_fmt_s(r['t_compute_s'])} "
            f"| {_fmt_s(r['t_memory_s'])} | {_fmt_s(r['t_collective_s'])} "
            f"| {r['bottleneck']} | {r['useful_flop_ratio']:.2f} "
            f"| {r['mfu_bound']*100:.1f}% |")
    return "\n".join(out)


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f} s"
    if x >= 1e-3:
        return f"{x*1e3:.2f} ms"
    return f"{x*1e6:.1f} µs"
