from repro.analysis import hlo_cost, roofline
__all__ = ["hlo_cost", "roofline"]
