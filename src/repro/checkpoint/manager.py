"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step)::

    <root>/step_000100/
        manifest.json          # step, mesh, config, leaf index, status
        proc000.npz            # this host's addressable shards

Guarantees engineered for fleet-scale runs:
* **Atomicity** — writes land in ``step_<k>.tmp`` and are renamed only after
  every array + the manifest are flushed; a crash mid-write never corrupts
  the latest checkpoint ("commit by rename").
* **Async** — ``save()`` snapshots device arrays to host then hands the file
  I/O to a background thread; training resumes immediately. ``wait()``
  joins before the next save or process exit.
* **Rolling retention** — keep the newest ``keep`` checkpoints.
* **Elastic restore** — shards are keyed by logical leaf path + index range,
  so ``restore`` reassembles full logical arrays and ``device_put``s them
  under the *current* mesh's shardings: restoring a 256-chip checkpoint on
  a 512-chip (or 8-chip) mesh is the same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":
            # ml_dtypes (bf16/f8, numpy kind 'V') don't survive npz
            # round-trips: store as f32; restore() casts back.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3,
                 process_index: int = 0):
        self.root = root
        self.keep = keep
        self.process_index = process_index
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: Any, *, metadata: Optional[dict] = None,
             blocking: bool = False) -> None:
        """Snapshot to host memory, then write asynchronously."""
        self.wait()                       # one in-flight save at a time
        flat = _flatten(jax.device_get(tree))
        meta = dict(metadata or {})
        meta.update({"step": step, "time": time.time(),
                     "n_leaves": len(flat)})

        def _write():
            tmp = os.path.join(self.root, f"step_{step:08d}.tmp")
            final = os.path.join(self.root, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"proc{self.process_index:03d}.npz"),
                     **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)         # the commit point
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.root)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, dict]:
        """Rebuild the pytree; ``shardings`` (optional) re-shards elastically
        onto the current mesh."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        arrays: Dict[str, np.ndarray] = {}
        for name in sorted(os.listdir(d)):
            if name.endswith(".npz"):
                with np.load(os.path.join(d, name)) as z:
                    for k in z.files:
                        arrays[k] = z[k]

        paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
        treedef = jax.tree_util.tree_structure(tree_like)
        shard_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(paths))
        leaves = []
        for (path, like), shd in zip(paths, shard_leaves):
            key = "/".join(_path_str(p) for p in path)
            if key not in arrays:
                raise KeyError(f"leaf {key} missing from checkpoint")
            arr = arrays[key]
            if hasattr(like, "dtype") and arr.dtype != like.dtype:
                # numpy can't cast into ml_dtypes (bf16); jax can
                arr = jax.numpy.asarray(arr).astype(like.dtype)
            leaves.append(jax.device_put(arr, shd) if shd is not None
                          else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), meta
