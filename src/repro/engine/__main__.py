"""``python -m repro.engine`` — the autotuner CLI (see tuner.main).

A package-level entry point (rather than ``-m repro.engine.tuner``) so
runpy doesn't double-import the tuner module through the package
re-exports.
"""
from repro.engine.tuner import main

if __name__ == "__main__":
    raise SystemExit(main())
