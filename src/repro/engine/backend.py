"""Backend probing + legal-tile arithmetic — the engine's leaf module.

Deliberately dependency-free (os + jax only) so that *both* sides of the
stack can import it without cycles: ``kernels/ops.py`` (which the engine
registry wraps) and the engine's own registry/tuner/cache modules.

Before the engine plane, backend sniffing lived in two places with two
spellings — ``kernels/ops.py _on_tpu()`` (the interpret-mode switch) and
``core/protocol.py plan_for``'s ``jax.default_backend()`` call (kernel-path
selection). They could never disagree in practice, but nothing *made* them
agree, and neither was overridable — CI could not pin plan selection on a
machine whose real backend differs from the one under test. ``backend()``
is now the single probe, honoring ``REPRO_FORCE_BACKEND``.
"""
from __future__ import annotations

import os

import jax

#: env override for backend probing ("cpu" | "tpu" | "gpu"). Forcing "tpu"
#: on a CPU host pins *plan selection* (scan="pallas", interpret=False
#: defaults) for deterministic tests — actually executing a forced-TPU plan
#: on CPU is the caller's (mis)use.
FORCE_BACKEND_ENV = "REPRO_FORCE_BACKEND"


def backend() -> str:
    """The platform plans are selected for: forced via env, else probed.

    The one backend probe for the whole stack — ``kernels/ops.py``'s
    interpret default, ``plan_for``'s kernel-path choice, the tuner's
    search space and the plan-cache key all read this.
    """
    forced = os.environ.get(FORCE_BACKEND_ENV, "").strip().lower()
    if forced:
        return forced
    return jax.default_backend()


def on_tpu() -> bool:
    return backend() == "tpu"


def default_interpret() -> bool:
    """Interpret-mode default: real Mosaic only on an (effective) TPU
    backend; everywhere else the Pallas bodies run the bit-exact Python
    interpreter."""
    return not on_tpu()


def resolve_interpret(interpret) -> bool:
    """Resolve an ``interpret=None`` request against the one backend probe.

    Every Pallas entry point (``kernels/ops.py`` wrappers AND the kernel
    modules' own jitted functions) funnels through this, so
    ``REPRO_FORCE_BACKEND`` governs interpret mode for all of them
    consistently. Must be called *outside* jit: the result becomes a
    static argument, and resolving inside a jitted function would freeze
    the env-dependent answer into the trace cache.
    """
    if interpret is None:
        return default_interpret()
    return bool(interpret)


def legal_tile(dim: int, requested: int, *, pow2: bool = False) -> int:
    """Largest legal tile for a dimension: the biggest divisor of ``dim``
    that is <= ``requested`` (and a power of two when the kernel demands
    it — ``dpxor``'s halving fold).

    This replaces the ``min(tile, dim)`` clamps that used to live in
    ``kernels/ops.py``: ``min`` silently produced *illegal* tiles whenever
    the clamp didn't divide the dimension (e.g. a non-power-of-two shard
    row count R=96 against the default 2048 yielded tile 96 — not a power
    of two — and the kernel raised deep inside ``pallas_call`` setup).
    """
    if dim <= 0:
        raise ValueError(f"dimension must be positive, got {dim}")
    if requested <= 0:
        raise ValueError(f"requested tile must be positive, got {requested}")
    cap = min(requested, dim)
    if pow2:
        # largest power of two that divides dim, capped at floor_pow2(cap)
        p2_of_dim = dim & -dim
        floor_p2 = 1 << (cap.bit_length() - 1)
        return min(p2_of_dim, floor_p2)
    if dim % cap == 0:
        return cap
    # enumerate divisors via trial division to sqrt(dim): dim is a row /
    # record count (<= 2^28 here), so this is thousands of iterations max
    best = 1
    d = 1
    while d * d <= dim:
        if dim % d == 0:
            if d <= cap:
                best = max(best, d)
            co = dim // d
            if co <= cap:
                best = max(best, co)
        d += 1
    return best
