"""Measured autotuner: enumerate feasible plans, time them, keep the winner.

IM-PIR's thesis is that PIR answering is memory-bandwidth-bound — which
makes kernel path + tiling *the* throughput story. The pre-engine stack
chose both by folklore: a hand-written heuristic (``plan_for``) plus tile
constants hardcoded in ``kernels/ops.py``, never validated against
measurement. The tuner closes that loop:

  1. enumerate candidate ``ExecutionPlan``s from the kernel registry
     (``engine/kernels.py``) — tile/chunk spaces already legalized for the
     concrete shapes and pruned by the VMEM-footprint model,
  2. **time each candidate on the real (db_view, bucket) shapes** — the
     protocol's own ``answer_local`` under ``jax.jit``, exactly the
     contraction one shard executes inside the compiled serve step (the
     cross-shard collective is topology- not tile-bound and is not tuned),
  3. keep the fastest; persist it via the plan cache (``engine/cache.py``)
     keyed by (backend, protocol, spec signature, bucket).

The **heuristic is always candidate #0** and is always measured, so a tune
can only ever match or beat it — and a cache miss falls back to it
bit-for-bit (``heuristic_plan`` reproduces the pre-engine ``plan_for``
exactly, modulo the backend probe now honoring ``REPRO_FORCE_BACKEND``).

Budgets: measurement costs wall clock (and, on this CPU container, XLA
compiles of interpret-mode Pallas bodies), so every entry point takes a
:class:`TuneBudget`. The CI smoke (``python -m repro.engine.tuner
--smoke``) runs with ≤2 candidates per kernel and single-iteration timing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.analysis import roofline
from repro.engine.backend import backend as probe_backend
from repro.engine.cache import spec_signature
from repro.engine.kernels import (ProblemShape, GEMM_TILE_R_DEFAULT,
                                  get_kernel, plans_from_kernel,
                                  predicted_step_bytes, serve_kernels)


# ---------------------------------------------------------------------------
# The deterministic fallback: the pre-engine plan_for, verbatim
# ---------------------------------------------------------------------------

def heuristic_plan(cfg, n_queries: int, *, backend: Optional[str] = None,
                   chunk_log: int = 12):
    """Pick the kernel path per (db size, batch bucket, backend).

    The selection rules are the pre-engine ``core.protocol.plan_for``
    logic, preserved bit-for-bit (DESIGN.md §7.3, asserted by
    tests/test_engine.py against an inline replica):

      * additive protocols contract via the GEMM regardless — ``scan``
        chooses jnp dot vs the Pallas ``pir_matmul`` body (reduction tile
        pinned to the pre-engine kernel default);
      * XOR protocols materialize bits only while the per-query bit vector
        stays small (db <= 2^chunk_log rows); past that the fused chunked
        expand+scan keeps selection bits out of HBM;
      * the Pallas bodies run real Mosaic only on a TPU backend — on CPU
        they would execute in interpret mode, so the jnp oracle is the
        fast CPU path;
      * single-query buckets skip the fused chunk machinery.

    The only behavioral delta vs the pre-engine code: the backend probe is
    ``engine.probe_backend()`` (one probe for the whole stack, ``REPRO_FORCE_
    BACKEND``-overridable) instead of a raw ``jax.default_backend()``.
    """
    from repro.core import protocol as protocol_mod
    if backend is None:
        backend = probe_backend()
    scan = "pallas" if backend == "tpu" else "jnp"
    proto = protocol_mod.get(cfg.protocol)
    if proto.share_kind in ("additive", "lwe"):
        # both contract via a materialized GEMM (int8 / int32); same rule
        return protocol_mod.ExecutionPlan(
            expand="materialize", scan=scan, chunk_log=chunk_log,
            tile_r=GEMM_TILE_R_DEFAULT)
    small_db = cfg.n_items <= (1 << chunk_log)
    expand = "materialize" if small_db or n_queries <= 1 else "fused"
    return protocol_mod.ExecutionPlan(expand=expand, scan=scan,
                                      chunk_log=chunk_log)


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def candidate_plans(cfg, bucket: int, *, n_shards: int = 1,
                    chunk_log: int = 12, collective: str = "gather",
                    max_per_kernel: Optional[int] = None) -> List:
    """Feasible ExecutionPlans for (cfg, bucket): the tuner's search space.

    One entry per surviving point of each registered serve kernel's
    parameter space; infeasible tilings (VMEM-footprint model) are pruned
    here, without ever being run. ``n_shards`` scales the per-shard row
    count the tiles must be legal for.
    """
    from repro.core import protocol as protocol_mod
    proto = protocol_mod.get(cfg.protocol)
    shape = problem_shape(cfg, bucket, n_shards=n_shards)
    base = protocol_mod.ExecutionPlan(chunk_log=min(chunk_log,
                                                    shape.log_rows),
                                      collective=collective)
    plans: List = []
    for desc in serve_kernels(proto.share_kind):
        for plan in plans_from_kernel(desc, shape, base_plan=base,
                                      max_candidates=max_per_kernel):
            if plan not in plans:
                plans.append(plan)
    return plans


def problem_shape(cfg, bucket: int, *, n_shards: int = 1) -> ProblemShape:
    from repro.db import DatabaseSpec
    rows = DatabaseSpec.from_config(cfg).rows_per_shard(n_shards)
    return ProblemShape(bucket=bucket, rows=rows,
                        item_bytes=cfg.item_bytes)


def plan_label(plan) -> str:
    """Stable human-readable key for timing tables / JSON records.

    Only execution-relevant, non-default fields appear: fused plans carry
    their chunk size, Pallas plans their row/reduction tile, and the GEMM
    tiles (tile_q/tile_l) only when legalization moved them off their
    defaults — XOR-scan plans never set them, so their labels stay clean.
    """
    lbl = f"{plan.expand}/{plan.scan}"
    if plan.expand == "fused":
        lbl += f"/cl{plan.chunk_log}"
    elif plan.expand == "fused-pallas":
        lbl += f"/cl{plan.chunk_log}/tr{plan.tile_r}/d{plan.depth}"
    elif plan.scan == "pallas":
        lbl += f"/tr{plan.tile_r}"
        defaults = _plan_defaults()
        if plan.tile_q != defaults.tile_q:
            lbl += f"/tq{plan.tile_q}"
        if plan.tile_l != defaults.tile_l:
            lbl += f"/tl{plan.tile_l}"
    return lbl


_DEFAULT_PLAN = None


def _plan_defaults():
    global _DEFAULT_PLAN
    if _DEFAULT_PLAN is None:
        from repro.core.protocol import ExecutionPlan
        _DEFAULT_PLAN = ExecutionPlan()
    return _DEFAULT_PLAN


def _canonical(plan):
    """Normalize execution-irrelevant plan fields before dedup/timing.

    The fused XOR body's inner fold is always the jnp ``dpxor`` —
    ``plan.scan`` never reaches it — so on a TPU backend the heuristic's
    fused/pallas and the registry's fused/jnp candidate are the same
    executable. Canonicalizing ``scan`` keeps the tuner from compiling
    and timing it twice.
    """
    if plan.expand == "fused" and plan.scan != "jnp":
        return replace(plan, scan="jnp")
    return plan


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TuneBudget:
    """How much wall clock / search breadth a tune may spend."""
    max_candidates: Optional[int] = 8      # per kernel, post-pruning
    warmup: int = 1                        # compile + cache warm
    iters: int = 3                         # timed reps (median kept)
    max_seconds: float = 120.0             # soft cap, checked between plans
    #: skip candidates whose predicted-bytes model alone — divided by the
    #: backend's peak bandwidth — already exceeds the best measured wall so
    #: far. Bandwidth is a *lower* bound on wall, so a pruned candidate
    #: could not have won even at 100% of peak; the saving is its compile.
    prune_bytes: bool = True


#: the CI smoke budget: ≤2 candidates per kernel, single timed rep
SMOKE_BUDGET = TuneBudget(max_candidates=2, warmup=1, iters=1,
                          max_seconds=90.0)


@dataclass
class TuneResult:
    plan: object                   # the winner, provenance="tuned"
    heuristic: object              # the deterministic fallback (measured)
    timings: Dict[str, float]      # plan_label -> median seconds
    n_candidates: int              # search-space size after pruning
    n_timed: int                   # how many the budget let us measure
    n_pruned: int = 0              # skipped on the bytes bound, no compile

    @property
    def heuristic_s(self) -> float:
        return self.timings[plan_label(self.heuristic)]

    @property
    def tuned_s(self) -> float:
        return self.timings[plan_label(self.plan)]

    @property
    def speedup(self) -> float:
        return self.heuristic_s / self.tuned_s if self.tuned_s else 0.0


def _measurement_inputs(cfg, bucket: int, proto, seed: int):
    """Real-shape inputs for timing: the protocol's declared db view and a
    party-0 batched key pytree of ``bucket`` random queries."""
    from repro.core import pir
    from repro.db import DatabaseSpec
    rng = np.random.default_rng(seed)
    spec = DatabaseSpec.from_config(cfg)
    db_words = pir.make_database(rng, cfg.n_items, cfg.item_bytes)
    db = jax.numpy.asarray(spec.pack_host(db_words, proto.db_view))
    idx = rng.integers(0, cfg.n_items, size=bucket).tolist()
    keys = pir.batch_queries(rng, idx, cfg)[0]
    return db, keys


def time_plan(proto, plan, db, keys, log_local: int,
              budget: TuneBudget) -> float:
    """Median wall time of one plan's jitted shard contraction."""
    fn = jax.jit(lambda d, k: proto.answer_local(d, k, 0, log_local, plan))
    for _ in range(max(budget.warmup, 1)):      # compile off the clock
        jax.block_until_ready(fn(db, keys))
    ts = []
    for _ in range(max(budget.iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(db, keys))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def tune(cfg, bucket: int, *, backend: Optional[str] = None,
         budget: Optional[TuneBudget] = None, chunk_log: int = 12,
         collective: str = "gather", cache=None, seed: int = 0
         ) -> TuneResult:
    """Measure the candidate plans for one (cfg, bucket) and pick a winner.

    The heuristic plan is measured first and unconditionally, so the tuned
    result can never be slower than the fallback *on the measured shapes*.
    Pass ``cache`` (a :class:`~repro.engine.cache.PlanCache`) to record the
    winner; the caller owns ``cache.save()``.
    """
    from repro.core import protocol as protocol_mod
    budget = budget or TuneBudget()
    be = backend or probe_backend()
    proto = protocol_mod.get(cfg.protocol)
    heur = heuristic_plan(cfg, bucket, backend=be, chunk_log=chunk_log)
    heur = _canonical(replace(heur, collective=collective))
    cands = [_canonical(p) for p in
             candidate_plans(cfg, bucket, chunk_log=chunk_log,
                             collective=collective,
                             max_per_kernel=budget.max_candidates)]
    ordered = [heur] + [p for p in cands if p != heur]

    db, keys = _measurement_inputs(cfg, bucket, proto, seed)
    log_local = cfg.log_n
    shape = problem_shape(cfg, bucket)
    peak = roofline.peak_bytes_per_s(be)
    t_start = time.perf_counter()
    timings: Dict[str, float] = {}
    n_pruned = 0
    for i, plan in enumerate(ordered):
        if i > 0 and time.perf_counter() - t_start > budget.max_seconds:
            break                    # budget spent; heuristic was first
        label = plan_label(plan)
        if label in timings:
            continue
        if i > 0 and budget.prune_bytes and timings:
            # bandwidth-bound lower bound: if the plan's modeled HBM
            # traffic can't beat the best measured wall even at 100% of
            # peak, never pay its compile (heuristic is never pruned)
            floor_s = predicted_step_bytes(plan, proto.share_kind,
                                           shape) / peak
            if floor_s > min(timings.values()):
                n_pruned += 1
                continue
        timings[label] = time_plan(proto, plan, db, keys, log_local, budget)

    best_label = min(timings, key=timings.get)
    winner = next(p for p in ordered if plan_label(p) == best_label)
    tuned = replace(winner, provenance="tuned")
    if cache is not None:
        cache.put(be, proto.name, spec_signature(cfg), bucket,
                  tuned, meta={
                      "tuned_s": timings[best_label],
                      "heuristic_s": timings[plan_label(heur)],
                      "n_candidates": len(ordered),
                      "n_timed": len(timings),
                      "n_pruned": n_pruned,
                  })
    return TuneResult(plan=tuned, heuristic=heur, timings=timings,
                      n_candidates=len(ordered), n_timed=len(timings),
                      n_pruned=n_pruned)


def autotune(cfg, buckets: Sequence[int], *,
             backend: Optional[str] = None,
             budget: Optional[TuneBudget] = None,
             cache=None, persist: bool = True,
             seed: int = 0) -> Dict[int, TuneResult]:
    """Tune every bucket of a config and (optionally) persist the winners.

    ``cache=None`` uses the process-wide plan cache (``repro.engine.
    plan_cache()``), so servers built afterwards with ``path=None/"auto"``
    in the same process pick the tuned plans up immediately; ``persist``
    additionally writes the JSON store for future processes.
    """
    from repro import engine
    cache = cache if cache is not None else engine.plan_cache()
    out = {}
    for b in sorted(set(buckets)):
        out[b] = tune(cfg, b, backend=backend, budget=budget, cache=cache,
                      seed=seed)
    if persist:
        cache.save()
    return out


def tune_standalone(kernel_name: str, n: int, *,
                    budget: Optional[TuneBudget] = None,
                    rounds: int = 12, seed: int = 0) -> Dict:
    """Tune a non-serve kernel (currently ``ggm-expand``) standalone.

    Measures ``ops.ggm_expand`` over its pruned tile space at ``n`` leaf
    nodes; returns {"params", "timings"}. GGM expansion is not part of an
    ``ExecutionPlan`` (DPF eval happens inside ``answer_local``), so its
    tuning result is reported rather than cached.
    """
    from repro.kernels import ops
    budget = budget or TuneBudget()
    desc = get_kernel(kernel_name)
    if desc.serve:
        raise ValueError(f"{kernel_name} is a serve kernel; use tune()")
    shape = ProblemShape(bucket=1, rows=n, item_bytes=4)
    rng = np.random.default_rng(seed)
    seeds = jax.numpy.asarray(
        rng.integers(0, 1 << 32, size=(n, 4), dtype=np.uint32))
    t_bits = jax.numpy.asarray(
        rng.integers(0, 2, size=(n,), dtype=np.uint32))
    cw_s = jax.numpy.asarray(
        rng.integers(0, 1 << 32, size=(4,), dtype=np.uint32))
    cw_t = jax.numpy.asarray(
        rng.integers(0, 2, size=(2,), dtype=np.uint32))
    timings: Dict[str, float] = {}
    for params in desc.candidates(shape, budget.max_candidates):
        fn = lambda: ops.ggm_expand(seeds, t_bits, cw_s, cw_t,
                                    rounds=rounds, tile=params["tile"])
        for _ in range(max(budget.warmup, 1)):
            jax.block_until_ready(fn())
        ts = []
        for _ in range(max(budget.iters, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        timings[f"tile{params['tile']}"] = float(np.median(ts))
    best = min(timings, key=timings.get)
    return {"params": {"tile": int(best[4:])}, "timings": timings}


# ---------------------------------------------------------------------------
# CI smoke: tiny-budget tune + heuristic-fallback equivalence gate
# ---------------------------------------------------------------------------

#: the pre-engine ``plan_for`` choices on the smoke grid, as literals —
#: (protocol, log_n, n_queries, backend) -> (expand, scan). Hardcoded
#: rather than computed so the gate is independent of ``heuristic_plan``
#: (a rule change there cannot silently rewrite its own oracle).
_PRE_ENGINE_EXPECTED = {
    ("xor-dpf-2", 10, 1, "cpu"): ("materialize", "jnp"),
    ("xor-dpf-2", 10, 4, "cpu"): ("materialize", "jnp"),
    ("xor-dpf-2", 10, 4, "tpu"): ("materialize", "pallas"),
    ("additive-dpf-2", 10, 1, "cpu"): ("materialize", "jnp"),
    ("additive-dpf-2", 10, 4, "cpu"): ("materialize", "jnp"),
    ("additive-dpf-2", 10, 4, "tpu"): ("materialize", "pallas"),
    ("xor-dpf-2", 14, 1, "cpu"): ("materialize", "jnp"),   # single query
    ("xor-dpf-2", 14, 4, "cpu"): ("fused", "jnp"),         # big-db regime
    ("xor-dpf-2", 14, 4, "tpu"): ("fused", "pallas"),
}


def smoke() -> int:
    """Tiny-budget autotune smoke for scripts/ci_check.sh.

    Interpret mode (CPU), ≤2 candidates per kernel, one bucket per
    protocol — and, for every cell of a small grid, asserts the
    heuristic-fallback plan (what an empty cache resolves to) equals the
    pre-engine ``plan_for`` output, pinned above as literals. Nothing is
    persisted. (tests/test_engine.py holds the broader independent
    replica of the old rules; this is the fast CI spot check.)
    """
    from repro.config import PIRConfig
    from repro.core.protocol import plan_for
    from repro.engine.cache import PlanCache

    for (proto, log_n, n_q, be), want in _PRE_ENGINE_EXPECTED.items():
        cfg = PIRConfig(n_items=1 << log_n, item_bytes=32, protocol=proto)
        got = plan_for(cfg, n_q, backend=be)
        assert (got.expand, got.scan) == want, (
            f"heuristic drifted from the pre-engine plan_for: "
            f"{proto} 2^{log_n} n_q={n_q} {be}: "
            f"{(got.expand, got.scan)} != {want}")
        assert got.chunk_log == 12 and got.provenance == "heuristic"
        if proto == "additive-dpf-2":
            assert got.tile_r == GEMM_TILE_R_DEFAULT
    print("[smoke] heuristic fallback == pre-engine plan_for "
          f"on {len(_PRE_ENGINE_EXPECTED)} grid cells")
    grid = [
        PIRConfig(n_items=1 << 10, item_bytes=32),
        PIRConfig(n_items=1 << 10, item_bytes=32,
                  protocol="additive-dpf-2"),
    ]

    cache = PlanCache(path=None)             # in-memory only
    for cfg in grid:                         # one tune per share kind
        res = tune(cfg, 2, budget=SMOKE_BUDGET, cache=cache)
        assert res.tuned_s <= res.heuristic_s + 1e-9
        print(f"[smoke] {cfg.protocol}: tuned {plan_label(res.plan)} "
              f"{res.tuned_s * 1e3:.1f} ms vs heuristic "
              f"{res.heuristic_s * 1e3:.1f} ms "
              f"({res.n_timed}/{res.n_candidates} candidates timed)")
        hit = cache.get(probe_backend(), cfg.protocol,
                        spec_signature(cfg), 2)
        assert hit == res.plan and hit.provenance == "tuned"
    print("[smoke] plan cache round-trip ok")

    # megakernel gate: one fused-scan-pallas candidate at the tiniest
    # shape (2^8 rows: the legalized space collapses to a single point,
    # one interpret-mode compile) — byte parity vs the materialized
    # heuristic oracle + descriptor provenance
    from repro.core import protocol as protocol_mod
    from repro.engine.kernels import descriptor_for_plan
    cfg = PIRConfig(n_items=1 << 8, item_bytes=32)
    proto = protocol_mod.get(cfg.protocol)
    fused = [p for p in candidate_plans(cfg, 2)
             if p.expand == "fused-pallas"]
    assert fused, "no legal fused-pallas candidate at 2^8"
    plan = fused[0]
    assert descriptor_for_plan(plan, proto.share_kind).name == \
        "xor-fused-pallas"
    db, keys = _measurement_inputs(cfg, 2, proto, seed=7)
    oracle = heuristic_plan(cfg, 2, backend=probe_backend())
    want = proto.answer_local(db, keys, 0, cfg.log_n, oracle)
    got = proto.answer_local(db, keys, 0, cfg.log_n, plan)
    assert (np.asarray(got) == np.asarray(want)).all(), \
        "fused-pallas answer diverges from the materialized oracle"
    print(f"[smoke] fused-pallas megakernel parity ok "
          f"({plan_label(plan)})")
    return 0


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-budget CI smoke (see scripts/ci_check.sh)")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
