"""Kernel registry: descriptors over the answer-kernel bodies + feasibility.

The engine's inventory of *how an answer step can run*. Each
:class:`KernelDescriptor` wraps one existing kernel body — the materialized
select-XOR scan (jnp oracle / Pallas ``dpxor``), the fused chunked
expand+scan, the additive int8 GEMM (jnp dot / Pallas ``pir_matmul``), and
the standalone GGM level expansion — and declares:

  * its **tunable-parameter space** (the tile sizes that used to be
    hardcoded constants in ``kernels/ops.py``), already normalized to
    *legal* tiles for the concrete problem shape (``backend.legal_tile``),
  * a **VMEM-footprint model** (``analysis/roofline.py`` constants): the
    per-grid-step working set in bytes, streamed blocks counted twice for
    Pallas's double-buffered pipeline. Candidates whose footprint exceeds
    ``VMEM_BYTES`` are pruned *without running* — the tuner never wastes
    budget timing a plan Mosaic would refuse to schedule,
  * a **predicted-bytes model**: HBM traffic of one answer step, the
    memory-roofline numerator that dry-run/launch reporting surfaces next
    to each chosen plan.

Serve-path descriptors (``serve=True``) emit ``ExecutionPlan`` candidates;
the GGM expansion is registered ``serve=False`` — it is tuned standalone
(``tuner.tune_standalone``) because DPF evaluation happens inside the
protocol's ``answer_local``, not as a separately planned stage.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis.roofline import VMEM_BYTES
from repro.engine.backend import legal_tile

U32_BYTES = 4


@dataclass(frozen=True)
class ProblemShape:
    """The concrete shapes one plan candidate must serve.

    bucket      Q — padded query-batch size (one compiled bucket)
    rows        R — rows held by ONE DB shard (n_items / n_shards)
    item_bytes  L — record payload bytes (words = L / 4)
    """
    bucket: int
    rows: int
    item_bytes: int

    @property
    def words(self) -> int:
        return self.item_bytes // 4

    @property
    def log_rows(self) -> int:
        return (self.rows - 1).bit_length()


@dataclass(frozen=True)
class KernelDescriptor:
    """One answer-kernel body + its tunable space and validity model."""

    name: str
    share_kind: str                       # xor | additive | lwe | prg
    #: ExecutionPlan base fields (serve kernels); empty for standalone
    expand: str = ""
    scan: str = ""
    #: shape -> {param: candidate values}, already legal for that shape
    space_fn: Callable[[ProblemShape], Dict[str, Tuple[int, ...]]] = \
        field(default=lambda s: {})
    #: shape, params -> params with *coupled* constraints applied (e.g.
    #: the megakernel's chunk_log <= log2(tile_r)); runs before dedup so
    #: two requests that legalize identically are measured once
    legalize_fn: Callable[[ProblemShape, Dict[str, int]], Dict[str, int]] = \
        field(default=lambda s, p: p)
    #: shape, params -> per-grid-step VMEM working set (bytes)
    footprint_fn: Callable[[ProblemShape, Dict[str, int]], int] = \
        field(default=lambda s, p: 0)
    #: shape, params -> HBM bytes moved by one answer step (reporting)
    bytes_fn: Callable[[ProblemShape, Dict[str, int]], int] = \
        field(default=lambda s, p: 0)
    serve: bool = True

    def feasible(self, shape: ProblemShape, params: Dict[str, int]) -> bool:
        return self.footprint_fn(shape, params) <= VMEM_BYTES

    def candidates(self, shape: ProblemShape,
                   max_candidates: Optional[int] = None
                   ) -> List[Dict[str, int]]:
        """Feasible parameter assignments, deduped after legalization.

        Two requested tiles can legalize to the same effective tile on a
        small shape (e.g. 512 and 2048 both collapse to R=64); duplicates
        are measured once. ``max_candidates`` is the per-kernel budget cap
        (the CI smoke runs with 2).
        """
        space = self.space_fn(shape)
        names = sorted(space)
        combos = itertools.product(*(space[n] for n in names)) \
            if names else [()]
        seen, out = set(), []
        for combo in combos:
            params = self.legalize_fn(shape, dict(zip(names, combo)))
            key = tuple(sorted(params.items()))
            if key in seen or not self.feasible(shape, params):
                continue
            seen.add(key)
            out.append(params)
            if max_candidates is not None and len(out) >= max_candidates:
                break
        return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

KERNELS: Dict[str, KernelDescriptor] = {}


def register_kernel(desc: KernelDescriptor) -> KernelDescriptor:
    KERNELS[desc.name] = desc
    return desc


def serve_kernels(share_kind: str) -> List[KernelDescriptor]:
    """Serve-path descriptors for one share algebra, registry order."""
    return [d for d in KERNELS.values()
            if d.serve and d.share_kind == share_kind]


def get_kernel(name: str) -> KernelDescriptor:
    if name not in KERNELS:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(KERNELS)}")
    return KERNELS[name]


# ---------------------------------------------------------------------------
# Descriptor bodies: spaces, VMEM footprints, byte models
# ---------------------------------------------------------------------------
# Requested tile ladders (the pre-engine hardcoded constants are members,
# so the heuristic plan is always inside the search space).
_DPXOR_TILES = (512, 1024, 2048, 4096)
_GEMM_TILE_Q = (8, 16)
_GEMM_TILE_R = (512, 1024, 2048)
_GEMM_TILE_L = (128, 256)
_FUSED_CHUNK_LOGS = (8, 10, 12, 14)
_GGM_TILES = (512, 2048, 8192, 65536)

#: the GEMM reduction-tile default before tiles moved into the plan
#: (``kernels/ops.py pir_gemm`` hardcoded 1024 vs the scan's 2048)
GEMM_TILE_R_DEFAULT = 1024


def _xor_scan_space(shape: ProblemShape) -> Dict[str, Tuple[int, ...]]:
    tiles = sorted({legal_tile(shape.rows, t, pow2=True)
                    for t in _DPXOR_TILES})
    return {"tile_r": tuple(tiles)}


def _xor_scan_footprint(shape: ProblemShape, p: Dict[str, int]) -> int:
    q, w = shape.bucket, shape.words
    tr = p.get("tile_r", legal_tile(shape.rows, 2048, pow2=True))
    # streamed blocks ×2 (double buffer): bits [Q,TR] + db [W,TR];
    # resident: accumulator [Q,W] + the masked intermediate [Q,W,TR]
    return U32_BYTES * (2 * (q * tr + w * tr) + q * w + q * w * tr)


def _xor_mat_bytes(shape: ProblemShape, p: Dict[str, int],
                   *, pallas: bool) -> int:
    q, r, w = shape.bucket, shape.rows, shape.words
    bits = 2 * q * r * U32_BYTES          # materialized: written then read
    db = (1 if pallas else q) * r * w * U32_BYTES   # jnp vmap re-reads/query
    return bits + db + q * w * U32_BYTES


def _fused_space(shape: ProblemShape) -> Dict[str, Tuple[int, ...]]:
    # chunks larger than the shard are degenerate duplicates (n_chunks=1)
    logs = sorted({min(c, shape.log_rows) for c in _FUSED_CHUNK_LOGS})
    return {"chunk_log": tuple(logs)}


def _fused_footprint(shape: ProblemShape, p: Dict[str, int]) -> int:
    chunk = 1 << p.get("chunk_log", 12)
    # per-chunk working set: db rows + selection bits (never hit HBM)
    return U32_BYTES * chunk * (2 * shape.words + 1)


def _fused_bytes(shape: ProblemShape, p: Dict[str, int]) -> int:
    # every query streams the whole shard once; bits stay on-chip
    return (shape.bucket * shape.rows * shape.words + shape.bucket
            * shape.words) * U32_BYTES


def _gemm_space(shape: ProblemShape) -> Dict[str, Tuple[int, ...]]:
    return {
        "tile_q": tuple(sorted({legal_tile(shape.bucket, t)
                                for t in _GEMM_TILE_Q})),
        "tile_r": tuple(sorted({legal_tile(shape.rows, t)
                                for t in _GEMM_TILE_R})),
        "tile_l": tuple(sorted({legal_tile(shape.item_bytes, t)
                                for t in _GEMM_TILE_L})),
    }


def _gemm_footprint(shape: ProblemShape, p: Dict[str, int]) -> int:
    tq = p.get("tile_q", legal_tile(shape.bucket, 8))
    tr = p.get("tile_r", legal_tile(shape.rows, GEMM_TILE_R_DEFAULT))
    tl = p.get("tile_l", legal_tile(shape.item_bytes, 128))
    # int8 streamed blocks ×2; int32 output block resident
    return 2 * (tq * tr + tr * tl) + 4 * tq * tl


def _gemm_bytes(shape: ProblemShape, p: Dict[str, int]) -> int:
    q, r, l = shape.bucket, shape.rows, shape.item_bytes
    # shares materialized (write+read, int8) + one DB pass + int32 out
    return 2 * q * r + r * l + 4 * q * l


def _lwe_gemm_footprint(shape: ProblemShape, p: Dict[str, int]) -> int:
    tq = p.get("tile_q", legal_tile(shape.bucket, 8))
    tr = p.get("tile_r", legal_tile(shape.rows, GEMM_TILE_R_DEFAULT))
    tl = p.get("tile_l", legal_tile(shape.item_bytes, 128))
    # int32 everywhere: streamed ct/db blocks ×2 + resident output block.
    # 4× the int8 GEMM's streams — the same tile ladder prunes earlier.
    return 4 * (2 * (tq * tr + tr * tl) + tq * tl)


def _lwe_gemm_bytes(shape: ProblemShape, p: Dict[str, int]) -> int:
    q, r, l = shape.bucket, shape.rows, shape.item_bytes
    # ciphertexts read once (int32) + one DB pass (int32 view) + int32 out
    return 4 * (q * r + r * l + q * l)


# -- the fused megakernel (kernels/fused_scan.py) ---------------------------
_FUSED_PALLAS_CHUNK_LOGS = (8, 10, 12)
_FUSED_PALLAS_DEPTHS = (2, 4)


def _fused_pallas_space(shape: ProblemShape) -> Dict[str, Tuple[int, ...]]:
    tiles = sorted({legal_tile(shape.rows, t, pow2=True)
                    for t in _DPXOR_TILES})
    logs = sorted({min(c, shape.log_rows) for c in _FUSED_PALLAS_CHUNK_LOGS})
    return {"tile_r": tuple(tiles), "chunk_log": tuple(logs),
            "depth": _FUSED_PALLAS_DEPTHS}


def _fused_pallas_legalize(shape: ProblemShape,
                           p: Dict[str, int]) -> Dict[str, int]:
    """Coupled constraints the product space can't express: one DMA tile
    must hold whole chunks (chunk_log <= log2(tile_r)) and the rotating
    buffer count never exceeds the tile count (deeper is pure waste)."""
    tr = legal_tile(shape.rows, p["tile_r"], pow2=True)
    cl = min(p["chunk_log"], shape.log_rows, tr.bit_length() - 1)
    d = max(1, min(p["depth"], shape.rows // tr))
    return {**p, "tile_r": tr, "chunk_log": cl, "depth": d}


def _fused_pallas_xor_footprint(shape: ProblemShape,
                                p: Dict[str, int]) -> int:
    q, w = shape.bucket, shape.words
    tr = p.get("tile_r", legal_tile(shape.rows, 2048, pow2=True))
    d = p.get("depth", 2)
    # d rotating DB buffers [W, TR]; expand scratch per tile: 16 ChaCha
    # state rows + 10 output rows + 1 t row at [Q, TR]; the masked
    # intermediate [Q, W, TR]; the accumulator [Q, W]
    return U32_BYTES * (d * w * tr + q * tr * (16 + 10 + 1)
                        + q * w * tr + q * w)


def _fused_pallas_xor_bytes(shape: ProblemShape, p: Dict[str, int]) -> int:
    q, r, w = shape.bucket, shape.rows, shape.words
    cl = p.get("chunk_log", 12)
    c = max(1, r >> cl)
    # THE headline: the DB streams HBM->VMEM once per *batch* (vs once per
    # query for fused-jnp); queries ship chunk roots + clog CW levels
    key_words = c * 5 + cl * 6            # roots[4]+t per chunk, (4+2)/level
    return (r * w + q * key_words + q * w) * U32_BYTES


def _fused_pallas_add_footprint(shape: ProblemShape,
                                p: Dict[str, int]) -> int:
    q, l = shape.bucket, shape.item_bytes
    tr = p.get("tile_r", legal_tile(shape.rows, 2048, pow2=True))
    d = p.get("depth", 2)
    # d int8 DB buffers [TR, L]; u32 expand + share-conversion scratch
    # (16 state + 10 out + 1 t + 1 conv rows at [Q, TR]); int32 out [Q, L]
    return (d * tr * l + 4 * q * tr * (16 + 10 + 1 + 1) + 4 * q * l)


def _fused_pallas_add_bytes(shape: ProblemShape, p: Dict[str, int]) -> int:
    q, r, l = shape.bucket, shape.rows, shape.item_bytes
    cl = p.get("chunk_log", 12)
    c = max(1, r >> cl)
    key_words = c * 5 + cl * 6 + 1        # + cw_final
    return r * l + (q * key_words + q * l) * 4


def _ggm_space(shape: ProblemShape) -> Dict[str, Tuple[int, ...]]:
    n = shape.rows                         # leaves at the widest level
    return {"tile": tuple(sorted({legal_tile(n, t) for t in _GGM_TILES}))}


def _ggm_footprint(shape: ProblemShape, p: Dict[str, int]) -> int:
    tile = p.get("tile", 65536)
    # 16 ChaCha state rows + (4 seed + 1 t) in ×2 + (8 child + 2 t) out
    return U32_BYTES * tile * (16 + 2 * 5 + 10)


MATERIALIZE_JNP = register_kernel(KernelDescriptor(
    name="xor-materialize-jnp", share_kind="xor",
    expand="materialize", scan="jnp",
    bytes_fn=lambda s, p: _xor_mat_bytes(s, p, pallas=False),
))

MATERIALIZE_PALLAS = register_kernel(KernelDescriptor(
    name="xor-materialize-pallas", share_kind="xor",
    expand="materialize", scan="pallas",
    space_fn=_xor_scan_space, footprint_fn=_xor_scan_footprint,
    bytes_fn=lambda s, p: _xor_mat_bytes(s, p, pallas=True),
))

FUSED_XOR = register_kernel(KernelDescriptor(
    name="xor-fused", share_kind="xor",
    expand="fused", scan="jnp",
    space_fn=_fused_space, footprint_fn=_fused_footprint,
    bytes_fn=_fused_bytes,
))

FUSED_PALLAS_XOR = register_kernel(KernelDescriptor(
    name="xor-fused-pallas", share_kind="xor",
    expand="fused-pallas", scan="pallas",
    space_fn=_fused_pallas_space, legalize_fn=_fused_pallas_legalize,
    footprint_fn=_fused_pallas_xor_footprint,
    bytes_fn=_fused_pallas_xor_bytes,
))

GEMM_JNP = register_kernel(KernelDescriptor(
    name="gemm-jnp", share_kind="additive",
    expand="materialize", scan="jnp",
    bytes_fn=_gemm_bytes,
))

GEMM_PALLAS = register_kernel(KernelDescriptor(
    name="gemm-pallas", share_kind="additive",
    expand="materialize", scan="pallas",
    space_fn=_gemm_space, footprint_fn=_gemm_footprint,
    bytes_fn=_gemm_bytes,
))

FUSED_PALLAS_GEMM = register_kernel(KernelDescriptor(
    name="gemm-fused-pallas", share_kind="additive",
    expand="fused-pallas", scan="pallas",
    space_fn=_fused_pallas_space, legalize_fn=_fused_pallas_legalize,
    footprint_fn=_fused_pallas_add_footprint,
    bytes_fn=_fused_pallas_add_bytes,
))

LWE_GEMM_JNP = register_kernel(KernelDescriptor(
    name="lwe-gemm-jnp", share_kind="lwe",
    expand="materialize", scan="jnp",
    bytes_fn=_lwe_gemm_bytes,
))

LWE_GEMM_PALLAS = register_kernel(KernelDescriptor(
    name="lwe-gemm-pallas", share_kind="lwe",
    expand="materialize", scan="pallas",
    space_fn=_gemm_space, footprint_fn=_lwe_gemm_footprint,
    bytes_fn=_lwe_gemm_bytes,
))

GGM_EXPAND = register_kernel(KernelDescriptor(
    name="ggm-expand", share_kind="prg", serve=False,
    space_fn=_ggm_space, footprint_fn=_ggm_footprint,
))


# ---------------------------------------------------------------------------
# Plan <-> descriptor bridges
# ---------------------------------------------------------------------------

def plans_from_kernel(desc: KernelDescriptor, shape: ProblemShape, *,
                      base_plan, max_candidates: Optional[int] = None):
    """ExecutionPlan candidates of one serve descriptor for one shape.

    ``base_plan`` supplies the non-kernel axes (collective, default
    chunk_log); tunables overwrite their plan fields. Parameter names in
    descriptor spaces deliberately match ``ExecutionPlan`` field names.
    """
    if not desc.serve:
        raise ValueError(f"{desc.name} is not a serve-path kernel")
    out = []
    for params in desc.candidates(shape, max_candidates):
        out.append(replace(base_plan, expand=desc.expand, scan=desc.scan,
                           **params))
    if not out:
        # a descriptor with an empty (or fully pruned) space still offers
        # its base form — e.g. the jnp oracles have no tunables
        if desc.space_fn(shape) == {} and desc.feasible(shape, {}):
            out.append(replace(base_plan, expand=desc.expand,
                               scan=desc.scan))
    return out


def descriptor_for_plan(plan, share_kind: str) -> KernelDescriptor:
    """The registered descriptor a plan executes on (for byte models).

    Matching mirrors ``answer_local`` dispatch: ``expand="fused-pallas"``
    is matched exactly first (the megakernel serves XOR *and* additive
    protocols); beyond that, additive and LWE protocols ignore ``expand``
    (the GEMM always materializes its operand matrix), so any such plan —
    including a legacy ``path="fused"`` one — maps to the GEMM descriptor
    of its ``scan``; the fused XOR body ignores ``scan`` (its inner fold
    is always the jnp dpxor).
    """
    for d in serve_kernels(share_kind):
        if plan.expand == "fused-pallas":
            if d.expand == "fused-pallas":
                return d
        elif share_kind in ("additive", "lwe"):
            if d.expand != "fused-pallas" and d.scan == plan.scan:
                return d
        elif d.expand == plan.expand and (plan.expand == "fused"
                                          or d.scan == plan.scan):
            return d
    raise KeyError(f"no registered kernel for plan {plan.name!r} "
                   f"({share_kind})")


def plan_params(plan) -> Dict[str, int]:
    """The tunable fields of a plan, as a descriptor params dict."""
    return {"tile_r": plan.tile_r, "tile_q": plan.tile_q,
            "tile_l": plan.tile_l, "chunk_log": plan.chunk_log,
            "depth": plan.depth}


def predicted_step_bytes(plan, share_kind: str, shape: ProblemShape) -> int:
    """Modeled HBM bytes one answer step moves under ``plan`` (per shard).

    The memory-roofline numerator (`analysis/roofline.py` HBM_BW divides
    it into a time bound); surfaced by dry-run and launch reporting next
    to each bucket's chosen plan.
    """
    desc = descriptor_for_plan(plan, share_kind)
    return desc.bytes_fn(shape, plan_params(plan))
