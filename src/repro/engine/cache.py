"""Persistent JSON plan cache: measured plans survive the process.

Autotuning is measurement, and measurement costs wall clock — the point of
persisting winners is that a serving process never re-pays it. The cache
maps

    (backend, protocol, DatabaseSpec signature, bucket)  ->  ExecutionPlan

where the spec signature is ``"{n_items}x{item_bytes}"`` — exactly the
shape axes plan selection depends on. Lookup happens once per bucket at
``BucketedServeFns`` build time (never on the dispatch path); a hit
returns the tuned plan (provenance ``"tuned"``), a miss falls through to
the deterministic heuristic, so a machine without a cache file behaves
bit-for-bit like the pre-engine stack.

Robustness contract (tested): a missing, corrupted, or stale-schema cache
file silently degrades to "no cache" — tuning artifacts must never be able
to take serving down. Writes are atomic (tmp + rename) so a crashed tuner
can't leave a torn file.

Location: ``REPRO_PLAN_CACHE`` env var; unset -> ``results/plan_cache.json``
relative to the working directory; the literal values ``off``/``none``/``0``
disable persistence entirely.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, Optional

SCHEMA_VERSION = 1
DEFAULT_PATH = os.path.join("results", "plan_cache.json")
CACHE_ENV = "REPRO_PLAN_CACHE"

#: ExecutionPlan fields a cache entry round-trips; provenance is stored
#: alongside (entry-level, default "tuned" for pre-provenance files)
#: "depth" (fused-pallas DMA buffers) joined in the megakernel PR; older
#: cache files simply lack the key and fall back to the plan default
_PLAN_FIELDS = ("expand", "scan", "chunk_log", "collective",
                "tile_r", "tile_q", "tile_l", "depth")


def cache_path() -> Optional[str]:
    """The configured cache file, or None when persistence is disabled."""
    raw = os.environ.get(CACHE_ENV)
    if raw is None:
        return DEFAULT_PATH
    raw = raw.strip()
    if raw.lower() in ("", "off", "none", "0"):
        return None
    return raw


def plan_key(backend: str, protocol: str, spec_sig: str, bucket: int) -> str:
    return f"{backend}|{protocol}|{spec_sig}|b{bucket}"


def spec_signature(cfg) -> str:
    """DatabaseSpec signature of a PIRConfig (the cache's shape axes).

    A checksum column widens every stored row by one word, changing the
    shapes plan selection tunes against — checksummed configs get their
    own cache rows (``"+c"`` marker) instead of poisoning the plain ones.
    """
    sig = f"{cfg.n_items}x{cfg.item_bytes}"
    if getattr(cfg, "checksum", False):
        sig += "+c"
    return sig


def plan_to_dict(plan) -> Dict:
    return {f: getattr(plan, f) for f in _PLAN_FIELDS}


def plan_from_dict(d: Dict, provenance: str = "tuned"):
    from repro.core.protocol import ExecutionPlan
    unknown = set(d) - set(_PLAN_FIELDS)
    if unknown:
        raise ValueError(f"unknown plan fields {sorted(unknown)}")
    fields = {f: d[f] for f in _PLAN_FIELDS if f in d}
    for f in ("expand", "scan"):
        if f not in fields or not isinstance(fields[f], str):
            raise ValueError(f"plan entry missing/invalid {f!r}")
    return ExecutionPlan(provenance=provenance, **fields)


class PlanCache:
    """In-memory mirror of the JSON plan store.

    ``path=None`` is a purely in-memory cache (persistence disabled);
    ``save()`` is then a no-op. One process-wide instance is held by
    ``repro.engine`` and consulted by ``resolve``; tests construct their
    own against tmp paths.
    """

    def __init__(self, path: Optional[str] = None, *, chaos=None):
        self.path = path
        self.plans: Dict[str, Dict] = {}
        self.load_error: Optional[str] = None
        #: optional ChaosInjector (repro.chaos) consulted at the
        #: plan_cache.load seam — proves the degrade-to-heuristic
        #: contract holds under injected load failures
        self.chaos = chaos
        if path is not None:
            self._load(path)

    # -- persistence ----------------------------------------------------

    def _load(self, path: str) -> None:
        if self.chaos is not None:
            from repro.chaos import InjectedFault
            try:
                hits = self.chaos.visit("plan_cache.load")  # raises on kill
                dropped = any(ev.action == "drop" for ev in hits)
            except InjectedFault as e:
                # same degrade path as a torn file: serving never dies
                # because a tuning artifact is unreadable
                self.load_error = f"{type(e).__name__}: {e}"
                return
            if dropped:
                self.load_error = "InjectedFault: chaos drop at plan_cache.load"
                return
        if not os.path.exists(path):
            return
        try:
            with open(path) as f:
                raw = json.load(f)
            if not isinstance(raw, dict) or raw.get("schema") \
                    != SCHEMA_VERSION:
                raise ValueError(
                    f"stale cache schema {raw.get('schema')!r} "
                    f"(want {SCHEMA_VERSION})")
            plans = raw.get("plans", {})
            if not isinstance(plans, dict):
                raise ValueError("malformed 'plans' table")
            # validate every entry now: a single bad row must not be able
            # to crash plan resolution later
            for key, entry in plans.items():
                plan_from_dict(entry["plan"])
            self.plans = plans
        except (OSError, ValueError, KeyError, TypeError,
                json.JSONDecodeError) as e:
            # degrade to heuristic-only; remember why for diagnostics
            self.load_error = f"{type(e).__name__}: {e}"
            self.plans = {}

    def save(self) -> Optional[str]:
        if self.path is None:
            return None
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        payload = {"schema": SCHEMA_VERSION, "plans": self.plans}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(self.path) or ".",
                                   prefix=".plan_cache_")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return os.path.abspath(self.path)

    # -- lookup / update ------------------------------------------------

    def get(self, backend: str, protocol: str, spec_sig: str, bucket: int):
        entry = self.plans.get(plan_key(backend, protocol, spec_sig,
                                        bucket))
        if entry is None:
            return None
        try:
            return plan_from_dict(entry["plan"],
                                  entry.get("provenance", "tuned"))
        except (ValueError, KeyError, TypeError):
            return None

    def put(self, backend: str, protocol: str, spec_sig: str, bucket: int,
            plan, meta: Optional[Dict] = None,
            provenance: str = "tuned") -> None:
        self.plans[plan_key(backend, protocol, spec_sig, bucket)] = {
            "plan": plan_to_dict(plan), "meta": meta or {},
            "provenance": provenance,
        }

    def warm_put(self, backend: str, protocol: str, spec_sig: str,
                 bucket: int, plan, meta: Optional[Dict] = None) -> bool:
        """Seed an entry only if the slot is empty (provenance ``"warm"``).

        The cross-replica warm-start path: a rejoining replica records the
        plans a healthy peer is serving with, so its first serve-fn build
        resolves to a measured plan instead of re-paying tuning (or worse,
        falling to the heuristic). A tuned entry always wins over a warm
        one — never overwrite. Returns whether an entry was written.
        """
        key = plan_key(backend, protocol, spec_sig, bucket)
        if key in self.plans:
            return False
        self.plans[key] = {"plan": plan_to_dict(plan), "meta": meta or {},
                           "provenance": "warm"}
        return True

    def __len__(self) -> int:
        return len(self.plans)
