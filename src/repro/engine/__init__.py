"""The engine plane: kernel registry + measured autotuner + plan cache.

This package owns plan selection end to end (DESIGN.md §9):

``engine/backend.py``   one backend probe for the whole stack
                        (``REPRO_FORCE_BACKEND`` override) + legal-tile
                        arithmetic (largest legal divisor ≤ requested).
``engine/kernels.py``   descriptors over the answer-kernel bodies with
                        declared tunable spaces and a VMEM-footprint
                        validity model (``analysis/roofline.py`` math) —
                        infeasible candidates are pruned without running.
``engine/tuner.py``     the measured autotuner: times feasible
                        ``ExecutionPlan`` candidates on the real
                        (db_view, bucket) shapes under a budget.
``engine/cache.py``     persistent JSON plan cache keyed by
                        (backend, protocol, spec signature, bucket).

:func:`resolve` is the seam the protocol plane delegates to
(``core/protocol.py resolve_plan`` with ``path=None/"auto"``): cache hit →
tuned plan; miss → the deterministic heuristic, bit-for-bit the
pre-engine ``plan_for``. Resolution happens once per bucket at
``BucketedServeFns`` build time — never on the dispatch path.
"""
from __future__ import annotations

from dataclasses import replace as _replace
from typing import Optional

# the probe is re-exported under a DIFFERENT name on purpose: a package
# global named ``backend`` would shadow the ``repro.engine.backend``
# submodule attribute on this package (module globals ARE package attrs),
# making ``import repro.engine.backend as m`` bind the function instead of
# the module. tests/test_engine.py pins the regression.
from repro.engine.backend import backend as probe_backend
from repro.engine.backend import (FORCE_BACKEND_ENV, default_interpret,
                                  legal_tile, on_tpu)
from repro.engine.cache import (PlanCache, cache_path, plan_key,
                                spec_signature)
from repro.engine.kernels import (KERNELS, KernelDescriptor, ProblemShape,
                                  get_kernel, predicted_step_bytes,
                                  serve_kernels)
from repro.engine.tuner import (SMOKE_BUDGET, TuneBudget, TuneResult,
                                autotune, candidate_plans, heuristic_plan,
                                plan_label, problem_shape, tune,
                                tune_standalone)

__all__ = [
    "FORCE_BACKEND_ENV", "probe_backend", "default_interpret", "legal_tile",
    "on_tpu", "PlanCache", "cache_path", "plan_key", "spec_signature",
    "KERNELS", "KernelDescriptor", "ProblemShape", "get_kernel",
    "predicted_step_bytes", "serve_kernels", "SMOKE_BUDGET", "TuneBudget",
    "TuneResult", "autotune", "candidate_plans", "heuristic_plan",
    "plan_label", "problem_shape", "tune", "tune_standalone",
    "plan_cache", "resolve", "plan_report", "record_plans",
]

_PLAN_CACHE: Optional[PlanCache] = None


def plan_cache(reload: bool = False) -> PlanCache:
    """The process-wide plan cache (``REPRO_PLAN_CACHE`` location).

    Loaded lazily once; ``reload=True`` re-reads the file (tests, or after
    an external tuner wrote new entries).
    """
    global _PLAN_CACHE
    if _PLAN_CACHE is None or reload:
        _PLAN_CACHE = PlanCache(cache_path())
    return _PLAN_CACHE


def resolve(cfg, n_queries: int, *, backend_name: Optional[str] = None,
            chunk_log: int = 12, collective: str = "gather"):
    """A plan for (cfg, bucket): tuned on cache hit, heuristic on miss.

    The tuned plan keeps its measured tiling (including chunk_log); only
    the collective — a topology choice the tuner does not measure — is
    taken from the caller. The miss path is ``heuristic_plan``, i.e. the
    pre-engine ``plan_for`` verbatim.
    """
    be = backend_name or probe_backend()
    hit = plan_cache().get(be, cfg.protocol, spec_signature(cfg), n_queries)
    if hit is not None:
        return _replace(hit, collective=collective)
    plan = heuristic_plan(cfg, n_queries, backend=be, chunk_log=chunk_log)
    return _replace(plan, collective=collective)


def record_plans(cfg, plans: dict, *, backend_name: Optional[str] = None,
                 persist: bool = False) -> int:
    """Seed the process-wide cache with ``{bucket: plan}`` warm entries.

    The replica plane's cross-replica warm start: a healthy replica
    exports its per-bucket plans (``BucketedServeFns.plans``), a rejoining
    one records them here before building serve fns, so its first query is
    served from a measured plan — no re-tuning, no heuristic fallback.
    Warm entries never displace tuned ones (``PlanCache.warm_put``).
    Returns the number of entries written; ``persist=True`` also saves the
    cache file so the warm start survives the process.
    """
    be = backend_name or probe_backend()
    cache = plan_cache()
    sig = spec_signature(cfg)
    written = sum(
        cache.warm_put(be, cfg.protocol, sig, bucket, plan)
        for bucket, plan in plans.items())
    if persist and written:
        cache.save()
    return written


def plan_report(cfg, plan, bucket: int, *, n_shards: int = 1,
                measured_wall_s: Optional[float] = None,
                backend_name: Optional[str] = None) -> dict:
    """Reporting row for one bucket's chosen plan: provenance, the modeled
    HBM bytes its answer step moves, and the backend's bandwidth roof those
    bytes are judged against (dry-run / launch / bench surfaces).

    Pass ``measured_wall_s`` (e.g. a tuner timing) to additionally report
    ``achieved_frac`` — the fraction of peak bandwidth the measured run
    achieved over the modeled bytes (``analysis.roofline``).
    """
    from repro.analysis.roofline import achieved_fraction, peak_bytes_per_s
    from repro.core import protocol as protocol_mod
    be = backend_name or probe_backend()
    proto = protocol_mod.get(cfg.protocol)
    shape = problem_shape(cfg, bucket, n_shards=n_shards)
    step_bytes = predicted_step_bytes(plan, proto.share_kind, shape)
    out = {
        "plan": plan.name,
        "label": plan_label(plan),
        "provenance": plan.provenance,
        "predicted_step_bytes": step_bytes,
        "peak_bytes_per_s": peak_bytes_per_s(be),
    }
    if measured_wall_s is not None:
        out["measured_wall_s"] = measured_wall_s
        out["achieved_frac"] = achieved_fraction(step_bytes,
                                                 measured_wall_s,
                                                 backend=be)
    return out
