from repro.optim.optimizer import (adafactor_init, adafactor_update,
                                   adamw_init, adamw_update, lr_schedule,
                                   opt_init, opt_update, spec_for_state)
from repro.optim import compression
__all__ = ["adafactor_init", "adafactor_update", "adamw_init",
           "adamw_update", "lr_schedule", "opt_init", "opt_update",
           "spec_for_state", "compression"]
