"""int8 gradient compression with error feedback — the cross-pod trick.

At 2+ pods the data-parallel all-reduce crosses the slow inter-pod links;
quantizing gradients to int8 (per-leaf max-abs scale) cuts those bytes 4×
(vs f32 accumulation; 2× vs bf16). The quantization residual is carried in
an error-feedback buffer and re-added next step, which keeps SGD unbiased
in the long run (EF-SGD).

``compressed_psum`` is designed to sit inside a ``shard_map`` over the pod
axis: quantize → integer psum (int32 accumulate, exact) → dequantize with
the max of the per-pod scales (psum of scales gives the conservative bound).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def ef_init(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, F32), params)


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 -> (int8, scale). scale = maxabs / 127."""
    gf = g.astype(F32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compress_with_feedback(grads, ef_state):
    """Returns (quantized tree, scales tree, new ef_state)."""
    def one(g, e):
        gf = g.astype(F32) + e
        q, s = quantize(gf)
        new_e = gf - dequantize(q, s)
        return q, s, new_e

    leaf = lambda x: isinstance(x, jax.Array)
    out = jax.tree_util.tree_map(one, grads, ef_state, is_leaf=leaf)
    is_t = lambda x: isinstance(x, tuple) and len(x) == 3
    pick = lambda i: jax.tree_util.tree_map(lambda t: t[i], out, is_leaf=is_t)
    return pick(0), pick(1), pick(2)


def compressed_psum(grads, ef_state, axis: str):
    """EF-int8 all-reduce over ``axis`` (use inside shard_map).

    int8 payloads psum in int32 (exact); scales take the max over pods so
    dequantization never clips.
    """
    q, s, new_ef = compress_with_feedback(grads, ef_state)
    q_sum = jax.tree_util.tree_map(
        lambda x: jax.lax.psum(x.astype(jnp.int32), axis), q)
    s_max = jax.tree_util.tree_map(lambda x: jax.lax.pmax(x, axis), s)
    n = jax.lax.psum(1, axis)
    mean = jax.tree_util.tree_map(
        lambda qq, ss: (qq.astype(F32) * ss) / n, q_sum, s_max)
    return mean, new_ef
