"""Optimizers: AdamW and Adafactor, functional, pjit-shardable.

AdamW keeps fp32 (m, v) + an fp32 master copy — 12+ bytes/param, fine for
the ≤35 B dense archs. Adafactor factorizes the second moment over the last
two dims and drops momentum — the only way grok-1-314b / deepseek-v3-671b
optimizer state fits a 256-chip pod (DESIGN.md §5 memory math).

State sharding: every optimizer-state leaf inherits its parameter's
PartitionSpec (TP-sharded moments). ``spec_for_state`` additionally offers
ZeRO-1 ("zero1") which shards the leading dim over the data axis when
divisible — GSPMD inserts the reduce-scatter/all-gather pair.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import OptimizerConfig

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any       # fp32 master weights


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any           # row second-moment (last dim reduced)
    vc: Any           # col second-moment (second-to-last dim reduced)
    v: Any            # full second moment for rank<2 leaves (else ())


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), gnorm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        master=jax.tree_util.tree_map(lambda p: p.astype(F32), params),
    )


def adamw_update(cfg: OptimizerConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    def upd(g, m, v, master):
        gf = g.astype(F32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        master_new = master - lr * (update + cfg.weight_decay * master)
        return m_new, v_new, master_new

    flat = jax.tree_util.tree_map(upd, grads, state.m, state.v, state.master,
                                  is_leaf=lambda x: isinstance(x, jax.Array))
    m = jax.tree_util.tree_map(lambda t: t[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree_util.tree_map(lambda t: t[1], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree_util.tree_map(lambda t: t[2], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree_util.tree_map(
        lambda p, mw: mw.astype(p.dtype), params, master)
    return new_params, AdamWState(step, m, v, master), \
        {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum, no master copy)
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    def vr(p):
        return (jnp.zeros(p.shape[:-1], F32) if _factored(p) else ())

    def vc(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)
                if _factored(p) else ())

    def vfull(p):
        return () if _factored(p) else jnp.zeros(p.shape, F32)

    leaf = lambda x: isinstance(x, jax.Array)
    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree_util.tree_map(vr, params, is_leaf=leaf),
        vc=jax.tree_util.tree_map(vc, params, is_leaf=leaf),
        v=jax.tree_util.tree_map(vfull, params, is_leaf=leaf),
    )


def adafactor_update(cfg: OptimizerConfig, grads, state: AdafactorState,
                     params) -> Tuple[Any, AdafactorState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    decay = 1.0 - (step.astype(F32) + 1.0) ** -0.8
    eps = 1e-30

    def upd(g, vr, vc, v, p):
        gf = g.astype(F32)
        g2 = gf * gf + eps
        if _factored(p):
            vr_new = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
            vc_new = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
            row = vr_new / jnp.maximum(
                jnp.mean(vr_new, axis=-1, keepdims=True), eps)
            precond = gf / (jnp.sqrt(row)[..., None]
                            * jnp.sqrt(vc_new)[..., None, :] + 1e-9)
            v_new = v
        else:
            v_new = decay * v + (1 - decay) * g2
            precond = gf / (jnp.sqrt(v_new) + 1e-9)
            vr_new, vc_new = vr, vc
        # relative update clipping (Adafactor's d=1.0)
        rms = jnp.sqrt(jnp.mean(precond * precond) + eps)
        precond = precond / jnp.maximum(1.0, rms)
        pf = p.astype(F32)
        p_new = pf - lr * precond - lr * cfg.weight_decay * pf
        return p_new.astype(p.dtype), vr_new, vc_new, v_new

    leaf = lambda x: isinstance(x, jax.Array)
    is_t = lambda x: isinstance(x, tuple) and not isinstance(x, jax.Array)
    out = jax.tree_util.tree_map(upd, grads, state.vr, state.vc, state.v,
                                 params, is_leaf=leaf)
    pick = lambda i: jax.tree_util.tree_map(lambda t: t[i], out, is_leaf=is_t)
    return pick(0), AdafactorState(step, pick(1), pick(2), pick(3)), \
        {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Uniform facade + state sharding specs
# ---------------------------------------------------------------------------

def opt_init(cfg: OptimizerConfig, params):
    if cfg.name == "adamw":
        return adamw_init(params)
    if cfg.name == "adafactor":
        return adafactor_init(params)
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def opt_update(cfg: OptimizerConfig, grads, state, params):
    if cfg.name == "adamw":
        return adamw_update(cfg, grads, state, params)
    return adafactor_update(cfg, grads, state, params)


def spec_for_state(cfg: OptimizerConfig, param_specs, params_shape,
                   *, zero1: bool = False, data_axis: str = "data"):
    """PartitionSpec pytree matching ``opt_init``'s state structure.

    By default moments inherit the parameter specs. Adafactor's factored
    leaves reduce one dim away, so their specs drop that dim's entry.
    """
    leafP = lambda x: isinstance(x, P)

    def shard0(spec, shape):
        if not zero1 or not len(shape):
            return spec
        if spec[0] is None and shape[0] % 2 == 0:
            return P(data_axis, *spec[1:])
        return spec

    if cfg.name == "adamw":
        mspec = jax.tree_util.tree_map(
            shard0, param_specs,
            jax.tree_util.tree_map(lambda s: s.shape, params_shape),
            is_leaf=leafP)
        return AdamWState(step=P(), m=mspec, v=mspec, master=mspec)

    def vr_spec(spec, shape):
        return P(*spec[:-1]) if len(shape) >= 2 else ()

    def vc_spec(spec, shape):
        return P(*(tuple(spec[:-2]) + (spec[-1],))) if len(shape) >= 2 else ()

    def v_spec(spec, shape):
        return () if len(shape) >= 2 else spec

    shapes = jax.tree_util.tree_map(lambda s: s.shape, params_shape)
    return AdafactorState(
        step=P(),
        vr=jax.tree_util.tree_map(vr_spec, param_specs, shapes, is_leaf=leafP),
        vc=jax.tree_util.tree_map(vc_spec, param_specs, shapes, is_leaf=leafP),
        v=jax.tree_util.tree_map(v_spec, param_specs, shapes, is_leaf=leafP),
    )
