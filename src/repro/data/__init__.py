from repro.data.pipeline import TokenPipeline, QueryPipeline
__all__ = ["TokenPipeline", "QueryPipeline"]
