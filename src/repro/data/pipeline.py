"""Deterministic synthetic data pipeline.

Design goals (the ones that matter at 1000+ nodes):
* **Stateless resumability** — batch ``i`` is a pure function of
  ``(seed, step)``; restoring a checkpoint at step k needs no data-loader
  state, and elastic re-sharding just changes which slice each host draws.
* **Host sharding** — each process materializes only its ``[local_batch]``
  slice (``process_index/num_processes``), so no host ever holds the global
  batch.
* **Modality stubs** — the audio/VLM frontends are stubs per the assignment;
  the pipeline emits the precomputed frame/patch embeddings those configs
  declare.

Token statistics: Zipfian-ish via squaring a uniform (cheap, gives the loss
curves some structure vs pure uniform).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.config import ModelConfig, ShapeConfig


@dataclass
class TokenPipeline:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    process_index: int = 0
    num_processes: int = 1

    def __post_init__(self):
        if self.shape.global_batch % self.num_processes:
            raise ValueError("global batch not divisible across hosts")
        self.local_batch = self.shape.global_batch // self.num_processes

    def _rng(self, step: int) -> np.random.Generator:
        # counter-based: independent stream per (seed, step, host)
        return np.random.default_rng(
            np.random.SeedSequence(
                [self.seed, step, self.process_index]))

    def tokens(self, step: int) -> np.ndarray:
        rng = self._rng(step)
        seq = self.shape.seq_len
        if self.cfg.family == "vlm":
            seq -= self.cfg.n_frontend_tokens
        u = rng.random((self.local_batch, seq))
        toks = (u * u * (self.cfg.vocab - 1)).astype(np.int32)
        return toks

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Full input dict for one local step (tokens + modality stubs)."""
        out: Dict[str, np.ndarray] = {"tokens": self.tokens(step)}
        rng = self._rng(step + (1 << 30))
        if self.cfg.family == "vlm":
            out["prefix_embeds"] = rng.standard_normal(
                (self.local_batch, self.cfg.n_frontend_tokens,
                 self.cfg.d_model)).astype(np.float32) * 0.02
        if self.cfg.family == "audio":
            out["frame_embeds"] = rng.standard_normal(
                (self.local_batch, self.cfg.encoder_len,
                 self.cfg.d_model)).astype(np.float32) * 0.02
        return out


@dataclass
class QueryPipeline:
    """PIR query-index stream (client side of the serve loop)."""
    n_items: int
    batch: int
    seed: int = 0

    def indices(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        return rng.integers(0, self.n_items, size=self.batch, dtype=np.int64)
