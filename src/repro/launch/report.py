"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from results/dryrun.jsonl.

Takes the LAST record per (kind, arch, shape, mesh) so re-runs supersede
earlier failures. ``--markdown`` emits the tables; default prints a summary.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List


def load(path: str) -> List[dict]:
    last: Dict[tuple, dict] = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            last[(r.get("kind"), r.get("arch"), r.get("shape"),
                  r.get("mesh"))] = r
    return list(last.values())


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}µs"


def _fmt_b(x) -> str:
    if x is None:
        return "-"
    for unit, div in (("GB", 1 << 30), ("MB", 1 << 20), ("KB", 1 << 10)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def roofline_table(rows: List[dict], mesh: str = "single") -> str:
    out = ["| cell | chips | HLO FLOPs | t_comp | t_mem | t_coll | "
           "bottleneck | useful/HLO | MFU-bound | HBM/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        if r.get("skipped"):
            out.append(f"| {r['arch']}/{r['shape']} | - | - | - | - | - | "
                       f"skipped | - | - | - |")
            continue
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0))
        out.append(
            f"| {r['arch']}/{r['shape']} | {r['n_chips']} "
            f"| {r['hlo_flops']:.2e} "
            f"| {_fmt_s(r['t_compute_s'])} | {_fmt_s(r['t_memory_s'])} "
            f"| {_fmt_s(r['t_collective_s'])} | {r['bottleneck']} "
            f"| {r['useful_flop_ratio']:.2f} | {r['mfu_bound']*100:.2f}% "
            f"| {_fmt_b(hbm)} |")
    return "\n".join(out)


def dryrun_table(rows: List[dict]) -> str:
    out = ["| cell | mesh | status | compile | bytes/dev (arg+tmp) | "
           "collectives |",
           "|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r.get("kind", ""), r["arch"],
                                         r["shape"], r["mesh"])):
        if r.get("skipped"):
            out.append(f"| {r['arch']}/{r['shape']} | {r['mesh']} | "
                       f"SKIP ({r.get('reason', '')[:40]}…) | - | - | - |")
            continue
        if not r.get("ok"):
            out.append(f"| {r['arch']}/{r['shape']} | {r['mesh']} | "
                       f"FAIL | - | - | {r.get('error', '')[:60]} |")
            continue
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0))
        coll = r.get("collective_breakdown", {})
        coll_s = ", ".join(f"{k.split('-')[-1][:4]}:{_fmt_b(v)}"
                           for k, v in sorted(coll.items(),
                                              key=lambda kv: -kv[1])[:3])
        out.append(f"| {r['arch']}/{r['shape']} | {r['mesh']} | ok | "
                   f"{r.get('compile_s', '-')}s | {_fmt_b(hbm)} | "
                   f"{coll_s} |")
    return "\n".join(out)


def summary(rows: List[dict]) -> str:
    ok = sum(1 for r in rows if r.get("ok") and not r.get("skipped"))
    skip = sum(1 for r in rows if r.get("skipped"))
    fail = sum(1 for r in rows if not r.get("ok"))
    over = [r for r in rows if r.get("ok") and not r.get("skipped")
            and r.get("memory", {}).get("temp_size_in_bytes", 0)
            + r.get("memory", {}).get("argument_size_in_bytes", 0)
            > 16 * (1 << 30)]
    lines = [f"cells ok={ok} skipped={skip} failed={fail}"]
    for r in over:
        mem = r["memory"]
        tot = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / (1 << 30)
        lines.append(f"  HBM>16G: {r['arch']}/{r['shape']}/{r['mesh']} "
                     f"= {tot:.1f} GiB/dev (CPU-f32 accounting)")
    for r in rows:
        if not r.get("ok"):
            lines.append(f"  FAIL {r['arch']}/{r['shape']}/{r['mesh']}: "
                         f"{r.get('error', '')[:120]}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    rows = load(args.inp)
    if args.markdown:
        print("### Dry-run grid\n")
        print(dryrun_table(rows))
        print("\n### Roofline (single-pod, 256 chips)\n")
        print(roofline_table(rows, "single"))
        print("\n### Roofline (multi-pod, 512 chips)\n")
        print(roofline_table(rows, "multi"))
    else:
        print(summary(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
