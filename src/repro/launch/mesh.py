"""Mesh construction for the production pod topologies.

``make_production_mesh`` is a *function* (not a module-level constant) so that
importing this module never touches JAX device state — critical because the
dry-run launcher must set ``XLA_FLAGS=--xla_force_host_platform_device_count``
before the first JAX initialization, while unit tests must see the single real
CPU device.

Axis semantics (see DESIGN.md §3):
  pod    cross-pod data parallelism (train) / extra cluster parallelism (PIR)
  data   batch shards (train/serve) == PIR "DPU clusters" (DB replicas)
  model  tensor parallelism (heads/ffn/vocab/experts) == PIR DB shards
         (the "DPUs of one cluster")
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.config import MeshConfig

SINGLE_POD = MeshConfig(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig) -> jax.sharding.Mesh:
    """Build a mesh for an arbitrary MeshConfig (used by tests & elastic)."""
    return jax.make_mesh(tuple(cfg.shape), tuple(cfg.axes))


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """A mesh over however many devices this process actually has.

    Used by smoke tests and the CPU benchmarks; collapses gracefully to
    (1, 1) on the single-CPU container.
    """
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def split_devices(n_groups: int, devices=None, *,
                  min_per_group: int = 1) -> list:
    """Partition the live device list into ``n_groups`` disjoint groups.

    The replica plane carves one serve replica per group (each group then
    becomes its own sub-mesh via ``runtime/elastic.carve_submeshes``).
    Groups are equal-sized; leftover devices idle until the next resize
    (same policy as ``plan_mesh``). When the host has fewer than
    ``n_groups * min_per_group`` devices, every group gets the FULL device
    list — the single-host degenerate case: replicas share silicon but
    keep separate schedulers, compiled steps, and DB placements, exactly
    how k parties share the one CPU device on this container.
    """
    if n_groups < 1:
        raise ValueError(f"n_groups must be >= 1, got {n_groups}")
    devs = list(devices if devices is not None else jax.devices())
    per = len(devs) // n_groups
    if per < max(min_per_group, 1):
        return [list(devs) for _ in range(n_groups)]
    return [devs[i * per:(i + 1) * per] for i in range(n_groups)]


def mesh_axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes over which the global batch is sharded."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def pir_cluster_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes that enumerate PIR clusters (DB replicas)."""
    return batch_axes(mesh)


def pir_shard_axis(mesh: jax.sharding.Mesh) -> Optional[str]:
    """Axis that shards the PIR database inside one cluster."""
    return "model" if "model" in mesh.axis_names else None
