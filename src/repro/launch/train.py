"""Training launcher.

CPU-scale driver for the same code path the pod runs: pick an architecture
(full or smoke), build the mesh (production placeholder grid or the local
device set), and run the fault-tolerant loop.

Examples:
  # ~100M-class end-to-end run on this container (examples/train_lm.py
  # wraps this with a fixed recipe):
  python -m repro.launch.train --arch granite-3-2b --smoke --steps 200

  # full-config step construction against the production mesh is exercised
  # by launch/dryrun.py (lower+compile only — no CPU can execute it).
"""
from __future__ import annotations

import argparse

import jax

from repro.config import MeshConfig, OptimizerConfig, RunConfig, ShapeConfig
from repro.configs import get_arch
from repro.configs.shapes import SMOKE_TRAIN, get_shape
from repro.launch.mesh import make_local_mesh
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=0,
                    help="override global batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    model_cfg = get_arch(args.arch, smoke=args.smoke)
    shape = SMOKE_TRAIN if args.smoke else get_shape("train_4k")
    if args.batch or args.seq:
        shape = ShapeConfig(
            name="custom",
            seq_len=args.seq or shape.seq_len,
            global_batch=args.batch or shape.global_batch,
            kind="train")

    mesh = make_local_mesh()
    run = RunConfig(
        model=model_cfg, shape=shape,
        mesh=MeshConfig(shape=tuple(mesh.devices.shape),
                        axes=tuple(mesh.axis_names)),
        optimizer=OptimizerConfig(
            name=args.optimizer, lr=args.lr, warmup_steps=args.steps // 20,
            total_steps=args.steps, compress_grads=args.compress_grads),
        microbatches=args.microbatches, seed=args.seed)

    loop = TrainLoop(run, mesh, TrainLoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir))
    with mesh:
        res = loop.run_loop(resume=args.resume)
    print(f"[train] done at step {res.final_step}; "
          f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}; "
          f"skipped {res.skipped_steps}, rewinds {res.rewinds}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
