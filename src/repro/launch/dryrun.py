import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any other import — JAX locks the device
count at first initialization, and the production meshes need 512 host
placeholder devices (256 single-pod + 512 multi-pod).

For every cell this driver:
  1. builds the production mesh (16×16 or 2×16×16),
  2. builds the pjit'd step (train_step for train shapes; prefill / decode
     serve steps for inference shapes),
  3. ``.lower(**ShapeDtypeStructs).compile()`` — no arrays are allocated,
  4. records ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
     (FLOPs/bytes) and the collective-bytes parse into a JSONL row.

Resumable: cells already present in the output JSONL are skipped, so the
grid can run incrementally (single-core CPU compiles are slow).

Usage:
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.jsonl
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --pir pir-8g --mesh single
"""
import argparse
import json
import time
import traceback
from functools import partial
from typing import Optional

import jax
import numpy as np

from repro.analysis import roofline as rl
from repro.config import MeshConfig, OptimizerConfig, RunConfig
from repro.configs import (ARCHS, PIR_CONFIGS, SHAPES, cell_is_skipped,
                           get_arch, get_shape)
from repro.launch.mesh import MULTI_POD, SINGLE_POD, make_production_mesh
from repro.models import build_model
from repro.runtime.steps import make_serve_step, make_train_step

# per-arch run policy: optimizer + microbatches + FSDP (DESIGN.md §5)
ARCH_POLICY = {
    "granite-3-2b":     dict(opt="adamw", micro=4, fsdp=False),
    "qwen3-4b":         dict(opt="adamw", micro=4, fsdp=False),
    "starcoder2-3b":    dict(opt="adamw", micro=4, fsdp=False),
    "stablelm-3b":      dict(opt="adamw", micro=4, fsdp=False),
    "whisper-small":    dict(opt="adamw", micro=2, fsdp=False),
    "xlstm-350m":       dict(opt="adamw", micro=4, fsdp=False),
    "llava-next-34b":   dict(opt="adafactor", micro=8, fsdp=True),
    "grok-1-314b":      dict(opt="adafactor", micro=8, fsdp=True),
    "deepseek-v3-671b": dict(opt="adafactor", micro=8, fsdp=True),
    "zamba2-7b":        dict(opt="adamw", micro=8, fsdp=False),
}


def make_run(arch: str, shape_name: str, multi_pod: bool,
             *, micro_override: Optional[int] = None) -> RunConfig:
    pol = ARCH_POLICY[arch]
    shape = get_shape(shape_name)
    mesh_cfg = MULTI_POD if multi_pod else SINGLE_POD
    micro = micro_override or pol["micro"]
    if shape.kind == "train":
        batch_shards = mesh_cfg.n_devices // 16   # batch axes = all but model
        while shape.global_batch // micro % batch_shards:
            micro //= 2
        micro = max(micro, 1)
    else:
        micro = 1
    return RunConfig(
        model=get_arch(arch), shape=shape, mesh=mesh_cfg,
        optimizer=OptimizerConfig(name=pol["opt"]),
        microbatches=micro, remat="block", fsdp=pol["fsdp"],
    )


def _struct(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               *, compile_only: bool = False,
               micro_override: Optional[int] = None) -> dict:
    """Lower + compile one cell; returns the JSONL record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    run = make_run(arch, shape_name, multi_pod,
                   micro_override=micro_override)
    cfg, shape = run.model, run.shape
    n_chips = run.mesh.n_devices
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            ts = make_train_step(run, mesh)
            params_s = jax.eval_shape(ts.model.init_params,
                                      jax.random.PRNGKey(0))
            from repro.optim.optimizer import opt_init
            opt_s = jax.eval_shape(partial(opt_init, run.optimizer),
                                   params_s)
            ef_s = None
            lowered = ts.step.lower(params_s, opt_s, ef_s,
                                    ts.input_structs)
            n_tokens = shape.global_batch * shape.seq_len
            training = True
        else:
            ss = make_serve_step(run, mesh)
            params_s = jax.eval_shape(ss.model.init_params,
                                      jax.random.PRNGKey(0))
            if shape.kind == "prefill":
                lowered = ss.prefill.lower(params_s, ss.input_structs)
                n_tokens = shape.global_batch * shape.seq_len
            else:   # decode
                cache_s = jax.eval_shape(
                    partial(ss.model.init_cache, shape.global_batch,
                            shape.seq_len))
                tok_s = jax.ShapeDtypeStruct((shape.global_batch, 1),
                                             np.int32)
                lowered = ss.decode.lower(params_s, cache_s, tok_s)
                n_tokens = shape.global_batch
            training = False

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        model_flops = rl.model_flops_for(
            cfg.n_active_params(), n_tokens, training=training)
        roof = rl.from_compiled(
            f"{arch}/{shape_name}/{'multi' if multi_pod else 'single'}",
            compiled, n_chips=n_chips, model_flops=model_flops)

    rec = {
        "kind": "lm", "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "microbatches": run.microbatches, "fsdp": run.fsdp,
        "optimizer": run.optimizer.name,
        "memory": _mem_dict(mem),
        **roof.to_dict(),
    }
    return rec


def lower_pir_cell(pir_name: str, multi_pod: bool, *, path: str = "fused",
                   n_queries: int = 32, collective: str = "gather",
                   chunk_log: int = 12) -> dict:
    """Lower + compile a PIR serve step on the production mesh."""
    import dataclasses
    from repro.core.server import build_serve_fn, key_specs
    from repro.db import DatabaseSpec
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = PIR_CONFIGS[pir_name]
    if path == "matmul" and cfg.protocol != "additive-dpf-2":
        # the GEMM path contracts additive Z_256 shares
        cfg = dataclasses.replace(cfg, protocol="additive-dpf-2")
    n_chips = 512 if multi_pod else 256
    t0 = time.time()
    with mesh:
        # path="auto" resolves through the engine plane: plan-cache hit ->
        # tuned plan, miss -> the plan_for heuristic (DESIGN.md §9)
        fns = build_serve_fn(cfg, mesh, n_queries=n_queries,
                             path=None if path == "auto" else path,
                             collective=collective, chunk_log=chunk_log)
        keys = key_specs(cfg, n_queries)
        # the struct of the protocol's declared view (words for XOR, int8
        # bytes for additive) — the database plane owns this math
        db_s = DatabaseSpec.from_config(cfg).view_struct(
            fns.protocol.db_view)
        lowered = jax.jit(fns.serve).lower(db_s, keys)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        # PIR "model flops": the useful work is one pass over the DB per
        # query batch — count it as bytes-limited ops (1 XOR word-op per
        # 4 bytes) for the ratio bookkeeping.
        model_flops = cfg.db_bytes / 4 * n_queries
        roof = rl.from_compiled(
            f"{pir_name}/{path}/{'multi' if multi_pod else 'single'}",
            compiled, n_chips=n_chips, model_flops=model_flops)
    return {
        "kind": "pir", "arch": pir_name, "shape": path,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips, "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "n_queries": n_queries, "collective": collective,
        "chunk_log": chunk_log,
        # engine-plane provenance: which kernel path this cell compiled
        # to, how it was chosen, and the modeled per-device HBM bytes of
        # one answer step (the memory-roofline numerator)
        "plan": fns.plan.describe(),
        "plan_predicted_bytes": fns.plan_report()["predicted_step_bytes"],
        "memory": _mem_dict(mem),
        **roof.to_dict(),
    }


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _done_cells(path: str) -> set:
    done = set()
    if path and os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("ok"):
                    done.add((r["kind"], r["arch"], r["shape"], r["mesh"]))
    return done


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, help="shape cell name")
    ap.add_argument("--pir", default=None, help="PIR config name")
    ap.add_argument("--pir-path", default="fused",
                    choices=["baseline", "fused", "matmul", "pallas",
                             "auto"])
    ap.add_argument("--pir-collective", default="gather",
                    choices=["gather", "butterfly"])
    ap.add_argument("--pir-chunk-log", type=int, default=12)
    ap.add_argument("--pir-queries", type=int, default=32)
    ap.add_argument("--micro", type=int, default=None,
                    help="override ARCH_POLICY microbatches")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run the whole 40-cell grid + PIR cells")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args(argv)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = _done_cells(args.out)

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append(("lm", arch, shape))
        cells.append(("pir", "pir-8g", args.pir_path))
        cells.append(("pir", "pir-1g", args.pir_path))
    else:
        if args.arch:
            shapes = [args.shape] if args.shape else list(SHAPES)
            for s in shapes:
                cells.append(("lm", args.arch, s))
        if args.pir:
            cells.append(("pir", args.pir, args.pir_path))

    n_fail = 0
    with open(args.out, "a") as out:
        for kind, arch, shape in cells:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                key = (kind, arch, shape, mesh_name)
                if key in done:
                    print(f"[skip/done] {key}")
                    continue
                if kind == "lm" and cell_is_skipped(arch, shape):
                    rec = {"kind": kind, "arch": arch, "shape": shape,
                           "mesh": mesh_name, "ok": True, "skipped": True,
                           "reason": "long_500k requires sub-quadratic "
                                     "attention (DESIGN.md §4)"}
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
                    print(f"[skip/rule] {key}")
                    continue
                print(f"[lower] {key} ...", flush=True)
                try:
                    if kind == "lm":
                        rec = lower_cell(arch, shape, multi,
                                         micro_override=args.micro)
                    else:
                        rec = lower_pir_cell(
                            arch, multi, path=shape,
                            collective=args.pir_collective,
                            chunk_log=args.pir_chunk_log,
                            n_queries=args.pir_queries)
                    print(f"[ok] {key}: compile {rec['compile_s']}s "
                          f"bottleneck={rec.get('bottleneck')}", flush=True)
                except Exception as e:   # record failures, keep going
                    rec = {"kind": kind, "arch": arch, "shape": shape,
                           "mesh": mesh_name, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    n_fail += 1
                    print(f"[FAIL] {key}: {e}", flush=True)
                out.write(json.dumps(rec) + "\n")
                out.flush()
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
