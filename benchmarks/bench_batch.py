"""Batch-PIR amortization: cuckoo-bucketed m-query rounds vs single-query.

The §Perf companion to the batch composite (``runtime/batch.py``,
DESIGN.md §14). Every cell serves the IDENTICAL offered load — the same
``N_RECORDS`` pre-generated record requests, fully enqueued up front
(saturated regime, client-side Gen/cuckoo planning off the clock, the
paper's measurement boundary) — and reports **records/s**, the metric the
composite exists to move:

  single/<proto>      the m=1 baseline: each record is an independent
                      full-N-scan query through ``MultiServerPIR``
                      (bucket=1 — one record per compiled step)
  batch-m{m}/<proto>  ``BatchPIR``: m records per round over B = 2m cuckoo
                      buckets of ``capacity`` rows; per-round scanned rows
                      = B·capacity ≈ 4N serve m records, so records per
                      scanned row improve ~m/4-fold. Rounds are scheduler-
                      stacked ``ROUNDS_PER_DISPATCH`` deep so the per-call
                      dispatch overhead is amortized too (one compiled
                      Q=ROUNDS step per party, shared by ALL buckets).

The acceptance gate the artifact carries: the best batched cell's
records/s >= 2x its protocol's single-query baseline at equal DB size
(m=16 measures ~3-3.5x on the CPU container; m=1 deliberately shows the
regime where bucketing only costs — expansion without amortization).

Run: PYTHONPATH=src python -m benchmarks.run --only batch
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Csv, record_json
from repro.config import PIRConfig
from repro.core import pir
from repro.core.batch import plan_round
from repro.launch.mesh import make_local_mesh
from repro.runtime.batch import BatchPIR
from repro.runtime.serve_loop import MultiServerPIR

LOG_N = 14                      # 16384 records x 32 B (CPU-container scale)
ITEM_BYTES = 32
N_RECORDS = 64                  # offered load per repetition (records)
ROUNDS_PER_DISPATCH = 4         # batch cells: RoundPlans stacked per step
REPS = 3                        # keep the median wall time
OUT_JSON = "BENCH_batch.json"

#: the amortization grid: m=1 (pure bucketing overhead, no sharing),
#: m=4 (break-even region), m=16 (the acceptance cell) — plus a second
#: inner protocol at m=16 to show the composite is protocol-generic.
CELLS = [
    ("single/xor-fused", "xor-dpf-2", "fused", 0),
    ("batch-m1/xor-fused", "xor-dpf-2", "fused", 1),
    ("batch-m4/xor-fused", "xor-dpf-2", "fused", 4),
    ("batch-m16/xor-fused", "xor-dpf-2", "fused", 16),
    ("single/additive-gemm", "additive-dpf-2", "matmul", 0),
    ("batch-m16/additive-gemm", "additive-dpf-2", "matmul", 16),
]


def _median_wall(run_rep) -> float:
    walls = [run_rep() for _ in range(REPS)]
    return sorted(walls)[len(walls) // 2]


def _run_single(cfg: PIRConfig, path: str, db: np.ndarray,
                indices: List[int], mesh) -> dict:
    """m=1 baseline: every record is its own full-DB-scan round."""
    system = MultiServerPIR(db, cfg, mesh, path=path,
                            n_queries=1, buckets=(1,))
    system.query(indices[:1])                      # warm the compiled step
    queries = [pir.query_gen(np.random.default_rng(1000 + j), i, cfg).keys
               for j, i in enumerate(indices)]     # Gen off the clock

    def rep():
        sched = system._make_scheduler(max_wait_s=0.005, n_clusters=1)
        t0 = time.perf_counter()
        futs = [sched.submit(q) for q in queries]
        sched.pump()
        wall = time.perf_counter() - t0
        assert all(f.done() for f in futs)
        return wall

    wall = _median_wall(rep)
    return {"wall_s": wall, "records_per_s": len(indices) / wall,
            "records_per_round": 1, "scan_rows_per_record": cfg.n_items,
            "n_parties": system.n_parties}


def _run_batch(cfg: PIRConfig, path: str, db: np.ndarray,
               indices: List[int], mesh) -> dict:
    """BatchPIR cell: m records per round, rounds stacked per dispatch."""
    system = BatchPIR(db, cfg, mesh, path=path,
                      rounds=(ROUNDS_PER_DISPATCH,))
    m = cfg.batch_m
    system.query_batch(indices[:m])                # warm the compiled step
    # client-side cuckoo planning + keygen off the clock (the same
    # boundary as the baseline's pre-generated key stream)
    groups = [indices[i:i + m] for i in range(0, len(indices), m)]
    plans = [plan_round(np.random.default_rng(2000 + j), g, system.layout,
                        system.inner_cfg, system.protocol)
             for j, g in enumerate(groups)]

    def rep():
        sched = system._make_scheduler(max_wait_s=0.005, n_clusters=1)
        t0 = time.perf_counter()
        futs = [sched.submit(p) for p in plans]
        sched.pump()
        wall = time.perf_counter() - t0
        assert all(f.done() for f in futs)
        return wall

    wall = _median_wall(rep)
    bdb = system.db
    return {"wall_s": wall, "records_per_s": len(indices) / wall,
            "records_per_round": m, "n_buckets": bdb.n_buckets,
            "capacity": bdb.capacity, "expansion": bdb.expansion,
            "scan_rows_per_record": bdb.n_buckets * bdb.capacity / m,
            "rounds_per_dispatch": ROUNDS_PER_DISPATCH,
            "n_parties": system.n_parties}


def run() -> Csv:
    rng = np.random.default_rng(0)
    n = 1 << LOG_N
    db = pir.make_database(rng, n, ITEM_BYTES)
    # equal offered load: one record-request stream shared by every cell.
    # Unique indices so every cell serves N_RECORDS distinct records
    # (duplicates would let batch cells share bucket queries for free).
    indices = rng.choice(n, size=N_RECORDS, replace=False).tolist()
    mesh = make_local_mesh()

    cells, baselines = {}, {}
    for label, proto, path, m in CELLS:
        if m == 0:
            cfg = PIRConfig(n_items=n, item_bytes=ITEM_BYTES, protocol=proto)
            res = _run_single(cfg, path, db, indices, mesh)
            baselines[proto] = res["records_per_s"]
        else:
            cfg = PIRConfig(n_items=n, item_bytes=ITEM_BYTES, protocol=proto,
                            batch_m=m)
            res = _run_batch(cfg, path, db, indices, mesh)
        res.update(protocol=proto, path=path, m=m)
        res["speedup_vs_single"] = (res["records_per_s"] / baselines[proto]
                                    if proto in baselines else None)
        cells[label] = res

    # the acceptance gate: best batched cell vs ITS protocol's m=1 baseline
    batched = {k: v for k, v in cells.items() if v["m"] > 0}
    best = max(batched, key=lambda k: batched[k]["speedup_vs_single"])
    acceptance = {
        "criterion": "batched records/s >= 2x the m=1 single-query "
                     "baseline at equal DB size, for >= 1 inner protocol",
        "best_batch_cell": best,
        "best_batch_records_per_s": batched[best]["records_per_s"],
        "baseline_cell": f"single ({batched[best]['protocol']})",
        "baseline_records_per_s": baselines[batched[best]["protocol"]],
        "speedup": batched[best]["speedup_vs_single"],
        "speedup_ge_2x": batched[best]["speedup_vs_single"] >= 2.0,
    }

    csv = Csv(["cell", "protocol", "path", "m", "n_buckets",
               "scan_rows_per_record", "wall_s", "records_per_s",
               "speedup_vs_single", "label"])
    for label, res in cells.items():
        csv.add(label, res["protocol"], res["path"], res["m"],
                res.get("n_buckets", "-"),
                round(res["scan_rows_per_record"]),
                res["wall_s"], res["records_per_s"],
                res["speedup_vs_single"], "measured-cpu")

    record_json(OUT_JSON, {
        "bench": "batch", "schema": 1,
        "log_n": LOG_N, "item_bytes": ITEM_BYTES,
        "offered_records": N_RECORDS, "reps": REPS,
        "rounds_per_dispatch": ROUNDS_PER_DISPATCH,
        "cells": cells,
        "records_per_s": {k: v["records_per_s"] for k, v in cells.items()},
        "acceptance": acceptance,
    })
    return csv


if __name__ == "__main__":
    print(run().dump())
