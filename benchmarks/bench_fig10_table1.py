"""Paper Figure 10 + Table 1: per-phase latency breakdown.

Phases (paper Algorithm 1): DPF Eval ②, share staging ③ (CPU→DPU copy in
the paper; device transfer here), dpXOR ④⑤, aggregation ⑥.

Paper's finding: CPU-PIR spends 83% in dpXOR; IM-PIR flips it — dpXOR
drops to 16% and DPF eval becomes the bottleneck (76%). Our fused path
goes further: eval and scan are one kernel, so the split is reported for
the phase-split design and the fusion win as a single number.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, timeit
from repro.config import PIRConfig
from repro.core import pir
from repro.core.server import PIRServer
from repro.launch.mesh import make_local_mesh


def run() -> Csv:
    csv = Csv(["design", "phase", "time_ms", "pct"])
    rng = np.random.default_rng(0)
    log_n, batch = 16, 8
    n = 1 << log_n
    cfg = PIRConfig(n_items=n, batch_queries=batch)
    db = jnp.asarray(pir.make_database(rng, n, 32))
    keys, _ = pir.batch_queries(rng, list(range(batch)), cfg)

    # phase-split design (the paper's structure)
    t_eval = timeit(lambda: pir.phase_eval_bits(keys, log_n))
    bits = pir.phase_eval_bits(keys, log_n)
    t_stage = timeit(lambda: jax.device_put(bits))
    t_dpxor = timeit(lambda: pir.phase_dpxor(db, bits))
    t_agg = 1e-6     # XOR of per-shard partials; single-shard here
    total = t_eval + t_stage + t_dpxor + t_agg
    for phase, t in (("dpf_eval", t_eval), ("share_staging", t_stage),
                     ("dpxor", t_dpxor), ("aggregation", t_agg)):
        csv.add("phase-split", phase, t * 1e3, 100 * t / total)

    # fused design (IM-PIR production path)
    mesh = make_local_mesh()
    srv = PIRServer(0, np.asarray(db), cfg, mesh, n_queries=batch,
                    path="fused")
    t_fused = timeit(srv.answer, keys)
    csv.add("fused", "expand+scan", t_fused * 1e3,
            100 * t_fused / total)
    csv.add("fused", "speedup_vs_split_total", total / t_fused, 0.0)
    return csv


if __name__ == "__main__":
    print(run().dump())
