"""Chaos-plane cost model: verified-reconstruction overhead + recovery.

Two questions this bench answers with numbers (DESIGN.md §12):

* **What does integrity cost when nothing is wrong?** The checksummed
  config stores one extra u32 per row (+12.5% GEMM width at 32-byte
  records) and runs ``verify_records`` host-side per reconstructed
  batch. We serve the same offered load through ``SingleServerPIR`` on
  the plain (``pir-smoke-repl``) and checksummed (``pir-smoke-chk``)
  LWE configs and report the steady-state QPS delta — the acceptance
  budget is ≤15% overhead.

* **What does a detected fault cost when something IS wrong?** Recovery
  latency: a 2-replica fleet with a seeded :class:`ChaosInjector`, both
  replicas pre-warmed (compiles excluded), then a pinned session offers
  a load that trips the fault on its first batch. The wall from submit
  to every-answer-byte-correct covers detection (``InjectedFault`` /
  ``IntegrityError``), quarantine, and resubmission on the survivor.

All rows are ``measured-cpu`` wall clock on this container (one core:
the two replicas time-slice, so recovery walls are upper bounds for
disjoint-lane deployments).

Run: PYTHONPATH=src python -m benchmarks.run --only chaos
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Csv, record_json
from repro.chaos import ChaosInjector, FaultEvent, FaultPlan
from repro.configs.pir import PIR_SMOKE_CHK, PIR_SMOKE_REPL
from repro.core import pir
from repro.launch.mesh import make_local_mesh
from repro.replica import metrics as fleet_metrics
from repro.runtime.serve_loop import SingleServerPIR

N_QUERIES = 64                  # offered load per steady-state rep
BUCKET = 8
REPS = 3
OUT_JSON = "BENCH_chaos.json"
SCHEMA = 1
OVERHEAD_BUDGET = 0.15          # acceptance: verify costs <= 15% QPS


# ---------------------------------------------------------------------------
# steady state: verified reconstruction on the healthy path
# ---------------------------------------------------------------------------

def _steady_qps(cfg):
    """Median steady-state QPS of one SingleServerPIR at ``cfg``; every
    answer is checked against the plaintext oracle (a benchmark that
    returns wrong bytes fast would be measuring the wrong thing)."""
    db_host = pir.make_database(np.random.default_rng(0), cfg.n_items,
                                cfg.item_bytes)
    oracle = pir.db_as_bytes(db_host)
    idx = np.random.default_rng(1).integers(
        0, cfg.n_items, size=N_QUERIES).tolist()
    system = SingleServerPIR(db_host, cfg, make_local_mesh(),
                             n_queries=BUCKET, buckets=(BUCKET,))
    try:
        system.query(idx[:BUCKET])           # warm: compile + hint fetch
        walls = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            recs = system.query(idx)
            walls.append(time.perf_counter() - t0)
        for i, rec in zip(idx, recs):
            assert np.array_equal(np.asarray(rec), oracle[i]), \
                f"D[{i}] wrong on the healthy path"
        wall = float(np.median(walls))
        return wall, N_QUERIES / wall
    finally:
        system.close()


# ---------------------------------------------------------------------------
# faulted state: detection -> quarantine -> resubmit, compiles excluded
# ---------------------------------------------------------------------------

def _recovery_point(action):
    """Wall from submit to every-answer-correct with a seeded fault on
    the pinned replica's FIRST post-warm batch. Both replicas serve one
    warm batch first (``at=1`` windows skip it), so the wall measures
    the failover machinery, not XLA compiles."""
    from repro.chaos.smoke import _fleet, _teardown

    if action == "corrupt":
        cfg = PIR_SMOKE_CHK
        plan = FaultPlan(seed=11, events=(
            FaultEvent(seam="replica.serve_step", action="corrupt",
                       target="r0", at=1),))
    else:
        cfg = PIR_SMOKE_REPL
        plan = FaultPlan(seed=7, events=(
            FaultEvent(seam="scheduler.dispatch", action="kill",
                       target="r0", at=1),))
    injector = ChaosInjector(plan)
    router, oracle = _fleet(cfg, injector, np.random.default_rng(0))
    try:
        for rid in ("r0", "r1"):             # warm both lanes (visit 0)
            warm = router.session(f"warm-{rid}")
            warm.replica = rid
            for f in [router.submit(i, session=warm) for i in (1, 2, 3, 4)]:
                f.result()
        victim = router.session("victim")
        victim.replica = "r0"
        idx = [5, 99, 1234, cfg.n_items - 1, 17, 2048, 0, 7]
        t0 = time.perf_counter()
        futs = [router.submit(i, session=victim, deadline_s=600.0)
                for i in idx]
        for i, f in zip(idx, futs):
            assert np.array_equal(np.asarray(f.result()), oracle[i]), \
                f"D[{i}] wrong after {action} recovery"
        wall = time.perf_counter() - t0
        assert action in injector.fired_actions(), \
            f"planned {action} never fired"
        snap = fleet_metrics.snapshot(router)
        return wall, len(idx), snap
    finally:
        _teardown(router)


def run() -> Csv:
    csv = Csv(["mode", "config", "queries", "wall_s", "qps",
               "overhead_pct", "failovers", "integrity_failures", "label"])

    # --- steady state: plain vs checksummed ------------------------------
    wall_off, qps_off = _steady_qps(PIR_SMOKE_REPL)
    wall_on, qps_on = _steady_qps(PIR_SMOKE_CHK)
    overhead = 1.0 - qps_on / qps_off
    csv.add("verify-off", "pir-smoke-repl", N_QUERIES, wall_off, qps_off,
            0.0, 0, 0, "measured-cpu")
    csv.add("verify-on", "pir-smoke-chk", N_QUERIES, wall_on, qps_on,
            overhead * 100.0, 0, 0, "measured-cpu")

    # --- recovery: kill and corrupt, warmed fleets -----------------------
    recovery = {}
    for action in ("kill", "corrupt"):
        wall, n, snap = _recovery_point(action)
        recovery[action] = {
            "queries_in_flight": n,
            "recovery_s": wall,
            "failovers": snap["router"]["failovers"],
            "integrity_failures": snap["router"]["integrity_failures"],
            "zero_lost": True,               # every future resolved
        }
        csv.add(f"recovery-{action}",
                "pir-smoke-chk" if action == "corrupt" else "pir-smoke-repl",
                n, wall, n / wall, 0.0, snap["router"]["failovers"],
                snap["router"]["integrity_failures"], "measured-cpu")

    record_json(OUT_JSON, {
        "bench": "chaos", "schema": SCHEMA,
        "n_items": PIR_SMOKE_REPL.n_items,
        "item_bytes": PIR_SMOKE_REPL.item_bytes,
        "protocol": PIR_SMOKE_REPL.protocol, "bucket": BUCKET,
        "offered_queries": N_QUERIES, "reps": REPS,
        "verify": {
            "qps_plain": qps_off, "qps_checksummed": qps_on,
            "overhead_frac": overhead,
            "stored_row_growth_frac":
                4.0 / PIR_SMOKE_REPL.item_bytes,    # +1 u32 per row
        },
        "recovery": recovery,
        "acceptance": {
            "verify_overhead_frac": overhead,
            "budget_frac": OVERHEAD_BUDGET,
            "within_budget": bool(overhead <= OVERHEAD_BUDGET),
            "note": ("steady-state QPS delta of the checksummed LWE "
                     "config vs plain at identical offered load; "
                     "recovery walls exclude XLA compiles (both lanes "
                     "pre-warmed) and cover detection + quarantine + "
                     "resubmission on one time-sliced CPU core"),
        },
    })
    return csv


if __name__ == "__main__":
    print(run().dump())
