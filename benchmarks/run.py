"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run`` runs everything and prints labeled CSV blocks;
``--only fig9`` runs one. ``--report`` instead audits the persisted JSON
artifacts the benches are registered to produce — printing each record's
provenance line, and SKIPPING (with a reason, never a crash) artifacts
that are missing or carry a stale schema, so a perf-trajectory check
stays usable while the repo grows. Roofline-table regeneration from the
dry-run artifacts lives in ``python -m repro.launch.report`` (reads
results/dryrun.jsonl), not here — these are the paper-figure benches.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCHES = ["fig3", "fig9", "fig10_table1", "fig11", "fig12", "kernels",
           "serving", "protocols", "db_updates", "autotune", "replicas",
           "chaos", "batch"]

#: bench -> (artifact file, keys every readable record must carry).
#: A registered bench without a row here produces no persisted artifact.
ARTIFACTS = {
    "kernels": ("BENCH_kernels.json", ("bench", "label", "cells")),
    "serving": ("BENCH_serving.json", ("bench", "label", "sweep")),
    "protocols": ("BENCH_protocols.json", ("bench", "label", "cells")),
    "db_updates": ("BENCH_db.json", ("bench", "label", "updates")),
    "autotune": ("BENCH_autotune.json", ("bench", "label", "cells")),
    "replicas": ("BENCH_replicas.json",
                 ("bench", "label", "schema", "sweep", "failover",
                  "acceptance")),
    "chaos": ("BENCH_chaos.json",
              ("bench", "label", "schema", "verify", "recovery",
               "acceptance")),
    "batch": ("BENCH_batch.json",
              ("bench", "label", "schema", "cells", "records_per_s",
               "acceptance")),
}


def report(names) -> int:
    """Audit registered artifacts: print a provenance line per record,
    SKIP (don't crash) anything missing, unreadable, or schema-stale —
    a half-regenerated checkout must not take the report down."""
    for name in names:
        if name not in ARTIFACTS:
            continue
        path, required = ARTIFACTS[name]
        try:
            with open(path) as f:
                rec = json.load(f)
        except FileNotFoundError:
            print(f"{name:12s} SKIP (missing {path} — run "
                  f"`python -m benchmarks.run --only {name}`)")
            continue
        except (json.JSONDecodeError, OSError) as e:
            print(f"{name:12s} SKIP (unreadable {path}: "
                  f"{type(e).__name__}: {e})")
            continue
        missing = [k for k in required if k not in rec]
        if missing:
            print(f"{name:12s} SKIP (stale schema in {path}: missing "
                  f"{missing} — regenerate)")
            continue
        # records/s column: benches that measure record throughput carry a
        # {cell: records_per_s} summary — report the best cell inline so
        # the perf trajectory is readable without opening the artifact
        rps = rec.get("records_per_s")
        if isinstance(rps, dict) and rps:
            top = max(rps, key=rps.get)
            rps_col = f"{rps[top]:8.1f} ({top})"
        else:
            rps_col = "       -"
        print(f"{name:12s} OK   {path} records/s={rps_col} "
              f"label={rec.get('label')} platform={rec.get('platform')}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=BENCHES)
    ap.add_argument("--report", action="store_true",
                    help="audit persisted JSON artifacts instead of "
                         "running benches (skip-and-report on missing/"
                         "stale files)")
    args = ap.parse_args(argv)
    names = [args.only] if args.only else BENCHES
    if args.report:
        return report(names)
    rc = 0
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            csv = mod.run()
        except Exception as e:      # report and continue
            print(f"== bench_{name}: FAILED {type(e).__name__}: {e}",
                  file=sys.stderr)
            rc = 1
            continue
        print(f"== bench_{name} ({time.time() - t0:.1f}s) ==")
        print(csv.dump())
        print()
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
