"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run`` runs everything and prints labeled CSV blocks;
``--only fig9`` runs one. Roofline-table regeneration from the dry-run
artifacts lives in ``python -m repro.launch.report`` (reads
results/dryrun.jsonl), not here — these are the paper-figure benches.
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = ["fig3", "fig9", "fig10_table1", "fig11", "fig12", "kernels",
           "serving", "protocols", "db_updates", "autotune"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=BENCHES)
    args = ap.parse_args(argv)
    names = [args.only] if args.only else BENCHES
    rc = 0
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            csv = mod.run()
        except Exception as e:      # report and continue
            print(f"== bench_{name}: FAILED {type(e).__name__}: {e}",
                  file=sys.stderr)
            rc = 1
            continue
        print(f"== bench_{name} ({time.time() - t0:.1f}s) ==")
        print(csv.dump())
        print()
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
