"""Paper Figure 12: cross-platform comparison (CPU vs GPU vs PIM/TPU).

The paper measures UPMEM (2,048 DPUs, ~1.8 TB/s) vs an RTX 4090 (1.01
TB/s) vs a Xeon (~0.1 TB/s street bandwidth) and attributes the ordering
to aggregate memory bandwidth — dpXOR is bandwidth-limited (Fig. 3b).

We reproduce that reasoning as a modeled-v5e table: dpXOR step time =
DB_bytes / aggregate_bw for each platform, against the paper's platforms
and our target (a v5e pod slice, HBM 819 GB/s/chip). The measured-cpu
column anchors the model on this container's silicon. The `paper_ratio`
column recomputes the paper's headline (PIM/CPU > 3.7×) under the model
for the paper's own 8 GB DB.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import Csv, timeit
from repro.config import PIRConfig
from repro.core import pir

PLATFORMS = [
    # name, aggregate bandwidth (bytes/s)
    ("xeon-2s (paper CPU)", 0.12e12),
    ("rtx-4090 (paper GPU)", 1.01e12),
    ("upmem-2048dpu (paper PIM)", 1.43e12),   # 2048 × 0.7 GB/s
    ("tpu-v5e-16 (2 hosts)", 16 * 819e9),
    ("tpu-v5e-256 (this repo's pod)", 256 * 819e9),
]


def run() -> Csv:
    csv = Csv(["platform", "db_gb", "t_dpxor_modeled_ms",
               "qps_modeled_batch32", "speedup_vs_paper_cpu"])
    db_bytes = 8 * (1 << 30)           # the paper's 8 GB point
    base = None
    for name, bw in PLATFORMS:
        t = db_bytes / bw              # one all-for-one scan
        qps = 32 / (32 * t)            # per-query scan; batch amortizes keys
        if base is None:
            base = t
        csv.add(name, 8.0, t * 1e3, 1.0 / t, base / t)

    # measured anchor: scan rate on this container
    rng = np.random.default_rng(0)
    n = 1 << 16
    cfg = PIRConfig(n_items=n, batch_queries=1)
    db = jnp.asarray(pir.make_database(rng, n, 32))
    keys, _ = pir.batch_queries(rng, [5], cfg)
    bits = pir.phase_eval_bits(keys, 16)
    t = timeit(lambda: pir.phase_dpxor(db, bits))
    bw_here = n * 32 / t
    csv.add("this-container (measured-cpu)", n * 32 / (1 << 30),
            t * 1e3, 1.0 / t, bw_here / 0.12e12)
    return csv


if __name__ == "__main__":
    print(run().dump())
