"""Replica-plane scaling: aggregate QPS vs replica count + failover cost.

IM-PIR's throughput claim is linear scaling in the number of clusters,
each scanning its own DB replica (paper Take-away 5). This bench drives
that topology through the front-tier :class:`Router` at equal offered
load and reports aggregate QPS at 1 and 2 replicas, plus the failover
recovery cost (kill one replica mid-load, time until every already-
submitted query has resolved on the survivor).

Measurement honesty on this container: there is ONE physical CPU core,
so two *real* replicas time-slice the same silicon and aggregate QPS
cannot exceed 1x — the ``real-fleet`` rows record exactly that (routing
and failover overhead at equal load, labeled ``measured-cpu``). The
scaling claim is about disjoint compute lanes, so the ``lane-replay``
rows re-run the identical router/scheduler stack with each replica's
dispatch replaying the *measured* serve-step occupancy of the real
system as a GIL-releasing sleep — the replica lanes then overlap the way
disjoint devices do. Those rows are labeled ``lane-replay(measured-cpu
step)``: real control plane, real measured per-step cost, modeled lane
disjointness.

Run: PYTHONPATH=src python -m benchmarks.run --only replicas
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Csv, percentile, record_json
from repro.configs.pir import PIR_SMOKE_REPL
from repro.core import pir
from repro.replica import Router, ServeReplica
from repro.replica import metrics as fleet_metrics
from repro.runtime.elastic import carve_submeshes
from repro.runtime.serve_loop import (AnswerFuture, QueryScheduler,
                                      ServeStats)

N_QUERIES = 64                  # offered load per sweep point
BUCKET = 4
REPS = 3
OUT_JSON = "BENCH_replicas.json"
SCHEMA = 1


# ---------------------------------------------------------------------------
# lane-replay replica: real scheduler/router stack, sleep-replayed step
# ---------------------------------------------------------------------------

class _LaneDB:
    """Epoch counter with the subscribe/stage/publish surface the
    router's propagation path needs (contents are not what this bench
    measures — the scatter cost is bench_db_updates' subject)."""

    def __init__(self):
        self.epoch = 0
        self._staged = 0
        self._subs = []

    def subscribe(self, fn):
        self._subs.append(fn)
        return lambda: self._subs.remove(fn)

    def stage(self, rows, vals):
        self._staged += 1
        return self._staged

    def publish(self):
        if not self._staged:
            return self.epoch
        self.epoch += 1
        self._staged = 0
        for fn in list(self._subs):
            fn(type("D", (), {"epoch": self.epoch})())
        return self.epoch


class LaneReplica:
    """ServeReplica surface over a ``QueryScheduler`` whose dispatch
    sleeps for the measured serve-step occupancy: sleeps release the
    GIL, so N lanes overlap exactly the way N disjoint devices do."""

    def __init__(self, rid: str, step_s: float):
        self.id = rid
        self.db = _LaneDB()
        self.lost = False

        def dispatch(staged):
            time.sleep(step_s)          # the measured step, on "our" lane
            return staged

        self.scheduler = QueryScheduler(
            collate=list, stage=lambda p: p, dispatch=dispatch,
            finalize=lambda raw, n: raw[:n], buckets=(BUCKET,),
            max_wait_s=0.001,
            epoch_of=lambda raw: self.db.epoch)

    @property
    def epoch(self):
        return self.db.epoch

    @property
    def stats(self) -> ServeStats:
        return self.scheduler.stats

    @property
    def queue_depth(self):
        return self.scheduler.queue_depth

    @property
    def running(self):
        return self.scheduler.running

    def submit(self, index):
        return self.scheduler.submit(index)

    def resubmit(self, item, future):
        return self.scheduler.submit(item, future=future)

    def start(self):
        self.lost = False
        self.scheduler.start()

    def close(self):
        self.scheduler.stop()

    def drain_handoff(self):
        pairs = self.scheduler.drain_handoff()
        self.scheduler.stop()
        return pairs

    def kill(self, reason="bench kill"):
        from repro.replica import ReplicaLost
        exc = ReplicaLost(self.id, reason)
        self.lost = True
        self.scheduler.kill(exc)
        return exc

    def set_heartbeat(self, fn):
        self.scheduler.heartbeat = fn

    def subscribe_epochs(self, fn):
        return self.db.subscribe(lambda d: fn(d.epoch))

    def export_plans(self):
        return {}

    def warm_start(self, plans, persist=False):
        return 0


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def _drive(router: Router, indices: List[int], timeout: float = 600.0):
    """Offer the whole load up front (saturated regime), wait for every
    answer; wall covers submit-to-last-resolve."""
    t0 = time.perf_counter()
    futs = [router.submit(i) for i in indices]
    for f in futs:
        f.result(timeout=timeout)
    return time.perf_counter() - t0, futs


def _fleet_point(router: Router, replicas, indices, reps=REPS):
    walls = []
    for _ in range(reps):
        wall, _ = _drive(router, indices)
        walls.append(wall)
    lat = [x for r in replicas for x in r.stats.latencies]
    return float(np.median(walls)), lat


def _lane_fleet(n: int, step_s: float, router_kw=None):
    router = Router(rng=np.random.default_rng(0), base_delay=0.001,
                    max_delay=0.01, **(router_kw or {}))
    reps = [router.attach(LaneReplica(f"lane{i}", step_s))
            for i in range(n)]
    return router, reps


def run() -> Csv:
    cfg = PIR_SMOKE_REPL
    rng = np.random.default_rng(3)
    db_host = pir.make_database(np.random.default_rng(0), cfg.n_items,
                                cfg.item_bytes)
    indices = rng.integers(0, cfg.n_items, size=N_QUERIES).tolist()
    kw = dict(n_queries=BUCKET, buckets=(BUCKET,), max_wait_s=0.001)

    csv = Csv(["mode", "replicas", "offered_queries", "wall_s", "qps",
               "speedup_vs_1", "p50_step_ms", "p99_step_ms", "failovers",
               "label"])
    sweep = {"real-fleet": {}, "lane-replay": {}}

    # --- real fleet: 1 then 2 replicas on the one physical core ---------
    meshes = carve_submeshes(2, model_axis=1)
    r0 = ServeReplica("r0", db_host, cfg, meshes[0], **kw)
    r1 = ServeReplica("r1", db_host, cfg, meshes[1], **kw)
    real_qps = {}
    step_s = None
    for n, members in ((1, [r0]), (2, [r0, r1])):
        router = Router(rng=np.random.default_rng(0), base_delay=0.001,
                        max_delay=0.01)
        for r in members:
            router.attach(r)
        _drive(router, indices[:8])              # warm (hint fetch, jit)
        for r in members:                        # fresh stats per point
            r.scheduler.stats = ServeStats()
        wall, lat = _fleet_point(router, members, indices)
        qps = N_QUERIES / wall
        real_qps[n] = qps
        if n == 1:
            step_s = float(np.median(lat))       # measured step occupancy
        csv.add("real-fleet", n, N_QUERIES, wall, qps,
                qps / real_qps[1], percentile(lat, 50) * 1e3,
                percentile(lat, 99) * 1e3, router.failovers,
                "measured-cpu")
        sweep["real-fleet"][str(n)] = {
            "wall_s": wall, "qps": qps, "speedup_vs_1": qps / real_qps[1],
            "p50_step_ms": percentile(lat, 50) * 1e3,
            "failovers": router.failovers,
        }
        for rid in list(router.replicas):
            router.detach(rid)

    # --- lane-replay: measured step on disjoint lanes --------------------
    replay_qps = {}
    for n in (1, 2):
        router, lanes = _lane_fleet(n, step_s)
        wall, lat = _fleet_point(router, lanes, indices)
        qps = N_QUERIES / wall
        replay_qps[n] = qps
        csv.add("lane-replay", n, N_QUERIES, wall, qps,
                qps / replay_qps[1], percentile(lat, 50) * 1e3,
                percentile(lat, 99) * 1e3, router.failovers,
                "lane-replay(measured-cpu step)")
        sweep["lane-replay"][str(n)] = {
            "wall_s": wall, "qps": qps, "speedup_vs_1": qps / replay_qps[1],
            "step_s_replayed": step_s, "failovers": router.failovers,
        }
        for r in lanes:
            r.close()

    # --- failover recovery: kill one lane mid-load ----------------------
    router, lanes = _lane_fleet(2, step_s)
    router.update([0], np.zeros((1, 8), np.uint32))
    router.publish()                             # epochs move: lag visible
    session = router.session("victim")
    session.replica = "lane0"
    futs = [router.submit(i, session=session) for i in indices[:32]]
    t_kill = time.perf_counter()
    lanes[0].kill()
    for f in futs:
        f.result(timeout=600.0)
    recovery_s = time.perf_counter() - t_kill
    snap = fleet_metrics.snapshot(router)
    csv.add("failover", 2, 32, recovery_s, 32 / recovery_s, 1.0,
            step_s * 1e3, step_s * 1e3, router.failovers,
            "lane-replay(measured-cpu step)")
    for r in lanes:
        if not r.lost:
            r.close()

    record_json(OUT_JSON, {
        "bench": "replicas", "schema": SCHEMA,
        "config": "pir-smoke-repl", "n_items": cfg.n_items,
        "protocol": cfg.protocol, "bucket": BUCKET,
        "offered_queries": N_QUERIES, "reps": REPS,
        "measured_step_s": step_s,
        "sweep": sweep,
        "failover": {
            "queries_in_flight": 32,
            "recovery_s": recovery_s,
            "failovers": snap["router"]["failovers"],
            "resubmit_attempts": snap["router"]["retry"]["attempts"],
            "zero_lost": True,                   # every future resolved
            "per_replica": {r["id"]: {"epoch_lag": r["epoch_lag"],
                                      "state": r["state"],
                                      "answered": r["answered"]}
                            for r in snap["replicas"]},
        },
        "acceptance": {
            "qps_2rep_over_1rep_lane_replay": replay_qps[2] / replay_qps[1],
            "qps_2rep_over_1rep_real": real_qps[2] / real_qps[1],
            "note": ("lane-replay models disjoint replica lanes (the "
                     "quantity IM-PIR scales) by replaying the measured "
                     "serve-step occupancy; real-fleet rows share the "
                     "container's single core and are reported unscaled"),
        },
    })
    return csv


if __name__ == "__main__":
    print(run().dump())
