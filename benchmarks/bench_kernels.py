"""Kernel-path shootout: materialize vs fused-jnp vs fused-scan-pallas.

The §Perf companion to the megakernel (``kernels/fused_scan.py``,
DESIGN.md §13): for each (protocol, bucket) cell, time the three answer
paths on the real (db_view, bucket) shapes — the same jitted
``answer_local`` the tuner measures — and report, per path,

  * QPS (bucket / median wall),
  * the modeled HBM bytes of one answer step
    (``engine.predicted_step_bytes`` — the megakernel's headline is that
    its DB term is per *batch*, not per query), and
  * the achieved-vs-peak bandwidth fraction
    (``analysis/roofline.py achieved_fraction``) — the roofline
    verification number. On this container the roof is the nominal CPU
    figure and rows are labeled measured-cpu; on a TPU the same bench
    judges against the v5e HBM roof.

The tuned row re-reports the measured tuner's pick for the cell
(heuristic always candidate #0, so tuned QPS >= heuristic QPS by
construction). Alongside, the original per-kernel microbenches (dpxor /
ggm_expand / pir_matmul) are kept as layout-true bytes-per-call rows.

Run: PYTHONPATH=src python -m benchmarks.run --only kernels
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import Csv, record_json, timeit
from repro import engine
from repro.analysis import roofline
from repro.config import PIRConfig
from repro.core import protocol as protocol_mod
from repro.engine.tuner import (TuneBudget, candidate_plans,
                                heuristic_plan, plan_label)
from repro.kernels import ops

LOG_N = 12                      # 4096 records x 32 B (CPU-container scale)
BUCKET = 8
ITEM_BYTES = 32
OUT_JSON = "BENCH_kernels.json"

#: per-cell tuning budget: 4 candidates per kernel family reaches the
#: large-tile fused-pallas points (the measured winners on this
#: container) while keeping the interpret-mode compile bill ~2-3 min per
#: cell; the tuner's winner is persisted to the plan cache with
#: provenance="tuned".
BUDGET = TuneBudget(max_candidates=4, warmup=1, iters=3,
                    max_seconds=300.0)

CELLS = [
    ("xor-dpf-2", PIRConfig(n_items=1 << LOG_N, item_bytes=ITEM_BYTES)),
    ("additive-dpf-2", PIRConfig(n_items=1 << LOG_N, item_bytes=ITEM_BYTES,
                                 protocol="additive-dpf-2")),
]

#: reporting buckets: label -> plan.expand values folded into it
PATH_OF_EXPAND = {"materialize": "materialize", "fused": "fused-jnp",
                  "fused-pallas": "fused-pallas"}


def _plans_by_label(cfg, bucket):
    """label -> plan for every plan the tuner might have timed."""
    out = {}
    for p in [heuristic_plan(cfg, bucket)] + candidate_plans(cfg, bucket):
        out.setdefault(plan_label(p), p)
    return out


def run() -> Csv:
    be = engine.probe_backend()
    peak = roofline.peak_bytes_per_s(be)
    label = "measured-cpu" if be == "cpu" else f"measured-{be}"
    csv = Csv(["cell", "path", "plan", "qps", "modeled_mb",
               "achieved_frac_pct", "label"])
    cache = engine.plan_cache()
    cells = {}
    for name, cfg in CELLS:
        proto = protocol_mod.get(cfg.protocol)
        shape = engine.problem_shape(cfg, BUCKET)
        res = engine.tune(cfg, BUCKET, budget=BUDGET, cache=cache)
        by_label = _plans_by_label(cfg, BUCKET)
        # fold measured labels into the three comparable paths, keeping
        # each path's best (min-wall) representative
        paths = {}
        for lbl, wall in res.timings.items():
            plan = by_label.get(lbl)
            if plan is None:
                continue
            path = PATH_OF_EXPAND.get(plan.expand, plan.expand)
            if path in paths and paths[path]["wall_s"] <= wall:
                continue
            step_bytes = engine.predicted_step_bytes(
                plan, proto.share_kind, shape)
            paths[path] = {
                "plan": lbl, "wall_s": wall, "qps": BUCKET / wall,
                "modeled_bytes": step_bytes,
                "achieved_frac": roofline.achieved_fraction(
                    step_bytes, wall, backend=be),
            }
        for path, row in sorted(paths.items()):
            csv.add(f"{name}/b{BUCKET}", path, row["plan"], row["qps"],
                    row["modeled_bytes"] / (1 << 20),
                    100.0 * row["achieved_frac"], label)
        tuned_path = PATH_OF_EXPAND.get(res.plan.expand, res.plan.expand)
        cells[f"{name}/b{BUCKET}"] = {
            "protocol": cfg.protocol, "bucket": BUCKET,
            "paths": paths,
            "tuned_path": tuned_path,
            "tuned_plan": plan_label(res.plan),
            "tuned_qps": BUCKET / res.tuned_s,
            "heuristic_plan": plan_label(res.heuristic),
            "heuristic_qps": BUCKET / res.heuristic_s,
            "n_candidates": res.n_candidates, "n_timed": res.n_timed,
            "n_pruned": res.n_pruned,
        }
    cache.save()

    record_json(OUT_JSON, {
        "bench": "kernels",
        "log_n": LOG_N, "item_bytes": ITEM_BYTES, "bucket": BUCKET,
        "backend": be, "peak_bytes_per_s": peak,
        "cells": cells,
        "micro": _micro_rows(csv),
    })
    return csv


def _micro_rows(csv: Csv) -> dict:
    """The original per-kernel microbenches (layout-true bytes/call)."""
    rng = np.random.default_rng(0)
    micro = {}

    q, r, w = 8, 1 << 14, 8
    db_t = jnp.asarray(rng.integers(0, 1 << 32, size=(w, r),
                                    dtype=np.uint32))
    bits = jnp.asarray(rng.integers(0, 2, size=(q, r), dtype=np.uint32))
    t = timeit(lambda: ops.dpxor_transposed(db_t, bits, tile_r=4096))
    micro["dpxor"] = {"shape": f"q{q}_r{r}_w{w}", "us_per_call": t * 1e6,
                      "mb_touched": (db_t.size + bits.size) * 4 / (1 << 20)}

    n = 1 << 12
    seeds = jnp.asarray(rng.integers(0, 1 << 32, size=(n, 4),
                                     dtype=np.uint32))
    tb = jnp.asarray(rng.integers(0, 2, size=(n,), dtype=np.uint32))
    cw_s = jnp.asarray(rng.integers(0, 1 << 32, size=(4,),
                                    dtype=np.uint32))
    cw_t = jnp.asarray(rng.integers(0, 2, size=(2,), dtype=np.uint32))
    t = timeit(lambda: ops.ggm_expand(seeds, tb, cw_s, cw_t))
    micro["ggm_expand"] = {"shape": f"n{n}", "us_per_call": t * 1e6,
                           "mb_touched": seeds.size * 4 * 3 / (1 << 20)}

    q2, r2, l2 = 8, 1 << 12, 128
    s = jnp.asarray(rng.integers(-128, 128, size=(q2, r2), dtype=np.int8))
    d = jnp.asarray(rng.integers(-128, 128, size=(r2, l2), dtype=np.int8))
    t = timeit(lambda: ops.pir_gemm(s, d))
    micro["pir_matmul"] = {"shape": f"q{q2}_r{r2}_l{l2}",
                           "us_per_call": t * 1e6,
                           "mb_touched": (s.size + d.size) / (1 << 20)}

    for k, v in micro.items():
        csv.add(f"micro/{k}", "-", v["shape"], 0.0,
                v["mb_touched"], 0.0, "micro")
    return micro


if __name__ == "__main__":
    print(run().dump())
