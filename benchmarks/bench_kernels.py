"""Per-kernel microbenchmarks (interpret-mode on CPU; layout sanity).

Numbers here are *correctness-path* timings — Mosaic compilation on a real
TPU is the performance target; the interesting derived column is bytes per
call (the kernel's HBM-traffic contract), which is layout-true.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import Csv, timeit
from repro.kernels import ops


def run() -> Csv:
    csv = Csv(["kernel", "shape", "us_per_call", "mb_touched"])
    rng = np.random.default_rng(0)

    q, r, w = 8, 1 << 14, 8
    db_t = jnp.asarray(rng.integers(0, 1 << 32, size=(w, r),
                                    dtype=np.uint32))
    bits = jnp.asarray(rng.integers(0, 2, size=(q, r), dtype=np.uint32))
    t = timeit(lambda: ops.dpxor_transposed(db_t, bits, tile_r=4096))
    csv.add("dpxor", f"q{q}_r{r}_w{w}", t * 1e6,
            (db_t.size + bits.size) * 4 / (1 << 20))

    n = 1 << 12
    seeds = jnp.asarray(rng.integers(0, 1 << 32, size=(n, 4),
                                     dtype=np.uint32))
    tb = jnp.asarray(rng.integers(0, 2, size=(n,), dtype=np.uint32))
    cw_s = jnp.asarray(rng.integers(0, 1 << 32, size=(4,), dtype=np.uint32))
    cw_t = jnp.asarray(rng.integers(0, 2, size=(2,), dtype=np.uint32))
    t = timeit(lambda: ops.ggm_expand(seeds, tb, cw_s, cw_t))
    csv.add("ggm_expand", f"n{n}", t * 1e6, seeds.size * 4 * 3 / (1 << 20))

    q2, r2, l2 = 8, 1 << 12, 128
    s = jnp.asarray(rng.integers(-128, 128, size=(q2, r2), dtype=np.int8))
    d = jnp.asarray(rng.integers(-128, 128, size=(r2, l2), dtype=np.int8))
    t = timeit(lambda: ops.pir_gemm(s, d))
    csv.add("pir_matmul", f"q{q2}_r{r2}_l{l2}", t * 1e6,
            (s.size + d.size) / (1 << 20))
    return csv


if __name__ == "__main__":
    print(run().dump())
