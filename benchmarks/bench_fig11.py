"""Paper Figure 11: DPU-clustering effect on throughput.

Cluster semantics (paper §3.4): c clusters each hold a full DB replica and
answer disjoint query groups concurrently; 1 cluster = all DPUs scan one
query at a time. On this 1-core container concurrency cannot be measured,
so we measure the *work shape* (per-cluster batch of Q/c queries over the
full DB) and model c-way overlap: t_cluster(c) = t_measured(Q/c); the
paper's observed 1.35× comes from exactly this query-parallelism minus
scheduling overheads.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, timeit
from repro.config import PIRConfig
from repro.core import pir
from repro.core.server import PIRServer
from repro.launch.mesh import make_local_mesh


def run() -> Csv:
    csv = Csv(["n_clusters", "batch_total", "per_cluster_batch",
               "t_cluster_ms", "qps_modeled", "speedup_vs_1cluster"])
    rng = np.random.default_rng(0)
    log_n, q_total = 14, 32
    n = 1 << log_n
    db = pir.make_database(rng, n, 32)
    mesh = make_local_mesh()
    base_qps = None
    for c in (1, 2, 4, 8):
        q_local = q_total // c
        cfg = PIRConfig(n_items=n, batch_queries=q_local, clusters=c)
        srv = PIRServer(0, db, cfg, mesh, n_queries=q_local, path="fused")
        keys, _ = pir.batch_queries(rng, list(range(q_local)), cfg)
        t = timeit(srv.answer, keys)
        qps = q_total / t          # c clusters run their groups in parallel
        if base_qps is None:
            base_qps = qps
        csv.add(c, q_total, q_local, t * 1e3, qps, qps / base_qps)
    return csv


if __name__ == "__main__":
    print(run().dump())
