"""Online-update throughput + post-publish serving vs the static baseline.

The scenario the paper excludes (§3.3 freezes the DB after preloading),
measured three ways on the database plane (DESIGN.md §8):

  update     stage+publish wall time and host->device bytes for R-row
             deltas (R = 1, 16, 256), i.e. the epoched delta path;
  repreload  the static-system alternative for the same R rows: rebuild
             and re-place the whole database (what a frozen-DB design
             must do to serve new data);
  serving    QPS through one compiled bucket before any update and after
             a publish — the swap must not stall serving or trigger a
             recompile (answers come off the same cached executable).

The delta path wins on two axes recorded to BENCH_db.json: bytes moved
(O(R·item_bytes) vs O(db_bytes)) and wall time per published row.

Run: PYTHONPATH=src python -m benchmarks.run --only db_updates
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Csv, record_json
from repro.config import PIRConfig
from repro.core import pir
from repro.db import ShardedDatabase
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import MultiServerPIR

LOG_N = 12                      # 4096 records x 32 B (CPU-container scale)
BUCKET = 4                      # the single compiled bucket
N_QUERIES = 32                  # queries per serving measurement
DELTA_SIZES = (1, 16, 256)
REPS = 3
OUT_JSON = "BENCH_db.json"


def _publish_delta(db: ShardedDatabase, rng, r: int) -> float:
    rows = rng.choice(db.spec.n_items, size=r, replace=False)
    vals = rng.integers(0, 1 << 32, size=(r, db.spec.item_words),
                        dtype=np.uint32)
    t0 = time.perf_counter()
    db.stage(rows, vals)
    db.publish()
    jax.block_until_ready(db.view("words"))
    return time.perf_counter() - t0


def _repreload(host: np.ndarray, cfg, mesh, rng, r: int) -> float:
    """Static baseline: apply the same R rows by full re-placement."""
    rows = rng.choice(cfg.n_items, size=r, replace=False)
    vals = rng.integers(0, 1 << 32, size=(r, cfg.item_bytes // 4),
                        dtype=np.uint32)
    t0 = time.perf_counter()
    host = host.copy()                      # a frozen DB mutates on host…
    host[rows] = vals
    db = ShardedDatabase(host, cfg, mesh)   # …then re-preloads everything
    jax.block_until_ready(db.view("words"))
    return time.perf_counter() - t0


def _qps(system: MultiServerPIR, indices) -> float:
    t0 = time.perf_counter()
    out = system.query(indices)
    assert out.shape[0] == len(indices)
    return len(indices) / (time.perf_counter() - t0)


def run() -> Csv:
    cfg = PIRConfig(n_items=1 << LOG_N, item_bytes=32,
                    batch_queries=BUCKET)
    mesh = make_local_mesh()
    rng = np.random.default_rng(0)
    host = pir.make_database(rng, cfg.n_items, cfg.item_bytes)
    system = MultiServerPIR(host, cfg, mesh, path="fused",
                            n_queries=BUCKET, buckets=(BUCKET,),
                            client_rng=np.random.default_rng(1))
    indices = rng.integers(0, cfg.n_items, size=N_QUERIES).tolist()
    system.query(indices[:BUCKET])          # warm the compiled bucket

    csv = Csv(["metric", "rows", "wall_ms", "rows_per_s", "h2d_bytes",
               "qps", "label"])
    results = {"db_bytes": cfg.db_bytes}

    qps_static = _qps(system, indices)
    csv.add("serving_pre_update", 0, 0.0, 0.0, 0, qps_static,
            "measured-cpu")

    update_cells = {}
    for r in DELTA_SIZES:
        walls, base_walls = [], []
        for _ in range(REPS):
            before = system.db.stats.update_h2d_bytes
            walls.append(_publish_delta(system.db, rng, r))
            delta_bytes = system.db.stats.update_h2d_bytes - before
            base_walls.append(_repreload(host, cfg, mesh, rng, r))
        wall = float(np.median(walls))
        base = float(np.median(base_walls))
        csv.add("delta_publish", r, wall * 1e3, r / wall, delta_bytes,
                0.0, "measured-cpu")
        csv.add("full_repreload", r, base * 1e3, r / base, cfg.db_bytes,
                0.0, "measured-cpu")
        update_cells[str(r)] = {
            "publish_wall_s": wall, "publish_rows_per_s": r / wall,
            "publish_h2d_bytes": int(delta_bytes),
            "repreload_wall_s": base,
            "repreload_h2d_bytes": cfg.db_bytes,
            "speedup_vs_repreload": base / wall,
        }

    n_compiles_before = [s.n_compiles for s in system.servers]
    qps_post = _qps(system, indices)
    csv.add("serving_post_publish", 0, 0.0, 0.0, 0, qps_post,
            "measured-cpu")
    assert [s.n_compiles for s in system.servers] == n_compiles_before, \
        "publish must not trigger serve-step recompiles"

    results.update({
        "updates": update_cells,
        "serving": {
            "qps_static": qps_static, "qps_post_publish": qps_post,
            "post_publish_ratio": qps_post / qps_static,
        },
    })
    record_json(OUT_JSON, {
        "bench": "db_updates", "log_n": LOG_N, "item_bytes": 32,
        "bucket": BUCKET, "offered_queries": N_QUERIES, "reps": REPS,
        "protocol": cfg.protocol, **results,
    })
    return csv


if __name__ == "__main__":
    print(run().dump())
