"""Engine-plane throughput: measured tuned plans vs the plan_for heuristic.

The §Perf companion to the kernel engine (``src/repro/engine/``,
DESIGN.md §9): for every (protocol, bucket) cell the autotuner enumerates
the feasible candidate plans, times each on the real (db_view, bucket)
shapes, and keeps the winner. This bench reports the winner's QPS next to
the heuristic's **from the same measurement session**, so the comparison
is noise-consistent: the heuristic is always candidate #0, hence
``tuned_qps >= heuristic_qps`` by construction — the interesting number is
*how much* headroom measurement finds over folklore on this backend.

The grid covers both share algebras (the XOR scan family and the additive
GEMM) at two bucket sizes; the k-party ring protocol reuses the same XOR
scan kernels per component, so its plan space is the xor-dpf-2 one
(measured end-to-end in ``bench_protocols``). Tuned winners are persisted
to the plan cache (``REPRO_PLAN_CACHE``, default
``results/plan_cache.json``), so subsequent ``path=None/"auto"`` servers
in this working directory pick them up.

Run: PYTHONPATH=src python -m benchmarks.run --only autotune
"""
from __future__ import annotations

from benchmarks.common import Csv, record_json
from repro import engine
from repro.config import PIRConfig
from repro.engine.tuner import TuneBudget, plan_label

LOG_N = 12                      # 4096 records x 32 B (CPU-container scale)
BUCKETS = (2, 8)                # two compiled bucket sizes per protocol
OUT_JSON = "BENCH_autotune.json"

#: per-cell tuning budget. Deliberately small on this container: XLA
#: compiles of the interpret-mode Pallas bodies cost ~30 s each, so one
#: candidate per kernel family keeps the whole grid inside the bench
#: budget; on a real TPU (sub-second Mosaic compiles) raise
#: max_candidates to sweep the full tile ladders.
BUDGET = TuneBudget(max_candidates=1, warmup=1, iters=3, max_seconds=120.0)

CELLS = [
    ("xor-dpf-2",
     PIRConfig(n_items=1 << LOG_N, item_bytes=32)),
    ("additive-dpf-2",
     PIRConfig(n_items=1 << LOG_N, item_bytes=32,
               protocol="additive-dpf-2")),
]


def run() -> Csv:
    cache = engine.plan_cache()
    csv = Csv(["cell", "protocol", "bucket", "heuristic_plan", "tuned_plan",
               "heuristic_qps", "tuned_qps", "speedup", "candidates",
               "timed", "label"])
    cells = {}
    for name, cfg in CELLS:
        for bucket in BUCKETS:
            res = engine.tune(cfg, bucket, budget=BUDGET, cache=cache)
            h_qps = bucket / res.heuristic_s
            t_qps = bucket / res.tuned_s
            key = f"{name}/b{bucket}"
            cells[key] = {
                "protocol": cfg.protocol, "bucket": bucket,
                "heuristic_plan": plan_label(res.heuristic),
                "tuned_plan": plan_label(res.plan),
                "heuristic_s": res.heuristic_s, "tuned_s": res.tuned_s,
                "heuristic_qps": h_qps, "tuned_qps": t_qps,
                "speedup": res.speedup,
                "timings": res.timings,
                "n_candidates": res.n_candidates, "n_timed": res.n_timed,
            }
            csv.add(key, cfg.protocol, bucket, plan_label(res.heuristic),
                    plan_label(res.plan), h_qps, t_qps, res.speedup,
                    res.n_candidates, res.n_timed, "measured-cpu")
    cache.save()

    record_json(OUT_JSON, {
        "bench": "autotune",
        "log_n": LOG_N, "item_bytes": 32, "buckets": list(BUCKETS),
        "budget": {"max_candidates": BUDGET.max_candidates,
                   "iters": BUDGET.iters, "warmup": BUDGET.warmup},
        "backend": engine.probe_backend(),
        "plan_cache": cache.path,
        "cells": cells,
    })
    return csv


if __name__ == "__main__":
    print(run().dump())
