"""Paper Figure 3: execution-time breakdown of DPF-PIR operations.

Phases per the paper: client key generation (Gen), server key evaluation
(Eval over the full domain), and dpXOR (select-XOR scan over the DB).
The paper's finding at 4 GB: dpXOR ≈ 10× Eval ≈ 10,000× Gen, with dpXOR
memory-bound. Scaled to this container (≤ 2^18 items); all measured-cpu.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, timeit
from repro.config import PIRConfig
from repro.core import dpf, pir


def run() -> Csv:
    csv = Csv(["n_items", "db_mb", "t_keygen_us", "t_eval_us",
               "t_dpxor_us", "dpxor_over_eval"])
    rng = np.random.default_rng(0)
    for log_n in (12, 14, 16, 18):
        n = 1 << log_n
        cfg = PIRConfig(n_items=n)
        db = jnp.asarray(pir.make_database(rng, n, 32))

        pir.query_gen(rng, 1, cfg)            # warm the per-depth jits
        t0 = time.perf_counter()
        q = pir.query_gen(rng, n // 3, cfg)
        t_keygen = time.perf_counter() - t0

        k0 = dpf.stack_keys([q.keys[0]])
        t_eval = timeit(lambda: pir.phase_eval_bits(k0, log_n))
        bits = pir.phase_eval_bits(k0, log_n)
        t_dpxor = timeit(lambda: pir.phase_dpxor(db, bits))

        csv.add(n, n * 32 / (1 << 20), t_keygen * 1e6, t_eval * 1e6,
                t_dpxor * 1e6,
                t_dpxor / max(t_eval, 1e-12))
    return csv


if __name__ == "__main__":
    print(run().dump())
