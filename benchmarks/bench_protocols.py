"""Protocol-plane throughput: registered protocols x plan paths, equal load.

The §Perf companion to the protocol registry (``core/protocol.py``,
DESIGN.md §7): every registered protocol serves the IDENTICAL offered load
— the same pre-generated query-index stream, fully enqueued up front
(saturated-throughput regime, client-side Gen off the clock) — through the
same ``MultiServerPIR`` facade and ``QueryScheduler``. What varies is the
(protocol, plan) cell:

  xor-dpf-2 / materialize   paper-faithful phase split (eval bits -> scan)
  xor-dpf-2 / fused         chunked expand+scan, bits never hit HBM
  additive-dpf-2 / gemm     Z_256 shares, one int8 GEMM per batch
  xor-dpf-k(3) / fused      3-party XOR ring (k-of-k shares)
  lwe-simple-1 / auto       single-server LWE (SimplePIR-style): int32
                            GEMM answers via SingleServerPIR; the one-time
                            hint build H = A^T.DB is reported separately
                            (``hint_preprocess_s``), never inside QPS

QPS counts real queries only. Note the work scales with the party count:
a k-party cell runs k full DB scans per batch on this single device (in
production the parties are disjoint machines), so per-party QPS is also
reported for a like-for-like view.

Run: PYTHONPATH=src python -m benchmarks.run --only protocols
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Csv, percentile, record_json
from repro.config import PIRConfig
from repro.core import pir
from repro.core import protocol as protocol_mod
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import MultiServerPIR, SingleServerPIR

LOG_N = 12                      # 4096 records x 32 B (CPU-container scale)
BUCKET = 4                      # the single compiled bucket per party
N_QUERIES = 32                  # offered load per repetition
REPS = 3                        # keep the median wall time
OUT_JSON = "BENCH_protocols.json"

#: (label, config, legacy path string) — the protocol x plan grid
CELLS = [
    ("xor-dpf-2/materialize",
     PIRConfig(n_items=1 << LOG_N, item_bytes=32, batch_queries=BUCKET),
     "baseline"),
    ("xor-dpf-2/fused",
     PIRConfig(n_items=1 << LOG_N, item_bytes=32, batch_queries=BUCKET),
     "fused"),
    ("additive-dpf-2/gemm",
     PIRConfig(n_items=1 << LOG_N, item_bytes=32, batch_queries=BUCKET,
               protocol="additive-dpf-2"),
     "matmul"),
    ("xor-dpf-k3/fused",
     PIRConfig(n_items=1 << LOG_N, item_bytes=32, batch_queries=BUCKET,
               protocol="xor-dpf-k", n_servers=3),
     "fused"),
    ("lwe-simple-1/auto",
     PIRConfig(n_items=1 << LOG_N, item_bytes=32, batch_queries=BUCKET,
               protocol="lwe-simple-1", n_servers=1),
     "auto"),
]


def _run_cell(label: str, cfg: PIRConfig, path: str, db: np.ndarray,
              indices: List[int]) -> dict:
    proto = protocol_mod.get(cfg.protocol)
    facade = SingleServerPIR if proto.needs_hint else MultiServerPIR
    system = facade(db, cfg, make_local_mesh(), path=path,
                    n_queries=BUCKET, buckets=(BUCKET,))
    k = system.n_parties
    # hint protocols: the one-time server preprocessing (H = A^T.DB) is a
    # per-epoch cost amortized over every query — measured apart from QPS
    hint_s = None
    if proto.needs_hint:
        t0 = time.perf_counter()
        np.asarray(system.db.hint(proto.name))
        hint_s = time.perf_counter() - t0
    # warm every party's compiled bucket (preloading is off the clock,
    # paper §3.3); staged + host inputs share one executable per party
    system.query(indices[:BUCKET])
    # client-side Gen is off the clock (the paper's measurement boundary):
    # the identical pre-generated key stream replays into every repetition
    if proto.needs_hint:
        # scheduler items are ((keys,), state): the secret rides along
        queries = [proto.query_gen_full(np.random.default_rng(1000 + j),
                                        i, cfg)
                   for j, i in enumerate(indices)]
    else:
        queries = [pir.query_gen(np.random.default_rng(1000 + j), i,
                                 cfg).keys
                   for j, i in enumerate(indices)]

    walls, rep_stats = [], []
    for _ in range(REPS):
        sched = system._make_scheduler(max_wait_s=0.005, n_clusters=1)
        t0 = time.perf_counter()
        futs = [sched.submit(q) for q in queries]
        sched.pump()
        walls.append(time.perf_counter() - t0)
        assert all(f.done() for f in futs)
        rep_stats.append(sched.stats)
    # report the median repetition's stats so latencies stay consistent
    # with the recorded wall/QPS (not a mix of median wall + last-rep p99)
    mid = int(np.argsort(walls)[len(walls) // 2])
    wall, stats = walls[mid], rep_stats[mid]
    qps = len(indices) / wall
    out = {
        "protocol": cfg.protocol, "path": path, "n_parties": k,
        "wall_s": wall, "qps": qps, "qps_per_party": qps / k,
        "serve_steps": stats.batches,
        "batch_p50_ms": percentile(stats.latencies, 50) * 1e3,
        "batch_p99_ms": percentile(stats.latencies, 99) * 1e3,
        "pad_fraction": stats.pad_fraction,
    }
    if hint_s is not None:
        out["hint_preprocess_s"] = hint_s
    return out


def run() -> Csv:
    rng = np.random.default_rng(0)
    db = pir.make_database(rng, 1 << LOG_N, 32)
    # equal offered load: one index stream shared by every cell
    indices = rng.integers(0, 1 << LOG_N, size=N_QUERIES).tolist()

    csv = Csv(["cell", "protocol", "path", "n_parties", "offered_queries",
               "wall_s", "qps", "qps_per_party", "batch_p50_ms",
               "batch_p99_ms", "label"])
    cells = {}
    for label, cfg, path in CELLS:
        res = _run_cell(label, cfg, path, db, indices)
        cells[label] = res
        csv.add(label, res["protocol"], path, res["n_parties"], N_QUERIES,
                res["wall_s"], res["qps"], res["qps_per_party"],
                res["batch_p50_ms"], res["batch_p99_ms"], "measured-cpu")

    record_json(OUT_JSON, {
        "bench": "protocols",
        "log_n": LOG_N, "item_bytes": 32, "bucket": BUCKET,
        "offered_queries": N_QUERIES, "reps": REPS, "cells": cells,
    })
    return csv


if __name__ == "__main__":
    print(run().dump())
