"""Shared benchmark utilities.

Measurement discipline on this container: single CPU core, so DBs are
scaled down (≤ 2^18 items) and every number is labeled either
``measured-cpu`` (wall clock here) or ``modeled-v5e`` (three-term roofline
with the assignment's hardware constants, driven by the dry-run artifacts).
The measured numbers compare *algorithm structure* (phase-split vs fused vs
batched-GEMM) on identical silicon — the paper's CPU-vs-PIM axis maps onto
the modeled numbers, where aggregate bandwidth is the variable.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List

import jax
import numpy as np


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (s) of jitted fn; blocks on results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


class Csv:
    def __init__(self, header: List[str]):
        self.header = header
        self.rows: List[list] = []

    def add(self, *row):
        assert len(row) == len(self.header)
        self.rows.append(list(row))

    def dump(self) -> str:
        out = [",".join(self.header)]
        for r in self.rows:
            out.append(",".join(_fmt(v) for v in r))
        return "\n".join(out)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def percentile(xs: List[float], p: float) -> float:
    """p-th percentile of a latency sample (p in [0, 100])."""
    return float(np.percentile(np.asarray(xs, np.float64), p)) if xs else 0.0


def record_json(path: str, payload: Dict[str, Any], *,
                label: str = "measured-cpu") -> str:
    """Persist a benchmark record so future PRs have a perf trajectory.

    Every record carries the measurement label (``measured-cpu`` /
    ``modeled-v5e`` — see module docstring) and the device platform, so a
    number from this container is never confused with a TPU number.
    """
    record = {
        "label": label,
        "platform": jax.devices()[0].platform,
        "device_count": jax.device_count(),
        **payload,
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    return os.path.abspath(path)
