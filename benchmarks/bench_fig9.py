"""Paper Figure 9: query throughput / latency vs DB size and batch size.

Two server designs on identical silicon (measured-cpu):
  cpu-pir   the paper's processor-centric baseline structure: per-query
            phase-split (materialize Eval bits, then scan the whole DB).
  im-pir    this repo's production path: fused expand+scan, shard_map'd —
            the algorithmic shape that PIM enables (in-place processing,
            no bit-vector round trip).

The modeled-v5e columns scale the dpXOR phase by aggregate-bandwidth
ratios (256-chip pod ≈ 210 TB/s vs 1-socket CPU ≈ 0.1 TB/s), the paper's
own explanatory variable for its >3.7× gain.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, timeit
from repro.config import PIRConfig
from repro.core import pir
from repro.core.server import PIRServer
from repro.launch.mesh import make_local_mesh


def _servers(db, cfg, mesh, n_queries):
    base = PIRServer(0, db, cfg, mesh, n_queries=n_queries, path="baseline")
    fused = PIRServer(0, db, cfg, mesh, n_queries=n_queries, path="fused")
    return base, fused


def run() -> Csv:
    csv = Csv(["sweep", "n_items", "batch", "design", "latency_ms",
               "qps_measured_cpu"])
    mesh = make_local_mesh()
    rng = np.random.default_rng(0)

    # (a)(c): fixed batch 8 queries, DB size sweep
    for log_n in (12, 14, 16):
        n = 1 << log_n
        cfg = PIRConfig(n_items=n, batch_queries=8)
        db = pir.make_database(rng, n, 32)
        keys, _ = pir.batch_queries(rng, list(range(8)), cfg)
        for name, srv in zip(("cpu-pir", "im-pir"),
                             _servers(db, cfg, mesh, 8)):
            t = timeit(srv.answer, keys)
            csv.add("db_size", n, 8, name, t * 1e3, 8 / t)

    # (b)(d): fixed DB 2^14, batch sweep
    n = 1 << 14
    cfg0 = PIRConfig(n_items=n)
    db = pir.make_database(rng, n, 32)
    for batch in (4, 8, 16, 32):
        cfg = PIRConfig(n_items=n, batch_queries=batch)
        keys, _ = pir.batch_queries(rng, list(range(batch)), cfg)
        for name, srv in zip(("cpu-pir", "im-pir"),
                             _servers(db, cfg, mesh, batch)):
            t = timeit(srv.answer, keys)
            csv.add("batch", n, batch, name, t * 1e3, batch / t)
    return csv


if __name__ == "__main__":
    print(run().dump())
