"""Serving-frontend throughput: serial drain vs the pipelined scheduler.

The Figure 8 analogue for this repo's beyond-paper serving frontend
(DESIGN.md §6.2). The paper's multi-query workflow — and this repo's
pre-tentpole ``PIRServeLoop.drain`` — serves strictly synchronously: one
hardcoded batch size, each arriving key group answered as its own serve
step, ``block_until_ready`` per batch. The ``QueryScheduler`` instead
coalesces a ragged per-client query stream into *full* padded bucket
batches and double-buffers dispatch (batch k+1's keys staged while batch
k executes).

Offered-load design: every mode replays the IDENTICAL pre-generated
ragged key stream (client groups of 1..BATCH queries; client-side Gen is
off the clock, matching the paper's measurement boundary), fully enqueued
up front — the saturated-throughput regime Figure 8 reports. Modes:

  serial      PIRServeLoop.drain            one serve step per client
                                            group, padded to the bucket,
                                            stage -> run -> block
  pipelined   PIRServeLoop.drain_pipelined  same batching, depth-2 double
                                            buffering (isolates the
                                            overlap term alone)
  scheduler   QueryScheduler.pump           dynamic cross-client
                                            coalescing into full buckets
                                            + double buffering

QPS counts *real* queries only (pad slots are waste, not work). All modes
share ONE PIRServer — one compiled bucket step (staged and host-resident
inputs hit the same executable) — so the comparison is pure serving
policy. On this 2-core CPU container the dynamic-batching term dominates
(fewer, fuller serve steps); the overlap term is within noise here but is
the term that scales on a real accelerator, where host staging and device
compute are different silicon.

Run: PYTHONPATH=src python -m benchmarks.run --only serving
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, percentile, record_json
from repro.config import PIRConfig
from repro.core import dpf, pir
from repro.core.server import PIRServer
from repro.launch.mesh import make_local_mesh
from repro.runtime.serve_loop import PIRServeLoop, QueryScheduler

LOG_N = 12                      # 4096 records x 32 B (CPU-container scale)
BATCH = 4                       # the single compiled bucket
N_GROUPS = 48                   # client submissions per sweep point
REPS = 3                        # repetitions per (mode, load); keep median
OUT_JSON = "BENCH_serving.json"


def _make_server():
    cfg = PIRConfig(n_items=1 << LOG_N, item_bytes=32, batch_queries=BATCH)
    db = pir.make_database(np.random.default_rng(0), cfg.n_items,
                           cfg.item_bytes)
    server = PIRServer(party=0, db_words=db, cfg=cfg,
                       mesh=make_local_mesh(), n_queries=BATCH,
                       path="fused", buckets=(BATCH,))
    return server, cfg


def _ragged_groups(cfg: PIRConfig, n_groups: int, rng) -> List[dpf.DPFKey]:
    """Per-client key groups of ragged size 1..BATCH (the offered load)."""
    out = []
    for _ in range(n_groups):
        size = int(rng.integers(1, BATCH + 1))
        idx = rng.integers(0, cfg.n_items, size=size).tolist()
        out.append(pir.batch_queries(rng, idx, cfg)[0])
    return out


def _split_queries(groups: List[dpf.DPFKey]) -> List[dpf.DPFKey]:
    """Unstack groups into single-query pytrees (the scheduler's intake)."""
    singles = []
    for g in groups:
        for i in range(dpf.n_queries_of(g)):
            singles.append(
                jax.tree_util.tree_map(lambda x, i=i: x[i:i + 1], g))
    return singles


def _collate(items):
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *items)


def _run_loop(server, groups, *, pipelined: bool):
    loop = PIRServeLoop(server)
    for keys in groups:
        loop.submit(keys)
    t0 = time.perf_counter()
    out = loop.drain_pipelined() if pipelined else loop.drain()
    for a in out:
        a.block_until_ready()
    return time.perf_counter() - t0, loop.stats


def _run_scheduler(server, singles):
    sched = QueryScheduler(
        collate=_collate,
        stage=server.stage_keys,
        dispatch=server.answer,
        finalize=lambda raw, n: list(np.asarray(raw[:n])),
        buckets=server.buckets,
    )
    futs = [sched.submit(k) for k in singles]
    t0 = time.perf_counter()
    sched.pump()
    wall = time.perf_counter() - t0
    assert all(f.done() for f in futs)
    return wall, sched.stats


def run() -> Csv:
    server, cfg = _make_server()
    rng = np.random.default_rng(1)

    # warm the compiled bucket once (preloading, excluded — paper §3.3);
    # staged + host inputs share the executable, so one warm call suffices
    warm = _ragged_groups(cfg, 1, np.random.default_rng(9))[0]
    server.answer(warm).block_until_ready()
    server.answer(server.stage_keys(warm)).block_until_ready()

    csv = Csv(["mode", "offered_queries", "serve_steps", "wall_s", "qps",
               "batch_p50_ms", "batch_p99_ms", "pad_fraction", "label"])
    sweep = {}
    for n_groups in (N_GROUPS // 4, N_GROUPS // 2, N_GROUPS):
        groups = _ragged_groups(cfg, n_groups, rng)
        singles = _split_queries(groups)
        n_q = len(singles)
        results = {}
        for mode in ("serial", "pipelined", "scheduler"):
            walls, stats = [], None
            for _ in range(REPS):
                if mode == "scheduler":
                    wall, stats = _run_scheduler(server, singles)
                else:
                    wall, stats = _run_loop(server, groups,
                                            pipelined=(mode == "pipelined"))
                walls.append(wall)
            wall = float(np.median(walls))
            if mode == "scheduler":
                pad_frac = stats.pad_fraction
            else:
                # drain pads every ragged group up to the compiled bucket
                pad_frac = (stats.batches * BATCH - n_q) / \
                           (stats.batches * BATCH)
            qps = n_q / wall
            p50 = percentile(stats.latencies, 50) * 1e3
            p99 = percentile(stats.latencies, 99) * 1e3
            csv.add(mode, n_q, stats.batches, wall, qps, p50, p99,
                    pad_frac, "measured-cpu")
            results[mode] = {"wall_s": wall, "qps": qps, "serve_steps":
                             stats.batches, "batch_p50_ms": p50,
                             "batch_p99_ms": p99, "pad_fraction": pad_frac}
        results["speedup_scheduler_vs_serial"] = (
            results["scheduler"]["qps"] / results["serial"]["qps"])
        results["speedup_pipelined_vs_serial"] = (
            results["pipelined"]["qps"] / results["serial"]["qps"])
        sweep[str(n_q)] = results

    record_json(OUT_JSON, {
        "bench": "serving",
        "log_n": LOG_N, "item_bytes": 32, "bucket": BATCH,
        "path": "fused", "reps": REPS, "sweep": sweep,
    })
    return csv


if __name__ == "__main__":
    print(run().dump())
